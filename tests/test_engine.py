"""Unified sharded execution engine (distrib.engine): plan emitters,
zero-collective execution, bit-identity with the per-PE reference
generators, canonical chunk ownership, and the capacity-independent
draw invariant that cross-PE recomputation rests on."""
import numpy as np
import pytest

from repro.core import chunking, er, graph, rgg
from repro.core.chunking import chunks_per_dim, cube_chunks_for_pe, morton_decode, morton_encode
from repro.core.prng import device_key
from repro.core.rhg import RHGParams, RHGPlan, rhg_point_plan
from repro.core.sampling import sample_wo_replacement
from repro.distrib.engine import (
    collective_ops_in,
    run_edges,
    run_points,
)


def _es(e):
    return {tuple(x) for x in np.asarray(e, np.int64)}


# ----------------------------------------------------- sampler invariant

def test_sampler_values_independent_of_capacity():
    """Two PEs may pad the same chunk to different static capacities;
    the sampled set must not change (cross-PE recomputation)."""
    key = device_key(3, 11, 0)
    ref = None
    for cap in (64, 128, 320):
        vals, mask = sample_wo_replacement(key, 100_000, 50, cap)
        got = np.asarray(vals)[np.asarray(mask)]
        if ref is None:
            ref = got
        np.testing.assert_array_equal(got, ref)


def test_gumbel_sampler_contract():
    """method='gumbel': distinct sorted in-range values, prefix mask,
    and capacity-independence — the same contract as the default path."""
    key = device_key(5, 13, 2)
    U, k = 5000, 70
    ref = None
    for cap in (128, 256):
        vals, mask = sample_wo_replacement(key, U, k, cap, method="gumbel")
        got = np.asarray(vals)[np.asarray(mask)]
        assert len(got) == k and len(np.unique(got)) == k
        assert (got >= 0).all() and (got < U).all()
        assert (np.diff(got) > 0).all()
        if ref is None:
            ref = got
        np.testing.assert_array_equal(got, ref)
    with pytest.raises(ValueError, match="unknown sampling method"):
        sample_wo_replacement(key, U, k, 128, method="bogus")
    with pytest.raises(ValueError, match="gumbel path holds"):
        sample_wo_replacement(key, 64, 100, 128, method="gumbel")


def test_gumbel_sampler_unbiased_at_k_sqrt_u():
    """The ROADMAP bias re-evaluation: at k ~ sqrt(U) (where collision
    resampling's O(k^2/U) TV bias is largest relative to signal) the
    Gumbel-top-k path's per-element inclusion frequencies pass a
    chi-square test against the uniform k/U law."""
    import jax

    from repro.stats.gof import chi_square_gof

    U = 4096
    k = 64  # == sqrt(U)
    T = 400
    base = device_key(17, 99)

    def draw(t):
        key = jax.random.fold_in(base, t)
        vals, mask = sample_wo_replacement(key, U, k, 64, method="gumbel")
        return np.asarray(vals)[np.asarray(mask)]

    counts = np.zeros(U, np.int64)
    for t in range(T):
        counts[draw(t)] += 1
    assert counts.sum() == T * k
    gof = chi_square_gof(counts, np.full(U, T * k / U))
    assert gof.pvalue > 1e-3, (gof.stat, gof.dof, gof.pvalue)


def test_points_independent_of_capacity():
    from repro.core.prng import counter_uniform

    key = device_key(4, 22, 9)
    a = np.asarray(counter_uniform(key, 16, 2))
    b = np.asarray(counter_uniform(key, 64, 2))
    np.testing.assert_array_equal(a, b[:16])
    assert (a >= 0).all() and (a < 1).all()


# ------------------------------------------------- engine == reference

def test_engine_gnm_directed_bit_identical():
    seed, n, m, P = 7, 256, 1500, 4
    plan = er.gnm_directed_plan(seed, n, m, P)
    assert plan.num_pes == P and plan.chunks_per_pe == 1
    assert plan.total_edges == m  # owned counts partition m exactly
    edges, hlo = run_edges(plan)
    assert not collective_ops_in(hlo)
    assert len(edges) == m
    assert _es(edges) == _es(er.gnm_directed(seed, n, m, P=P))


def test_engine_gnm_undirected_bit_identical():
    seed, n, m, P = 17, 200, 900, 4
    plan = er.gnm_undirected_plan(seed, n, m, P)
    assert plan.num_pes == P and plan.chunks_per_pe == P  # row + column cross
    assert plan.total_edges == m
    edges, hlo = run_edges(plan)
    assert not collective_ops_in(hlo)
    assert len(edges) == m
    assert _es(edges) == _es(er.gnm_undirected(seed, n, m, P=P))


def test_engine_gnp_undirected_bit_identical():
    seed, n, p, P = 5, 200, 0.03, 4
    edges, _ = run_edges(er.gnp_undirected_plan(seed, n, p, P))
    host = er.gnp_undirected(seed, n, p, P=P)
    assert len(edges) == len(host)
    assert _es(edges) == _es(host)


def test_engine_gnp_directed_bit_identical():
    seed, n, p, P = 5, 200, 0.03, 4
    edges, _ = run_edges(er.gnp_directed_plan(seed, n, p, P))
    host = np.concatenate([er.gnp_directed_pe(seed, n, p, P, pe) for pe in range(P)])
    assert _es(edges) == _es(host)


def test_engine_rgg_points_bit_identical():
    seed, n, r, P, dim = 5, 800, 0.05, 4, 2
    plan = rgg.rgg_point_plan(seed, n, r, P, dim)
    assert plan.total_points == n  # cell counts partition n exactly
    pts, mask, hlo = run_points(plan)
    assert not collective_ops_in(hlo)
    assert int(mask.sum()) == n
    host = rgg.rgg_all_points(seed, n, r, P, dim)
    got = np.sort(pts[mask], axis=0)
    np.testing.assert_array_equal(got, np.sort(host, axis=0))


def test_engine_rhg_polar_points():
    params = RHGParams(n=1000, avg_deg=8, gamma=2.7, seed=3)
    P = 4
    plan = RHGPlan(params, P)
    pts, mask, hlo = run_points(rhg_point_plan(params, P))
    assert not collective_ops_in(hlo)
    assert int(mask.sum()) == params.n - plan.n_core
    r, theta = pts[..., 0][mask], pts[..., 1][mask]
    assert (r >= params.R / 2 - 1e-9).all() and (r <= params.R + 1e-9).all()
    assert (theta >= 0).all() and (theta < 2 * np.pi).all()


# ------------------------------------- ownership union (no sort dedup)

@pytest.mark.parametrize("P", [2, 4, 6])
def test_gnm_undirected_ownership_union_exact(P):
    """Owned-chunk concatenation == np.unique of the full per-PE union."""
    seed, n, m = 9, 150, 700
    owned_union = er.gnm_undirected(seed, n, m, P)
    assert owned_union.shape == (m, 2)
    assert not graph.has_duplicates(owned_union)
    full = np.concatenate([er.gnm_undirected_pe(seed, n, m, P, pe) for pe in range(P)])
    assert _es(owned_union) == _es(full)


@pytest.mark.parametrize("P", [2, 5])
def test_gnp_undirected_ownership_union_exact(P):
    seed, n, p = 11, 150, 0.04
    owned_union = er.gnp_undirected(seed, n, p, P)
    assert not graph.has_duplicates(owned_union)
    full = np.concatenate([er.gnp_undirected_pe(seed, n, p, P, pe) for pe in range(P)])
    assert _es(owned_union) == _es(full)


def test_gnp_per_pe_chunk_lists_duplicate_free():
    """The (I, J) walk of row pe + column pe yields P distinct chunks —
    the old tautological diagonal condition and set dedup are gone."""
    n, p, P = 120, 0.02, 6
    for pe in range(P):
        chunks = er.gnp_chunks_for_pe(1, n, p, P, pe)
        assert len(chunks) == P
        ids = [(ch.row_sec, ch.col_sec) for ch, _ in chunks]
        assert len(set(ids)) == P
        for ch, cnt in chunks:
            assert pe in (ch.row_sec, ch.col_sec)
            assert 0 <= cnt <= ch.universe


def test_gnp_per_pe_output_duplicate_free_and_union_consistent():
    seed, n, p, P = 2, 100, 0.05, 3
    per_pe = [er.gnp_undirected_pe(seed, n, p, P, pe) for pe in range(P)]
    for e in per_pe:
        assert not graph.has_duplicates(e)
        assert (e[:, 0] > e[:, 1]).all()
    union = set().union(*[_es(e) for e in per_pe])
    assert union == _es(er.gnp_undirected(seed, n, p, P))


# ------------------------------------------------- cube chunk dealing

def test_morton_roundtrip():
    for dim in (2, 3):
        for bits in (1, 2, 3):
            k = 1 << (dim * bits)
            seen = set()
            for code in range(k):
                coords = morton_decode(code, dim, bits)
                assert morton_encode(coords, dim, bits) == code
                assert all(0 <= c < (1 << bits) for c in coords)
                seen.add(coords)
            assert len(seen) == k


@pytest.mark.parametrize("P,dim", [(1, 2), (3, 2), (4, 2), (7, 3), (8, 3)])
def test_cube_chunks_round_robin_deal(P, dim):
    """cube_chunks_for_pe returns the chunk list (not a tuple); the deal
    covers the full Morton grid disjointly."""
    cpd = chunks_per_dim(P, dim)
    assert cpd ** dim >= P
    all_chunks = [cube_chunks_for_pe(P, dim, pe) for pe in range(P)]
    for chunks in all_chunks:
        assert isinstance(chunks, list)
        for c in chunks:
            assert isinstance(c, tuple) and len(c) == dim
            assert all(0 <= x < cpd for x in c)
    flat = [c for chunks in all_chunks for c in chunks]
    assert len(flat) == cpd ** dim
    assert len(set(flat)) == len(flat)  # disjoint cover
