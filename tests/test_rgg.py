"""RGG generator: exact equivalence to the brute-force oracle on the same
point set, halo-recomputation consistency, count-recursion invariants."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st  # guarded: collection never hard-fails

from repro.core import rgg
from repro.core.rgg import CellCounter, make_grid


def _edge_set(e):
    return {tuple(x) for x in np.asarray(e, dtype=np.int64)}


@pytest.mark.parametrize("P,dim", [(1, 2), (4, 2), (9, 2), (1, 3), (8, 3)])
def test_union_equals_bruteforce(P, dim):
    seed, n = 11, 300
    r = 0.5 * (np.log(n) / n) ** (1.0 / dim)
    pts = rgg.rgg_all_points(seed, n, r, P, dim)
    brute = rgg.rgg_brute_edges(pts.astype(np.float32), r)
    union = rgg.rgg_union(seed, n, r, P, dim)
    assert _edge_set(brute) == _edge_set(union)


def test_counts_partition_n():
    seed, n = 5, 1000
    grid = make_grid(n, 0.05, 4, 2)
    counter = CellCounter(seed, grid, n)
    total = sum(counter.cell_count(tuple(c)) for c in np.ndindex(grid.g, grid.g))
    assert total == n


def test_cell_offsets_are_a_permutation():
    seed, n = 6, 500
    grid = make_grid(n, 0.07, 4, 2)
    counter = CellCounter(seed, grid, n)
    cells = [tuple(c) for c in np.ndindex(grid.g, grid.g)]
    offs = [(counter.cell_offset(c), counter.cell_count(c)) for c in cells]
    offs.sort()
    cursor = 0
    for off, cnt in offs:
        assert off == cursor
        cursor += cnt
    assert cursor == n


def test_two_counters_agree():
    """Separate CellCounter instances (PEs) must agree on every cell."""
    seed, n = 9, 800
    grid = make_grid(n, 0.04, 16, 2)
    a, b = CellCounter(seed, grid, n), CellCounter(seed, grid, n)
    cells = [tuple(c) for c in np.ndindex(grid.g, grid.g)]
    rng = np.random.default_rng(0)
    for c in rng.permutation(len(cells))[:50]:  # different query orders
        cell = cells[c]
        assert a.cell_count(cell) == b.cell_count(cell)
        assert a.cell_offset(cell) == b.cell_offset(cell)


def test_halo_points_recomputed_identically():
    """Points of a shared cell must be identical from any PE's context."""
    seed, n, P, dim = 4, 500, 4, 2
    r = 0.5 * np.sqrt(np.log(n) / n)
    results = {}
    for pe in range(P):
        _, gids, pos = rgg.rgg_pe(seed, n, r, P, pe, dim)
        for g, p in zip(gids, pos):
            if g in results:
                np.testing.assert_allclose(results[g], p, rtol=0, atol=0)
            results[g] = p
    assert len(results) == n  # every vertex generated exactly once as local


def test_each_edge_on_both_endpoint_pes():
    seed, n, P, dim = 8, 400, 4, 2
    r = 0.6 * np.sqrt(np.log(n) / n)
    per_pe_edges, per_pe_gids = [], []
    for pe in range(P):
        e, gids, _ = rgg.rgg_pe(seed, n, r, P, pe, dim)
        u = np.maximum(e[:, 0], e[:, 1]); v = np.minimum(e[:, 0], e[:, 1])
        per_pe_edges.append({tuple(x) for x in np.stack([u, v], 1)})
        per_pe_gids.append(set(gids.tolist()))
    union = set().union(*per_pe_edges)
    for (u, v) in union:
        pes_u = [i for i in range(P) if u in per_pe_gids[i]]
        pes_v = [i for i in range(P) if v in per_pe_gids[i]]
        assert pes_u and pes_v
        assert (u, v) in per_pe_edges[pes_u[0]]
        assert (u, v) in per_pe_edges[pes_v[0]]


@given(st.integers(0, 10_000), st.sampled_from([1, 4, 16]))
@settings(max_examples=8, deadline=None)
def test_property_determinism_and_degree(seed, P):
    n, dim = 200, 2
    r = 0.6 * np.sqrt(np.log(n) / n)
    e1 = rgg.rgg_union(seed, n, r, P, dim)
    e2 = rgg.rgg_union(seed, n, r, P, dim)
    np.testing.assert_array_equal(e1, e2)
    if e1.size:
        assert e1.max() < n and e1.min() >= 0


def test_expected_degree_matches_theory():
    """Interior expected degree = n * pi * r^2 (paper §2.1.2)."""
    seed, n, dim = 2, 4000, 2
    r = 0.02
    e = rgg.rgg_union(seed, n, r, 4, dim)
    mean_deg = 2 * len(e) / n
    expect = n * np.pi * r * r  # boundary effects lower it slightly
    assert 0.7 * expect < mean_deg <= 1.05 * expect, (mean_deg, expect)
