"""The unified geometric edge engine: kind-tagged PairPlans for RGG
(GEOM_TORUS), RDG (GEOM_CERT) and RHG (GEOM_HYP) — exact parity vs the
brute-force oracles and the retired per-PE host loops, bit-identical
P-invariance of the streamed output, zero-collective HLO for all three
geometry kinds, and the emitter-derived table layout."""
import numpy as np
import pytest

from repro.api import RDG, RGG, RHG, generate, iter_edge_chunks
from repro.core import rdg, rgg
from repro.distrib import engine
from repro.distrib.engine import (
    GEOM_CERT,
    GEOM_HYP,
    GEOM_TORUS,
    collective_ops_in,
    pair_slot_index,
    run_pairs,
)

RGG2 = RGG(n=300, radius=0.07, seed=11)
RGG3 = RGG(n=260, radius=0.16, dim=3, seed=5)
RDG2 = RDG(n=300, seed=318)
RDG3 = RDG(n=220, dim=3, seed=7)
RHG1 = RHG(n=400, avg_deg=8, gamma=2.8, seed=23)

GEOM_SPECS = [RGG2, RGG3, RDG2, RHG1]


def _es(e):
    return {tuple(x) for x in np.asarray(e, np.int64)}


def _sorted(e):
    return np.unique(np.asarray(e, np.int64), axis=0)


# ------------------------------------------------- brute-force oracle parity

@pytest.mark.parametrize("spec", [RGG2, RGG3], ids=["rgg2d", "rgg3d"])
def test_rgg_pair_plan_matches_bruteforce(spec):
    """GEOM_TORUS edges == the O(n^2) oracle over the same point set
    (float32 threshold semantics of the pairdist kernel)."""
    g = generate(spec, 4, return_points=True)
    brute = rgg.rgg_brute_edges(g.points.astype(np.float32), spec.radius)
    assert _es(g.edges) == _es(brute)
    assert len(g.edges) > 0


def test_rgg_pair_plan_matches_retired_host_loop():
    """The engine path reproduces the per-PE host loop (rgg_pe, now the
    test oracle) exactly, at the matched virtual chunk grid."""
    spec, P = RGG2, 4
    got = _es(generate(spec, P).edges)
    host = set()
    for pe in range(P):
        e, _, _ = rgg.rgg_pe(spec.seed, spec.n, spec.radius, P, pe,
                             spec.dim, chunk_P=16)
        if e.size:
            u = np.maximum(e[:, 0], e[:, 1])
            v = np.minimum(e[:, 0], e[:, 1])
            host |= _es(np.stack([u, v], axis=1))
    assert got == host


def test_rdg_pair_plan_matches_bruteforce_exact():
    """GEOM_CERT edges == the periodic-DT brute oracle (typical case:
    exact; Qhull lacks exact predicates, hence the seeded instance)."""
    for spec in (RDG2, RDG3):
        g = generate(spec, 4, return_points=True)
        brute = rdg.rdg_brute_edges(g.points, spec.dim)
        sym = _es(g.edges) ^ _es(brute)
        # near-cospherical flips only; the seeded cases are exact
        assert len(sym) <= max(2, int(0.003 * len(brute))), len(sym)
        deg = np.bincount(np.asarray(g.edges).ravel(), minlength=spec.n)
        assert (deg >= 2).all()


def test_rdg_pair_plan_matches_retired_host_loop():
    """Engine GEOM_CERT edges == ownership-filtered rdg_pe union at the
    matched virtual chunk grid."""
    spec, P = RDG2, 4
    got = _es(generate(spec, P).edges)
    host = set()
    for pe in range(P):
        e, local_gids, _ = rdg.rdg_pe(spec.seed, spec.n, P, pe, spec.dim,
                                      chunk_P=16)
        if e.size:
            host |= _es(e[np.isin(e[:, 0], local_gids)])
    assert got == host


def test_rdg_device_certificates_all_pass():
    """Every shipped simplex was host-certified with the same Cramer
    formula the device re-evaluates: no masked edge may be lost to a
    host/device certificate disagreement.  Checked by comparing the
    executed edge count against the plan's emit-mask popcount."""
    plan = RDG2.plan(4)
    expected = sum(bin(int(plan.gid_b[pe, c, 0])).count("1")
                   for pe in range(plan.num_pes)
                   for c in range(plan.pairs_per_pe)
                   if plan.active[pe, c])
    edges, _ = run_pairs(plan)
    assert len(edges) == expected > 0


# -------------------------------------------------- streamed P-invariance

@pytest.mark.parametrize("spec", GEOM_SPECS,
                         ids=lambda s: f"{type(s).__name__}{getattr(s, 'dim', 2)}")
def test_streamed_edges_P_invariant(spec):
    """iter_edge_chunks == generate for P in {1, 2, 8} (per-PE stream
    order regrouped pe-major — exact on any device count), and the edge
    set is bit-identically P-invariant (sorted comparison)."""
    ref = None
    for P in (1, 2, 8):
        g = generate(spec, P)
        per_pe = {}
        for c in iter_edge_chunks(spec, P, batch=16):
            per_pe.setdefault(c.pe, []).append(c.edges())
        streamed = np.concatenate(
            [e for pe in sorted(per_pe) for e in per_pe[pe]], axis=0)
        np.testing.assert_array_equal(streamed, g.edges)
        s = _sorted(g.edges)
        if ref is None:
            ref = s
        np.testing.assert_array_equal(s, ref)


# --------------------------------------------- zero collectives, all kinds

@pytest.mark.parametrize("spec,kind", [(RGG2, GEOM_TORUS), (RDG2, GEOM_CERT),
                                       (RHG1, GEOM_HYP)],
                         ids=["torus", "cert", "hyp"])
def test_zero_collectives_per_geometry_kind(spec, kind):
    """Each geometry kind's SPMD lowering contains zero collectives, and
    the plan advertises exactly that kind."""
    plan = spec.plan(4)
    assert plan.kinds_present == (kind,)
    edges, hlo = run_pairs(plan)
    assert not collective_ops_in(hlo)
    assert len(edges) > 0


# ------------------------------------------------- table layout invariants

def test_geom_width_is_emitter_derived():
    """make_pair_plan derives trailing widths from the emitter instead
    of a hardcoded [P, C, 4] table: a 2d TORUS plan carries 2 geometry
    floats, a CERT plan (d+1)*d, a HYP plan 4."""
    assert RGG2.plan(2).geom_a.shape[-1] == 2
    assert RGG3.plan(2).geom_a.shape[-1] == 3
    assert RDG2.plan(2).geom_a.shape[-1] == 6   # 3 vertices x 2 coords
    assert RHG1.plan(2).geom_a.shape[-1] == 4
    # CERT rows index per-vertex gids; the gid table is capacity-wide
    plan = RDG2.plan(2)
    assert plan.gid_a.shape[-1] == plan.capacity == 4


def test_fill_fraction_reports_padding_waste():
    plan = RGG2.plan(4)
    assert 0.0 < plan.fill_fraction <= 1.0
    assert plan.fill_fraction == plan.total_pairs / (
        plan.num_pes * plan.pairs_per_pe)
    # a deliberately lopsided deal: all pairs on PE 0 of 4
    lop = rgg.rgg_pair_plan(RGG2.seed, RGG2.n, RGG2.radius, 1, chunk_P=16)
    from repro.distrib.engine import PairPlan  # noqa: F401  (type sanity)
    assert lop.fill_fraction > 0.5  # single-PE table has no cross-PE padding


def test_pair_slot_index_is_lexicographic():
    cap = 4
    expect = 0
    for i in range(cap):
        for j in range(i + 1, cap):
            assert pair_slot_index(i, j, cap) == expect
            expect += 1
    assert expect == cap * (cap - 1) // 2


@pytest.mark.parametrize("spec", [RGG2, RDG2, RHG1],
                         ids=lambda s: type(s).__name__)
def test_pair_plans_reject_non_counter_rng(spec):
    """'rbg' draws different values for the same key in different vmap
    rows, so a cell recomputed in two candidate-pair rows would disagree
    with itself — pair plans must refuse it loudly instead of silently
    emitting a graph that corresponds to no consistent point set."""
    with pytest.raises(ValueError, match="counter-based"):
        spec.plan(2, rng_impl="rbg")
    with pytest.raises(ValueError, match="counter-based"):
        generate(spec, 2, rng_impl="rbg")


def test_return_points_consistent_with_edges():
    """g.points and g.edges come from the same hashed stream: the brute
    oracle over the returned points reproduces the returned edges."""
    g = generate(RGG2, 2, return_points=True)
    brute = rgg.rgg_brute_edges(g.points.astype(np.float32), RGG2.radius)
    assert _es(g.edges) == _es(brute)
    gd = generate(RDG2, 2, return_points=True)
    assert _es(gd.edges) == _es(rdg.rdg_brute_edges(gd.points, RDG2.dim))


def test_streamed_chunks_carry_pe_and_capacity_bound():
    """Geometric streams honor the EdgeChunk contract: fixed-capacity
    buffers with scattered masks and an owning PE."""
    plan = RGG2.plan(4)
    for chunk in iter_edge_chunks(RGG2, 4):
        assert chunk.buffer.shape == (plan.capacity ** 2, 2)
        assert chunk.mask is not None and chunk.pe in range(4)
