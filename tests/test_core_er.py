"""ER generator correctness: partition exactness, cross-PE consistency,
no dups/self-loops, distribution sanity (paper §4 invariants)."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st  # guarded: collection never hard-fails

from repro.core import chunking, er, graph
from repro.core.prng import hash_path, host_rng
from repro.core.sampling import sample_wo_replacement_host, decode_tri_host
from repro.core.variates import hypergeometric, binomial, multinomial_split


# ---------------------------------------------------------------- substrate

def test_hash_path_rank_independent_and_distinct():
    assert hash_path(1, 2, 3) == hash_path(1, 2, 3)
    seen = {hash_path(1, a, b) for a in range(30) for b in range(30)}
    assert len(seen) == 900  # no collisions on a small grid


def test_hypergeometric_bounds_and_mean():
    rng = host_rng(0, 1)
    draws = np.array([hypergeometric(host_rng(0, i), 50, 150, 40) for i in range(4000)])
    assert draws.min() >= 0 and draws.max() <= 40
    assert abs(draws.mean() - 40 * 50 / 200) < 0.3


def test_hypergeometric_large_universe_normal_path():
    g = hypergeometric(host_rng(0, 2), 1 << 61, 1 << 61, 1 << 20)
    assert abs(g - (1 << 19)) < 6 * np.sqrt((1 << 20) * 0.25)


def test_multinomial_split_sums():
    rng = host_rng(3, 4)
    probs = np.array([0.1, 0.2, 0.3, 0.4])
    out = multinomial_split(rng, 10000, probs)
    assert out.sum() == 10000
    assert np.all(np.abs(out - 10000 * probs) < 300)


@given(st.integers(1, 100), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_sampler_host_distinct_sorted(universe, count):
    count = min(count, universe)
    s = sample_wo_replacement_host(0, (universe, count), universe, count)
    assert len(s) == count
    assert len(np.unique(s)) == count
    assert (s >= 0).all() and (s < universe).all()
    assert (np.diff(s) > 0).all() if count > 1 else True


def test_decode_tri_exact_roundtrip():
    s = 200
    idx = np.arange(s * (s - 1) // 2, dtype=np.int64)
    u, v = decode_tri_host(idx, 0)
    assert (u > v).all()
    back = u * (u - 1) // 2 + v
    np.testing.assert_array_equal(back, idx)


def test_decode_tri_huge_indices():
    idx = np.array([(1 << 61) + k for k in range(5)], dtype=np.int64)
    u, v = decode_tri_host(idx, 0)
    tri = u * (u - 1) // 2
    assert ((tri <= idx) & (idx < tri + u)).all()
    np.testing.assert_array_equal(tri + v, idx)


# ---------------------------------------------------------------- chunking

@given(st.integers(0, 2**32), st.integers(2, 12))
@settings(max_examples=20, deadline=None)
def test_directed_counts_partition(seed, P):
    n, m = 256, 2000
    counts = chunking.directed_counts_all(seed, n, m, P)
    assert counts.sum() == m
    for pe in range(P):
        assert chunking.directed_counts_for_pe(seed, n, m, P, pe) == counts[pe]


@given(st.integers(0, 2**32), st.integers(2, 9))
@settings(max_examples=15, deadline=None)
def test_undirected_descent_matches_global(seed, P):
    n, m = 128, 900
    full = chunking.undirected_counts_all(seed, n, m, P)
    assert sum(full.values()) == m
    for pe in range(P):
        mine = chunking.undirected_chunks_for_pe(seed, n, m, P, pe)
        assert len(mine) == P  # row i + column i
        for ch, c in mine:
            assert full[(ch.row_sec, ch.col_sec)] == c
            assert 0 <= c <= ch.universe


def test_chunk_universes_tile_matrix():
    n, P = 97, 7  # deliberately non-divisible
    tot = 0
    for I in range(P):
        for J in range(I + 1):
            tot += chunking._make_chunk(n, P, I, J).universe
    assert tot == n * (n - 1) // 2


# ---------------------------------------------------------------- generators

@pytest.mark.parametrize("P", [1, 2, 5, 8])
def test_gnm_directed_exact(P):
    n, m, seed = 100, 700, 42
    e = er.gnm_directed(seed, n, m, P)
    assert e.shape == (m, 2)
    assert not graph.has_duplicates(e)
    assert not graph.has_self_loops(e)
    assert e.min() >= 0 and e.max() < n


@pytest.mark.parametrize("P", [1, 2, 5, 8])
def test_gnm_undirected_exact(P):
    n, m, seed = 100, 600, 17
    e = er.gnm_undirected(seed, n, m, P)
    assert e.shape == (m, 2)
    assert (e[:, 0] > e[:, 1]).all()
    assert not graph.has_duplicates(e)


def test_gnm_undirected_cross_pe_consistency():
    """Chunk (i,j) must be recomputed bit-identically by PE i and PE j."""
    n, m, P, seed = 120, 800, 6, 5
    per_pe = [graph.edges_to_set(er.gnm_undirected_pe(seed, n, m, P, pe)) for pe in range(P)]
    bounds = [chunking.section_bounds(n, P, i) for i in range(P)]

    def owner(v):
        return next(i for i, (lo, hi) in enumerate(bounds) if lo <= v < hi)

    union = set().union(*per_pe)
    assert len(union) == m
    for (u, v) in union:
        ou, ov = owner(u), owner(v)
        assert (u, v) in per_pe[ou], "row-owner PE must hold the edge"
        assert (u, v) in per_pe[ov], "col-owner PE must hold the edge"


def test_gnm_determinism():
    a = er.gnm_undirected(9, 80, 300, 4)
    b = er.gnm_undirected(9, 80, 300, 4)
    np.testing.assert_array_equal(a, b)
    c = er.gnm_undirected(10, 80, 300, 4)
    assert not np.array_equal(a, c)


def test_gnm_uniformity_chi2():
    """Each potential edge should appear ~ m/U of the time."""
    n, m, trials = 12, 20, 400
    U = n * (n - 1) // 2
    hits = np.zeros(U)
    for t in range(trials):
        e = er.gnm_undirected(1000 + t, n, m, 2)
        idx = e[:, 0] * (e[:, 0] - 1) // 2 + e[:, 1]
        hits[idx] += 1
    expect = trials * m / U
    chi2 = ((hits - expect) ** 2 / expect).sum()
    # dof = U-1 = 65; generous 5-sigma-ish bound
    assert chi2 < 65 + 5 * np.sqrt(2 * 65), chi2


@pytest.mark.parametrize("P", [1, 3])
def test_gnp_mean_edge_count(P):
    n, p = 256, 0.03
    ms = [len(er.gnp_undirected(s, n, p, P)) for s in range(8)]
    expect = p * n * (n - 1) / 2
    sd = np.sqrt(p * (1 - p) * n * (n - 1) / 2)
    assert abs(np.mean(ms) - expect) < 4 * sd / np.sqrt(8)


def test_gnp_directed_chunks_union():
    n, p, P = 100, 0.02, 4
    es = [er.gnp_directed_pe(3, n, p, P, pe) for pe in range(P)]
    allp = np.concatenate(es)
    assert not graph.has_duplicates(allp)
    assert not graph.has_self_loops(allp)
    # row-disjointness: PE chunks partition by rows
    for pe, e in enumerate(es):
        lo, hi = chunking.section_bounds(n, P, pe)
        if e.size:
            assert (e[:, 0] >= lo).all() and (e[:, 0] < hi).all()
