"""repro.analyze — the static contract verifier, proven with planted
violations.

The gate's whole value is that it *fires*: each test here plants one
specific contract violation (a psum in a slot fn, a host callback, rbg
on a recompute path, an np.unique in an emitter-role module) and
asserts the matching pass reports exactly that violation — and that
the inline ``# repro: allow(...)`` suppression silences exactly the
AST one.  The dialect-duality test pins the historical bug this
subsystem replaced: the seed's regex knew only the hyphenated HLO
spelling, so a planted collective in StableHLO text passed unseen.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analyze import hloscan, lint
from repro.analyze.__main__ import main as analyze_main
from repro.api import GNM, RGG, verify_contracts
from repro.distrib import engine, runtime


@pytest.fixture(scope="module")
def chunk_plan():
    return GNM(n=64, m=128, seed=1, chunks=4).plan(4)


class _PlantedCollective:
    """ChunkPlan facade whose slot fn hides a psum over the mesh axis."""

    def __init__(self, inner, tag="planted"):
        self.inner = inner
        self.tag = tag

    @property
    def num_pes(self):
        return self.inner.num_pes

    def input_arrays(self):
        return self.inner.input_arrays()

    def stream_index(self):
        return self.inner.stream_index()

    def signature(self):
        return (self.tag,) + self.inner.signature()

    def slot_fn(self):
        one = self.inner.slot_fn()

        def bad(*rows):
            payload, ok = one(*rows)
            return payload + jax.lax.psum(payload, "pe"), ok

        return bad


# --------------------------------------------------------------------------
# Pass 1: the IR scanner
# --------------------------------------------------------------------------

class TestPass1:
    def test_clean_chunk_program(self, chunk_plan):
        rep = hloscan.scan_lowered(runtime.lower_run(chunk_plan))
        assert rep.ok and not rep.collectives

    def test_clean_wave_step(self, chunk_plan):
        low = runtime.lower_wave(chunk_plan, batch=2)
        rep = hloscan.scan_lowered(low)
        assert rep.ok

    def test_planted_psum_is_exactly_one_collective_finding(self, chunk_plan):
        low = runtime.lower_run(_PlantedCollective(chunk_plan))
        rep = hloscan.scan_lowered(low)
        assert [f.rule for f in rep.findings] == [hloscan.RULE_COLLECTIVE]
        assert "all_reduce" in set(rep.collectives)

    def test_planted_psum_fires_runtime_check(self, chunk_plan):
        """The runtime's check=True path is the same scanner: a planted
        collective aborts run() before anything executes, with the
        historical error text."""
        with pytest.raises(AssertionError,
                           match="generator lowering contains collectives"):
            runtime.run(_PlantedCollective(chunk_plan, "planted-run"),
                        check=True)

    def test_planted_psum_fires_wave_check(self, chunk_plan):
        with pytest.raises(AssertionError,
                           match="generator lowering contains collectives"):
            list(runtime.stream_waves(
                _PlantedCollective(chunk_plan, "planted-wave"), check=True))

    def test_both_ir_spellings_detected(self):
        """StableHLO (underscore) and HLO (hyphen) both match — the
        seed's hyphen-only regex let StableHLO collectives through."""
        assert hloscan.collective_ops_in(
            "  %1 = stablehlo.all_reduce %0 ...") == ["all_reduce"]
        assert hloscan.collective_ops_in(
            "  %ar = f32[8] all-reduce(%d), replica_groups={}") == ["all-reduce"]
        assert engine.collective_ops_in(
            "stablehlo.collective_permute") == ["collective_permute"]

    def test_host_callback_detected(self):
        f = jax.jit(lambda x: jax.pure_callback(
            lambda v: np.asarray(v) * 2,
            jax.ShapeDtypeStruct((4,), jnp.float64), x))
        rep = hloscan.scan_lowered(f.lower(jnp.ones(4)))
        assert [f.rule for f in rep.findings] == [hloscan.RULE_HOST_CALLBACK]

    def test_rbg_flagged_on_recompute_path_only(self):
        g = jax.jit(lambda: jax.random.bits(
            jax.random.key(0, impl="rbg"), (8,), dtype=jnp.uint32))
        low = g.lower()
        rep = hloscan.scan_lowered(low, hloscan.RECOMPUTE_CONTRACT)
        assert [f.rule for f in rep.findings] == [hloscan.RULE_NONDET_RNG]
        assert "DEFAULT" in rep.rng_algorithms
        # the ChunkPlan perf path may opt in: no violation there
        assert hloscan.scan_lowered(low, hloscan.GENERATOR_CONTRACT).ok

    def test_f64_and_dynamic_shape_rules(self):
        f64_text = "%0 = stablehlo.add %a, %b : tensor<4x3xf64>"
        assert hloscan.scan_text(
            f64_text, hloscan.FLOAT32_KERNEL_CONTRACT).findings
        assert hloscan.scan_text(f64_text, hloscan.GENERATOR_CONTRACT).ok
        dyn = "%1 = stablehlo.dynamic_reshape %x : tensor<?xf32>"
        rep = hloscan.scan_text(dyn)
        assert [f.rule for f in rep.findings] == [hloscan.RULE_DYNAMIC_SHAPE]

    def test_verify_contracts_front_door(self):
        reports = verify_contracts(RGG(n=32, radius=0.3, seed=2, chunks=4), 4)
        assert {r.plan_kind for r in reports} == {"pair", "point"}
        assert {r.mode for r in reports} == {"run", "wave"}
        assert all(r.ok for r in reports)


# --------------------------------------------------------------------------
# Pass 2: the AST linter
# --------------------------------------------------------------------------

EMITTER = "src/repro/core/planted.py"         # role: emitter
KERNEL = "src/repro/kernels/planted.py"       # role: kernels
SUPPORT = "src/repro/launch/planted.py"       # role: support
TESTROLE = "tests/test_planted.py"            # role: tests (exempt)


class TestPass2:
    def test_planted_np_unique_exactly_one_finding(self):
        src = "import numpy as np\nedges = np.unique(e, axis=0)\n"
        found = lint.lint_source(src, EMITTER)
        assert [f.rule for f in found] == [lint.RULE_NP_UNIQUE]
        assert found[0].line == 2

    def test_allow_comment_suppresses(self):
        src = ("import numpy as np\n"
               "edges = np.unique(e, axis=0)"
               "  # repro: allow(no-numpy-unique) oracle\n")
        assert lint.lint_source(src, EMITTER) == []

    def test_allow_comment_is_rule_specific(self):
        src = ("import numpy as np\n"
               "edges = np.unique(e, axis=0)  # repro: allow(no-raw-prngkey)\n")
        assert [f.rule for f in lint.lint_source(src, EMITTER)] == [
            lint.RULE_NP_UNIQUE]

    def test_np_unique_scoped_to_emitter_and_kernel_roles(self):
        src = "import numpy as np\nx = np.unique(y)\n"
        assert lint.lint_source(src, SUPPORT) == []
        assert lint.lint_source(src, TESTROLE) == []
        assert lint.lint_source(src, KERNEL)

    def test_python_random_flagged_everywhere_outside_tests(self):
        src = "import random\nx = random.random()\n"
        assert {f.rule for f in lint.lint_source(src, SUPPORT)} == {
            lint.RULE_PY_RANDOM}
        assert lint.lint_source(src, TESTROLE) == []

    def test_wallclock_state_flagged(self):
        src = ("import time\nimport numpy as np\n"
               "seed = time.time_ns()\nrng = np.random.default_rng()\n")
        assert [f.rule for f in lint.lint_source(src, EMITTER)] == [
            lint.RULE_WALLCLOCK, lint.RULE_WALLCLOCK]
        # a *seeded* generator is deterministic: allowed
        ok = "import numpy as np\nrng = np.random.default_rng(42)\n"
        assert lint.lint_source(ok, EMITTER) == []

    def test_collective_in_kernels_flagged(self):
        src = "import jax\ny = jax.lax.psum(x, 'pe')\n"
        assert [f.rule for f in lint.lint_source(src, KERNEL)] == [
            lint.RULE_KERNEL_COLLECTIVE]
        src2 = "from jax import lax\ny = lax.all_gather(x, 'pe')\n"
        assert [f.rule for f in lint.lint_source(src2, KERNEL)] == [
            lint.RULE_KERNEL_COLLECTIVE]
        src3 = "from repro.distrib import engine\n"
        assert [f.rule for f in lint.lint_source(src3, KERNEL)] == [
            lint.RULE_KERNEL_COLLECTIVE]

    def test_raw_prngkey_flagged_outside_prng_module(self):
        src = "import jax\nk = jax.random.PRNGKey(0)\n"
        assert [f.rule for f in lint.lint_source(src, EMITTER)] == [
            lint.RULE_RAW_PRNGKEY]
        assert lint.lint_source(src, "src/repro/core/prng.py") == []

    def test_deprecated_shim_flagged_but_not_its_definition(self):
        use = "from repro.core.er import gnm_directed\ne = gnm_directed(0, 8, 4)\n"
        rules = [f.rule for f in lint.lint_source(use, SUPPORT)]
        assert rules == [lint.RULE_DEPRECATED, lint.RULE_DEPRECATED]
        define = ("def gnm_directed(seed, n, m, P=1):\n"
                  "    return gnm_directed_impl(seed, n, m, P)\n")
        assert lint.lint_source(define, EMITTER) == []

    def test_noncounter_pair_rng_flagged_statically(self):
        flagged = [
            "from repro.api import RGG, generate\n"
            "g = generate(RGG(n=64, radius=0.1), 4, rng_impl='rbg')\n",
            "spec = RHG(n=64, avg_deg=4, gamma=2.7)\n"
            "plan = spec.plan(4, rng_impl='rbg')\n",
            "plan = make_pair_plan(rows, rng_impl='rbg')\n",
            "spec = RDG(n=64)\n"
            "for c in iter_edge_chunks(spec, 8, rng_impl='rbg'):\n"
            "    pass\n",
        ]
        for src in flagged:
            assert [f.rule for f in lint.lint_source(src, SUPPORT)] == [
                lint.RULE_NONCOUNTER_PAIR], src
        # counter impls and non-pair families stay legal
        for src in [
            "g = generate(RGG(n=64, radius=0.1), 4, rng_impl='threefry2x32')\n",
            "g = generate(GNM(n=64, m=32), 4, rng_impl='rbg')\n",
        ]:
            assert lint.lint_source(src, SUPPORT) == [], src

    def test_per_chunk_host_loop_flagged(self):
        # one finding per per-chunk call, anchored to the call line
        src = ("from repro.core.prng import host_rng\n"
               "def plan(seed, P):\n"
               "    for pe in range(P):\n"
               "        c = host_rng(seed, 1, pe)\n")
        found = lint.lint_source(src, EMITTER)
        assert [f.rule for f in found] == [lint.RULE_PER_CHUNK_LOOP]
        assert found[0].line == 4
        # comprehensions count as loops
        comp = ("from repro.distrib.engine import ChunkSpec\n"
                "specs = [ChunkSpec(k, kd, u, c, p) for k in ks]\n")
        assert [f.rule for f in lint.lint_source(comp, EMITTER)] == [
            lint.RULE_PER_CHUNK_LOOP]

    def test_per_chunk_host_loop_scope_and_exemptions(self):
        src = ("from repro.core.prng import host_rng\n"
               "for pe in range(P):\n"
               "    c = host_rng(seed, 1, pe)\n")
        # emitter-role only: support/tests stay silent
        assert lint.lint_source(src, SUPPORT) == []
        assert lint.lint_source(src, TESTROLE) == []
        # a For's iterable runs once, not per iteration
        once = ("from repro.core.prng import host_rng\n"
                "for v in host_rng(seed, 1, 0).permutation(8):\n"
                "    use(v)\n")
        assert lint.lint_source(once, EMITTER) == []
        # replayed variate draws are the sanctioned loop shape
        replay = ("from repro.core.variates import binomial\n"
                  "for k, h in enumerate(hashes):\n"
                  "    out[k] = binomial(rep.at(h), int(U[k]), float(p[k]))\n")
        assert lint.lint_source(replay, EMITTER) == []
        # line suppression works, as on the retained oracles
        allowed = ("from repro.core.prng import host_rng\n"
                   "for pe in range(P):\n"
                   "    c = host_rng(seed, 1, pe)"
                   "  # repro: allow(no-per-chunk-host-loop) oracle\n")
        assert lint.lint_source(allowed, EMITTER) == []

    def test_per_chunk_qhull_flagged(self):
        # a per-chunk host triangulation in a loop is the retired RDG
        # pattern the batched device DT replaced
        src = ("from scipy.spatial import Delaunay\n"
               "def plan(chunks):\n"
               "    for pts in chunks:\n"
               "        tri = Delaunay(pts)\n")
        found = lint.lint_source(src, EMITTER)
        assert [f.rule for f in found] == [lint.RULE_PER_CHUNK_LOOP]
        assert found[0].line == 4
        # ... and so is a per-chunk certificate batch
        cert = ("from repro.core.rdg import circumspheres\n"
                "while pending:\n"
                "    c, r = circumspheres(pts[sel])\n")
        assert [f.rule for f in lint.lint_source(cert, EMITTER)] == [
            lint.RULE_PER_CHUNK_LOOP]
        # retained oracles suppress in place (as rdg._certified_triangulation
        # and the once-per-halo-round certification batch do)
        ok = ("from scipy.spatial import Delaunay\n"
              "for pts in chunks:\n"
              "    tri = Delaunay(pts)"
              "  # repro: allow(no-per-chunk-host-loop) oracle\n")
        assert lint.lint_source(ok, EMITTER) == []
        # a single whole-batch call outside any loop is the sanctioned shape
        assert lint.lint_source(
            "from scipy.spatial import Delaunay\ntri = Delaunay(pts)\n",
            EMITTER) == []

    def test_repo_is_clean(self):
        """The shipping tree passes its own gate (inline allows and all)."""
        found = lint.lint_paths(["src/repro", "examples", "benchmarks"])
        assert found == [], "\n".join(f.format() for f in found)


# --------------------------------------------------------------------------
# the CI gate itself
# --------------------------------------------------------------------------

class TestGate:
    def test_cli_fails_on_planted_lint_violation(self, tmp_path, capsys):
        planted = tmp_path / "src" / "repro" / "core"
        planted.mkdir(parents=True)
        (planted / "bad.py").write_text(
            "import numpy as np\ne = np.unique(e, axis=0)\n")
        report = tmp_path / "report.json"
        rc = analyze_main(["--lint", str(planted), "--json", str(report),
                           "--fail-on-violation"])
        assert rc == 1
        data = json.loads(report.read_text())
        assert data["summary"]["violations"] == 1
        assert data["lint"][0]["rule"] == lint.RULE_NP_UNIQUE
        assert not data["summary"]["ok"]

    def test_cli_passes_on_clean_tree_and_writes_report(self, tmp_path):
        clean = tmp_path / "src" / "repro" / "core"
        clean.mkdir(parents=True)
        (clean / "good.py").write_text("x = 1\n")
        report = tmp_path / "report.json"
        rc = analyze_main(["--lint", str(clean), "--json", str(report),
                           "--fail-on-violation"])
        assert rc == 0
        assert json.loads(report.read_text())["summary"]["ok"]

    def test_cli_pass1_single_family(self):
        rc = analyze_main(["--families", "gnm", "--no-cost", "--lint"])
        assert rc == 0
