"""Per-kernel correctness: pallas_call (interpret=True) vs pure-jnp ref,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pairdist.ops import pad_points
from repro.kernels.pairdist.pairdist import pairdist_mask
from repro.kernels.pairdist.ref import pairdist_mask_ref


@pytest.mark.parametrize("m,n", [(128, 128), (256, 128), (128, 384), (512, 512)])
@pytest.mark.parametrize("dim", [2, 3])
def test_pairdist_matches_ref(m, n, dim):
    k = jax.random.key(m * n + dim)
    a = jax.random.uniform(k, (m, 8), dtype=jnp.float32)
    b = jax.random.uniform(jax.random.fold_in(k, 1), (n, 8), dtype=jnp.float32)
    r2 = 0.05
    got = pairdist_mask(a, b, r2, dim=dim, interpret=True)
    want = pairdist_mask_ref(a, b, r2, dim=dim)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block", [64, 128, 256])
def test_pairdist_block_shapes(block):
    k = jax.random.key(0)
    a = jax.random.uniform(k, (256, 8), dtype=jnp.float32)
    b = jax.random.uniform(jax.random.fold_in(k, 1), (256, 8), dtype=jnp.float32)
    got = pairdist_mask(a, b, 0.1, dim=2, block_m=block, block_n=block, interpret=True)
    want = pairdist_mask_ref(a, b, 0.1, dim=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pairdist_inf_padding_never_matches():
    pts = jnp.array([[0.1, 0.1], [0.2, 0.2]])
    padded = pad_points(pts)
    assert padded.shape == (128, 8)
    m = pairdist_mask(padded, padded, 1e9, dim=2, interpret=True)
    m = np.asarray(m)
    assert m[:2, :2].all()
    assert not m[2:, :].any() and not m[:, 2:].any()


def test_pairdist_threshold_is_inclusive():
    a = jnp.zeros((128, 8), jnp.float32)
    b = jnp.zeros((128, 8), jnp.float32).at[:, 0].set(0.5)
    m = pairdist_mask(a, b, 0.25, dim=2, interpret=True)
    assert np.asarray(m).all()  # dist^2 == r^2 exactly -> edge (<=)


# ------------------------------------------------------------------ hypdist

from repro.kernels.hypdist.hypdist import hypdist_mask
from repro.kernels.hypdist.ops import pad_features, precompute_features
from repro.kernels.hypdist.ref import hypdist_mask_ref


def _random_features(key, n, R, dtype):
    import jax.random as jr
    r = jr.uniform(key, (n,), minval=0.3 * R, maxval=R)
    th = jr.uniform(jr.fold_in(key, 1), (n,), minval=0.0, maxval=2 * np.pi)
    return jnp.asarray(precompute_features(np.asarray(r), np.asarray(th), dtype=dtype))


@pytest.mark.parametrize("m,n", [(128, 128), (256, 128), (384, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_hypdist_matches_ref(m, n, dtype):
    R = 14.0
    q = _random_features(jax.random.key(m + n), m, R, dtype)
    c = _random_features(jax.random.key(m * n), n, R, dtype)
    got = hypdist_mask(q, c, np.cosh(R), interpret=True)
    want = hypdist_mask_ref(q, c, np.cosh(R))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hypdist_matches_true_hyperbolic_distance():
    """Eq. 9 kernel == direct acosh evaluation of Eq. 4 (f64)."""
    rng = np.random.default_rng(0)
    n, R = 100, 12.0
    r = rng.uniform(0.3 * R, R, n)
    th = rng.uniform(0, 2 * np.pi, n)
    f = pad_features(precompute_features(r, th))
    got = np.asarray(hypdist_mask(jnp.asarray(f), jnp.asarray(f), np.cosh(R), interpret=True))[:n, :n]
    arg = (np.cosh(r)[:, None] * np.cosh(r)[None, :]
           - np.sinh(r)[:, None] * np.sinh(r)[None, :] * np.cos(th[:, None] - th[None, :]))
    dist = np.arccosh(np.maximum(arg, 1.0))
    want = dist < R
    np.fill_diagonal(want, True)  # kernel does not exclude self-pairs
    disagree = (got.astype(bool) != want)
    # borderline float disagreements only; none expected at this scale
    assert disagree.sum() == 0


def test_hypdist_padding_rows_never_match():
    import warnings

    from repro.kernels.hypdist.ops import cosh_threshold

    f = precompute_features(np.array([8.0, 9.0]), np.array([0.1, 0.2]))
    p = pad_features(f)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # cosh overflow must stay silent
        thr = cosh_threshold(1000.0)
        m = np.asarray(hypdist_mask(jnp.asarray(p), jnp.asarray(p), thr, interpret=True))
    assert not m[2:, :].any() and not m[:, 2:].any()


def test_cosh_threshold_matches_cosh_and_never_overflows():
    import warnings

    from repro.kernels.hypdist.ops import cosh_threshold

    for R in (0.0, 1.0, 14.0, 100.0, 699.0):
        assert cosh_threshold(R) == pytest.approx(np.cosh(R), rel=1e-15)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        for R in (701.0, 1000.0, 1e6):
            v = cosh_threshold(R)
            assert np.isfinite(v) and v > 0
