"""Per-kernel correctness: pallas_call (interpret=True) vs pure-jnp ref,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pairdist.ops import pad_points
from repro.kernels.pairdist.pairdist import pairdist_mask
from repro.kernels.pairdist.ref import pairdist_mask_ref


@pytest.mark.parametrize("m,n", [(128, 128), (256, 128), (128, 384), (512, 512)])
@pytest.mark.parametrize("dim", [2, 3])
def test_pairdist_matches_ref(m, n, dim):
    k = jax.random.key(m * n + dim)
    a = jax.random.uniform(k, (m, 8), dtype=jnp.float32)
    b = jax.random.uniform(jax.random.fold_in(k, 1), (n, 8), dtype=jnp.float32)
    r2 = 0.05
    got = pairdist_mask(a, b, r2, dim=dim, interpret=True)
    want = pairdist_mask_ref(a, b, r2, dim=dim)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block", [64, 128, 256])
def test_pairdist_block_shapes(block):
    k = jax.random.key(0)
    a = jax.random.uniform(k, (256, 8), dtype=jnp.float32)
    b = jax.random.uniform(jax.random.fold_in(k, 1), (256, 8), dtype=jnp.float32)
    got = pairdist_mask(a, b, 0.1, dim=2, block_m=block, block_n=block, interpret=True)
    want = pairdist_mask_ref(a, b, 0.1, dim=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pairdist_inf_padding_never_matches():
    pts = jnp.array([[0.1, 0.1], [0.2, 0.2]])
    padded = pad_points(pts)
    assert padded.shape == (128, 8)
    m = pairdist_mask(padded, padded, 1e9, dim=2, interpret=True)
    m = np.asarray(m)
    assert m[:2, :2].all()
    assert not m[2:, :].any() and not m[:, 2:].any()


def test_pairdist_threshold_is_inclusive():
    a = jnp.zeros((128, 8), jnp.float32)
    b = jnp.zeros((128, 8), jnp.float32).at[:, 0].set(0.5)
    m = pairdist_mask(a, b, 0.25, dim=2, interpret=True)
    assert np.asarray(m).all()  # dist^2 == r^2 exactly -> edge (<=)


# ----------------------------------------------------------------- pairmask

from repro.kernels.pairmask.pairmask import TILES, pair_mask
from repro.kernels.pairmask.ref import pair_mask_ref


def _tile_inputs(tile, m, n):
    k = jax.random.key(m * 31 + n)
    if tile == "euclid":
        a = jax.random.uniform(k, (m, 8), dtype=jnp.float32)
        b = jax.random.uniform(jax.random.fold_in(k, 1), (n, 8), dtype=jnp.float32)
        return a, b, 0.05
    from repro.kernels.hypdist.ops import precompute_features
    r = np.asarray(jax.random.uniform(k, (m,), minval=3.0, maxval=14.0))
    th = np.asarray(jax.random.uniform(jax.random.fold_in(k, 1), (m,),
                                       maxval=2 * np.pi))
    q = jnp.asarray(precompute_features(r, th))
    c = jnp.asarray(precompute_features(r[: n], th[: n])) if n <= m else None
    if c is None:
        r2 = np.asarray(jax.random.uniform(jax.random.fold_in(k, 2), (n,),
                                           minval=3.0, maxval=14.0))
        th2 = np.asarray(jax.random.uniform(jax.random.fold_in(k, 3), (n,),
                                            maxval=2 * np.pi))
        c = jnp.asarray(precompute_features(r2, th2))
    return q, c, np.cosh(14.0)


@pytest.mark.parametrize("tile", TILES)
@pytest.mark.parametrize("m,n", [(128, 128), (256, 384)])
def test_pair_mask_tiles_match_shared_ref(tile, m, n):
    """Both geometry kinds are tiles of one kernel: pallas_call output
    == the shared jnp reference for every tile kind."""
    a, b, s = _tile_inputs(tile, m, n)
    got = pair_mask(a, b, s, tile=tile, dim=2, interpret=True)
    want = pair_mask_ref(a, b, s, tile=tile, dim=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("tile", TILES)
def test_pair_mask_facades_delegate(tile):
    """pairdist_mask / hypdist_mask are exact facades over pair_mask."""
    a, b, s = _tile_inputs(tile, 128, 128)
    unified = np.asarray(pair_mask(a, b, s, tile=tile, dim=3, interpret=True))
    if tile == "euclid":
        facade = pairdist_mask(a, b, s, dim=3, interpret=True)
    else:
        from repro.kernels.hypdist.hypdist import hypdist_mask as _hm
        facade = _hm(a, b, s, interpret=True)
    np.testing.assert_array_equal(unified, np.asarray(facade))


def test_pair_mask_rejects_unknown_tile():
    a = jnp.zeros((128, 8), jnp.float32)
    with pytest.raises(ValueError, match="unknown tile"):
        pair_mask(a, a, 1.0, tile="minkowski")
    with pytest.raises(ValueError, match="unknown tile"):
        pair_mask_ref(a, a, 1.0, tile="minkowski")


# ------------------------------------------------------------------ hypdist

from repro.kernels.hypdist.hypdist import hypdist_mask
from repro.kernels.hypdist.ops import pad_features, precompute_features
from repro.kernels.hypdist.ref import hypdist_mask_ref


def _random_features(key, n, R, dtype):
    import jax.random as jr
    r = jr.uniform(key, (n,), minval=0.3 * R, maxval=R)
    th = jr.uniform(jr.fold_in(key, 1), (n,), minval=0.0, maxval=2 * np.pi)
    return jnp.asarray(precompute_features(np.asarray(r), np.asarray(th), dtype=dtype))


@pytest.mark.parametrize("m,n", [(128, 128), (256, 128), (384, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_hypdist_matches_ref(m, n, dtype):
    R = 14.0
    q = _random_features(jax.random.key(m + n), m, R, dtype)
    c = _random_features(jax.random.key(m * n), n, R, dtype)
    got = hypdist_mask(q, c, np.cosh(R), interpret=True)
    want = hypdist_mask_ref(q, c, np.cosh(R))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hypdist_matches_true_hyperbolic_distance():
    """Eq. 9 kernel == direct acosh evaluation of Eq. 4 (f64)."""
    rng = np.random.default_rng(0)
    n, R = 100, 12.0
    r = rng.uniform(0.3 * R, R, n)
    th = rng.uniform(0, 2 * np.pi, n)
    f = pad_features(precompute_features(r, th))
    got = np.asarray(hypdist_mask(jnp.asarray(f), jnp.asarray(f), np.cosh(R), interpret=True))[:n, :n]
    arg = (np.cosh(r)[:, None] * np.cosh(r)[None, :]
           - np.sinh(r)[:, None] * np.sinh(r)[None, :] * np.cos(th[:, None] - th[None, :]))
    dist = np.arccosh(np.maximum(arg, 1.0))
    want = dist < R
    np.fill_diagonal(want, True)  # kernel does not exclude self-pairs
    disagree = (got.astype(bool) != want)
    # borderline float disagreements only; none expected at this scale
    assert disagree.sum() == 0


def test_hypdist_padding_rows_never_match():
    import warnings

    from repro.kernels.hypdist.ops import cosh_threshold

    f = precompute_features(np.array([8.0, 9.0]), np.array([0.1, 0.2]))
    p = pad_features(f)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # cosh overflow must stay silent
        thr = cosh_threshold(1000.0)
        m = np.asarray(hypdist_mask(jnp.asarray(p), jnp.asarray(p), thr, interpret=True))
    assert not m[2:, :].any() and not m[:, 2:].any()


def test_cosh_threshold_matches_cosh_and_never_overflows():
    import warnings

    from repro.kernels.hypdist.ops import cosh_threshold

    for R in (0.0, 1.0, 14.0, 100.0, 699.0):
        assert cosh_threshold(R) == pytest.approx(np.cosh(R), rel=1e-15)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        for R in (701.0, 1000.0, 1e6):
            v = cosh_threshold(R)
            assert np.isfinite(v) and v > 0


# --------------------------------------------------------------------- hist

from repro.kernels.hist.hist import LOG2_BINS, hist_counts
from repro.kernels.hist.ops import (
    bincount_ids,
    degree_histogram,
    log2_histogram,
    pad_values,
)
from repro.kernels.hist.ref import hist_counts_ref, log2_bin_ref


@pytest.mark.parametrize("n,num_bins", [(1024, 64), (5000, 300), (2048, 1000)])
@pytest.mark.parametrize("log2", [False, True])
def test_hist_matches_ref(n, num_bins, log2):
    v = np.random.default_rng(n + num_bins).integers(0, 4 * num_bins, n)
    got = np.asarray(hist_counts(pad_values(v), num_bins=num_bins, log2=log2,
                                 interpret=True))[:num_bins]
    want = np.asarray(hist_counts_ref(v, num_bins=num_bins, log2=log2))
    np.testing.assert_array_equal(got, want)
    assert got.sum() == n  # every non-negative value lands in some bin


@pytest.mark.parametrize("block_v,block_b", [(256, 64), (1024, 128), (2048, 256)])
def test_hist_block_shapes(block_v, block_b):
    v = np.random.default_rng(0).integers(0, 500, 4096)
    got = np.asarray(hist_counts(pad_values(v, block=block_v), num_bins=500,
                                 block_v=block_v, block_b=block_b,
                                 interpret=True))[:500]
    np.testing.assert_array_equal(got, np.bincount(v, minlength=500))


def test_hist_padding_rows_count_nowhere():
    padded = pad_values(np.array([3, 3, 7]))
    assert padded.shape == (1024, 1) and int((padded >= 0).sum()) == 3
    got = np.asarray(degree_histogram(np.array([3, 3, 7]), 16))
    assert got.sum() == 3 and got[3] == 2 and got[7] == 1


def test_hist_log2_bin_semantics():
    """bin 0 <- 0; bin 1+k <- [2^k, 2^(k+1)): the log-binned degree
    histogram used at huge n."""
    v = np.array([0, 1, 2, 3, 4, 7, 8, 1 << 20, (1 << 31) - 1])
    bins = np.asarray(log2_bin_ref(v))
    np.testing.assert_array_equal(bins, [0, 1, 2, 2, 3, 3, 4, 21, 31])
    h = np.asarray(log2_histogram(v))
    assert h.shape == (LOG2_BINS,)
    np.testing.assert_array_equal(h, np.bincount(bins, minlength=LOG2_BINS))


def test_hist_overflow_clamps_to_last_bin():
    got = np.asarray(degree_histogram(np.array([1, 5, 99, 1000]), 8))
    assert got[7] == 2 and got.sum() == 4  # 99 and 1000 clamp into bin 7


def test_bincount_ids_both_paths_match_numpy():
    """Scatter-add dispatch: Pallas one-hot kernel below the bin limit,
    XLA scatter above — identical counts either way."""
    ids = np.random.default_rng(1).integers(0, 3000, 10_000)
    np.testing.assert_array_equal(np.asarray(bincount_ids(ids, 3000)),
                                  np.bincount(ids, minlength=3000))
    np.testing.assert_array_equal(np.asarray(bincount_ids(ids, 6000)),
                                  np.bincount(ids, minlength=6000))


def test_bincount_ids_drops_out_of_range_on_both_paths():
    """Sentinel / out-of-range ids must be dropped, not clamped into the
    last bin, on both sides of SCATTER_BINS_LIMIT."""
    ids = np.array([0, 1, 1, 99, 10_000])
    for length in (100, 5000):  # kernel path, XLA scatter path
        got = np.asarray(bincount_ids(ids, length))
        assert got.sum() == 4 and got[0] == 1 and got[1] == 2 and got[99] == 1
