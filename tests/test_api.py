"""The unified GraphSpec -> plan -> run/stream API (repro.api): machine-
size invariance for every family, streaming == batch execution, engine
coverage (zero collectives) for all eight spec types, and bit-identity
of the new engine chunk kinds against their reference generators."""
import numpy as np
import pytest

from repro.api import (
    BA,
    GNM,
    GNP,
    RDG,
    RGG,
    RHG,
    RMAT,
    SBM,
    EdgeChunk,
    Graph,
    generate,
    iter_edge_chunks,
)
from repro.core import ba, graph, rmat, sbm
from repro.distrib import engine


def _es(e):
    return {tuple(x) for x in np.asarray(e, np.int64)}


ALL_SPECS = [
    GNM(n=200, m=900, seed=17),
    GNM(n=200, m=900, directed=True, seed=3),
    GNP(n=200, p=0.03, seed=5),
    GNP(n=200, p=0.02, directed=True, seed=5),
    BA(n=128, d=2, seed=5),
    RMAT(log_n=9, m=2000, seed=1),
    SBM(n=300, blocks=6, p_in=0.2, p_out=0.01, seed=5),
    RGG(n=300, radius=0.07, seed=11),
    RHG(n=400, avg_deg=8, gamma=2.8, seed=23),
    RDG(n=300, seed=318),
]


# ------------------------------------------------ machine-size invariance

@pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: type(s).__name__)
def test_generate_edge_set_invariant_in_P(spec):
    """The instance is a function of the spec (virtual chunk grid), not
    of the PE count: P in {1, 2, 4} must yield identical edge sets."""
    sets = [_es(generate(spec, P).edges) for P in (1, 2, 4)]
    assert sets[0] == sets[1] == sets[2]
    assert len(sets[0]) > 0


def _regroup(chunks):
    """The documented reconstruction: per-PE stream order is exact, so
    grouping chunks by owning PE and concatenating pe-major reproduces
    the generate order on any device count (on a 1-device mesh the flat
    stream order already is generate order)."""
    per_pe = {}
    for c in chunks:
        per_pe.setdefault(c.pe, []).append(c.edges())
    return np.concatenate([e for pe in sorted(per_pe) for e in per_pe[pe]],
                          axis=0)


@pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: type(s).__name__)
def test_iter_edge_chunks_concatenates_to_generate(spec):
    """Streaming is exact: per-PE chunk order and content match the
    batch run bit-for-bit, independent of local device count."""
    g = generate(spec, 4)
    streamed = _regroup(iter_edge_chunks(spec, 4))
    np.testing.assert_array_equal(streamed, g.edges)


def test_stream_buffers_bounded_by_plan_capacity():
    """The memory contract: every streamed buffer is one [cap, 2] chunk
    buffer — peak memory O(capacity), never O(total edges)."""
    spec = GNM(n=4000, m=60_000, seed=9)
    plan = spec.plan(4)
    seen = 0
    for chunk in iter_edge_chunks(spec, 4):
        assert chunk.buffer.shape == (plan.capacity, 2)
        seen += chunk.count
    assert seen == spec.m
    assert plan.capacity * plan.num_pes < spec.m  # buffers << total edges


def test_generate_returns_graph_metadata():
    g = generate(GNM(n=100, m=400, seed=1), 2)
    assert isinstance(g, Graph)
    assert (g.n, g.m, g.directed) == (100, 400, False)
    assert g.degrees().sum() == 2 * g.m


# ---------------------------------------- engine coverage, zero collectives

@pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: type(s).__name__)
def test_all_spec_types_execute_through_engine(spec):
    """Every spec emits an engine plan and its SPMD lowering contains
    zero collectives (asserted on the HLO, not assumed)."""
    plan = spec.plan(4)
    if isinstance(plan, engine.ChunkPlan):
        _, hlo = engine.run_edges(plan)
    elif isinstance(plan, engine.PairPlan):
        _, hlo = engine.run_pairs(plan)
    else:
        _, _, hlo = engine.run_points(plan)
    assert not engine.collective_ops_in(hlo)


def test_engine_ba_bit_identical_to_sequential_reference():
    seed, n, d = 5, 200, 3
    edges, hlo = engine.run_edges(ba.ba_plan(seed, n, d, 4))
    assert not engine.collective_ops_in(hlo)
    np.testing.assert_array_equal(edges, ba.ba_sequential_reference(seed, n, d))


def test_engine_rmat_bit_identical_to_reference():
    seed, log_n, m, P = 1, 10, 5000, 4
    edges, hlo = engine.run_edges(rmat.rmat_plan(seed, log_n, m, P))
    assert not engine.collective_ops_in(hlo)
    ref = np.concatenate([rmat.rmat_pe(seed, log_n, m, P, pe) for pe in range(P)])
    np.testing.assert_array_equal(edges, ref)


def test_engine_sbm_matches_host_union_no_sort_dedup():
    """Canonical region ownership: engine per-PE concatenation equals
    the host union exactly, with no duplicate edges to dedup."""
    args = (5, 300, 6, 0.2, 0.01)
    plan = sbm.sbm_plan(*args, P=4)
    edges, hlo = engine.run_edges(plan)
    assert not engine.collective_ops_in(hlo)
    assert not graph.has_duplicates(edges)
    assert _es(edges) == _es(sbm.sbm_union(*args))
    # the plan mirrors cross-owner regions (recomputation) but owns each once
    assert plan.total_edges == len(edges)


def test_sbm_plan_regions_recomputed_on_both_owners():
    """Region (i, j) with i % P != j % P appears on both block owners'
    rows (the paper's recomputation bound), owned by exactly one."""
    plan = sbm.sbm_plan(9, 500, 6, 0.1, 0.02, P=3)
    key_rows = {}
    for pe in range(plan.num_pes):
        for c in range(plan.chunks_per_pe):
            if plan.kind[pe, c] == engine.KIND_EMPTY:
                continue
            k = plan.key_data[pe, c].tobytes()
            key_rows.setdefault(k, []).append(bool(plan.owned[pe, c]))
    assert any(len(v) == 2 for v in key_rows.values())
    for owners in key_rows.values():
        assert sum(owners) == 1  # exactly one owner per region


def test_rhg_pair_plan_matches_bruteforce_oracle():
    """The candidate-pair windows cover every adjacent pair: engine
    edges == O(n^2) oracle over the same (engine-layout) vertex set."""
    spec = RHG(n=500, avg_deg=6, gamma=2.6, seed=13)
    g = generate(spec, 4, return_points=True)
    from repro.core.rhg import rhg_brute_edges

    brute = rhg_brute_edges(g.points[:, 0], g.points[:, 1], spec.params.R)
    assert _es(g.edges) == _es(brute)
    assert not graph.has_duplicates(g.edges)


def test_deal_plan_conserves_owned_chunks():
    spec = GNM(n=300, m=2000, seed=4, chunks=12)
    p1, p3 = spec.plan(1), spec.plan(3)
    assert p1.num_pes == 1 and p3.num_pes == 3
    assert p1.total_edges == p3.total_edges == spec.m
    assert _es(engine.run_edges(p1)[0]) == _es(engine.run_edges(p3)[0])


def test_rbg_rng_impl_through_engine():
    """The 'rbg' perf path lowers, runs and stays collective-free; it is
    a different PRNG, so the instance differs from threefry."""
    spec = GNM(n=256, m=1200, directed=True, seed=7)
    tf = generate(spec, 4)
    rbg = generate(spec, 4, rng_impl="rbg")
    assert tf.m == rbg.m == 1200
    assert not graph.has_duplicates(rbg.edges)
    assert _es(tf.edges) != _es(rbg.edges)


# ------------------------------------------------------------- regressions

def test_degrees_empty_edge_array():
    """graph.degrees used to crash on asarray([]) (shape (0,) has no
    column axis); it must return zeros."""
    for empty in ([], np.zeros((0, 2), np.int64), np.asarray([])):
        d = graph.degrees(empty, 5)
        np.testing.assert_array_equal(d, np.zeros(5, np.int64))
    assert generate(GNP(n=50, p=0.0, seed=1), 2).degrees().sum() == 0


def test_edge_chunk_materialization():
    c = EdgeChunk(buffer=np.arange(10).reshape(5, 2), count=3)
    assert c.edges().shape == (3, 2)
    c = EdgeChunk(buffer=np.arange(10).reshape(5, 2),
                  mask=np.array([True, False, True, False, False]))
    assert c.edges().shape == (2, 2)
