"""SWA ring-buffer cache: teacher-forced decode through multiple window
wraps must match the full forward pass exactly (the ring's modular slot
arithmetic is the risky part)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T


@pytest.mark.parametrize("window,S", [(8, 24), (8, 8), (16, 20)])
def test_ring_decode_matches_full_forward(window, S):
    cfg = get_smoke_config("mixtral_8x7b").replace(window=window, n_experts=4)
    key = jax.random.key(2)
    params = T.model_init(key, cfg)
    B = 2
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, dtype=jnp.int32)
    pos = jnp.tile(jnp.arange(S, dtype=jnp.int32), (B, 1))

    h, _, _ = T.forward(params, cfg, {"tokens": toks, "positions": pos})
    full_logits = h @ params["embed"]["head"].astype(h.dtype)

    caches = T.caches_init(cfg, B, S, jnp.float32)
    # ring active iff the swa cache is window-sized
    step = jax.jit(lambda p, t, q, c: T.decode_step(p, cfg, t, q, c))
    outs = []
    for t in range(S):
        lg, caches = step(params, toks[:, t:t+1], pos[:, t:t+1], caches)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full_logits))) / float(jnp.max(jnp.abs(full_logits)))
    assert rel < 5e-3, rel  # MoE capacity differences only


def test_ring_prefill_then_decode():
    """Prefill S0 tokens (> window), then decode more — mixes the rolled
    prefill write with ring decode writes."""
    cfg = get_smoke_config("mixtral_8x7b").replace(window=8, n_experts=4)
    key = jax.random.key(3)
    params = T.model_init(key, cfg)
    B, S0, S1 = 2, 16, 6
    S = S0 + S1
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, dtype=jnp.int32)
    pos = jnp.tile(jnp.arange(S, dtype=jnp.int32), (B, 1))

    h, _, _ = T.forward(params, cfg, {"tokens": toks, "positions": pos})
    full_logits = h @ params["embed"]["head"].astype(h.dtype)

    caches = T.caches_init(cfg, B, S, jnp.float32)
    # NOTE: swa layers get a ring of size `window`; full prefill writes
    # the rolled last-window tokens
    batch = {"tokens": toks[:, :S0], "positions": pos[:, :S0]}
    _, _, caches = T.forward(params, cfg, batch, caches=caches)
    step = jax.jit(lambda p, t, q, c: T.decode_step(p, cfg, t, q, c))
    outs = []
    for t in range(S0, S):
        lg, caches = step(params, toks[:, t:t+1], pos[:, t:t+1], caches)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    rel = (float(jnp.max(jnp.abs(dec - full_logits[:, S0:])))
           / float(jnp.max(jnp.abs(full_logits))))
    assert rel < 5e-3, rel
