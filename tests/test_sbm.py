"""Stochastic block model (paper §Future-Work, delivered)."""
import numpy as np
import pytest

from repro.core import sbm
from repro.core.graph import has_duplicates, has_self_loops


@pytest.mark.parametrize("P", [1, 2, 4])
def test_sbm_union_invariant_in_P(P):
    e = sbm.sbm_union(3, n=400, B=8, p_in=0.1, p_out=0.005, P=P)
    e1 = sbm.sbm_union(3, n=400, B=8, p_in=0.1, p_out=0.005, P=1)
    np.testing.assert_array_equal(e, e1)  # regions are keyed by block ids


def test_sbm_no_dups_no_loops_and_canonical():
    e = sbm.sbm_union(5, n=300, B=6, p_in=0.2, p_out=0.01)
    assert not has_duplicates(e) and not has_self_loops(e)
    assert (e[:, 0] > e[:, 1]).all()


def test_sbm_block_densities():
    n, B, p_in, p_out = 1200, 4, 0.08, 0.01
    e = sbm.sbm_union(7, n, B, p_in, p_out)
    bi = sbm.block_of(n, B, e[:, 0])
    bj = sbm.block_of(n, B, e[:, 1])
    within = (bi == bj).sum()
    across = (bi != bj).sum()
    U_in = B * (n // B) * (n // B - 1) // 2
    U_out = (n * (n - 1) // 2) - U_in
    assert abs(within / U_in - p_in) < 0.01
    assert abs(across / U_out - p_out) < 0.002


def test_sbm_cross_pe_region_consistency():
    """Region (i, j) is recomputed identically by both owner PEs."""
    args = (9, 500, 6, 0.1, 0.02)
    a = {tuple(x) for x in sbm.sbm_pe(*args, P=3, pe=0)}
    b = {tuple(x) for x in sbm.sbm_pe(*args, P=3, pe=1)}
    shared = a & b
    assert shared, "cross-block regions must appear on both owners"
