"""Serving tier: plan-cache reseeds, packed mixed-request slabs,
continuous batching, fault reissue — all bit-identical to generate()."""
import dataclasses

import numpy as np
import pytest

from repro.api import (BA, GNM, GNP, RDG, RGG, RHG, RMAT, SBM, generate,
                       serve)
from repro.serve import PlanCache, Service, program_of, spec_shape

# ---------------------------------------------------------------- fixtures

def mixed_specs():
    """Eight families, distinct seeds — one of each shape."""
    return [
        GNM(n=128, m=400, seed=11),
        GNM(n=128, m=400, seed=12),            # same shape, new seed
        GNM(n=128, m=400, directed=True, seed=13),
        GNP(n=100, p=0.06, seed=5),
        BA(n=90, d=2, seed=3),
        RMAT(log_n=6, m=120, seed=9),
        SBM(n=96, blocks=3, p_in=0.2, p_out=0.02, seed=4),
        RGG(n=80, radius=0.2, seed=2),
        RHG(n=70, avg_deg=4.0, gamma=2.7, seed=8),
        RDG(n=40, seed=6),
    ]


def assert_graphs_equal(got, spec, P):
    ref = generate(spec, P)
    assert got.n == ref.n and got.directed == ref.directed
    np.testing.assert_array_equal(got.edges, ref.edges,
                                  err_msg=f"{spec} P={P}")


# ------------------------------------------------------- serve == generate

@pytest.mark.parametrize("P", [1, 2, 8])
def test_serve_matches_generate_mixed_families(P):
    """Concurrent mixed-family requests == per-request generate(),
    bit-for-bit, at several virtual PE counts."""
    specs = mixed_specs()
    svc = Service(P)
    for spec, g in zip(specs, svc.serve(specs)):
        assert_graphs_equal(g, spec, P)
    assert svc.stats["cache"]["hits"] >= 1  # the repeated GNM shape


def test_serve_64_concurrent_requests():
    """The acceptance-scale run: 64 concurrent requests across four
    families with distinct seeds, packed into shared slabs."""
    shapes = [
        lambda s: GNM(n=256, m=700, seed=s, chunks=8),
        lambda s: GNP(n=256, p=0.01, seed=s, chunks=8),
        lambda s: BA(n=128, d=2, seed=s),
        lambda s: RGG(n=96, radius=0.15, seed=s),
    ]
    specs = [shapes[i % 4](1000 + i) for i in range(64)]
    svc = Service(2, slab_batch=16)
    graphs = svc.serve(specs)
    for spec, g in zip(specs, graphs):
        assert_graphs_equal(g, spec, 2)
    st = svc.stats
    assert st["cache"]["hits"] == 60 and st["cache"]["misses"] == 4
    # packing really shares slabs: far fewer dispatches than slots
    assert st["slabs"] < st["slots"] / 4


def test_serve_function_front_door():
    specs = [GNM(n=64, m=100, seed=1), RGG(n=50, radius=0.25, seed=2)]
    for spec, g in zip(specs, serve(specs, 2)):
        assert_graphs_equal(g, spec, 2)


# ------------------------------------------------------------- plan cache

def test_spec_shape_excludes_seed():
    assert spec_shape(GNM(n=64, m=100, seed=1)) == spec_shape(
        GNM(n=64, m=100, seed=999))
    assert spec_shape(GNM(n=64, m=100, seed=1)) != spec_shape(
        GNM(n=64, m=101, seed=1))
    assert spec_shape(GNM(n=64, m=100, seed=1)) != spec_shape(
        GNP(n=64, p=0.1, seed=1))


def plans_equal(a, b):
    assert type(a) is type(b)
    for f in dataclasses.fields(a):
        if f.name == "reseed_fn":
            continue
        x, y = getattr(a, f.name), getattr(b, f.name)
        if isinstance(x, np.ndarray):
            np.testing.assert_array_equal(x, y, err_msg=f.name)
        else:
            assert x == y, (f.name, x, y)


@pytest.mark.parametrize("make", [
    lambda s: GNM(n=128, m=300, seed=s),
    lambda s: GNM(n=128, m=300, directed=True, seed=s),
    lambda s: GNP(n=100, p=0.05, seed=s),
    lambda s: BA(n=90, d=2, seed=s),
    lambda s: RMAT(log_n=6, m=120, seed=s),
    lambda s: SBM(n=96, blocks=3, p_in=0.2, p_out=0.02, seed=s),
    lambda s: RGG(n=80, radius=0.2, seed=s),
    lambda s: RHG(n=70, avg_deg=4.0, gamma=2.7, seed=s),
    lambda s: RDG(n=40, seed=s),
], ids=["gnm", "gnm-dir", "gnp", "ba", "rmat", "sbm", "rgg", "rhg", "rdg"])
def test_plan_cache_hit_reseed_equals_cold(make):
    """A cache hit reseeded to the request's seed == the cold plan for
    that seed, field by field — the tentpole invariant."""
    cache = PlanCache()
    cache.plan(make(7), 3, "threefry2x32")          # cold (miss)
    hot = cache.plan(make(8), 3, "threefry2x32")    # hit -> reseed
    assert cache.hits == 1 and cache.misses == 1
    plans_equal(hot, make(8).plan(3))


def test_plan_cache_lru_eviction():
    cache = PlanCache(capacity=2)
    for m in (100, 110, 120):
        cache.plan(GNM(n=64, m=m, seed=1), 1, "threefry2x32")
    assert cache.evictions == 1 and len(cache) == 2
    cache.plan(GNM(n=64, m=100, seed=2), 1, "threefry2x32")  # evicted: miss
    assert cache.misses == 4 and cache.hits == 0
    cache.plan(GNM(n=64, m=120, seed=3), 1, "threefry2x32")  # still warm
    assert cache.hits == 1


# -------------------------------------------------- packing & mixed slabs

def test_chunk_families_share_a_packing_group():
    """G(n,m) and BA rows execute under one slab program (KIND_*
    dispatch is per row), as do RGG and RHG rows (GEOM_* dispatch)."""
    a = program_of(GNM(n=128, m=300, seed=1).plan(2))
    b = program_of(BA(n=150, d=2, seed=2).plan(2))
    if a.capacity == b.capacity:  # same capacity class -> same program
        assert a.signature() == b.signature()
    assert a.kinds == b.kinds  # both lower the full sampled+BA dispatch
    g = program_of(RGG(n=80, radius=0.2, seed=1).plan(2))
    h = program_of(RHG(n=70, avg_deg=4.0, gamma=2.7, seed=2).plan(2))
    assert g.kinds == h.kinds  # HYP + TORUS in one program
    cert = program_of(RDG(n=40, seed=3).plan(2))
    assert cert.kinds != g.kinds  # CERT packs only with exact-capacity peers


# ------------------------------------------------- streaming & admission

def test_continuous_batching_preserves_chunk_order():
    """A request admitted mid-drain rides partially drained slabs, and
    both requests' chunk streams stay in per-request plan order."""
    first = GNM(n=256, m=900, seed=1, chunks=16)
    second = GNM(n=256, m=900, seed=2, chunks=16)
    svc = Service(2, slab_batch=4)
    t1 = svc.submit(first, sink="chunks")
    parts, t2 = [], None
    for i, chunk in enumerate(t1.chunks()):
        parts.append(chunk.edges())
        if i == 1:  # admit mid-stream, into partially drained queues
            t2 = svc.submit(second)
    np.testing.assert_array_equal(np.concatenate(parts),
                                  generate(first, 2).edges)
    assert_graphs_equal(t2.result(), second, 2)


def test_stats_sink_matches_graph():
    spec = SBM(n=96, blocks=3, p_in=0.2, p_out=0.02, seed=4)
    svc = Service(2)
    r = svc.submit(spec, sink="stats").result()
    g = generate(spec, 2)
    assert r["num_edges"] == g.m
    np.testing.assert_array_equal(r["degrees"], g.degrees())


def test_empty_request_yields_empty_graph():
    # m = 0 still enqueues its (count-0) chunk rows; the sink must
    # still produce a well-formed empty edge list.
    g = Service(1).submit(GNM(n=16, m=0, seed=1)).result()
    assert g.m == 0 and g.edges.shape == (0, 2)


# ----------------------------------------------------------- fault model

def test_fault_reissue_parity_multirow():
    """Killing a mesh row mid-slab reissues its slots onto survivors
    (reassign_after_failure) with bit-identical delivery.  Runs
    in-process when the host exposes >= 2 devices (CI forces 8 via
    XLA_FLAGS); the single-device case is covered by
    tests/test_distrib.py::test_failure_recovery_is_exact in a
    subprocess."""
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (covered by test_distrib subprocess)")
    specs = [GNM(n=256, m=800, seed=s, chunks=16) for s in range(3)] + \
            [RGG(n=96, radius=0.15, seed=9)]
    svc = Service(len(jax.devices()), slab_batch=4)
    tickets = [svc.submit(s) for s in specs]
    svc.inject_fault([0, 1], at_slab=1)
    svc.drain()
    assert svc.scheduler.reissued > 0
    for spec, t in zip(specs, tickets):
        assert_graphs_equal(t.result(), spec, len(jax.devices()))


# ------------------------------------------------- stats & observability

def test_stats_counts_requests_and_queue():
    """The operational stats the ISSUE calls out: submitted/completed/
    in-flight/queue depth, live at every point of the request cycle."""
    svc = Service(2, slab_batch=4)
    st = svc.stats
    assert st["submitted"] == 0 and st["completed"] == 0
    assert st["inflight"] == 0 and st["queue_depth"] == 0

    tickets = [svc.submit(GNM(n=128, m=400, seed=s, chunks=8))
               for s in range(3)]
    st = svc.stats
    assert st["submitted"] == 3 and st["completed"] == 0
    assert st["inflight"] == 3 and st["queue_depth"] > 0

    svc.drain()
    st = svc.stats
    assert st["completed"] == 3 and st["inflight"] == 0
    assert st["queue_depth"] == 0
    assert all(t.done for t in tickets)


def test_metrics_exposition_parses_and_counts():
    from repro.obs import parse_exposition

    svc = Service(2, slab_batch=4)
    svc.serve(mixed_specs())
    parsed = parse_exposition(svc.metrics())
    n = len(mixed_specs())
    assert parsed["repro_serve_requests_submitted_total"] == n
    assert parsed["repro_serve_requests_completed_total"] == n
    assert parsed["repro_serve_inflight_requests"] == 0
    assert parsed["repro_serve_slabs_total"] == svc.stats["slabs"]
    assert parsed["repro_serve_plan_cache_hits"] == svc.stats["cache"]["hits"]
    assert parsed["repro_serve_ticket_latency_seconds_count"] == n
    assert svc.latency_percentile(0.5) is not None


def test_ticket_latency_stamped_under_mid_drain_admission():
    """Latency must be admission-to-completion per ticket even when a
    request is admitted into a partially drained queue."""
    svc = Service(2, slab_batch=4)
    t1 = svc.submit(GNM(n=256, m=900, seed=1, chunks=16), sink="chunks")
    t2 = None
    for i, _ in enumerate(t1.chunks()):
        if i == 0:  # admit mid-stream
            t2 = svc.submit(GNM(n=128, m=300, seed=2, chunks=8))
    svc.drain()
    assert t2 is not None and t2.done
    assert t1.latency is not None and t1.latency >= 0
    assert t2.latency is not None and t2.latency >= 0
    # t2 was admitted strictly after t1 yet completed inside t1's drain;
    # its latency window must be its own, not the service's
    assert svc.stats["completed"] == 2


# ---------------------------------------------------- contracts & errors

def test_packed_slab_programs_pass_contracts():
    """The registered serve-family slab programs lower clean: zero
    collectives, and no nondeterministic RNG on the recompute (pair)
    path."""
    from repro.analyze.programs import iter_programs, scan_case

    reports = [scan_case(c, with_cost=False)
               for c in iter_programs(families=["serve"], kernels=False)]
    assert len(reports) == 2
    for r in reports:
        assert r.ok, (r.name, r.error, [f.detail for f in r.scan.findings])


def test_unknown_sink_rejected():
    with pytest.raises(TypeError):
        Service(1).submit(GNM(n=16, m=10, seed=1), sink="bogus")
