"""Import guard for ``hypothesis``: collection must never hard-fail.

``hypothesis`` is a declared dev dependency (requirements-dev.txt) and
is installed in CI, but some environments run the tier-1 suite without
it.  Importing from this module instead of ``hypothesis`` directly
keeps every non-property test collectable and runnable: when hypothesis
is absent, ``@given`` becomes a skip marker and ``st``/``settings``
become inert stand-ins.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised only without the dep
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _InertStrategies:
        def __getattr__(self, _name):
            def strategy(*_a, **_k):
                return None

            return strategy

    st = _InertStrategies()
