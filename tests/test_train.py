"""Training substrate: overfit sanity, grad-accum equivalence, checkpoint
round-trip + elastic restore, compression convergence, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import pipeline as D
from repro.distrib import compress as C
from repro.models import transformer as T
from repro.train import checkpoint as CK
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step


def _setup(arch="smollm_360m", seed=0):
    cfg = get_smoke_config(arch)
    params = T.model_init(jax.random.key(seed), cfg)
    opt_cfg = O.OptConfig(lr=1e-3, warmup=5, total_steps=200)
    return cfg, params, opt_cfg


def _data(cfg, nsteps=1):
    dc = D.DataConfig(vocab=cfg.vocab, seq_len=32, batch_per_shard=4, seed=3)
    return [
        {k: jnp.asarray(v) for k, v in D.make_batch(dc, s, 0).items()}
        for s in range(nsteps)
    ]


def test_loss_decreases_overfit():
    cfg, params, opt_cfg = _setup()
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _data(cfg)[0]
    opt = O.opt_init(params)
    losses = []
    for _ in range(30):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]
    assert np.isfinite(losses).all()


def test_grad_accum_matches_full_batch():
    cfg, params, opt_cfg = _setup()
    batch = _data(cfg)[0]
    s1 = jax.jit(make_train_step(cfg, opt_cfg, accum=1))
    s2 = jax.jit(make_train_step(cfg, opt_cfg, accum=2))
    p1, _, m1 = s1(params, O.opt_init(params), batch)
    p2, _, m2 = s2(params, O.opt_init(params), batch)
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
    )
    assert d < 2e-5, d  # identical up to reduction-order float noise


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg, params, opt_cfg = _setup()
    step = jax.jit(make_train_step(cfg, opt_cfg))
    opt = O.opt_init(params)
    data = _data(cfg, 6)
    for s in range(3):
        params, opt, _ = step(params, opt, data[s])
    CK.save(str(tmp_path), 3, {"params": params, "opt": opt},
            meta={"arch": cfg.name}, num_shards=4)
    # continue 3 more steps -> reference
    p_ref, o_ref = params, opt
    for s in range(3, 6):
        p_ref, o_ref, _ = step(p_ref, o_ref, data[s])
    # crash + restore (different shard count on restore side)
    like = {"params": params, "opt": opt}
    restored, manifest = CK.restore(str(tmp_path), like)
    assert manifest["step"] == 3 and manifest["meta"]["arch"] == cfg.name
    p2, o2 = restored["params"], restored["opt"]
    for s in range(3, 6):
        p2, o2, _ = step(p2, o2, data[s])
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    cfg, params, _ = _setup()
    for s in [1, 2, 3, 4]:
        CK.save(str(tmp_path), s, {"p": params}, keep=2)
    assert CK.latest_step(str(tmp_path)) == 4
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000003", "step_00000004"]


def test_async_checkpoint(tmp_path):
    cfg, params, _ = _setup()
    t = CK.save(str(tmp_path), 7, {"p": params}, background=True)
    t.join(60)
    restored, man = CK.restore(str(tmp_path), {"p": params})
    assert man["step"] == 7


def test_compression_error_feedback_convergence():
    """int8+EF training tracks the uncompressed run closely."""
    cfg, params, opt_cfg = _setup()
    batch = _data(cfg)[0]
    codec, zero_err = C.make_error_feedback_codec()
    err = zero_err(params)

    plain = jax.jit(make_train_step(cfg, opt_cfg))
    p1, o1 = params, O.opt_init(params)
    losses_plain = []
    for _ in range(15):
        p1, o1, m = plain(p1, o1, batch)
        losses_plain.append(float(m["loss"]))

    from repro.train.train_loop import make_train_step as mts
    p2, o2 = params, O.opt_init(params)
    losses_c = []

    def compressed_step(p, o, b, e):
        from repro.models.transformer import lm_loss
        (l, _), g = jax.value_and_grad(lambda pp: lm_loss(pp, cfg, b))(p, b), None
        return None

    # run compression inside the step via the compress hook
    state = {"err": err}

    def hook(grads):
        g2, state["err"] = codec(grads, state["err"])
        return g2

    comp = make_train_step(cfg, opt_cfg, compress=hook)  # not jitted (stateful hook)
    for _ in range(15):
        p2, o2, m = comp(p2, o2, batch)
        losses_c.append(float(m["loss"]))
    assert losses_c[-1] < losses_plain[0]          # it is learning
    assert abs(losses_c[-1] - losses_plain[-1]) < 0.35 * losses_plain[0]


def test_quantize_roundtrip_accuracy():
    x = jax.random.normal(jax.random.key(0), (1000,), jnp.float32)
    q, s = C._quantize(x)
    back = C._dequantize(q, s, x.shape)
    assert float(jnp.max(jnp.abs(back - x))) < float(jnp.max(jnp.abs(x))) / 100


def test_data_pipeline_determinism_and_elasticity():
    dc = D.DataConfig(num_shards=4, seed=9)
    a = D.make_batch(dc, 5, 2)
    b = D.make_batch(dc, 5, 2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = D.make_batch(dc, 6, 2)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # tokens in range
    assert a["tokens"].min() >= 0 and a["tokens"].max() < dc.vocab


def test_generation_runs():
    from repro.train.serve import generate
    cfg, params, _ = _setup("qwen3_0p6b")
    prompts = np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    out = generate(params, cfg, prompts, steps=5)
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < cfg.vocab).all()
