"""repro.stats: streaming analytics (per-PE accumulators, clustering
samplers) and the paper-§7 statistical validation gates.

The acceptance-scale tests run the real thing: chi-square of an ER
G(n,p) degree distribution against Binomial and an RHG power-law tail
fit against 2*alpha + 1 at n = 2^18 on 8 virtual PEs, streamed —
the edge list is never materialized.
"""
import numpy as np
import pytest

from repro.api import BA, GNM, GNP, RHG, RMAT, SBM, generate
from repro.stats import (
    collect,
    expected_model,
    validate,
)

SMALL_SPECS = [
    GNP(n=1024, p=0.01, seed=3),
    GNM(n=1024, m=4096, seed=7),
    GNM(n=512, m=3000, directed=True, seed=5),
    BA(n=512, d=3, seed=9),
    RHG(n=768, avg_deg=8, gamma=2.9, seed=1),
    SBM(n=600, blocks=6, p_in=0.05, p_out=0.005, seed=2),
]


# ------------------------------------------------------- collect correctness

@pytest.mark.parametrize("spec", SMALL_SPECS, ids=lambda s: type(s).__name__)
def test_collect_degrees_match_generate(spec):
    """Streamed accumulation == degrees of the materialized graph."""
    r = collect(spec, 4)
    g = generate(spec, 4)
    assert r.num_edges == g.m
    if spec.directed:
        out_deg = np.bincount(g.edges[:, 0], minlength=g.n)
        in_deg = np.bincount(g.edges[:, 1], minlength=g.n)
        np.testing.assert_array_equal(r.degree.degrees, out_deg)
        np.testing.assert_array_equal(r.in_degree.degrees, in_deg)
    else:
        np.testing.assert_array_equal(r.degree.degrees, g.degrees())
    assert r.degree.deg_sum == int(r.degree.degrees.sum())
    assert r.degree.deg_max == int(r.degree.degrees.max())


@pytest.mark.parametrize("spec", SMALL_SPECS, ids=lambda s: type(s).__name__)
def test_collect_P_invariant(spec):
    """collect(spec, P=1) == collect(spec, P=8) for exact metrics: the
    streamed multiset and the ownership split are both P-independent."""
    r1, r8 = collect(spec, 1), collect(spec, 8)
    assert r1.num_edges == r8.num_edges
    np.testing.assert_array_equal(r1.degree.degrees, r8.degree.degrees)
    np.testing.assert_array_equal(r1.degree.log2_hist, r8.degree.log2_hist)
    assert (r1.degree.deg_sum, r1.degree.deg_sumsq, r1.degree.deg_max) == \
           (r8.degree.deg_sum, r8.degree.deg_sumsq, r8.degree.deg_max)


def test_clustering_P_invariant_and_exact():
    """Sampled clustering is exact per sampled vertex (vs a brute-force
    adjacency matrix) and P-invariant (hashed deterministic sample)."""
    spec = GNP(n=300, p=0.05, seed=9)
    r = collect(spec, 2, metrics=("degree", "clustering"), cluster_samples=32)
    g = generate(spec, 1)
    adj = np.zeros((spec.n, spec.n), bool)
    adj[g.edges[:, 0], g.edges[:, 1]] = True
    adj |= adj.T
    cc = r.clustering
    for si, s in enumerate(cc.sample):
        nb = np.nonzero(adj[s])[0]
        assert len(nb) == cc.degree[si]
        assert adj[np.ix_(nb, nb)].sum() // 2 == cc.triangles[si]
    r8 = collect(spec, 8, metrics=("degree", "clustering"), cluster_samples=32)
    np.testing.assert_array_equal(cc.triangles, r8.clustering.triangles)
    np.testing.assert_array_equal(cc.degree, r8.clustering.degree)
    assert cc.global_cc == r8.clustering.global_cc


def test_clustering_neighbor_cap_is_hard_and_exact():
    """Samples past neighbor_cap drop their stored neighbors mid-stream
    (hard memory bound) but still report their exact degree, and are
    excluded from the estimate."""
    spec = GNP(n=400, p=0.05, seed=13)
    cap = 15
    r = collect(spec, 2, metrics=("degree", "clustering"),
                cluster_samples=48, neighbor_cap=cap)
    cc = r.clustering
    true_deg = generate(spec, 1).degrees()[cc.sample]
    np.testing.assert_array_equal(cc.degree, true_deg)  # exact even past cap
    assert (cc.degree > cap).any()  # the cap actually triggered
    np.testing.assert_array_equal(cc.valid, (cc.degree >= 2) & (cc.degree <= cap))
    assert (cc.triangles[~cc.valid] == 0).all()


def test_clustering_empty_sample_is_a_noop():
    """cluster_samples=0 must degrade to an empty (all-zero) report,
    not crash on empty-array indexing."""
    r = collect(GNP(n=128, p=0.05, seed=1), 2,
                metrics=("degree", "clustering"), cluster_samples=0)
    assert len(r.clustering.sample) == 0
    assert r.clustering.global_cc == 0.0 and r.clustering.mean_local_cc == 0.0


def test_vertex_ownership_owner_of_agrees_with_split():
    """The two VertexOwnership views are one convention: owner_of(v)
    names the section whose split() part contains v."""
    from repro.stats import VertexOwnership

    own = VertexOwnership(n=1000, P=7)
    ids = np.random.default_rng(0).integers(0, 1000, 400)
    owners = own.owner_of(ids)
    assert ((own.bounds[owners] <= ids) & (ids < own.bounds[owners + 1])).all()
    for pe, part in enumerate(own.split(ids)):
        np.testing.assert_array_equal(part, np.sort(ids[owners == pe]))


def test_clustering_requires_undirected():
    with pytest.raises(ValueError, match="undirected"):
        collect(BA(n=64, d=2, seed=1), 2, metrics=("degree", "clustering"))


def test_unknown_metric_rejected():
    with pytest.raises(ValueError, match="unknown metric"):
        collect(GNP(n=64, p=0.1, seed=1), 2, metrics=("degree", "pagerank"))


def test_binned_mode_drops_exact_array_keeps_exact_summaries():
    """The huge-n path: no O(n) degree array in the merged report, but
    the log2 histogram and moments stay exact (== the exact path's)."""
    spec = GNP(n=4096, p=0.004, seed=4)
    rb = collect(spec, 4, mode="binned")
    re = collect(spec, 4, mode="exact")
    assert rb.degree.degrees is None and re.degree.degrees is not None
    np.testing.assert_array_equal(rb.degree.log2_hist, re.degree.log2_hist)
    assert rb.degree.deg_sum == re.degree.deg_sum
    assert rb.degree.deg_max == re.degree.deg_max
    assert rb.degree.num_isolated == re.degree.num_isolated


def test_degree_counts_is_exact_histogram():
    spec = GNM(n=512, m=2048, seed=11)
    r = collect(spec, 4)
    np.testing.assert_array_equal(
        r.degree_counts(), np.bincount(r.degree.degrees))
    assert r.degree_counts().sum() == spec.n


# --------------------------------------------------- model validation gates

def test_validate_er_chi_square_vs_binomial_2_18():
    """Acceptance gate: ER G(n,p) at n=2^18 on 8 PEs — the exact degree
    distribution passes chi-square against Binomial(n-1, p), streamed."""
    rep = validate(GNP(n=1 << 18, p=20.0 / (1 << 18), seed=11), 8)
    assert rep.passed, str(rep)
    chi = next(c for c in rep.checks if c.name == "degree-chi2")
    assert chi.passed and chi.pvalue > 1e-3
    assert rep.stats.num_edges > 2_500_000  # actually at scale


def test_validate_rhg_tail_exponent_2_18():
    """Acceptance gate: RHG at n=2^18 on 8 PEs — fitted power-law tail
    exponent matches the closed form 2*alpha + 1 == gamma."""
    spec = RHG(n=1 << 18, avg_deg=6, gamma=2.7, seed=2)
    rep = validate(spec, 8, batch=512)
    assert rep.passed, str(rep)
    tail = next(c for c in rep.checks if c.name == "tail-exponent")
    assert tail.expected == pytest.approx(2.7)
    mean = next(c for c in rep.checks if c.name == "mean-degree")
    assert mean.observed == pytest.approx(6.0, rel=0.1)


@pytest.mark.parametrize("spec", [
    GNM(n=2048, m=8192, seed=5),
    BA(n=2048, d=4, seed=7),
    SBM(n=1500, blocks=5, p_in=0.03, p_out=0.003, seed=3),
    RMAT(log_n=11, m=16000, seed=1),
], ids=lambda s: type(s).__name__)
def test_validate_smoke_other_families(spec):
    rep = validate(spec, 4)
    assert rep.passed, str(rep)


def test_chi_square_rejects_wrong_law():
    """Power, not just level: the same degree counts that pass against
    the true Binomial law must *reject* a 1.3x-off one."""
    from scipy import stats as sps

    from repro.stats import chi_square_gof

    spec = GNP(n=4096, p=0.004, seed=3)
    obs = collect(spec, 4).degree_counts()
    k = np.arange(len(obs))
    right = spec.n * sps.binom.pmf(k, spec.n - 1, spec.p)
    wrong = spec.n * sps.binom.pmf(k, spec.n - 1, 1.3 * spec.p)
    assert chi_square_gof(obs, right).pvalue > 1e-3
    assert chi_square_gof(obs, wrong).pvalue < 1e-6


def test_ks_discrete_level_and_power():
    """The conservative KS companion gate: passes the true Binomial
    law, rejects a 1.5x-off one."""
    from scipy import stats as sps

    from repro.stats import ks_discrete

    spec = GNP(n=4096, p=0.004, seed=3)
    deg = collect(spec, 4).degree.degrees
    k = np.arange(deg.max() + 1)
    assert ks_discrete(deg, sps.binom.cdf(k, spec.n - 1, spec.p)).pvalue > 1e-3
    assert ks_discrete(deg, sps.binom.cdf(k, spec.n - 1, 1.5 * spec.p)).pvalue < 1e-6


def test_expected_model_pmfs_are_distributions():
    for spec in (GNP(n=256, p=0.05, seed=1), GNM(n=256, m=900, seed=1),
                 SBM(n=240, blocks=4, p_in=0.1, p_out=0.01, seed=1)):
        m = expected_model(spec, kmax=255)
        assert m.degree_pmf is not None
        assert m.degree_pmf.sum() == pytest.approx(1.0, abs=1e-6)
        mu = float((np.arange(len(m.degree_pmf)) * m.degree_pmf).sum())
        assert mu == pytest.approx(m.mean_degree, rel=0.01)


# ----------------------------------------------------- api front-door wiring

def test_api_reexports_collect_validate():
    from repro import api

    spec = GNP(n=256, p=0.03, seed=2)
    r = api.collect(spec, 2)
    assert r.num_edges == generate(spec, 2).m
    assert api.validate(spec, 2).passed


def test_edge_chunks_carry_owning_pe():
    """The engine's ownership masks are surfaced per streamed chunk."""
    from repro.api import iter_edge_chunks

    spec = GNM(n=512, m=4000, seed=3)
    pes = [c.pe for c in iter_edge_chunks(spec, 4)]
    assert set(pes) <= set(range(4)) and len(set(pes)) > 1
    rhg = RHG(n=400, avg_deg=6, gamma=2.8, seed=1)
    for batch in (1, 64):
        chunks = list(iter_edge_chunks(rhg, 4, batch=batch))
        assert all(c.pe in range(4) for c in chunks)
        per_pe = {}
        for c in chunks:  # per-PE order is exact on any device count
            per_pe.setdefault(c.pe, []).append(c.edges())
        streamed = np.concatenate(
            [e for pe in sorted(per_pe) for e in per_pe[pe]])
        np.testing.assert_array_equal(streamed, generate(rhg, 4).edges)
