"""RHG generator: exact oracle equivalence, plan consistency across PEs,
degree/power-law sanity (paper §7)."""
import math

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # guarded: collection never hard-fails

from repro.core import rhg
from repro.core.rhg import RHGParams, RHGPlan, RangeCounter


def _es(e):
    return {tuple(x) for x in np.asarray(e, np.int64)}


@pytest.mark.parametrize("P,gamma,deg", [(1, 2.6, 8), (4, 2.6, 8), (7, 3.0, 16), (4, 2.2, 4)])
def test_union_equals_bruteforce(P, gamma, deg):
    params = RHGParams(n=500, avg_deg=deg, gamma=gamma, seed=13 * P)
    r, t = rhg.rhg_all_vertices(params, P=P)
    brute = rhg.rhg_brute_edges(r, t, params.R)
    union = rhg.rhg_union(params, P=P)
    assert _es(brute) == _es(union)


def test_region_counts_partition_n():
    params = RHGParams(n=2000, avg_deg=10, gamma=2.5, seed=1)
    n_core, ann, bounds = rhg.region_counts(params)
    assert n_core + ann.sum() == params.n
    assert bounds[0] == pytest.approx(params.R / 2)
    assert bounds[-1] == pytest.approx(params.R)


def test_range_counter_consistency_and_offsets():
    a = RangeCounter(5, 99, 0, 64, 1000)
    b = RangeCounter(5, 99, 0, 64, 1000)
    counts = [a.cell_count(i) for i in range(64)]
    assert sum(counts) == 1000
    # independent instance, reverse query order -> same results
    for i in reversed(range(64)):
        assert b.cell_count(i) == counts[i]
    off = 0
    for i in range(64):
        assert a.cell_offset(i) == off
        off += counts[i]


def test_cell_vertices_recomputed_identically():
    params = RHGParams(n=800, avg_deg=8, gamma=2.7, seed=3)
    p1, p2 = RHGPlan(params, 4), RHGPlan(params, 4)
    for b in range(len(p1.annuli)):
        for cell in [0, 1, p1.annuli[b].cells - 1]:
            r1, t1, g1 = p1.cell_vertices(b, cell)
            r2, t2, g2 = p2.cell_vertices(b, cell)
            np.testing.assert_array_equal(r1, r2)
            np.testing.assert_array_equal(t1, t2)
            assert g1 == g2


def test_radial_distribution_matches_cdf():
    params = RHGParams(n=20000, avg_deg=10, gamma=2.5, seed=7)
    r, _ = rhg.rhg_all_vertices(params, P=1)
    # empirical CDF at a few radii vs analytic mu(B_r(0))
    for q in [0.6, 0.75, 0.9]:
        rr = q * params.R
        emp = (r < rr).mean()
        ana = rhg._cdf(params, rr)
        assert abs(emp - ana) < 0.01, (rr, emp, ana)


def test_degrees_follow_power_law_tail():
    params = RHGParams(n=4000, avg_deg=12, gamma=2.5, seed=11)
    e = rhg.rhg_union(params, P=1)
    deg = np.bincount(np.concatenate([e[:, 0], e[:, 1]]), minlength=params.n)
    # Hill-ish slope estimate on the tail
    tail = np.sort(deg[deg >= 10])
    if len(tail) > 100:
        logd = np.log(tail)
        gamma_hat = 1.0 + 1.0 / (logd.mean() - math.log(10))
        assert 2.0 < gamma_hat < 3.3, gamma_hat


def test_core_is_clique():
    params = RHGParams(n=1500, avg_deg=20, gamma=2.2, seed=5)
    plan = RHGPlan(params, 1)
    r, t = plan.core_vertices()
    if plan.n_core >= 2:
        e = rhg.rhg_brute_edges(r, t, params.R)
        assert len(e) == plan.n_core * (plan.n_core - 1) // 2


def test_each_edge_on_both_endpoint_pes():
    params = RHGParams(n=400, avg_deg=8, gamma=2.8, seed=23)
    P = 4
    per_pe, gids = [], []
    for pe in range(P):
        e, g, _, _ = rhg.rhg_pe(params, P, pe)
        per_pe.append(_es(e))
        gids.append(set(g.tolist()))
    assert set().union(*gids) == set(range(params.n))
    union = set().union(*per_pe)
    for (u, v) in union:
        for w in (u, v):
            holder = [i for i in range(P) if w in gids[i]]
            assert holder, (u, v)
            assert (u, v) in per_pe[holder[0]]


@given(st.integers(0, 10_000))
@settings(max_examples=5, deadline=None)
def test_property_determinism(seed):
    params = RHGParams(n=300, avg_deg=6, gamma=2.9, seed=seed)
    a = rhg.rhg_union(params, P=3)
    b = rhg.rhg_union(params, P=3)
    np.testing.assert_array_equal(a, b)


def test_avg_degree_close_to_target():
    params = RHGParams(n=3000, avg_deg=16, gamma=3.0, seed=2)
    e = rhg.rhg_union(params, P=1)
    avg = 2 * len(e) / params.n
    # Eq. 2 is asymptotic: allow a generous band at n=3000
    assert 0.6 * 16 < avg < 1.4 * 16, avg
