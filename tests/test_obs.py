"""repro.obs: tracer semantics, metrics exposition, phase attribution,
the lint-role carve-out, and the roofline join."""
import json
import os
import threading

import pytest

from repro import obs
from repro.analyze.lint import RULE_WALLCLOCK, lint_paths, role_of
from repro.api import BA, GNM, GNP, RMAT, SBM, generate
from repro.launch import roofline
from repro.launch.hlocost import HloCost

_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src", "repro")


# ---------------------------------------------------------------- tracer

def test_disabled_trace_is_shared_null_singleton():
    obs.disable()
    obs.tracer().clear()
    s1 = obs.trace("anything", phase="plan")
    s2 = obs.trace("else")
    assert s1 is obs.NULL_SPAN and s2 is obs.NULL_SPAN
    with s1:
        s1.set(ignored=True)
    obs.event("also-ignored", hit=True)
    assert obs.tracer().spans() == []


def test_spans_nest_with_parent_ids():
    with obs.capture() as tr:
        with obs.trace("outer", phase="plan"):
            with obs.trace("inner", phase="exec"):
                pass
        with obs.trace("sibling"):
            pass
    recs = {r.name: r for r in tr.spans()}
    assert recs["inner"].parent_id == recs["outer"].span_id
    assert recs["outer"].parent_id == 0
    assert recs["sibling"].parent_id == 0
    assert recs["inner"].dur_ns <= recs["outer"].dur_ns


def test_phase_totals_shadow_same_phase_descendants():
    with obs.capture() as tr:
        with obs.trace("plan/outer", phase="plan"):
            # a reseed emitter re-entering its cold emitter: the nested
            # plan span must not double-count
            with obs.trace("plan/inner", phase="plan"):
                pass
            with obs.trace("exec/inner", phase="exec"):
                pass
    totals = tr.phase_totals()
    recs = {r.name: r for r in tr.spans()}
    assert totals["plan_s"] == pytest.approx(recs["plan/outer"].seconds)
    assert totals["exec_s"] == pytest.approx(recs["exec/inner"].seconds)
    assert totals["sink_s"] == 0.0


def test_span_set_attaches_attrs_and_events_nest():
    with obs.capture() as tr:
        with obs.trace("work", phase="exec") as sp:
            sp.set(rows=7)
            obs.event("marker", hit=True)
    recs = {r.name: r for r in tr.spans()}
    assert recs["work"].attrs["rows"] == 7
    assert recs["marker"].instant
    assert recs["marker"].parent_id == recs["work"].span_id
    assert recs["marker"].seconds == 0.0


def test_tracer_thread_safety_separate_stacks():
    with obs.capture() as tr:
        def worker(i):
            with obs.trace(f"t{i}", phase="exec"):
                pass
        threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
        with obs.trace("main-span", phase="plan"):
            for t in threads:
                t.start()
            for t in threads:
                t.join()
    recs = {r.name: r for r in tr.spans()}
    # spans on other threads must not parent under the main thread's span
    for i in range(4):
        assert recs[f"t{i}"].parent_id == 0
    assert len({r.span_id for r in tr.spans()}) == len(tr.spans())


def test_export_chrome_schema(tmp_path):
    path = tmp_path / "trace.json"
    with obs.capture() as tr:
        with obs.trace("span", phase="exec", n=3):
            obs.event("evt", hit=False)
        tr.export_chrome(str(path))
    data = json.loads(path.read_text())
    evs = data["traceEvents"]
    assert {e["ph"] for e in evs} == {"X", "i"}
    x = next(e for e in evs if e["ph"] == "X")
    i = next(e for e in evs if e["ph"] == "i")
    assert x["name"] == "span" and x["cat"] == "exec" and x["dur"] >= 0
    assert set(x) >= {"name", "cat", "ph", "ts", "dur", "pid", "tid", "args"}
    assert x["args"]["n"] == 3
    assert i["s"] == "t"
    assert "phases" in data["otherData"]


def test_capture_restores_previous_tracer():
    obs.disable()
    before = obs.tracer()
    with obs.capture() as tr:
        assert obs.tracer() is tr and obs.is_enabled()
    assert obs.tracer() is before and not obs.is_enabled()


# ---------------------------------------------------------------- metrics

def test_counter_monotonic():
    c = obs.Counter("c")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_callback_reads_live():
    box = [1.0]
    g = obs.Gauge("g", fn=lambda: box[0])
    assert g.value == 1.0
    box[0] = 5.0
    assert g.value == 5.0


def test_histogram_buckets_and_percentile():
    h = obs.Histogram("h", buckets=(1.0, 10.0))
    for v in (0.5, 2.0, 20.0):
        h.observe(v)
    samples = dict(((n, labels), v) for n, labels, v in h.samples())
    assert samples[("h_bucket", (("le", "1"),))] == 1
    assert samples[("h_bucket", (("le", "10"),))] == 2
    assert samples[("h_bucket", (("le", "+Inf"),))] == 3
    assert samples[("h_count", ())] == 3
    assert h.percentile(0.5) == 2.0
    assert h.percentile(1.0) == 20.0


def test_registry_render_parse_round_trip():
    r = obs.Registry("x_")
    r.counter("reqs_total", "requests").inc(4)
    r.gauge("depth").set(2)
    r.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.05)
    parsed = obs.parse_exposition(r.render())
    assert parsed["x_reqs_total"] == 4
    assert parsed["x_depth"] == 2
    assert parsed['x_lat_seconds_bucket{le="0.1"}'] == 1
    assert parsed["x_lat_seconds_count"] == 1


def test_parse_exposition_rejects_untyped_samples():
    with pytest.raises(ValueError):
        obs.parse_exposition("mystery_metric 1\n")


def test_registry_get_or_create_idempotent():
    r = obs.Registry()
    assert r.counter("a") is r.counter("a")
    assert r.counter("a", labels={"k": "v"}) is not r.counter("a")


# ------------------------------------------------- end-to-end attribution

def test_generate_traced_has_all_three_phases():
    spec = GNM(n=128, m=300, seed=1)
    generate(spec, 2)  # warm compile so exec span times the cached fn
    with obs.capture() as tr:
        generate(spec, 2)
    names = {r.name for r in tr.spans()}
    assert "plan/gnm" in names and "run/exec" in names and "extract" in names
    totals = tr.phase_totals()
    assert totals["plan_s"] > 0 and totals["exec_s"] > 0 and totals["sink_s"] > 0


@pytest.mark.parametrize("spec,span", [
    (GNM(n=64, m=100, seed=1), "plan/gnm"),
    (GNP(n=64, p=0.05, seed=1), "plan/gnp"),
    (BA(n=32, d=2, seed=1), "plan/ba"),
    (RMAT(log_n=5, m=64, seed=1), "plan/rmat"),
    (SBM(n=48, blocks=2, p_in=0.2, p_out=0.05, seed=1), "plan/sbm"),
])
def test_every_family_opens_its_plan_span(spec, span):
    with obs.capture() as tr:
        spec.plan(2)
    assert span in {r.name for r in tr.spans()}


def test_reseed_span_shadows_inner_plan_span():
    spec = GNM(n=128, m=300, seed=1)
    plan = spec.plan(2)
    with obs.capture() as tr:
        plan.reseed(2)
    recs = {r.name: r for r in tr.spans()}
    assert recs["plan/reseed"].attrs["reseed"] is True
    assert tr.phase_totals()["plan_s"] == pytest.approx(
        recs["plan/reseed"].seconds)


def test_disabled_tracing_records_nothing_through_generate():
    obs.disable()
    obs.tracer().clear()
    generate(GNM(n=64, m=100, seed=3), 2)
    assert obs.tracer().spans() == []


def test_compile_cache_events_hit_and_miss():
    from repro.distrib import runtime

    spec = GNM(n=64, m=128, seed=5)
    runtime.cache_clear()
    try:
        with obs.capture() as tr:
            generate(spec, 2)
            generate(spec, 2)
        evs = [r for r in tr.spans() if r.name == "compile_cache"]
        assert [e.attrs["hit"] for e in evs] == [False, True]
    finally:
        runtime.cache_clear()


# ---------------------------------------------------------------- linting

def test_obs_role_is_wallclock_exempt():
    assert role_of("src/repro/obs/tracer.py") == "obs"
    assert role_of("src/repro/obs/metrics.py") == "obs"
    findings = lint_paths([os.path.join(_SRC, "obs")])
    assert [f for f in findings if f.rule == RULE_WALLCLOCK] == []


def test_instrumented_emitters_stay_lint_clean():
    findings = lint_paths([os.path.join(_SRC, "core"),
                           os.path.join(_SRC, "distrib")])
    assert findings == [], "\n".join(f.format() for f in findings)


# --------------------------------------------------------------- roofline

_TOY_HLO = """
HloModule toy

ENTRY %main (p0: f32[128,128], p1: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128] parameter(0)
  %p1 = f32[128,128] parameter(1)
  ROOT %dot = f32[128,128] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_roofline_floor_and_fraction():
    peaks = roofline.Peaks(flops_per_s=1e9, bytes_per_s=1e9)
    assert roofline.roofline_seconds(2e9, 1e9, peaks) == pytest.approx(2.0)
    assert roofline.achieved_fraction(2e9, 1e9, 4.0, peaks) == pytest.approx(0.5)
    assert roofline.achieved_fraction(2e9, 1e9, 0.0, peaks) is None


def test_program_summary_from_hlo_cost():
    cost = HloCost(_TOY_HLO)
    assert cost.flops == 2 * 128 * 128 * 128
    peaks = roofline.Peaks(flops_per_s=1e9, bytes_per_s=1e12)
    s = roofline.program_summary(cost, measured_s=cost.flops / 1e9 * 2, peaks=peaks)
    assert s["bound"] == "compute"
    assert s["achieved_fraction"] == pytest.approx(0.5)


def test_trace_summary_joins_spans_with_programs():
    with obs.capture() as tr:
        with obs.trace("run/exec", phase="exec"):
            pass
    out = roofline.trace_summary(
        tr, programs={"run": HloCost(_TOY_HLO)},
        peaks=roofline.Peaks(1e9, 1e12))
    assert set(out["phases"]) == {"plan_s", "exec_s", "sink_s"}
    prog = out["programs"]["run"]
    assert prog["flops"] == 2 * 128 ** 3
    assert prog["measured_s"] == pytest.approx(out["phases"]["exec_s"])


def test_default_peaks_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PEAK_FLOPS", "123.0")
    monkeypatch.setenv("REPRO_PEAK_BW", "456.0")
    p = roofline.default_peaks()
    assert p.flops_per_s == 123.0 and p.bytes_per_s == 456.0
