"""End-to-end distributed LM training on 8 host devices: the sharded
train step must RUN (not just lower) and match single-device numerics."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    snippet = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.data import pipeline as D
        from repro.models import pmesh, shardings as SH, transformer as T
        from repro.train import optimizer as O
        from repro.train.train_loop import make_train_step

        cfg = get_smoke_config("qwen3_0p6b")
        dc = D.DataConfig(vocab=cfg.vocab, seq_len=32, batch_per_shard=8, seed=5)
        batch_np = D.make_batch(dc, 0, 0)

        # single-device reference
        params = T.model_init(jax.random.key(0), cfg)
        step = jax.jit(make_train_step(cfg, O.OptConfig(lr=1e-3)))
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        p1, o1, m1 = step(params, O.opt_init(params), batch)
        ref_loss = float(m1["loss"])

        # 8-device sharded run
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh, pmesh.use_hints(mesh):
            params = T.model_init(jax.random.key(0), cfg)
            specs = SH.param_specs(jax.tree.map(lambda x: x, params), mesh, cfg)
            put = lambda x, s: jax.device_put(x, NamedSharding(mesh, s))
            params = jax.tree.map(put, params, specs,
                                  is_leaf=lambda x: hasattr(x, "dtype"))
            opt = O.opt_init(params)
            bspecs = SH.batch_specs(cfg, mesh, batch)
            batch_s = {k: put(jnp.asarray(v), bspecs[k]) for k, v in batch_np.items()}
            stepd = jax.jit(make_train_step(cfg, O.OptConfig(lr=1e-3)))
            p2, o2, m2 = stepd(params, opt, batch_s)
            dist_loss = float(m2["loss"])
            # second step to prove the state round-trips
            p2, o2, m3 = stepd(p2, o2, batch_s)

        assert abs(ref_loss - dist_loss) < 1e-3 * max(1.0, abs(ref_loss)), \
            (ref_loss, dist_loss)
        print("OK", ref_loss, dist_loss, float(m3["loss"]))
    """)
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout
