"""The legacy per-family union entry points are deprecated shims: they
must warn, and they must still produce exactly api.generate's output."""
import numpy as np
import pytest

from repro.api import BA, GNM, GNP, RMAT, generate
from repro.core import ba, er, rmat


def _es(e):
    return {tuple(x) for x in np.asarray(e, np.int64)}


@pytest.mark.parametrize("shim,args,spec,P", [
    (er.gnm_directed, (3, 100, 400), GNM(n=100, m=400, directed=True, seed=3, chunks=2), 2),
    (er.gnm_undirected, (5, 100, 300), GNM(n=100, m=300, seed=5, chunks=2), 2),
    (er.gnp_undirected, (7, 100, 0.05), GNP(n=100, p=0.05, seed=7, chunks=2), 2),
    (ba.ba_union, (9, 100, 3), BA(n=100, d=3, seed=9), 2),
    (rmat.rmat_union, (1, 8, 900), RMAT(log_n=8, m=900, seed=1), 2),
], ids=lambda x: getattr(x, "__name__", ""))
def test_shim_warns_and_matches_generate(shim, args, spec, P):
    with pytest.warns(DeprecationWarning, match="deprecated shim"):
        legacy = shim(*args, P)
    np.testing.assert_array_equal(legacy, generate(spec, P).edges)
    assert _es(legacy) == _es(generate(spec, P).edges)