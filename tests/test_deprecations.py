"""The legacy per-family union entry points are deprecated shims: they
must warn, and they must still produce exactly api.generate's output.
Same contract for the legacy shard.py sharded entry points, now
deprecated onto repro.distrib.runtime facades."""
import numpy as np
import pytest

from repro.api import BA, GNM, GNP, RMAT, generate
from repro.core import ba, er, rmat


def _es(e):
    return {tuple(x) for x in np.asarray(e, np.int64)}


@pytest.mark.parametrize("shim,args,spec,P", [
    (er.gnm_directed, (3, 100, 400), GNM(n=100, m=400, directed=True, seed=3, chunks=2), 2),
    (er.gnm_undirected, (5, 100, 300), GNM(n=100, m=300, seed=5, chunks=2), 2),
    (er.gnp_undirected, (7, 100, 0.05), GNP(n=100, p=0.05, seed=7, chunks=2), 2),
    (ba.ba_union, (9, 100, 3), BA(n=100, d=3, seed=9), 2),
    (rmat.rmat_union, (1, 8, 900), RMAT(log_n=8, m=900, seed=1), 2),
], ids=lambda x: getattr(x, "__name__", ""))
def test_shim_warns_and_matches_generate(shim, args, spec, P):
    with pytest.warns(DeprecationWarning, match="deprecated shim"):
        legacy = shim(*args, P)
    np.testing.assert_array_equal(legacy, generate(spec, P).edges)
    assert _es(legacy) == _es(generate(spec, P).edges)


# ------------------------------------------- legacy shard.py entry points

def test_run_gnm_directed_sharded_warns_and_matches_runtime():
    from repro.distrib import engine, runtime, shard

    seed, n, m = 7, 200, 900
    mesh = engine.default_mesh(1)
    with pytest.warns(DeprecationWarning, match="deprecated shim"):
        legacy, hlo = shard.run_gnm_directed_sharded(seed, n, m, mesh)
    assert not engine.collective_ops_in(hlo)
    plan = er.gnm_directed_plan(seed, n, m, 1)
    edges, keep, _ = runtime.run(plan, mesh)
    np.testing.assert_array_equal(legacy, np.asarray(edges)[np.asarray(keep)])
    # and the shim's instance is exactly the chunks=P api instance
    assert _es(legacy) == _es(
        generate(GNM(n=n, m=m, directed=True, seed=seed, chunks=1), 1).edges)


def test_gnm_directed_sharded_warns_and_executes():
    from repro.distrib import engine, shard

    mesh = engine.default_mesh(1)
    with pytest.warns(DeprecationWarning, match="deprecated shim"):
        fn, inputs = shard.gnm_directed_sharded(3, 100, 400, mesh)
    edges, keep = fn(*inputs)
    assert int(np.asarray(keep).sum()) == 400


def test_rgg_points_sharded_warns_and_matches_runtime():
    from repro.core import rgg
    from repro.distrib import engine, runtime, shard

    seed, n, r = 2, 500, 0.05
    mesh = engine.default_mesh(1)
    with pytest.warns(DeprecationWarning, match="deprecated shim"):
        fn, inputs = shard.rgg_points_sharded(seed, n, r, mesh)
    pts, mask = fn(*inputs)
    ref_pts, ref_mask, _ = runtime.run(rgg.rgg_point_plan(seed, n, r, 1, 2), mesh)
    np.testing.assert_array_equal(np.asarray(pts), np.asarray(ref_pts))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(ref_mask))
    assert int(np.asarray(mask).sum()) == n