"""RDG halo protocol vs periodic-DT oracle, BA chain resolution vs the
sequential Batagelj-Brandes fill, R-MAT distribution sanity."""
import itertools

import numpy as np
import pytest

from repro.core import ba, rdg, rgg, rmat


def _points_of(seed, n, P, dim):
    # same grid rdg_pe defaults to: the instance is a function of the
    # chunk grid, which is sized by default_chunk_P, not by P
    grid = rdg.rdg_grid(n, rdg.default_chunk_P(P, dim), dim)
    counter = rgg.CellCounter(seed, grid, n)
    cells = [tuple(c) for c in np.ndindex(*([grid.g] * dim))]
    pos, counts, offsets, _ = rgg.points_for_cells(seed, grid, counter, cells)
    pts = np.zeros((n, dim))
    for i, c in enumerate(cells):
        pts[offsets[i]: offsets[i] + counts[i]] = pos[i][: counts[i]]
    return pts


@pytest.mark.parametrize("n,P,dim", [(200, 1, 2), (300, 4, 2), (400, 9, 2), (200, 1, 3), (220, 8, 3)])
def test_rdg_union_matches_periodic_oracle(n, P, dim):
    """Exact equality up to Delaunay float non-uniqueness: Qhull lacks
    exact predicates (unlike the paper's CGAL), so near-cospherical quads
    may flip between the local and global triangulations.  We require the
    symmetric difference to be tiny (<= 0.3% of edges)."""
    seed = 101 + n + P
    pts = _points_of(seed, n, P, dim)
    brute = {tuple(x) for x in rdg.rdg_brute_edges(pts, dim)}
    union = {tuple(x) for x in rdg.rdg_union(seed, n, P, dim)}
    sym = brute ^ union
    assert len(sym) <= max(2, int(0.003 * len(brute))), (len(sym), len(brute))


def test_rdg_exact_match_typical_case():
    seed, n, P, dim = 318, 300, 4, 2
    pts = _points_of(seed, n, P, dim)
    brute = {tuple(x) for x in rdg.rdg_brute_edges(pts, dim)}
    union = {tuple(x) for x in rdg.rdg_union(seed, n, P, dim)}
    assert brute == union


def test_rdg_every_vertex_covered_and_degree_sane():
    seed, n, P, dim = 7, 400, 4, 2
    e = rdg.rdg_union(seed, n, P, dim)
    deg = np.bincount(e.ravel(), minlength=n)
    assert (deg >= 2).all()          # torus DT: no boundary, min degree >= 2
    assert abs(deg.mean() - 6.0) < 0.3  # Euler: avg degree -> 6 on the torus


def test_rdg_halo_rarely_expands():
    seed, n, P = 9, 500, 4
    expansions = [rdg.rdg_pe(seed, n, P, pe, 2)[2] for pe in range(P)]
    assert max(expansions) <= 1  # paper: "usually no repetitions at all"


# ----------------------------------------------------------------- BA

@pytest.mark.parametrize("n,d", [(64, 1), (128, 2), (200, 3)])
def test_ba_parallel_equals_sequential(n, d):
    seed = 5
    par = ba.ba_union(seed, n, d, P=4)
    seq = ba.ba_sequential_reference(seed, n, d)
    np.testing.assert_array_equal(par, seq)


def test_ba_pe_partition():
    seed, n, d, P = 3, 100, 2, 5
    pes = [ba.ba_pe(seed, n, d, P, pe) for pe in range(P)]
    allp = np.concatenate(pes)
    assert len(allp) == n * d
    assert (np.sort(allp[:, 0] * d + np.arange(len(allp)) % 1) >= 0).all()
    # sources partition [0, n)
    srcs = np.concatenate([np.unique(p[:, 0]) for p in pes])
    assert len(np.unique(srcs)) == n


def test_ba_degree_distribution_power_law():
    n, d = 3000, 2
    e = ba.ba_union(11, n, d, P=1)
    deg = np.bincount(e.ravel(), minlength=n)
    # preferential attachment: early vertices dominate
    assert deg[:10].mean() > 8 * deg[n // 2:].mean()
    tail = np.sort(deg[deg >= 8])
    assert len(tail) > 20


# ----------------------------------------------------------------- R-MAT

def test_rmat_shapes_and_partition():
    e = rmat.rmat_union(1, log_n=10, m=5000, P=4)
    assert e.shape == (5000, 2)
    assert e.min() >= 0 and e.max() < 1024


def test_rmat_quadrant_distribution():
    probs = (0.57, 0.19, 0.19, 0.05)
    e = rmat.rmat_union(2, log_n=12, m=40000, P=1, probs=probs)
    half = 1 << 11
    q = 2 * (e[:, 0] >= half) + (e[:, 1] >= half)
    freq = np.bincount(q, minlength=4) / len(e)
    for i, p in enumerate([probs[0], probs[1], probs[2], probs[3]]):
        assert abs(freq[i] - p) < 0.01, (i, freq[i], p)


def test_rmat_determinism_across_P():
    a = rmat.rmat_union(4, log_n=8, m=1000, P=1)
    b = rmat.rmat_union(4, log_n=8, m=1000, P=7)
    np.testing.assert_array_equal(a, b)  # P only splits the edge range
