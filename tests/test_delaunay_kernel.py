"""Batched device Delaunay kernel vs the scipy-Qhull oracle.

The kernel is the RDG emitter's production triangulator
(:func:`repro.kernels.delaunay.batched_delaunay`); Qhull survives only
as the test oracle here and in :mod:`repro.core.rdg`'s retained host
paths.  The contract under test:

* alive simplices of a row == the Delaunay triangulation of that row's
  points + its bounding super-simplex (super-free simplices match
  Qhull's exactly as sets of vertex-id frozensets);
* padded rows (count 0) stay inert;
* degenerate/cocircular inputs clear ``ok`` instead of emitting a wrong
  triangulation (the emitter then expands the halo);
* the Cramer circumsphere predicate is bit-identical between the
  kernel-side certificates, the host planner (`rdg.circumspheres`), and
  the engine's GEOM_CERT re-check (`engine._circumsphere_in_box`).
"""
import itertools

import numpy as np
import pytest
from scipy.spatial import Delaunay

from repro.core import rdg
from repro.kernels.delaunay import (batched_delaunay, cavity_capacity,
                                    group_size, simplex_capacity)


def _interior_sets(simp, alive, nb):
    """Super-free alive simplices as a set of vertex-id frozensets."""
    live = np.asarray(simp)[np.asarray(alive).astype(bool)]
    live = live[(live < nb).all(axis=1)]
    return {frozenset(map(int, s)) for s in live}


def _qhull_sets(pts):
    return {frozenset(map(int, s)) for s in Delaunay(pts).simplices}


def _rows(seed, B, nmax, dim):
    rng = np.random.default_rng(seed)
    counts = rng.integers(dim + 2, nmax + 1, size=B)
    pts = rng.random((B, nmax, dim))
    for i in range(B):
        pts[i, counts[i]:] = 0.0
    return pts, counts


# ------------------------------------------------------------- DT parity

@pytest.mark.parametrize("dim", [2, 3])
def test_batched_rows_match_qhull(dim):
    """Every row's super-free simplex set == Qhull on that row's points.

    (Random uniform rows: no exact cosphericality, so the floating
    Qhull triangulation is unique and comparable set-wise.)"""
    pts, counts = _rows(20 + dim, B=5, nmax=48 if dim == 2 else 32, dim=dim)
    simp, alive, ok = batched_delaunay(pts, counts, dim=dim)
    assert np.asarray(ok).all()
    for i in range(len(counts)):
        got = _interior_sets(simp[i], alive[i], counts[i])
        want = _qhull_sets(pts[i, : counts[i]])
        # the kernel drops super-incident simplices; Qhull has no super
        # point, so its hull-adjacent simplices may exceed `got` only by
        # ones whose circumsphere leaves the unit box (never certified)
        assert got <= want
        missing = want - got
        if missing:
            arr = np.array([pts[i, sorted(s)] for s in missing])
            _, rad = rdg.circumspheres(arr.reshape(-1, dim + 1, dim))
            assert (rad > 0.02).all()  # all near the hull, none interior


@pytest.mark.parametrize("dim", [2, 3])
def test_padded_rows_inert(dim):
    """count-0 rows stay ok and contribute nothing: only the bounding
    super-simplex stays alive, and every emitter-visible (super-free)
    simplex set is empty."""
    pts, counts = _rows(7, B=4, nmax=24, dim=dim)
    counts[1] = 0
    counts[3] = 0
    simp, alive, ok = batched_delaunay(pts, counts, dim=dim)
    assert np.asarray(ok).all()
    for i in (1, 3):
        assert _interior_sets(simp[i], alive[i], 0) == set()
        assert int(np.asarray(alive[i]).sum()) == 1  # the super simplex
    for i in (0, 2):  # real rows unaffected by their dead neighbors
        assert _interior_sets(simp[i], alive[i], counts[i]) == \
            _qhull_sets(pts[i, : counts[i]])


# ------------------------------------------------- degenerate inputs

def test_cocircular_square_fails_closed():
    """Four exactly-cocircular points: the in-sphere tie is undecidable
    in the abort-on-tie kernel, so the row must clear ``ok`` (the
    emitter's cue to expand the halo), never emit a wrong DT."""
    sq = np.array([[0.2, 0.2], [0.8, 0.2], [0.8, 0.8], [0.2, 0.8]])
    pts = sq[None, :, :]
    _, _, ok = batched_delaunay(pts, np.array([4]), dim=2)
    assert not np.asarray(ok).any()


def test_collinear_points_fail_closed():
    """A degenerate (zero-area) configuration cannot triangulate."""
    line = np.stack([np.linspace(0.1, 0.9, 5), np.full(5, 0.5)], axis=1)
    _, _, ok = batched_delaunay(line[None], np.array([5]), dim=2)
    assert not np.asarray(ok).any()


def test_coplanar_3d_emits_nothing():
    """All-coplanar 3d input: any super-free tetrahedron would be
    degenerate, so none may form — every alive simplex keeps a super
    vertex and the emitter-visible set stays empty (certification can
    then never accept a wrong simplex; the halo expands instead)."""
    rng = np.random.default_rng(0)
    flat = rng.random((8, 3))
    flat[:, 2] = 0.5
    simp, alive, _ = batched_delaunay(flat[None], np.array([8]), dim=3)
    assert _interior_sets(simp[0], alive[0], 8) == set()


# ------------------------------------- predicate bit-parity (3 sites)

@pytest.mark.parametrize("dim", [2, 3])
def test_circumsphere_predicate_bit_parity(dim):
    """kernel predicate == host planner == engine GEOM_CERT re-check,
    bit for bit: the communication-free invariant that lets the device
    re-certify host-planned simplices without disagreement."""
    import jax.numpy as jnp

    from repro.distrib import engine
    from repro.kernels.delaunay import circumsphere

    rng = np.random.default_rng(5 + dim)
    simp = rng.random((64, dim + 1, dim))
    c_host, r_host = rdg.circumspheres(simp)
    c_dev, r2_dev, nondeg = circumsphere(jnp.asarray(simp))
    assert np.asarray(nondeg).all()
    np.testing.assert_array_equal(c_host, np.asarray(c_dev))
    np.testing.assert_array_equal(r_host, np.sqrt(np.asarray(r2_dev)))

    lo, hi = np.zeros(dim), np.ones(dim)
    for s, c, r in zip(simp, c_host, r_host):
        want = bool(((c - r >= lo).all() & (c + r <= hi).all()))
        geom_a = np.zeros((dim + 1) * dim)
        geom_a[:] = s.ravel()
        geom_b = np.concatenate([lo, hi, np.ones((dim + 1) * dim - 2 * dim)])
        got = bool(np.asarray(engine._circumsphere_in_box(
            jnp.asarray(geom_a), jnp.asarray(geom_b), dim)))
        assert got == want


def test_degenerate_certificate_fails_containment():
    """det == 0 simplices get radius inf on the host and a cleared
    nondeg flag on device: both sides fail containment, forcing halo
    expansion rather than shipping an uncertifiable simplex."""
    import jax.numpy as jnp

    from repro.kernels.delaunay import circumsphere

    flat = np.array([[[0.1, 0.1], [0.5, 0.5], [0.9, 0.9]]])
    _, rad = rdg.circumspheres(flat)
    assert np.isinf(rad).all()
    _, _, nondeg = circumsphere(jnp.asarray(flat))
    assert not np.asarray(nondeg).any()


# ------------------------------------------------- pallas-vs-ref parity

@pytest.mark.parametrize("dim", [2, 3])
def test_pallas_harness_matches_ref(dim):
    """The pallas_call path (interpret mode on CPU) returns the same
    simplices/alive/ok as the jitted reference the production dispatch
    uses."""
    from repro.kernels.delaunay.delaunay import delaunay_call
    from repro.kernels.delaunay.ref import delaunay_ref

    pts, counts = _rows(3, B=2, nmax=16, dim=dim)
    N = pts.shape[1]
    S, CAV, G = simplex_capacity(N, dim), cavity_capacity(dim), group_size(dim)
    rs, ra, rk = delaunay_ref(pts, counts, dim=dim, num_simplices=S,
                              cavity=CAV, group=G)
    ps, pa, pk = delaunay_call(pts, counts, dim=dim, num_simplices=S,
                               cavity=CAV, group=G, interpret=True)
    np.testing.assert_array_equal(np.asarray(rs), np.asarray(ps))
    np.testing.assert_array_equal(np.asarray(ra), np.asarray(pa).astype(bool))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(pk).astype(bool))


# --------------------------------------- emitter-level device-DT parity

@pytest.mark.parametrize("P", [1, 2, 8])
@pytest.mark.parametrize("dim,n", [(2, 512), (3, 128)], ids=["2d", "3d"])
def test_emitter_device_dt_matches_qhull_oracle(dim, n, P):
    """End-to-end: the device-DT plan's executed edge set == the per-PE
    Qhull host-loop union, at P in {1, 2, 8}.  2d n=512 runs the
    batched-kernel rounds; 3d n=128 wraps the torus and exercises the
    Qhull-resume fallback, so both protocol paths are covered.

    The device edge set is P-invariant at every seed (the chunk grid is
    P-independent); the *host* union is not quite — Qhull lacks exact
    predicates, so a near-cocircular quad can flip with the PE's local
    point set (seed 31 at 2d n=512 P=8 gains one unpaired edge).  Seed
    29 has no such tie, so equality here is exact; the tolerance-based
    brute-oracle comparison lives in test_rdg_ba_rmat."""
    from repro.distrib import runtime

    seed = 29
    plan = rdg.rdg_pair_plan(seed, n, P, dim)
    payload, valid, _ = runtime.run(plan, check=False)
    got = set(map(tuple, np.asarray(payload)[
        np.asarray(valid).astype(bool)].reshape(-1, 2).tolist()))
    want = set(map(tuple, rdg.rdg_union(seed, n, P, dim).tolist()))
    assert got == want and len(got) > 0


def test_emitter_halo_expansion_on_failed_certification():
    """A chunk whose first device round fails certification expands and
    converges (the level-synchronous analog of the oracle's expansion
    loop); max_expand=0 turns the same instance into the convergence
    error."""
    st = rdg.RdgStructure(512, 2, 2, max_expand=8)
    # ring-2 start certifies in one round at this shape; shrink the
    # start to chunk+1 ring to force at least one in-protocol expansion
    st._init_regions = [set(c) | rdg._ring(c, 2) for c in st.chunk_cells]
    plan = st.emit(31)
    from repro.distrib import runtime
    payload, valid, _ = runtime.run(plan, check=False)
    got = set(map(tuple, np.asarray(payload)[
        np.asarray(valid).astype(bool)].reshape(-1, 2).tolist()))
    want = set(map(tuple, rdg.rdg_union(31, 512, 2, 2).tolist()))
    assert got == want

    tight = rdg.RdgStructure(512, 2, 2, max_expand=0)
    tight._init_regions = [set(c) | rdg._ring(c, 2) for c in tight.chunk_cells]
    with pytest.raises(RuntimeError, match="halo did not converge"):
        tight.emit(31)


def test_too_few_points_raises():
    with pytest.raises(ValueError, match="too few points"):
        rdg.rdg_pair_plan(0, 4, 1, 3)


# ----------------------------------------------------- reseed fast path

def test_rdg_reseed_equals_cold_field_by_field():
    """structure.emit is the plan's reseed_fn; reseeding to a new seed
    must equal the cold plan for that seed in every array field (the
    serve PlanCache contract), with no host re-triangulation beyond the
    device passes."""
    import dataclasses

    spec_plan = rdg.rdg_pair_plan(3, 256, 4, 2)
    reseeded = spec_plan.reseed_fn(9)
    cold = rdg.RdgStructure(256, 4, 2).emit(9)
    for f in dataclasses.fields(cold):
        if f.name == "reseed_fn":
            continue
        a, b = getattr(cold, f.name), getattr(reseeded, f.name)
        if isinstance(a, np.ndarray):
            np.testing.assert_array_equal(a, b, err_msg=f.name)
        else:
            assert a == b, f.name
