"""Vectorized plan emitters vs the retained per-chunk oracles, plus the
lazily segmented :class:`~repro.distrib.runtime.PlanEmitter` contract.

The level-synchronous emitters must produce plan tables *bit-identical*
to the per-chunk loop/recursion constructions they replaced — same rows,
same order, field by field — so the generated instance is provably
unchanged.  The oracles are retained in-tree (``*_specs``, the split
trees, ``undirected_chunks_for_pe`` …) precisely so these tests stay
honest: each one reconstructs the old plan the old way and diffs.

The overlap half checks the PlanEmitter ordering guarantee: an
overlapped stream regrouped per PE equals the non-overlapped stream,
and segment tables equal ``slice_plan`` of the full plan.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ba, er, rmat, sbm
from repro.core.chunking import (
    directed_counts_all,
    section_bounds,
    tri_size,
    undirected_chunks_for_pe,
)
from repro.core.prng import device_key, fold_in_many, host_rng
from repro.core.variates import binomial
from repro.distrib import runtime
from repro.distrib.engine import (
    KIND_BA,
    KIND_DIRECTED,
    KIND_RECT,
    KIND_RMAT,
    KIND_TRI,
    ChunkSpec,
    make_chunk_plan,
    slice_plan,
)

PS = (1, 2, 8)
CHUNK_FIELDS = ("kind", "key_data", "universe", "count", "params",
                "fparams", "owned")


def same_chunk_plan(a, b, tag):
    assert a.capacity == b.capacity and a.n == b.n, tag
    for f in CHUNK_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f"{tag}:{f}")


def same_plan_dataclass(a, b, tag):
    """Every dataclass field equal (reseed_fn excluded)."""
    for f in dataclasses.fields(a):
        if f.name == "reseed_fn":
            continue
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            assert va.shape == vb.shape, (tag, f.name, va.shape, vb.shape)
            np.testing.assert_array_equal(va, vb, err_msg=f"{tag}:{f.name}")
        else:
            assert va == vb, (tag, f.name, va, vb)


# ------------------------------------------------------------------- ER

def _chunk_spec(ch, cnt, kd, owned):
    if ch.kind == "tri":
        return ChunkSpec(KIND_TRI, kd, ch.universe, int(cnt),
                         (ch.rlo, 0, 0), owned)
    return ChunkSpec(KIND_RECT, kd, ch.universe, int(cnt),
                     (ch.chi - ch.clo, ch.rlo, ch.clo), owned)


def _loop_cross_plan(seed, n, rows):
    """The retired per-chunk undirected emitter: one ChunkSpec per
    oracle chunk, keys in flat emission order."""
    flat = [ch for row in rows for ch, _ in row]
    path = [np.array([ch.row_sec for ch in flat], np.int64),
            np.array([ch.col_sec for ch in flat], np.int64)]
    kd = er._chunk_key_data(seed, path)
    per_pe, i = [], 0
    for pe, row in enumerate(rows):
        specs = []
        for ch, c in row:
            specs.append(_chunk_spec(ch, c, kd[i], owned=ch.row_sec == pe))
            i += 1
        per_pe.append(specs)
    return make_chunk_plan(per_pe, n)


def _loop_directed_plan(seed, n, counts):
    P = len(counts)
    kd = er._chunk_key_data(seed, [np.arange(P, dtype=np.int64)])
    per_pe = []
    for pe in range(P):
        lo, hi = section_bounds(n, P, pe)
        per_pe.append([ChunkSpec(KIND_DIRECTED, kd[pe], (hi - lo) * (n - 1),
                                 int(counts[pe]), (lo, n, 0))])
    return make_chunk_plan(per_pe, n)


@pytest.mark.parametrize("P", PS)
def test_gnm_undirected_plan_matches_loop_oracle(P):
    seed, n, m = 5, 4096, 30000
    rows = [undirected_chunks_for_pe(seed, n, m, P, pe) for pe in range(P)]
    same_chunk_plan(er.gnm_undirected_plan(seed, n, m, P),
                    _loop_cross_plan(seed, n, rows), f"gnm-u P={P}")


@pytest.mark.parametrize("P", PS)
def test_gnp_undirected_plan_matches_loop_oracle(P):
    seed, n, p = 5, 4096, 0.003
    rows = [er.gnp_chunks_for_pe(seed, n, p, P, pe) for pe in range(P)]
    same_chunk_plan(er.gnp_undirected_plan(seed, n, p, P),
                    _loop_cross_plan(seed, n, rows), f"gnp-u P={P}")


@pytest.mark.parametrize("P", PS)
def test_gnm_directed_plan_matches_loop_oracle(P):
    seed, n, m = 5, 4096, 30000
    same_chunk_plan(er.gnm_directed_plan(seed, n, m, P),
                    _loop_directed_plan(seed, n,
                                        directed_counts_all(seed, n, m, P)),
                    f"gnm-d P={P}")


@pytest.mark.parametrize("P", PS)
def test_gnp_directed_plan_matches_loop_oracle(P):
    seed, n, p = 5, 4096, 0.003
    counts = []
    for pe in range(P):
        lo, hi = section_bounds(n, P, pe)
        counts.append(binomial(host_rng(seed, er._CHUNK_TAG, pe),
                               (hi - lo) * (n - 1), p))
    same_chunk_plan(er.gnp_directed_plan(seed, n, p, P),
                    _loop_directed_plan(seed, n, counts), f"gnp-d P={P}")


# ------------------------------------------------------------------ SBM

def _loop_sbm_plan(seed, n, B, p_in, p_out, P, rng_impl="threefry2x32"):
    """The retired region-loop SBM emitter."""
    regions = [(i, j) for i in range(B) for j in range(i + 1)]
    base = device_key(seed, sbm._TAG_SBM, impl=rng_impl)
    keys = fold_in_many(base, jnp.asarray([i for i, _ in regions],
                                          dtype=jnp.int64))
    keys = jax.vmap(jax.random.fold_in)(
        keys, jnp.asarray([j for _, j in regions], dtype=jnp.int64))
    kd = np.asarray(jax.vmap(jax.random.key_data)(keys))
    per_pe = [[] for _ in range(P)]
    for r, (i, j) in enumerate(regions):
        lo_i, hi_i = section_bounds(n, B, i)
        lo_j, hi_j = section_bounds(n, B, j)
        cnt = sbm._region_count(seed, n, B, i, j, p_in, p_out)
        if i == j:
            kind, U, params = KIND_TRI, tri_size(hi_i - lo_i), (lo_i, 0, 0)
        else:
            kind, U, params = (KIND_RECT, (hi_i - lo_i) * (hi_j - lo_j),
                               (hi_j - lo_j, lo_i, lo_j))
        per_pe[i % P].append(ChunkSpec(kind, kd[r], U, cnt, params,
                                       owned=True))
        if j % P != i % P:
            per_pe[j % P].append(ChunkSpec(kind, kd[r], U, cnt, params,
                                           owned=False))
    return make_chunk_plan(per_pe, n, rng_impl=rng_impl)


@pytest.mark.parametrize("P", PS)
def test_sbm_plan_matches_loop_oracle(P):
    for n, B in [(1000, 5), (1000, 8), (300, 3)]:
        same_chunk_plan(sbm.sbm_plan(7, n, B, 0.02, 0.001, P),
                        _loop_sbm_plan(7, n, B, 0.02, 0.001, P),
                        f"sbm n={n} B={B} P={P}")


# -------------------------------------------------------------- BA/RMAT

def _broadcast_key(seed, tag, P, rng_impl="threefry2x32"):
    one = np.asarray(jax.random.key_data(
        device_key(seed, tag, impl=rng_impl))).ravel()
    return np.broadcast_to(one, (P, one.size))


@pytest.mark.parametrize("P", PS)
def test_ba_plan_matches_loop_oracle(P):
    seed, n, d = 7, 1000, 4
    kd = _broadcast_key(seed, ba._TAG_BA, P)
    per_pe = []
    for pe in range(P):
        vlo, vhi = section_bounds(n, P, pe)
        per_pe.append([ChunkSpec(KIND_BA, kd[pe], 0, (vhi - vlo) * d,
                                 (d, vlo * d, 0))])
    same_chunk_plan(ba.ba_plan(seed, n, d, P),
                    make_chunk_plan(per_pe, n), f"ba P={P}")


@pytest.mark.parametrize("P", PS)
def test_rmat_plan_matches_loop_oracle(P):
    seed, log_n, m = 7, 10, 5000
    a, b, c, _ = (0.57, 0.19, 0.19, 0.05)
    kd = _broadcast_key(seed, rmat._TAG_RMAT, P)
    per_pe = []
    for pe in range(P):
        elo, ehi = section_bounds(m, P, pe)
        per_pe.append([ChunkSpec(KIND_RMAT, kd[pe], 0, ehi - elo,
                                 (log_n, elo, 0),
                                 fparams=(float(a), float(b), float(c)))])
    same_chunk_plan(rmat.rmat_plan(seed, log_n, m, P),
                    make_chunk_plan(per_pe, 1 << log_n), f"rmat P={P}")


# -------------------------------------------------------------- RGG/RHG

@pytest.mark.parametrize("P", PS)
def test_rgg_plans_match_spec_oracles(P):
    from repro.core import rgg

    for n, r, dim in [(2000, 0.05, 2), (1500, 0.08, 3)]:
        new = rgg.rgg_pair_plan(5, n, r, P, dim)
        old = rgg.rgg_pair_plan_specs(5, n, r, P, dim)
        same_plan_dataclass(new, old, f"rgg-pair {n} {dim} P={P}")
        grid = rgg.make_grid(n, r, P, dim)
        oldp = rgg.grid_point_plan(5, grid, rgg.CellCounter(5, grid, n), P)
        same_plan_dataclass(rgg.rgg_point_plan(5, n, r, P, dim), oldp,
                            f"rgg-pt {n} {dim} P={P}")


def test_rhg_range_table_matches_counter():
    from repro.core import rhg

    for units, total, seed in [(1, 7, 0), (5, 100, 1), (64, 1000, 2),
                               (37, 0, 3)]:
        ctr = rhg.RangeCounter(seed, rhg._TAG_CELLS_ENG, 2, units, total)
        c, o = rhg._range_table(seed, rhg._TAG_CELLS_ENG, 2, units, total)
        for i in range(units):
            assert c[i] == ctr.cell_count(i), (units, total, i)
            assert o[i] == ctr.cell_offset(i), (units, total, i)


@pytest.mark.parametrize("P", PS)
def test_rhg_pair_plan_matches_spec_oracle(P):
    from repro.core import rhg

    params = rhg.RHGParams(n=1000, avg_deg=8.0, gamma=2.8, seed=9)
    cells, ring_lo = rhg.rhg_engine_cells(params)
    t = rhg.rhg_engine_table(params)
    assert len(cells) == len(t.ring)
    for i, c in enumerate(cells):
        assert (c.ring, c.cell, c.clo, c.chi, c.width, c.count, c.gid0) == \
            (t.ring[i], t.cell[i], t.clo[i], t.chi[i], t.width[i],
             t.count[i], t.gid0[i]), i
        np.testing.assert_array_equal(c.key_data, t.key_data[i])
    np.testing.assert_array_equal(np.asarray(ring_lo), t.ring_lo)
    same_plan_dataclass(rhg.rhg_pair_plan(params, P),
                        rhg.rhg_pair_plan_specs(params, P), f"rhg P={P}")


# ------------------------------------------------------------------ RDG

def _rdg_edges(plan):
    from repro.distrib import runtime

    payload, valid, _ = runtime.run(plan, check=False)
    e = np.asarray(payload)[np.asarray(valid).astype(bool)].reshape(-1, 2)
    return set(map(tuple, e.tolist()))


def test_rdg_pair_plan_matches_spec_oracle():
    """The batched device emitter vs the scalar Qhull designation walk.

    The two paths may certify a chunk at different halo sizes and pick
    different designated simplices per edge (the device protocol starts
    at ring 2 and drops super-incident simplices), so the plan *tables*
    are not comparable row-for-row — the executed edge *sets* are the
    contract, and they must be exactly equal (both paths only ever ship
    certified global-DT simplices)."""
    from repro.core import rdg

    for n, dim, seed in [(600, 2, 3), (400, 3, 1)]:
        for P in (1, 2, 8):
            newP = rdg.rdg_pair_plan(seed, n, P, dim, chunk_P=16)
            oldP = rdg.rdg_pair_plan_specs(seed, n, P, dim, chunk_P=16)
            assert _rdg_edges(newP) == _rdg_edges(oldP), (n, dim, P)
            assert newP.fill_fraction >= 0.85, (n, dim, P,
                                                newP.fill_fraction)


# --------------------------------------------------------------- reseed

def test_reseed_equals_cold_plan():
    from repro.core import rgg, rhg

    n, m, p = 4096, 30000, 0.003
    for fn in (lambda s: er.gnm_undirected_plan(s, n, m, 8),
               lambda s: er.gnm_directed_plan(s, n, m, 8),
               lambda s: er.gnp_undirected_plan(s, n, p, 8),
               lambda s: sbm.sbm_plan(s, 1000, 8, 0.02, 0.001, 8),
               lambda s: ba.ba_plan(s, 1000, 4, 8),
               lambda s: rmat.rmat_plan(s, 10, 5000, 8)):
        same_chunk_plan(fn(1).reseed(9), fn(9), "reseed")
    same_plan_dataclass(rgg.rgg_pair_plan(1, 2000, 0.05, 8, 2).reseed(9),
                        rgg.rgg_pair_plan(9, 2000, 0.05, 8, 2), "rgg-re")
    params = rhg.RHGParams(n=1000, avg_deg=8.0, gamma=2.8, seed=1)
    same_plan_dataclass(
        rhg.rhg_pair_plan(params, 8).reseed(9),
        rhg.rhg_pair_plan(dataclasses.replace(params, seed=9), 8), "rhg-re")


# ------------------------------------------- PlanEmitter: lazy segments

def test_segment_bounds_cover_and_align():
    em = runtime.PlanEmitter(16, lambda lo, hi: None, segments=5)
    for D in (1, 2, 4):
        bounds = em.segment_bounds(D)
        assert bounds[0][0] == 0 and bounds[-1][1] == 16
        for (lo, hi), (lo2, _) in zip(bounds, bounds[1:]):
            assert hi == lo2
        assert all((hi - lo) % D == 0 for lo, hi in bounds)
    with pytest.raises(ValueError):
        runtime.PlanEmitter(6, lambda lo, hi: None).segment_bounds(4)


def test_sbm_plan_segment_matches_slice_of_full_plan():
    """The native lazy SBM segment build == ``slice_plan`` of the full
    plan, field by field (capacity may be segment-local — per-slot
    draws are capacity-independent, so generated edges are identical
    either way)."""
    for P, B, n, seed in [(8, 16, 4000, 3), (4, 10, 1000, 0)]:
        full = sbm.sbm_plan(seed, n, B, 0.02, 0.001, P)
        for lo, hi in [(0, P), (0, P // 2), (P // 2, P), (1, 2)]:
            seg = sbm.sbm_plan_segment(seed, n, B, 0.02, 0.001, P, lo, hi)
            ref = slice_plan(full, lo, hi)
            for f in dataclasses.fields(ref):
                if f.name in ("reseed_fn", "capacity"):
                    continue
                a, b = getattr(ref, f.name), getattr(seg, f.name)
                if not isinstance(a, np.ndarray):
                    assert a == b, (f.name, a, b)
                elif a.shape == b.shape:
                    np.testing.assert_array_equal(a, b, err_msg=f.name)
                else:  # differing slot capacity: common prefix + dead tail
                    C = min(a.shape[1], b.shape[1])
                    np.testing.assert_array_equal(a[:, :C], b[:, :C],
                                                  err_msg=f.name)
                    tail = a[:, C:] if a.shape[1] > C else b[:, C:]
                    assert not tail.any(), (f.name, "tail")


def test_rdg_plan_segment_matches_slice_of_full_plan():
    """The native lazy RDG segment build == ``slice_plan`` of the full
    plan, field by field.  The per-seed device triangulation runs once
    (cached on the planning structure); each segment re-deals its PE
    slice of the same certified-simplex columns.  The rectangular slot
    width may differ (a segment deals only its own rows), so the
    contract is prefix equality + an *inactive* tail — RDG's geometry
    tables pad with the table fill (1.0), not zeros, so the dead tail
    is defined by ``active``, not by value."""
    from repro.core import rdg

    for P, n, dim, seed in [(8, 400, 2, 3), (4, 300, 3, 1)]:
        full = rdg.rdg_pair_plan(seed, n, P, dim)
        for lo, hi in [(0, P), (0, P // 2), (P // 2, P), (1, 2)]:
            seg = rdg.rdg_plan_segment(seed, n, P, lo, hi, dim)
            ref = slice_plan(full, lo, hi)
            C = min(ref.active.shape[1], seg.active.shape[1])
            wide = ref if ref.active.shape[1] > C else seg
            assert not wide.active[:, C:].any(), "tail slots must be dead"
            for f in dataclasses.fields(ref):
                if f.name in ("reseed_fn", "capacity"):
                    continue
                a, b = getattr(ref, f.name), getattr(seg, f.name)
                if not isinstance(a, np.ndarray):
                    assert a == b, (f.name, a, b)
                else:
                    np.testing.assert_array_equal(a[:, :C], b[:, :C],
                                                  err_msg=f.name)


def _regrouped(stream, P):
    """Per-PE payload streams from a stream_slots iterator."""
    per_pe = [[] for _ in range(P)]
    for pe, slots, payload, valid in stream:
        per_pe[pe].append((np.asarray(slots).copy(),
                           np.asarray(payload).copy(),
                           np.asarray(valid).copy()))
    return per_pe


def test_overlapped_stream_regroups_to_plan_order():
    """stream_waves(PlanEmitter) == stream_waves(plan), regrouped per
    PE: same slots, same payloads, same per-PE order."""
    P = 8
    plan = sbm.sbm_plan(3, 2000, 16, 0.02, 0.001, P)
    ref = _regrouped(runtime.stream_slots(plan), P)
    ovl = _regrouped(
        runtime.stream_slots(runtime.PlanEmitter.from_plan(plan, 4)), P)
    for pe in range(P):
        assert len(ref[pe]) == len(ovl[pe]), pe
        for (s0, p0, v0), (s1, p1, v1) in zip(ref[pe], ovl[pe]):
            np.testing.assert_array_equal(s0, s1)
            np.testing.assert_array_equal(v0, v1)
            np.testing.assert_array_equal(p0[v0], p1[v1])


def test_overlap_records_plan_spans():
    from repro import obs

    plan = sbm.sbm_plan(3, 1000, 8, 0.02, 0.001, 8)
    with obs.capture() as tr:
        for _ in runtime.stream_slots(runtime.PlanEmitter.from_plan(plan, 4)):
            pass
    names = [s.name for s in tr.spans()]
    assert names.count("plan/overlap") == 4, names


@pytest.mark.parametrize("family", ["sbm", "gnm", "rgg"])
def test_iter_edge_chunks_overlap_bit_identity(family):
    """End-to-end: overlapped iter_edge_chunks regrouped per PE equals
    generate(spec, P).edges — native SBM segments, fallback GNM/RGG."""
    from repro.api import GNM, RGG, SBM, generate, iter_edge_chunks

    spec = {"sbm": SBM(n=2000, blocks=16, p_in=0.02, p_out=0.001, seed=3),
            "gnm": GNM(n=3000, m=9000, seed=3),
            "rgg": RGG(n=1500, radius=0.05, seed=3)}[family]
    P = 8
    want = generate(spec, P).edges
    per_pe = [[] for _ in range(P)]
    for ch in iter_edge_chunks(spec, P, overlap=4):
        per_pe[ch.pe].append(ch.edges())
    flat = [e for row in per_pe for e in row if len(e)]
    got = np.concatenate(flat) if flat else np.zeros((0, 2), np.int64)
    np.testing.assert_array_equal(got, want)


def test_service_overlap_bit_identity():
    """Scheduler emitter admission: overlapped submit == generate."""
    from repro.api import SBM, generate
    from repro.serve import Service

    spec = SBM(n=2000, blocks=16, p_in=0.02, p_out=0.001, seed=5)
    svc = Service(P=8)
    t = svc.submit(spec, overlap=4)
    g = t.result()
    np.testing.assert_array_equal(g.edges, generate(spec, 8).edges)
    # mixed: an overlapped and a cached request drain together
    t2 = svc.submit(spec, overlap=2)
    t3 = svc.submit(spec)
    svc.drain()
    np.testing.assert_array_equal(t2.result().edges, g.edges)
    np.testing.assert_array_equal(t3.result().edges, g.edges)
