"""The roofline cost walker itself: synthetic HLO parsing + a real
lowering cross-check against hand-counted FLOPs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlocost import HloCost, split_computations

SYNTH = """\
HloModule test

%body.1 (p: (s64[], f32[8,8])) -> (s64[], f32[8,8]) {
  %p = (s64[], f32[8,8]) parameter(0)
  %i = s64[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add.2
  ROOT %t = (s64[], f32[8,8]) tuple(%i, %ar)
}

%cond.1 (p: (s64[], f32[8,8])) -> pred[] {
  %p = (s64[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main.1 (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %init = (s64[], f32[8,8]) tuple(%a, %a)
  %w = (s64[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_while_weighting():
    hc = HloCost(SYNTH)
    # dot: 2*8*8*8 = 1024 flops, x10 trips
    assert hc.flops == 1024 * 10
    assert hc.collectives["all-reduce"]["count"] == 10
    assert hc.collectives["all-reduce"]["bytes"] == 8 * 8 * 4 * 10
    # bytes: only the dot materializes (result 256 B + operands 512 B),
    # x10 trips; tuples/GTEs/parameters are zero-copy
    assert hc.bytes == (256 + 512) * 10


def test_split_computations_finds_entry():
    comps, entry = split_computations(SYNTH)
    assert entry == "main.1"
    assert "body.1" in comps and "cond.1" in comps


def test_real_lowering_matches_hand_count():
    """jit(x @ w) for [64,128]x[128,256]: 2*64*128*256 flops."""
    f = jax.jit(lambda x, w: x @ w)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    hlo = f.lower(x, w).compile().as_text()
    hc = HloCost(hlo)
    want = 2 * 64 * 128 * 256
    assert abs(hc.flops - want) <= 0.05 * want, (hc.flops, want)


def test_from_lowered_compiles_and_counts():
    """HloCost.from_lowered bridges the IR dialect gap: a
    ``jax.stages.Lowered`` carries StableHLO text (which the HLO walker
    cannot parse), so from_lowered compiles it first and walks the
    optimized HLO.  Exact counts for [64,128]@[128,256]:"""
    f = jax.jit(lambda x, w: x @ w)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    hc = HloCost.from_lowered(f.lower(x, w))
    assert hc.flops == 2 * 64 * 128 * 256
    # operands (32 KiB + 128 KiB) + result (64 KiB)
    assert hc.bytes == (64 * 128 + 128 * 256 + 64 * 256) * 4
    s = hc.summary()
    assert s["flops"] == hc.flops and s["bytes"] == hc.bytes
    assert s["collectives"] == {}
    # an already-Compiled object is accepted as-is
    hc2 = HloCost.from_lowered(f.lower(x, w).compile())
    assert hc2.flops == hc.flops


def test_scan_flops_weighted_by_trips():
    def step(c, _):
        return c @ c, None

    def fn(x):
        y, _ = jax.lax.scan(step, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    hlo = jax.jit(fn).lower(x).compile().as_text()
    hc = HloCost(hlo)
    want = 7 * 2 * 32 * 32 * 32
    assert abs(hc.flops - want) <= 0.1 * want, (hc.flops, want)
