"""Per-architecture smoke tests (assignment deliverable f): a REDUCED
config of each family runs one forward/train step on CPU; output shapes
+ no NaNs; decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import transformer as T


def _batch(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, dtype=jnp.int32)
    batch = {
        "positions": jnp.tile(jnp.arange(S, dtype=jnp.int32), (B, 1)),
        "labels": jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                     cfg.vocab, dtype=jnp.int32),
    }
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32) * 0.02
    else:
        batch["tokens"] = toks
    return batch, toks


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.key(0)
    params = T.model_init(key, cfg)
    batch, _ = _batch(cfg, key)

    loss, metrics = jax.jit(lambda p, b: T.lm_loss(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 3 * np.log(cfg.vocab)

    # one SGD step must reduce nothing NaN and change params
    grads = jax.jit(jax.grad(lambda p, b: T.lm_loss(p, cfg, b)[0]))(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch)
    if not cfg.causal:
        pytest.skip("encoder-only: no decode step")
    key = jax.random.key(1)
    params = T.model_init(key, cfg)
    B, S = 2, 16
    batch, toks = _batch(cfg, key, B, S)
    batch.pop("labels")
    if cfg.frontend != "none":
        # decode embeds generated tokens via the table — match it in prefill
        batch["embeds"] = params["embed"]["tok"].astype(jnp.float32)[toks]
    h, _, _ = T.forward(params, cfg, batch)
    full_logits = h @ params["embed"]["head"].astype(h.dtype)

    caches = T.caches_init(cfg, B, S, jnp.float32)
    step = jax.jit(lambda p, t, q, c: T.decode_step(p, cfg, t, q, c))
    outs = []
    pos = batch["positions"]
    for t in range(S):
        lg, caches = step(params, toks[:, t: t + 1], pos[:, t: t + 1], caches)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    # MoE archs: capacity-based routing differs slightly between batch sizes
    tol = 5e-3 if cfg.moe else 1e-4
    rel = float(jnp.max(jnp.abs(dec - full_logits))) / float(jnp.max(jnp.abs(full_logits)))
    assert rel < tol, rel


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_spec(arch):
    """Full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    spec = {
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 102400),
        "mixtral_8x7b": (32, 4096, 32, 8, 32000),
        "qwen2_vl_72b": (80, 8192, 64, 8, 152064),
        "smollm_360m": (32, 960, 15, 5, 49152),
        "granite_20b": (52, 6144, 48, 1, 49152),
        "gemma3_27b": (62, 5376, 32, 16, 262144),
        "qwen3_0p6b": (28, 1024, 16, 8, 151936),
        "jamba_v0_1_52b": (32, 4096, 32, 8, 65536),
        "hubert_xlarge": (48, 1280, 16, 16, 504),
        "mamba2_2p7b": (64, 2560, 1, 1, 50280),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.vocab) == spec


def test_param_counts_are_plausible():
    """Sanity: approximate parameter counts near the advertised sizes."""
    expect = {
        "deepseek_v2_lite_16b": (12e9, 20e9),
        "mixtral_8x7b": (40e9, 50e9),
        "qwen2_vl_72b": (65e9, 80e9),
        "smollm_360m": (0.3e9, 0.5e9),
        # granite-code uses a 2-matrix MLP; our uniform SwiGLU stack (3
        # matrices at the assigned d_ff) lands at ~28B
        "granite_20b": (18e9, 30e9),
        "gemma3_27b": (24e9, 32e9),
        "qwen3_0p6b": (0.5e9, 0.85e9),
        "jamba_v0_1_52b": (45e9, 58e9),
        "hubert_xlarge": (0.9e9, 1.3e9),
        "mamba2_2p7b": (2.4e9, 3.1e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n / 1e9)


def test_layer_patterns():
    gem = get_config("gemma3_27b")
    kinds = [gem.layer_attn_kind(i) for i in range(12)]
    assert kinds == ["swa"] * 5 + ["full"] + ["swa"] * 5 + ["full"]
    jam = get_config("jamba_v0_1_52b")
    assert [jam.layer_kind(i) for i in range(8)] == ["ssm"] * 4 + ["attn"] + ["ssm"] * 3
    assert sum(jam.layer_kind(i) == "attn" for i in range(32)) == 4
    assert sum(jam.layer_is_moe(i) for i in range(32)) == 16
    ds = get_config("deepseek_v2_lite_16b")
    assert not ds.layer_is_moe(0) and all(ds.layer_is_moe(i) for i in range(1, 27))
    mam = get_config("mamba2_2p7b")
    assert all(mam.layer_kind(i) == "ssm" for i in range(64))
