"""Distribution runtime: zero-collective lowering (the paper's headline
property), multi-device execution equivalence, fault tolerance."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distrib import fault

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_with_devices(snippet: str, ndev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(snippet)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_gnm_zero_collectives_and_equivalence():
    """8-device shard_map run: HLO has no collectives AND the generated
    edge set equals the host-path generator bit-for-bit."""
    out = _run_with_devices("""
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.distrib.shard import run_gnm_directed_sharded, collective_ops_in
        from repro.core import er

        mesh = jax.make_mesh((8,), ("pe",))
        seed, n, m = 7, 512, 4000
        edges, hlo = run_gnm_directed_sharded(seed, n, m, mesh)
        assert not collective_ops_in(hlo), "collectives found!"
        host = er.gnm_directed(seed, n, m, P=8)
        a = {tuple(x) for x in edges}
        b = {tuple(x) for x in host}
        assert len(edges) == m, len(edges)
        assert a == b, (len(a - b), len(b - a))
        print("OK", len(edges))
    """)
    assert "OK 4000" in out


def test_sharded_gnm_2d_mesh():
    """The PE axis can span a 2-D (pod x data style) mesh product."""
    out = _run_with_devices("""
        import jax
        from repro.distrib.shard import run_gnm_directed_sharded
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        edges, hlo = run_gnm_directed_sharded(3, 256, 1000, mesh)
        assert len(edges) == 1000
        print("OK2D")
    """)
    assert "OK2D" in out


def test_sharded_rgg_points_zero_collectives_and_match():
    """Spatial vertex generation sharded over 8 devices: zero collectives
    AND bit-identical points to the host-path generator."""
    out = _run_with_devices("""
        import jax, numpy as np
        from repro.distrib.shard import rgg_points_sharded, collective_ops_in, assert_communication_free
        from repro.core import rgg

        mesh = jax.make_mesh((8,), ("pe",))
        seed, n, r, dim = 5, 2000, 0.03, 2
        fn, inputs = rgg_points_sharded(seed, n, r, mesh, dim)
        lowered = fn.lower(*inputs)
        assert_communication_free(lowered)
        pts, mask = fn(*inputs)
        pts, mask = np.asarray(pts), np.asarray(mask)
        total = int(mask.sum())
        assert total == n, total
        # cross-check a few cells against the host path
        host = rgg.rgg_all_points(seed, n, r, 8, dim)
        got = np.sort(pts[mask][:, 0])
        want = np.sort(host[:, 0])
        np.testing.assert_allclose(got, want, rtol=0, atol=0)
        print("OKRGG", total)
    """)
    assert "OKRGG 2000" in out


def test_engine_four_families_zero_collectives_8_devices():
    """Acceptance: directed G(n,m), undirected G(n,m), G(n,p) and RGG
    points all run through the unified engine on an 8-device mesh with
    zero collectives in the lowered HLO and output bit-identical to the
    per-PE reference generators."""
    out = _run_with_devices("""
        import jax, numpy as np
        from repro.core import er, rgg
        from repro.distrib.engine import run_edges, run_points, collective_ops_in
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()), ("pe",))
        seed, n, P = 7, 384, 8

        def es(e):
            return {tuple(x) for x in np.asarray(e, np.int64)}

        edges, hlo = run_edges(er.gnm_directed_plan(seed, n, 3000, P), mesh)
        assert not collective_ops_in(hlo)
        assert len(edges) == 3000 and es(edges) == es(er.gnm_directed(seed, n, 3000, P=P))

        edges, hlo = run_edges(er.gnm_undirected_plan(seed, n, 2000, P), mesh)
        assert not collective_ops_in(hlo)
        assert len(edges) == 2000 and es(edges) == es(er.gnm_undirected(seed, n, 2000, P=P))

        edges, hlo = run_edges(er.gnp_undirected_plan(seed, n, 0.02, P), mesh)
        assert not collective_ops_in(hlo)
        assert es(edges) == es(er.gnp_undirected(seed, n, 0.02, P=P))

        pts, mask, hlo = run_points(rgg.rgg_point_plan(seed, 2000, 0.03, P, 2), mesh)
        assert not collective_ops_in(hlo)
        assert int(mask.sum()) == 2000
        host = rgg.rgg_all_points(seed, 2000, 0.03, P, 2)
        np.testing.assert_array_equal(np.sort(pts[mask], axis=0), np.sort(host, axis=0))
        print("OKENGINE")
    """)
    assert "OKENGINE" in out


# ------------------------------------------------------------ fault model

def test_lpt_beats_round_robin_makespan():
    rng = np.random.default_rng(0)
    costs = tuple(rng.pareto(1.5, size=64) + 0.1)
    rr = fault.ChunkAssignment(64, tuple(range(8)), None)
    lpt = fault.ChunkAssignment(64, tuple(range(8)), costs)
    rr_ms = max(
        sum(costs[c] for c in rr.chunks_of(w)) for w in range(8)
    )
    assert lpt.makespan() <= rr_ms + 1e-9
    assert lpt.makespan() <= (4 / 3) * sum(costs) / 8 + max(costs)


def test_failure_recovery_is_exact():
    """Output after a mid-wave worker death == output with no failures.

    The live fault path: the serving scheduler places slab slots by a
    ChunkAssignment, kills a mesh row mid-slab, and reissues the lost
    slots onto the survivors from reassign_after_failure — the
    delivered stream must be bit-identical (recovery = recomputation)."""
    out = _run_with_devices("""
        import numpy as np
        from repro.api import GNM, generate
        from repro.serve import Service

        specs = [GNM(n=256, m=2000, seed=s, chunks=16) for s in (5, 6)]
        svc = Service(2, slab_batch=4)
        tickets = [svc.submit(s) for s in specs]
        svc.inject_fault([0], at_slab=1)  # row 0 dies during the 2nd slab
        svc.drain()
        assert svc.scheduler.reissued > 0
        for spec, t in zip(specs, tickets):
            np.testing.assert_array_equal(t.result().edges,
                                          generate(spec, 2).edges)
        print("OKFAULT", svc.scheduler.reissued)
    """, ndev=2)
    assert "OKFAULT" in out


def test_reassignment_covers_all_chunks():
    a = fault.ChunkAssignment(40, tuple(range(10)))
    b = fault.reassign_after_failure(a, dead=[2, 3, 7])
    covered = set()
    for w in b.workers:
        covered.update(b.chunks_of(w))
    assert covered == set(range(40))
    assert set(b.workers).isdisjoint({2, 3, 7})


def test_elastic_scale_up_is_deterministic():
    a = fault.ChunkAssignment(32, tuple(range(4)))
    grown = fault.ChunkAssignment(32, tuple(range(8)))
    # same chunk ids, same graph — only the mapping changes
    assert {c for w in grown.workers for c in grown.chunks_of(w)} == set(range(32))
