"""repro.distrib.runtime — the one mesh-aware, wave-streamed executor:
stream == run bit-identity and P-invariance for all three plan types,
ragged final waves padded (never retraced), the zero-collective check
on the actual wave dispatch (once per program signature), and
whole-mesh wave execution on 8 devices."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import GNM, RGG, RHG, generate, iter_edge_chunks, iter_points
from repro.core import rgg
from repro.distrib import engine, runtime

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

GNM_SPEC = GNM(n=400, m=3000, seed=11, chunks=10)
RGG_SPEC = RGG(n=300, radius=0.07, seed=11)
RHG_SPEC = RHG(n=300, avg_deg=6, gamma=2.7, seed=4)


def _plan_of(kind: str, P: int):
    if kind == "chunk":
        return GNM_SPEC.plan(P)
    if kind == "pair":
        return RHG_SPEC.plan(P)
    return rgg.rgg_point_plan(RGG_SPEC.seed, RGG_SPEC.n, RGG_SPEC.radius,
                              P, 2, chunk_P=16)


def _reassemble(plan, **stream_kw) -> np.ndarray:
    """Group streamed rows by PE and concatenate the valid payload —
    the documented reconstruction of the run output from wave prefixes
    (per-PE stream order is exact; PEs concatenate pe-major)."""
    per_pe = {}
    for pe, _, payload, valid in runtime.stream_slots(plan, **stream_kw):
        per_pe.setdefault(pe, []).append(np.asarray(payload)[np.asarray(valid)])
    if not per_pe:
        return np.zeros((0,))
    return np.concatenate([x for pe in sorted(per_pe) for x in per_pe[pe]])


def _run_flat(plan) -> np.ndarray:
    payload, valid, _ = runtime.run(plan, check=False)
    return np.asarray(payload)[np.asarray(valid)]


# ------------------------------------------- stream == run bit-identity

@pytest.mark.parametrize("kind", ["chunk", "point", "pair"])
@pytest.mark.parametrize("batch", [1, 4])
@pytest.mark.parametrize("prefetch", [1, 2])
def test_stream_equals_run_bit_identical(kind, batch, prefetch):
    """Concatenating wave prefixes (grouped by PE) reproduces the
    materializing run output exactly, for every plan type, batch and
    prefetch depth."""
    plan = _plan_of(kind, 4)
    streamed = _reassemble(plan, batch=batch, prefetch=prefetch)
    np.testing.assert_array_equal(streamed, _run_flat(plan))
    assert len(streamed) > 0


def _row_sorted(a: np.ndarray) -> np.ndarray:
    """Rows sorted lexicographically (rows stay intact — a column-wise
    sort would destroy the pairing and pass on swapped endpoints)."""
    a = a.reshape(len(a), -1)
    return a[np.lexsort(a.T[::-1])]


@pytest.mark.parametrize("kind", ["chunk", "point", "pair"])
def test_streamed_output_P_invariant(kind):
    """The streamed multiset is bit-identically machine-size invariant:
    P in {1, 2, 8} produce the same rows (row-lexicographic comparison),
    and each P's stream reassembles to its own run output."""
    ref = None
    for P in (1, 2, 8):
        plan = _plan_of(kind, P)
        streamed = _reassemble(plan, batch=4)
        np.testing.assert_array_equal(streamed, _run_flat(plan))
        s = _row_sorted(streamed)
        if ref is None:
            ref = s
        np.testing.assert_array_equal(s, ref)


# ------------------------------------------------- wave schedule contract

def test_wave_schedule_never_straddles_pe_and_pads_ragged():
    """5 owned slots per PE at batch=4 -> waves of 4 and a ragged 1;
    padding rows are masked out, slot order per PE is preserved, and no
    batch mixes PEs."""
    plan = GNM_SPEC.plan(2)
    index = plan.stream_index()
    per_pe = [index[index[:, 0] == pe, 1] for pe in (0, 1)]
    assert any(len(s) % 4 for s in per_pe)  # the instance has ragged tails
    ws = runtime.wave_schedule(plan, D=1, batch=4)
    expect_waves = sum(-(-len(s) // 4) for s in per_pe)  # sum of ceils: no straddle
    assert ws.batch == 4 and ws.num_waves == expect_waves
    seen = {0: [], 1: []}
    for w in range(ws.num_waves):
        row = ws.rows[w][0]
        assert row is not None
        pe, slots = row
        assert 1 <= len(slots) <= 4
        assert ws.valid[w, 0, : len(slots)].all()
        assert not ws.valid[w, 0, len(slots):].any()  # ragged tail masked
        seen[pe].extend(slots.tolist())
    for pe in (0, 1):
        np.testing.assert_array_equal(seen[pe], per_pe[pe])


def test_ragged_final_wave_does_not_retrace():
    """Ragged last waves reuse the same compiled wave step (padded to
    the static batch shape): exactly one executable per program."""
    runtime.cache_clear()
    plan = GNM_SPEC.plan(2)
    streamed = _reassemble(plan, batch=4)
    np.testing.assert_array_equal(streamed, _run_flat(plan))
    wave_fns = [e.fn for k, e in runtime._CACHE.items() if k[0] == "wave"]
    assert len(wave_fns) == 1
    assert wave_fns[0]._cache_size() == 1  # one trace covers every wave


def test_batch_clamps_to_longest_pe_run():
    """A huge batch on a plan with few slots per PE must not pad every
    wave with dead rows: the slab batch clamps to the longest per-PE
    run (one wave per PE here, no padding beyond the ragged tail)."""
    plan = GNM_SPEC.plan(2)
    index = plan.stream_index()
    longest = max(int((index[:, 0] == pe).sum()) for pe in (0, 1))
    ws = runtime.wave_schedule(plan, D=1, batch=4096)
    assert ws.batch == longest and ws.num_waves == 2


# --------------------------------------------- the check=True wave assert

def test_check_asserts_on_wave_dispatch_once_per_signature(monkeypatch):
    """The old streams only lowered the *first slot's* fn; the runtime
    must assert zero collectives on the shard_map'd wave step itself,
    and exactly once per program signature across repeated streams."""
    runtime.cache_clear()
    calls = []
    real = runtime.assert_communication_free

    def spy(lowered):
        calls.append(lowered.as_text())
        return real(lowered)

    monkeypatch.setattr(runtime, "assert_communication_free", spy)
    plan = RHG_SPEC.plan(2)
    for _ in range(2):  # second stream: same signature, cached + checked
        for _ in runtime.stream_waves(plan, batch=4, check=True):
            pass
    assert len(calls) == 1
    # the asserted program is the wave step (slab-indexed gather), not a
    # single slot's fn: it consumes the [D, B, 2] schedule operand
    assert "tensor<1x4x2xi32>" in calls[0]


def test_engine_stream_facades_check_lowers_wave_step(monkeypatch):
    """The legacy stream entry points inherit the fixed check hole."""
    runtime.cache_clear()
    calls = []
    monkeypatch.setattr(runtime, "assert_communication_free",
                        lambda lowered: calls.append(1))
    plan = GNM_SPEC.plan(2)
    for _ in engine.stream_chunk_edges(plan, check=True):
        pass
    for _ in engine.stream_chunk_edges(plan, check=True):
        pass
    assert len(calls) == 1


# ----------------------------------------------------- point streaming

def test_stream_points_matches_run_points():
    """The PointPlan streaming path (new in this PR): masked streamed
    positions reassemble to run_points' masked output exactly."""
    plan = _plan_of("point", 4)
    pts, mask, hlo = engine.run_points(plan, check=True)
    assert not engine.collective_ops_in(hlo)
    per_pe = {}
    for pe, buf, m in engine.stream_points(plan, batch=2, with_pe=True):
        per_pe.setdefault(pe, []).append(np.asarray(buf)[np.asarray(m)])
    streamed = np.concatenate([x for pe in sorted(per_pe) for x in per_pe[pe]])
    np.testing.assert_array_equal(streamed, pts[mask])
    assert len(streamed) == RGG_SPEC.n


def test_iter_points_streams_graph_positions():
    """api.iter_points: the O(capacity) route to Graph.points — the
    streamed positions are exactly the materialized ones (as sets; gid
    order is recovered per PE, positions are what matter here)."""
    g = generate(RGG_SPEC, 4, return_points=True)
    streamed = np.concatenate(
        [c.points() for c in iter_points(RGG_SPEC, 4, batch=2)])
    assert streamed.shape == g.points.shape
    a = {tuple(np.round(p, 12)) for p in streamed}
    b = {tuple(np.round(p, 12)) for p in g.points}
    assert a == b


def test_iter_points_rejects_non_geometric_specs():
    with pytest.raises(TypeError, match="no vertex positions"):
        next(iter_points(GNM_SPEC, 2))


# ------------------------------------------------- mesh argument contract

def test_mesh_must_divide_plan_pes():
    plan = GNM_SPEC.plan(3)
    mesh = engine.default_mesh(1)
    # 1 device divides 3 PEs; a fabricated 2-row requirement cannot be
    # built on this machine, so exercise the validation path directly
    assert runtime.mesh_size(mesh) == 1
    with pytest.raises(ValueError, match="must be 0"):
        runtime._resolve_mesh(_FakePlan(3), _FakeMesh(2))


class _FakeMesh:
    def __init__(self, size):
        self.devices = np.empty(size, dtype=object)


class _FakePlan:
    def __init__(self, P):
        self.num_pes = P


# ------------------------------------------------- 8-device wave execution

def _run_with_devices(snippet: str, ndev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(snippet)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_wave_streaming_uses_whole_mesh_and_matches_generate():
    """On a real 8-device mesh, every wave slab spans all 8 mesh rows
    (streaming uses the whole mesh, not the default device) and the
    per-PE reassembly reproduces generate() bit-for-bit for both a
    ChunkPlan and a PairPlan family."""
    out = _run_with_devices("""
        import numpy as np, jax
        from repro.api import GNM, RGG, generate, iter_edge_chunks
        from repro.distrib import runtime

        assert len(jax.devices()) == 8
        for spec in (GNM(n=1024, m=8000, seed=5, chunks=16),
                     RGG(n=1024, radius=0.05, seed=3)):
            P = 8
            plan = spec.plan(P)
            waves = list(runtime.stream_waves(plan, batch=2))
            D = waves[0].payload.shape[0]
            assert D == 8, D  # one slab row per mesh device
            g = generate(spec, P)
            per_pe = {}
            for c in iter_edge_chunks(spec, P, batch=2):
                per_pe.setdefault(c.pe, []).append(c.edges())
            streamed = np.concatenate(
                [e for pe in sorted(per_pe) for e in per_pe[pe]])
            np.testing.assert_array_equal(streamed, g.edges)
        print("WAVE8OK")
    """)
    assert "WAVE8OK" in out
