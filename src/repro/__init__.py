"""KaGen-JAX: communication-free massively distributed graph generation,
plus the multi-pod training/serving framework it feeds.

x64 is enabled globally: edge universes exceed 2^32 almost immediately
(n(n-1)/2 for n = 2^17 already does).  All model code uses explicit
dtypes, so LM compute stays bf16/f32 regardless.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
