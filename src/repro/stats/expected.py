"""Closed-form model expectations per family (paper §7's verification
targets), resolved from a GraphSpec.

Each family maps to an :class:`ExpectedModel`: the degree law to test
against (a pmf where one exists in closed form), the expected mean
degree, and the power-law tail exponent where the model has one.  The
family-specific constants live next to their generators
(:func:`repro.core.er.expected_degree_law`,
:func:`repro.core.rhg.expected_tail_exponent`); this module only
dispatches and assembles.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np
from scipy import stats as sps

from ..core import er as _er
from ..core import rhg as _rhg


@dataclass(frozen=True)
class ExpectedModel:
    family: str
    mean_degree: Optional[float] = None       # expected average (out-)degree
    degree_pmf: Optional[np.ndarray] = None   # P[deg = k], k = 0..kmax
    tail_exponent: Optional[float] = None     # power-law gamma, if the model has one
    exact_edges: Optional[int] = None         # families with a fixed edge count
    mean_rel_tol: float = 0.05                # gate width on mean degree
    notes: str = ""


def unit_ball_volume(dim: int) -> float:
    """Volume of the unit L2 ball (RGG expected degree n*V(r))."""
    return math.pi ** (dim / 2.0) / math.gamma(dim / 2.0 + 1.0)


def _binomial_model(family: str, trials: int, p: float, kmax: int,
                    exact_edges: Optional[int] = None, notes: str = "") -> ExpectedModel:
    pmf = sps.binom.pmf(np.arange(kmax + 1), trials, p)
    return ExpectedModel(family=family, mean_degree=trials * p, degree_pmf=pmf,
                         exact_edges=exact_edges, notes=notes)


def expected_model(spec, kmax: int = 0) -> ExpectedModel:
    """Closed-form expectations for ``spec``; ``kmax`` sizes the pmf
    support (pass the observed max degree plus slack)."""
    from .. import api

    kmax = max(kmax, 8)
    if isinstance(spec, api.GNP):
        t, p = _er.expected_degree_law(spec.n, p=spec.p, directed=spec.directed)
        return _binomial_model("GNP", t, p, kmax,
                               notes="deg ~ Binomial(n-1, p), exact marginal")
    if isinstance(spec, api.GNM):
        t, p = _er.expected_degree_law(spec.n, m=spec.m, directed=spec.directed)
        return _binomial_model(
            "GNM", t, p, kmax, exact_edges=spec.m,
            notes="Binomial approximation; fixed edge total under-disperses")
    if isinstance(spec, api.SBM):
        nb = spec.n // spec.blocks
        pmf_in = sps.binom.pmf(np.arange(kmax + 1), nb - 1, spec.p_in)
        pmf_out = sps.binom.pmf(np.arange(kmax + 1), spec.n - nb, spec.p_out)
        pmf = np.convolve(pmf_in, pmf_out)[: kmax + 1]
        return ExpectedModel(
            family="SBM", degree_pmf=pmf,
            mean_degree=(nb - 1) * spec.p_in + (spec.n - nb) * spec.p_out,
            notes="deg = Bin(n_b-1, p_in) + Bin(n-n_b, p_out), equal blocks")
    if isinstance(spec, api.RGG):
        v = unit_ball_volume(spec.dim) * spec.radius ** spec.dim
        return ExpectedModel(
            family="RGG", mean_degree=(spec.n - 1) * v, mean_rel_tol=0.15,
            notes="interior law (n-1)*V(r); [0,1)^d boundary loses O(r) mass")
    if isinstance(spec, api.RHG):
        return ExpectedModel(
            family="RHG",
            mean_degree=_rhg.expected_avg_degree(spec.params),
            tail_exponent=_rhg.expected_tail_exponent(spec.params),
            mean_rel_tol=0.3,
            notes="mean from Eq. 4 calibration (slow o(1) convergence); "
                  "tail exponent 2*alpha + 1 = gamma")
    if isinstance(spec, api.BA):
        return ExpectedModel(
            family="BA", mean_degree=float(spec.d), exact_edges=spec.n * spec.d,
            tail_exponent=3.0, mean_rel_tol=0.0,
            notes="out-degree exactly d per vertex; in-degree tail exponent 3")
    if isinstance(spec, api.RMAT):
        return ExpectedModel(
            family="RMAT", mean_degree=spec.m / spec.num_vertices,
            exact_edges=spec.m, mean_rel_tol=0.0,
            notes="Graph500 semantics (loops+dups kept); heavy tail fitted, "
                  "no agreed closed-form exponent")
    if isinstance(spec, api.RDG):
        if spec.dim == 2:
            return ExpectedModel(
                family="RDG", mean_degree=6.0, mean_rel_tol=0.01,
                notes="torus triangulation: E = 3V, avg degree exactly 6")
        return ExpectedModel(
            family="RDG", mean_degree=15.54, mean_rel_tol=0.1,
            notes="3d Poisson-Delaunay asymptotic mean degree ~ 15.54")
    raise TypeError(f"no closed-form expectations for {type(spec).__name__}")
