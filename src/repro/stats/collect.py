"""`collect(spec, P) -> StatsReport`: streaming graph analytics.

Drives ``repro.api.iter_edge_chunks`` once (twice with clustering — the
second pass regenerates, it does not store) and folds every chunk into
the per-PE accumulators of :mod:`.accumulate`.  Peak memory is the
accumulators plus one chunk buffer, never the edge list; the report is
identical for every P because the streamed multiset and the vertex
ownership split both are.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import numpy as np

from .accumulate import (
    ClusteringReport,
    ClusteringSampler,
    DegreeSummary,
    SectionDegrees,
    VertexOwnership,
    merge_sections,
)

# above this the exact per-vertex degree array is no longer returned
# (per-PE sections still exist — O(n/P) each — but nothing of size n is
# ever assembled); log2 histograms + moments remain exact at any scale.
EXACT_N_LIMIT = 1 << 22

DEFAULT_METRICS = ("degree",)
KNOWN_METRICS = ("degree", "clustering")


@dataclass(frozen=True)
class StatsReport:
    """What one streaming pass measures.  All non-sampled fields are
    exact and P-invariant; clustering is exact on its (deterministic)
    vertex sample."""
    n: int
    P: int
    directed: bool
    mode: str                           # 'exact' | 'binned'
    num_edges: int
    degree: DegreeSummary               # undirected / out-degree view
    in_degree: Optional[DegreeSummary] = None   # directed only
    clustering: Optional[ClusteringReport] = None
    metrics: Tuple[str, ...] = field(default=DEFAULT_METRICS)

    @property
    def mean_degree(self) -> float:
        """Average (out-)degree over all n vertices."""
        return self.degree.deg_sum / max(1, self.n)

    def degree_counts(self) -> np.ndarray:
        """Exact degree-value histogram counts[0 .. deg_max] (the GOF
        input), via the device scatter-add (hist kernel below its bin
        limit, XLA scatter above).  Exact mode only."""
        if self.degree.degrees is None:
            raise ValueError("degree_counts needs mode='exact'")
        from ..kernels.hist.ops import bincount_ids

        return np.asarray(bincount_ids(self.degree.degrees,
                                       self.degree.deg_max + 1))


def collect(
    spec,
    P: int = 1,
    *,
    metrics: Sequence[str] = DEFAULT_METRICS,
    mode: Optional[str] = None,
    rng_impl: str = "threefry2x32",
    batch: int = 256,
    cluster_samples: int = 64,
    neighbor_cap: int = 8192,
) -> StatsReport:
    """Stream ``spec`` on P virtual PEs and measure it.

    metrics: subset of {'degree', 'clustering'}; clustering costs a
    second streaming pass and requires an undirected family.
    mode: 'exact' keeps the full per-vertex degree array (default for
    n <= 2^22), 'binned' keeps only log2 histograms + exact moments.
    batch: candidate pairs per mesh row per wave dispatch for the
    geometric (PairPlan) families; ChunkPlan families stream at
    batch=1 so one chunk's [capacity, 2] buffer stays the peak — chunk
    capacities are large (m/chunks edges), and batching them would
    multiply both the slab memory and the wedge-replay matrix by the
    batch size.
    """
    from .. import api

    unknown = set(metrics) - set(KNOWN_METRICS)
    if unknown:
        raise ValueError(f"unknown metrics {sorted(unknown)}; know {KNOWN_METRICS}")
    n, directed = spec.num_vertices, spec.directed
    mode = mode or ("exact" if n <= EXACT_N_LIMIT else "binned")
    if mode not in ("exact", "binned"):
        raise ValueError(f"unknown mode {mode!r}")
    if "clustering" in metrics and directed:
        raise ValueError("clustering is defined for undirected families only")

    # PairPlan rows are O(capacity^2) with tiny capacities; ChunkPlan
    # buffers are O(capacity) with large ones — batching the latter
    # would break the O(capacity) peak-memory contract
    batch = batch if isinstance(spec, (api.RGG, api.RHG, api.RDG)) else 1

    own = VertexOwnership(n, P)
    out_acc = [SectionDegrees(*own.bounds[pe: pe + 2]) for pe in range(P)]
    in_acc = ([SectionDegrees(*own.bounds[pe: pe + 2]) for pe in range(P)]
              if directed else None)
    sampler = (ClusteringSampler(n, spec.seed, cluster_samples, neighbor_cap)
               if "clustering" in metrics else None)

    def route(accs, ids):
        for pe, part in enumerate(own.split(ids)):
            accs[pe].add(part)

    num_edges = 0
    for chunk in api.iter_edge_chunks(spec, P, rng_impl=rng_impl, batch=batch):
        e = chunk.edges()
        num_edges += len(e)
        if not len(e):
            continue
        route(out_acc, e[:, 0] if directed else e.reshape(-1))
        if directed:
            route(in_acc, e[:, 1])
        if sampler is not None:
            sampler.observe(e)

    clustering = None
    if sampler is not None:
        sampler.finalize_neighbors()
        if sampler.has_work:  # else the regeneration pass would count nothing
            # the wedge replay rides the engine's streamed device buffers
            # (the PairPlan executor's output for the geometric families)
            # rather than per-sample host loops over materialized edges
            for chunk in api.iter_edge_chunks(spec, P, rng_impl=rng_impl, batch=batch):
                sampler.count_triangles_chunk(chunk.buffer, count=chunk.count,
                                              mask=chunk.mask)
        clustering = sampler.report()

    exact = mode == "exact"
    return StatsReport(
        n=n, P=P, directed=directed, mode=mode, num_edges=num_edges,
        degree=merge_sections(out_acc, exact),
        in_degree=merge_sections(in_acc, exact) if directed else None,
        clustering=clustering, metrics=tuple(metrics),
    )
