"""`validate(spec, P) -> ValidationReport`: measured vs closed form.

The paper's §7 verification loop as a library call: stream the graph
(:func:`repro.stats.collect`), resolve the family's closed-form law
(:mod:`.expected`), and run the goodness-of-fit gates (:mod:`.gof`).
Every gate is a :class:`ValidationCheck` with its evidence attached, so
a failing report says *what* diverged, not just that something did.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from .collect import StatsReport, collect
from .expected import ExpectedModel, expected_model
from .gof import chi_square_gof, hill_tail_exponent, tail_exponent_from_log2_hist


@dataclass(frozen=True)
class ValidationCheck:
    name: str
    passed: bool
    observed: float
    expected: float
    detail: str = ""
    pvalue: Optional[float] = None

    def __str__(self) -> str:
        mark = "PASS" if self.passed else "FAIL"
        p = f" p={self.pvalue:.4g}" if self.pvalue is not None else ""
        return (f"[{mark}] {self.name}: observed={self.observed:.6g} "
                f"expected={self.expected:.6g}{p}  {self.detail}")


@dataclass(frozen=True)
class ValidationReport:
    family: str
    P: int
    passed: bool
    checks: Tuple[ValidationCheck, ...]
    stats: StatsReport
    model: ExpectedModel = field(repr=False, default=None)

    def __str__(self) -> str:
        head = (f"{self.family}: n={self.stats.n} m={self.stats.num_edges} "
                f"P={self.P} mode={self.stats.mode} -> "
                f"{'PASS' if self.passed else 'FAIL'}")
        return "\n".join([head] + [f"  {c}" for c in self.checks])


def validate(spec, P: int = 1, *, alpha: float = 1e-3, **collect_kwargs) -> ValidationReport:
    """Generate-and-measure ``spec`` on P PEs, gate against its law.

    ``alpha`` is the significance level of the distributional (chi-
    square) gates; scale/tail gates use the model's tolerance.  Extra
    kwargs forward to :func:`collect`.
    """
    stats = collect(spec, P, **collect_kwargs)
    model = expected_model(spec, kmax=stats.degree.deg_max + 1)
    checks = []

    if model.exact_edges is not None:
        checks.append(ValidationCheck(
            name="edge-count", passed=stats.num_edges == model.exact_edges,
            observed=float(stats.num_edges), expected=float(model.exact_edges),
            detail="exact by construction"))

    if model.mean_degree is not None:
        mean = stats.mean_degree
        tol = model.mean_rel_tol
        rel = abs(mean - model.mean_degree) / max(model.mean_degree, 1e-12)
        checks.append(ValidationCheck(
            name="mean-degree", passed=rel <= tol + 1e-9,
            observed=mean, expected=model.mean_degree,
            detail=f"rel err {rel:.3g} <= tol {tol:.3g}; {model.notes}"))

    if model.degree_pmf is not None and stats.mode == "exact":
        obs = stats.degree_counts()
        kmax = len(obs) - 1
        exp = stats.n * model.degree_pmf[: kmax + 1]
        gof = chi_square_gof(obs, exp)
        checks.append(ValidationCheck(
            name="degree-chi2", passed=gof.pvalue > alpha,
            observed=gof.stat, expected=float(gof.dof),
            pvalue=gof.pvalue,
            detail=f"chi2 on pooled degree counts, dof={gof.dof}"))

    if model.tail_exponent is not None:
        checks.append(_tail_check(stats, model))

    return ValidationReport(
        family=model.family, P=P, passed=all(c.passed for c in checks),
        checks=tuple(checks), stats=stats, model=model)


def _tail_check(stats: StatsReport, model: ExpectedModel) -> ValidationCheck:
    """Fitted power-law tail exponent vs the model's closed form.

    Exact mode uses the Hill estimator on the heavy-tailed orientation
    (in-degrees for BA); binned mode fits the log2 histogram slope —
    the O(bins) path that survives any n.  The gate width folds in the
    fit's own standard error: tail estimates converge slowly, and a
    gate tighter than the estimator is noise, not rigor.
    """
    summary = stats.in_degree if stats.directed else stats.degree
    if stats.mode == "exact" and summary.degrees is not None:
        got, se = hill_tail_exponent(summary.degrees)
        how = "hill"
    else:
        got, se = tail_exponent_from_log2_hist(summary.log2_hist)
        how = "log2-slope"
    tol = 0.35 + 3.0 * min(se, 1.0)
    ok = np.isfinite(got) and abs(got - model.tail_exponent) <= tol
    return ValidationCheck(
        name="tail-exponent", passed=bool(ok), observed=float(got),
        expected=float(model.tail_exponent),
        detail=f"{how} fit, se={se:.3g}, tol={tol:.3g}")
