"""Per-PE streaming accumulators (degree sections, clustering samples).

The mirror image of generation: the engine streams each owned chunk
exactly once (:func:`repro.distrib.engine.owned_chunk_index`), so the
stream *is* the exact global edge multiset and accumulation is pure
addition — no dedup, no communication.  Vertices get the same treatment
edges already have: canonical ownership.  Vertex v belongs to exactly
one PE's contiguous section (:func:`repro.core.chunking.section_bounds`,
the generators' own vertex partition), that PE's accumulator counts it,
and per-PE results merge additively — each vertex counted exactly once
across PEs, for any P.

Memory: one PE's accumulator holds its O(n/P) degree section plus the
O(capacity) chunk in flight; edges are never materialized.  The merged
result in ``binned`` mode is just log2 histograms + moments (O(1) per
PE), so nothing global of size n ever needs to exist on one host.

Degree scatter-adds run on device through the hist kernel / XLA scatter
(:func:`repro.kernels.hist.ops.bincount_ids`), with chunk id batches
padded to a block multiple so repeated jits hit the trace cache.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.chunking import section_bounds
from ..core.prng import host_rng
from ..kernels.hist.hist import LOG2_BINS
from ..kernels.hist.ops import bincount_ids, degree_histogram

_TAG_SAMPLE = 71  # hashed stream for the clustering vertex sample
_ID_BLOCK = 1024  # id batches pad to this multiple (bounds trace-cache size)
_NB_SENTINEL = 1 << 62  # neighbor-table padding: larger than any vertex id


@jax.jit
def _close_wedges(edges, valid, nb):
    """int64 [S]: per sample, how many of this buffer's valid edges have
    BOTH endpoints in the sample's sorted sentinel-padded neighbor row.
    The wedge-closing membership test of clustering pass 2, vectorized
    on device over samples x edges."""
    u, v = edges[:, 0], edges[:, 1]

    def member(row, q):
        pos = jnp.minimum(jnp.searchsorted(row, q), row.shape[0] - 1)
        return row[pos] == q

    def one(row):
        return jnp.sum(member(row, u) & member(row, v) & valid).astype(jnp.int64)

    return jax.vmap(one)(nb)


class VertexOwnership:
    """Canonical vertex -> PE map: the contiguous section split."""

    def __init__(self, n: int, P: int):
        self.n, self.P = n, P
        self.bounds = np.array([section_bounds(n, P, i)[0] for i in range(P)]
                               + [n], dtype=np.int64)

    def owner_of(self, ids: np.ndarray) -> np.ndarray:
        """Owning PE of each vertex id."""
        return np.searchsorted(self.bounds, ids, side="right") - 1

    def split(self, ids: np.ndarray) -> List[np.ndarray]:
        """Partition an id batch by owner; concatenation-stable."""
        order = np.argsort(ids, kind="stable")
        s = ids[order]
        cuts = np.searchsorted(s, self.bounds)
        return [s[cuts[p]: cuts[p + 1]] for p in range(self.P)]


class SectionDegrees:
    """One PE's degree accumulator over its owned vertex section.

    Holds an int64 device array of section length; ``add`` scatter-adds
    one chunk's worth of endpoint ids (already filtered to the section)
    through :func:`repro.kernels.hist.ops.bincount_ids` — the Pallas
    one-hot kernel for small sections, XLA scatter for large, both on
    device.
    """

    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi
        self.size = hi - lo
        self.deg = jnp.zeros(self.size, jnp.int64)

    def add(self, global_ids: np.ndarray) -> None:
        if not len(global_ids):
            return
        k = len(global_ids)
        kpad = (k + _ID_BLOCK - 1) // _ID_BLOCK * _ID_BLOCK
        padded = np.full(kpad, self.size, np.int64)  # sentinel: dropped
        padded[:k] = global_ids - self.lo
        self.deg = self.deg + bincount_ids(padded, self.size)

    # ---- merged views (host-known scalars / O(bins) arrays) -------------

    def log2_hist(self) -> np.ndarray:
        return np.asarray(degree_histogram(self.deg, LOG2_BINS, log2=True))

    def moments(self) -> np.ndarray:
        d = self.deg
        return np.array([int(d.sum()), int((d * d).sum()),
                         int(d.max()) if self.size else 0, int((d == 0).sum())])


@dataclass
class DegreeSummary:
    """Merged (cross-PE) degree statistics for one orientation.

    ``degrees`` is only present in exact mode; the log2 histogram and
    moments are always exact and O(1)-sized per PE."""
    log2_hist: np.ndarray           # int64 [LOG2_BINS]
    deg_sum: int
    deg_sumsq: int
    deg_max: int
    num_isolated: int
    degrees: Optional[np.ndarray] = None   # int64 [n], exact mode only

    @property
    def mean(self) -> float:
        return self.deg_sum / max(1, int(self.log2_hist.sum()))

    @property
    def variance(self) -> float:
        n = max(1, int(self.log2_hist.sum()))
        mu = self.deg_sum / n
        return self.deg_sumsq / n - mu * mu


def merge_sections(accs: List[SectionDegrees], exact: bool) -> DegreeSummary:
    """Additive cross-PE merge: histograms and moments sum; the exact
    path concatenates the per-PE sections (vertex-id order)."""
    hist = np.zeros(LOG2_BINS, np.int64)
    mom = np.zeros(4, np.int64)
    deg_max = 0
    for a in accs:
        hist += a.log2_hist()
        m = a.moments()
        mom[0] += m[0]
        mom[1] += m[1]
        deg_max = max(deg_max, int(m[2]))
        mom[3] += m[3]
    degrees = (np.concatenate([np.asarray(a.deg) for a in accs])
               if exact else None)
    return DegreeSummary(log2_hist=hist, deg_sum=int(mom[0]),
                         deg_sumsq=int(mom[1]), deg_max=deg_max,
                         num_isolated=int(mom[3]), degrees=degrees)


# --------------------------------------------------------------------------
# sampled clustering (wedge / triangle counters)
# --------------------------------------------------------------------------

class ClusteringSampler:
    """Exact local clustering for a hashed deterministic vertex sample.

    Two streaming passes (streams are *replayable* — regeneration is the
    communication-free substitute for storage): pass 1 collects each
    sampled vertex's neighbor set, pass 2 counts the edges closing its
    wedges.  The sample is a pure function of (seed, n), so reports are
    P-invariant; counts per sampled vertex are exact, the clustering
    estimate is sampled only in which vertices it looks at.

    Memory: O(samples * neighbor_cap + chunk) — a hard bound.  The
    moment a sampled vertex's neighbor count exceeds ``neighbor_cap``
    its stored neighbors are discarded mid-stream (only the count keeps
    growing), so a sampled hub can never balloon pass-1 memory; it is
    excluded from the estimate (``valid`` False) but its exact degree
    is still reported.  Overflow status depends only on the final count,
    so it — like everything else here — is P- and order-invariant.
    """

    def __init__(self, n: int, seed: int, samples: int, neighbor_cap: int):
        rng = host_rng(seed, _TAG_SAMPLE)
        self.sample = np.sort(rng.choice(n, size=min(max(samples, 0), n),
                                         replace=False))
        self.neighbor_cap = neighbor_cap
        self._parts: List[List[np.ndarray]] = [[] for _ in self.sample]
        self._count = np.zeros(len(self.sample), np.int64)
        self._overflow = np.zeros(len(self.sample), bool)
        self.neighbors: Optional[List[np.ndarray]] = None
        self._nb_table = None
        self.triangles = np.zeros(len(self.sample), np.int64)

    def observe(self, e: np.ndarray) -> None:
        """Pass 1: record neighbors of sampled endpoints of one chunk.

        The exact-union stream has no duplicate undirected edges, so
        per-sample occurrence counts equal true degrees."""
        if not len(self.sample):
            return
        for col, other in ((0, 1), (1, 0)):
            pos = np.searchsorted(self.sample, e[:, col])
            pos = np.minimum(pos, len(self.sample) - 1)
            hit = self.sample[pos] == e[:, col]
            if not hit.any():
                continue
            p, o = pos[hit], e[hit, other]
            for si in np.unique(p):  # repro: allow(no-numpy-unique) O(samples) host loop over sampled vertex ids
                self._count[si] += int((p == si).sum())
                if self._overflow[si]:
                    continue
                if self._count[si] > self.neighbor_cap:  # hub: drop storage,
                    self._overflow[si] = True            # keep counting
                    self._parts[si] = []
                else:
                    self._parts[si].append(o[p == si])

    def finalize_neighbors(self) -> None:
        self.neighbors = [
            np.unique(np.concatenate(parts)) if parts else np.zeros(0, np.int64)  # repro: allow(no-numpy-unique) O(neighbor_cap) per sampled vertex, host side
            for parts in self._parts
        ]
        self._parts = []

    @property
    def has_work(self) -> bool:
        """Whether a triangle pass could count anything: at least one
        eligible sample with a wedge to close.  False means the second
        streaming pass can be skipped wholesale."""
        return any(not self._overflow[si] and len(nb) >= 2
                   for si, nb in enumerate(self.neighbors))

    def count_triangles(self, e: np.ndarray) -> None:
        """Pass 2, host reference path: one chunk's materialized edges
        closing sampled wedges (a per-sample Python loop; the streaming
        consumer uses :meth:`count_triangles_chunk` instead)."""
        for si, nb in enumerate(self.neighbors):
            if self._overflow[si] or len(nb) < 2:
                continue
            self.triangles[si] += int(np.count_nonzero(
                _in_sorted(nb, e[:, 0]) & _in_sorted(nb, e[:, 1])))

    def _neighbor_table(self):
        """Sorted, sentinel-padded [S, NB] neighbor matrix on device.
        Overflowed samples have empty rows (all-sentinel), so they count
        nothing — exactly the host path's skip."""
        if self._nb_table is None:
            nb_max = max((len(nb) for nb in self.neighbors), default=0)
            tbl = np.full((max(1, len(self.sample)), max(1, nb_max)),
                          _NB_SENTINEL, np.int64)
            for i, nb in enumerate(self.neighbors):
                tbl[i, : len(nb)] = nb
            self._nb_table = jnp.asarray(tbl)
        return self._nb_table

    def count_triangles_chunk(self, buffer, count: Optional[int] = None,
                              mask=None) -> None:
        """Pass 2, streaming path: close sampled wedges against one
        engine output buffer *on device* — the wedge replay rides the
        executor's chunk / candidate-pair buffers directly (vectorized
        membership over samples x edges) instead of materializing each
        chunk's edges on the host and looping per sample.  ``count`` is
        a validity-prefix length (ChunkPlan buffers), ``mask`` a
        scattered validity mask (PairPlan buffers); batched pair buffers
        ([b, cap^2, 2]) flatten transparently."""
        if self.neighbors is None:
            raise RuntimeError("finalize_neighbors() must run before pass 2")
        if not len(self.sample) or not max(
                (len(nb) for nb in self.neighbors), default=0):
            return
        buf = jnp.asarray(buffer).reshape(-1, 2)
        if mask is not None:
            valid = jnp.asarray(mask).reshape(-1)
        else:
            k = buf.shape[0] if count is None else count
            valid = jnp.arange(buf.shape[0]) < k
        self.triangles += np.asarray(_close_wedges(buf, valid,
                                                   self._neighbor_table()))

    def report(self) -> "ClusteringReport":
        deg = self._count.copy()
        valid = (deg >= 2) & ~self._overflow
        wedges = deg * (deg - 1) // 2
        return ClusteringReport(sample=self.sample, degree=deg,
                                triangles=self.triangles, wedges=wedges,
                                valid=valid)


def _in_sorted(sorted_vals: np.ndarray, q: np.ndarray) -> np.ndarray:
    """Membership of q in a sorted unique array, vectorized."""
    pos = np.minimum(np.searchsorted(sorted_vals, q), len(sorted_vals) - 1)
    return sorted_vals[pos] == q


@dataclass
class ClusteringReport:
    """Exact wedge/triangle counts over the deterministic vertex sample."""
    sample: np.ndarray      # sampled vertex ids, sorted
    degree: np.ndarray      # exact degree of each sampled vertex
    triangles: np.ndarray   # edges among its neighbors (== closed wedges)
    wedges: np.ndarray      # C(degree, 2)
    valid: np.ndarray       # bool: in-estimate (2 <= degree <= cap)

    @property
    def global_cc(self) -> float:
        """sum(closed) / sum(wedges) over the sample (transitivity-style)."""
        w = int(self.wedges[self.valid].sum())
        return float(self.triangles[self.valid].sum() / w) if w else 0.0

    @property
    def mean_local_cc(self) -> float:
        v = self.valid
        if not v.any():
            return 0.0
        return float((self.triangles[v] / self.wedges[v]).mean())
