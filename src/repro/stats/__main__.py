"""CLI smoke validation: `python -m repro.stats [--n N] [--pes P]`.

Validates one ER and one RHG instance against their closed-form laws
and exits non-zero on any failed gate — the CI guard that generation
*and* measurement stay statistically sound.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.stats",
                                 description=__doc__)
    ap.add_argument("--n", type=int, default=1 << 12, help="vertices per instance")
    ap.add_argument("--pes", type=int, default=4, help="virtual PEs")
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args(argv)

    from repro.api import GNP, RHG
    from repro.stats import validate

    specs = [
        GNP(n=args.n, p=16.0 / args.n, seed=args.seed),
        RHG(n=args.n, avg_deg=8, gamma=2.7, seed=args.seed),
    ]
    ok = True
    for spec in specs:
        report = validate(spec, args.pes)
        print(report)
        ok &= report.passed
    print("all gates passed" if ok else "GATE FAILURE", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
