"""repro.stats — communication-free streaming graph analytics.

The measurement half of the paper's §7 verification story: the same
zero-collective machinery that *generates* graphs also *reads* them.
``collect(spec, P)`` streams a spec's edge chunks through per-PE
accumulators (degrees by canonical vertex ownership, sampled
wedge/triangle counters) and merges additively; ``validate(spec, P)``
gates the result against the family's closed-form law (Binomial degree
distributions, RHG's 2*alpha + 1 tail exponent, BA's exponent 3, exact
edge counts).  Peak memory is the accumulators plus one chunk buffer —
the edge list is never materialized, so validation scales with the
generators it validates.

    >>> from repro.stats import validate
    >>> from repro.api import GNP
    >>> report = validate(GNP(n=4096, p=16 / 4096, seed=1), P=8)
    >>> report.passed
    True

``python -m repro.stats`` runs the ER + RHG smoke validation (CI).
"""
from .accumulate import ClusteringReport, DegreeSummary, VertexOwnership
from .collect import EXACT_N_LIMIT, StatsReport, collect
from .expected import ExpectedModel, expected_model
from .gof import GofResult, chi_square_gof, hill_tail_exponent, ks_discrete
from .validate import ValidationCheck, ValidationReport, validate

__all__ = [
    "ClusteringReport", "DegreeSummary", "VertexOwnership",
    "EXACT_N_LIMIT", "StatsReport", "collect",
    "ExpectedModel", "expected_model",
    "GofResult", "chi_square_gof", "hill_tail_exponent", "ks_discrete",
    "ValidationCheck", "ValidationReport", "validate",
]
