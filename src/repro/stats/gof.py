"""Goodness-of-fit machinery: chi-square, KS, power-law tail fits.

Small, numpy/scipy-only: these run on O(bins)-sized merged summaries,
not on edge lists, so they are free at any graph scale.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np
from scipy import stats as sps


@dataclass(frozen=True)
class GofResult:
    stat: float
    dof: int
    pvalue: float


def pool_bins(observed: np.ndarray, expected: np.ndarray,
              min_expected: float = 5.0) -> Tuple[np.ndarray, np.ndarray]:
    """Merge adjacent bins until every pooled bin has expected mass >=
    ``min_expected`` (the classical chi-square validity rule).  The last
    pool absorbs any underweight remainder."""
    obs_p, exp_p = [], []
    o_acc = e_acc = 0.0
    for o, e in zip(observed, expected):
        o_acc += o
        e_acc += e
        if e_acc >= min_expected:
            obs_p.append(o_acc)
            exp_p.append(e_acc)
            o_acc = e_acc = 0.0
    if e_acc > 0 or o_acc > 0:
        if exp_p:
            obs_p[-1] += o_acc
            exp_p[-1] += e_acc
        else:
            obs_p, exp_p = [o_acc], [e_acc]
    return np.asarray(obs_p, np.float64), np.asarray(exp_p, np.float64)


def chi_square_gof(observed: np.ndarray, expected: np.ndarray, *,
                   min_expected: float = 5.0, ddof: int = 0) -> GofResult:
    """Pearson chi-square of observed counts vs expected counts.

    ``expected`` is rescaled to the observed total (tiny truncated tail
    mass must not read as misfit), then adjacent bins are pooled to the
    min-expected rule."""
    observed = np.asarray(observed, np.float64)
    expected = np.asarray(expected, np.float64)
    expected = expected * (observed.sum() / expected.sum())
    obs, exp = pool_bins(observed, expected, min_expected)
    if len(obs) < 2:
        return GofResult(stat=0.0, dof=0, pvalue=1.0)
    stat = float(((obs - exp) ** 2 / exp).sum())
    dof = max(1, len(obs) - 1 - ddof)
    return GofResult(stat=stat, dof=dof, pvalue=float(sps.chi2.sf(stat, dof)))


def ks_discrete(degrees: np.ndarray, cdf: np.ndarray) -> GofResult:
    """Conservative KS test of integer samples vs a discrete CDF.

    cdf[k] = P(X <= k).  The Kolmogorov asymptotic p-value is
    conservative for discrete laws (true p is larger), so a pass is
    trustworthy and a marginal fail is soft — use chi-square as the
    primary gate."""
    degrees = np.asarray(degrees, np.int64)
    n = len(degrees)
    kmax = len(cdf) - 1
    counts = np.bincount(np.clip(degrees, 0, kmax), minlength=kmax + 1)
    ecdf = np.cumsum(counts) / n
    d = float(np.abs(ecdf - cdf).max())
    return GofResult(stat=d, dof=n, pvalue=float(sps.kstwobign.sf(d * np.sqrt(n))))


# --------------------------------------------------------------------------
# power-law tails
# --------------------------------------------------------------------------

def hill_tail_exponent(degrees: np.ndarray, k: int = 0) -> Tuple[float, float]:
    """Hill estimator of the tail exponent gamma (P[deg >= d] ~ d^(1-gamma)).

    Uses the k largest degrees (default ~sqrt(#positive), the classic
    bias/variance compromise).  Returns (gamma_hat, stderr); stderr is
    the asymptotic (gamma-1)/sqrt(k).
    """
    d = np.sort(np.asarray(degrees, np.float64))
    d = d[d > 0]
    if k <= 0:
        k = max(10, int(np.sqrt(len(d))))
    k = min(k, len(d) - 1)
    if k < 2:
        return float("nan"), float("inf")
    tail = d[-k:]
    ref = d[-k - 1]
    # +0.5 continuity shift: degrees are integers, Hill assumes continuity
    logs = np.log((tail + 0.5) / (ref + 0.5))
    mean_log = float(logs.mean())
    if mean_log <= 0:
        return float("nan"), float("inf")
    alpha_inv = 1.0 / mean_log          # Pareto index of the tail
    gamma = 1.0 + alpha_inv
    return float(gamma), float(alpha_inv / np.sqrt(k))


def tail_exponent_from_log2_hist(hist: np.ndarray,
                                 min_count: int = 16) -> Tuple[float, float]:
    """Power-law exponent from a log2-binned degree histogram.

    For counts[b] ~ integral of c * d^-gamma over bin b (width 2^(b-1)),
    log2(counts[b] / width[b]) is linear in the bin's log2 center with
    slope -gamma.  Fits the tail bins (past the histogram mode) with at
    least ``min_count`` mass; returns (gamma_hat, stderr of the slope).
    This is the huge-n path — O(bins) input, no per-vertex data.
    """
    hist = np.asarray(hist, np.float64)
    centers = np.array([0.0] + [1.5 * 2 ** (b - 1) for b in range(1, len(hist))])
    widths = np.array([1.0] + [max(1.0, 2 ** (b - 1)) for b in range(1, len(hist))])
    mode = int(np.argmax(hist))
    sel = np.arange(len(hist)) > mode
    sel &= hist >= min_count
    if sel.sum() < 3:
        return float("nan"), float("inf")
    x = np.log2(centers[sel])
    y = np.log2(hist[sel] / widths[sel])
    A = np.stack([x, np.ones_like(x)], axis=1)
    coef, res, *_ = np.linalg.lstsq(A, y, rcond=None)
    dof = max(1, sel.sum() - 2)
    resid = y - A @ coef
    s2 = float((resid ** 2).sum()) / dof
    cov = s2 * np.linalg.inv(A.T @ A)
    return float(-coef[0]), float(np.sqrt(cov[0, 0]))
