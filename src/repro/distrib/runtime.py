"""repro.distrib.runtime — one mesh-aware, wave-streamed executor for
every plan type.

:mod:`repro.distrib.engine` used to carry three copy-paste executor/
run/stream triples (``edge_executor``/``run_edges``/``stream_chunk_edges``
for :class:`~repro.distrib.engine.ChunkPlan`, ``point_executor``/
``run_points`` for :class:`~repro.distrib.engine.PointPlan`,
``pair_executor``/``run_pairs``/``stream_pair_edges`` for
:class:`~repro.distrib.engine.PairPlan`).  Every one of them was the
same program with a different table: shard the ``[P, C, ...]`` plan
arrays over a mesh, ``vmap`` a kind-specialized per-slot function over
the table, assert the lowering is collective-free, and hand the results
back.  This module is that program written once.

A plan participates by implementing the :class:`PlanProgram` protocol —
three methods plus a static signature:

========================  ====================================================
``input_arrays()``        the plan's ``[P, C, ...]`` table arrays, in the
                          order its slot fn consumes them
``slot_fn()``             the kind-specialized per-slot device function:
                          ``(*slot_rows) -> (payload, valid_mask)``
``stream_index()``        ``[K, 2]`` of ``(pe, slot)`` for every slot that
                          contributes output, in pe-major stream order (the
                          ownership mask as an index: each global chunk /
                          candidate pair / cell appears exactly once)
``signature()``           hashable static program identity (shapes, kinds,
                          capacity, rng impl) — the compile-cache key
========================  ====================================================

On top of the protocol the runtime owns

* **run** (:func:`run`): the materializing path — one jitted
  ``shard_map`` step over the full table, compile-cached per
  ``(signature, mesh)``, with the zero-collective HLO assertion run at
  most once per cache entry (and never skipped for a caller that asked).

* **wave streaming** (:func:`stream_waves`): the scaling path.  The
  plan's owned slots are dealt to the mesh rows that already hold their
  table shards (contiguous PE ranges — the same slicing
  :func:`~repro.distrib.engine.deal_plan` uses for virtual plans), and
  each dispatch executes one ``[D, batch]`` slab of *next* slots for
  every mesh row simultaneously under ``shard_map`` — streaming uses
  the whole mesh, not the default device.  Batches never straddle a PE
  boundary, so every slab row belongs to exactly one virtual PE and
  per-PE stream order is preserved exactly: grouping the streamed rows
  by PE and concatenating reproduces :func:`run`'s output
  bit-for-bit.  Ragged final waves are padded with masked rows (same
  static shapes — one compile per program, never a retrace), slab
  index buffers are donated to the step where the backend supports it,
  and ``prefetch`` waves are kept in flight so wave ``k+1`` is
  dispatched before the host consumes wave ``k``.

* **plan/execute overlap** (:class:`PlanEmitter`): the cold-start path.
  Plan emission is communication-free too, so a plan can be emitted
  one PE-range segment at a time on a background planner thread while
  the runtime executes the previous segment's waves — mirroring the
  wave prefetch double-buffering one level up.  Time-to-first-chunk
  drops from ``plan_s + exec_s`` to roughly ``max(segment_plan_s,
  exec_s)``; per-PE stream order is preserved exactly.

* **meshes**: every entry point takes an explicit ``mesh=`` and accepts
  a multi-process ``jax.make_mesh``.  Table and slab inputs are built
  per process from the host plan (``jax.make_array_from_callback`` when
  the sharding is not fully addressable), and wave outputs are consumed
  shard-wise: each process sees only its addressable mesh rows
  (``Wave.rows`` is ``None`` elsewhere).  The zero-collective invariant
  is asserted on the lowered wave step itself, so the claim covers the
  exact program the mesh executes.
"""
from __future__ import annotations

import queue as _queue
import threading
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, Optional, Protocol, Tuple, runtime_checkable

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# the zero-collective check IS analyze's Pass-1 scanner (one
# implementation for the runtime assertion and the static CI gate)
from ..analyze.hloscan import assert_communication_free
from .engine import default_mesh, shard_map_compat
# host-side tracing only: spans wrap dispatch/consume boundaries on the
# host — nothing below ever closes over obs inside a jitted program
from .. import obs


# --------------------------------------------------------------------------
# the protocol
# --------------------------------------------------------------------------

@runtime_checkable
class PlanProgram(Protocol):
    """What a plan type exposes to execute on the runtime.

    Implemented by :class:`~repro.distrib.engine.ChunkPlan`,
    :class:`~repro.distrib.engine.PointPlan` and
    :class:`~repro.distrib.engine.PairPlan`; any future plan type that
    implements it gets run, wave streaming, caching and the
    zero-collective assertion for free."""

    @property
    def num_pes(self) -> int: ...

    def input_arrays(self) -> Tuple[np.ndarray, ...]: ...

    def slot_fn(self) -> Callable: ...

    def stream_index(self) -> np.ndarray: ...

    def signature(self) -> tuple: ...


def mesh_size(mesh: Mesh) -> int:
    return int(mesh.devices.size)


@functools.lru_cache(maxsize=None)
def mesh_for(P: int) -> Mesh:
    """The cached default 1-D mesh for P virtual PEs (largest device
    count that divides P, so the [P, ...] tables shard evenly)."""
    return default_mesh(P)


def _resolve_mesh(plan: PlanProgram, mesh: Optional[Mesh]) -> Mesh:
    mesh = mesh if mesh is not None else mesh_for(plan.num_pes)
    D = mesh_size(mesh)
    if plan.num_pes % D:
        raise ValueError(
            f"mesh of {D} devices cannot shard a {plan.num_pes}-PE plan: "
            f"the [P, C] tables split over the mesh rows, so P % devices "
            f"must be 0 (re-deal the plan or pass a smaller mesh)")
    return mesh


def _sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec(mesh.axis_names))


def _put(x, ns: NamedSharding):
    """Host array -> device array under ``ns``; per-process shard
    construction when the mesh spans processes (each process supplies
    only its addressable slice of the host table)."""
    if ns.is_fully_addressable:
        return jax.device_put(jnp.asarray(x), ns)
    arr = np.asarray(x)
    return jax.make_array_from_callback(arr.shape, ns, lambda idx: arr[idx])


def _consumable(arr):
    """Make a wave output consumable by this process.  Fully
    addressable (single-process) arrays are handed back as-is — they
    stay on device, so device-side consumers (the stats wedge replay)
    never pay a host round-trip and the host only blocks when it
    actually materializes a buffer.  A multi-process array is read
    through its addressable shards only (non-addressable rows are left
    zero — their ``Wave.rows`` entries are ``None``)."""
    if getattr(arr, "is_fully_addressable", True):
        return arr
    out = np.zeros(arr.shape, arr.dtype)
    for sh in arr.addressable_shards:
        out[sh.index] = np.asarray(sh.data)
    return out


def _local_rows(mesh: Mesh) -> np.ndarray:
    """bool [D]: which mesh rows this process can address."""
    pi = jax.process_index()
    return np.array([d.process_index == pi for d in mesh.devices.ravel()])


# --------------------------------------------------------------------------
# compile cache (one entry per static program signature x mesh x mode)
# --------------------------------------------------------------------------

class _Entry:
    __slots__ = ("fn", "sharding", "checked")

    def __init__(self, fn, sharding):
        self.fn = fn
        self.sharding = sharding
        self.checked = False


_CACHE: Dict[tuple, _Entry] = {}


def cache_clear() -> None:
    _CACHE.clear()
    mesh_for.cache_clear()


# --------------------------------------------------------------------------
# run: the materializing full-table path
# --------------------------------------------------------------------------

def executor(plan: PlanProgram, mesh: Mesh):
    """(jitted fn, sharded inputs) for the plan's full-table SPMD step.

    ``fn(*inputs) -> (payload [P, C, ...], valid [P, C, L])``; ``valid``
    already folds in per-slot validity and ownership masks, so boolean
    extraction of ``payload`` by ``valid`` is the exact global output.
    This is the one executor behind the legacy ``edge_executor`` /
    ``point_executor`` / ``pair_executor`` facades."""
    spec = PartitionSpec(mesh.axis_names)
    one = plan.slot_fn()
    arrays = plan.input_arrays()

    def step(*tables):
        return jax.vmap(jax.vmap(one))(*tables)

    fn = jax.jit(shard_map_compat(
        step, mesh, in_specs=(spec,) * len(arrays), out_specs=(spec, spec)))
    ns = _sharding(mesh)
    inputs = tuple(_put(a, ns) for a in arrays)
    return fn, inputs


def run(plan: PlanProgram, mesh: Optional[Mesh] = None, check: bool = True,
        want_hlo: bool = False):
    """Execute a plan's full table; returns ``(payload, valid, hlo)``.

    The compiled step is cached per ``(signature, mesh)``, so repeated
    runs of structurally identical plans never retrace; the
    zero-collective assertion runs at most once per cache entry
    (identical program => identical HLO) but is never skipped for a
    caller that asked for it.  ``hlo`` is the lowered text when
    ``want_hlo`` (or on the entry's first checked call), else None."""
    mesh = _resolve_mesh(plan, mesh)
    key = ("run", plan.signature(), mesh)
    ent = _CACHE.get(key)
    obs.event("compile_cache", kind="run", hit=ent is not None)
    if ent is None:
        fn, inputs = executor(plan, mesh)
        ent = _CACHE[key] = _Entry(fn, inputs[0].sharding)
    else:
        inputs = tuple(_put(a, ent.sharding) for a in plan.input_arrays())
    hlo = None
    if (check and not ent.checked) or want_hlo:
        lowered = ent.fn.lower(*inputs)
        hlo = lowered.as_text()
        if check:
            assert_communication_free(lowered)
            ent.checked = True
    with obs.trace("run/exec", phase="exec", mode="run"):
        payload, valid = ent.fn(*inputs)
        if obs.is_enabled():
            # measurement mode: attribute device time to this span
            jax.block_until_ready((payload, valid))
    return payload, valid, hlo


def lower_run(plan: PlanProgram, mesh: Optional[Mesh] = None):
    """The ``jax.stages.Lowered`` of a plan's full-table run step.

    What :func:`run`'s ``check=True`` path asserts on and what
    :mod:`repro.analyze.programs` (Pass 1) scans — the same lowering,
    so the static gate verifies the exact program :func:`run`
    executes."""
    mesh = _resolve_mesh(plan, mesh)
    fn, inputs = executor(plan, mesh)
    return fn.lower(*inputs)


# --------------------------------------------------------------------------
# lazily segmented plans: plan/execute overlap
# --------------------------------------------------------------------------
#
# Cold-start latency of the streaming path is plan_s + exec_s: the full
# [P, C] table is emitted before the first wave dispatches.  But plan
# emission is communication-free too — any PE range's rows are a pure
# function of (spec, P) — so the table can be emitted *per PE range*,
# and the range covering the first mesh pass can start executing while
# later ranges are still being planned.  PlanEmitter is that contract:
# ``build(lo, hi)`` emits the plan rows of global PEs [lo, hi) as a
# standalone PlanProgram (num_pes == hi - lo), and stream_waves runs a
# background planner thread feeding segments through a bounded queue —
# the same double-buffering shape as the wave prefetch deque, one level
# up.  Time-to-first-chunk drops from plan_s + exec_s to roughly
# max(segment_plan_s, exec_s); ``plan/overlap`` spans (builder thread)
# against ``wave/*`` spans (consumer thread) make the pipelining
# visible in repro.obs traces.

#: default number of plan segments when the emitter does not pin one
DEFAULT_SEGMENTS = 4


class PlanEmitter:
    """A plan emitted lazily, one PE-range segment at a time.

    ``build(lo, hi)`` must return a :class:`PlanProgram` holding exactly
    the rows of global PEs ``[lo, hi)`` re-indexed to ``[0, hi - lo)``
    — for table plans, field-by-field equal to
    :func:`repro.distrib.engine.slice_plan` of the full emission (the
    segment's *capacity* may be segment-local: per-slot draws are
    capacity-independent, so outputs are unchanged).  Family emitters
    whose per-PE rows are cheap to restrict implement ``build`` natively
    (cost ∝ ``(hi - lo) / P``); :meth:`from_plan` wraps an
    already-built plan for callers that only want the ordering contract.

    Segment boundaries are chosen at stream time: each segment's width
    is a multiple of the mesh row count D, so every segment shards over
    the same mesh.  Segments arrive in ascending-PE order and each
    preserves per-PE stream order, so the concatenated overlapped
    stream regroups to the exact per-PE order of the unsegmented plan.
    """

    def __init__(self, num_pes: int, build: Callable[[int, int], PlanProgram],
                 segments: int = 0):
        self.num_pes = int(num_pes)
        self.build = build
        self.segments = int(segments)

    @classmethod
    def from_plan(cls, plan: PlanProgram, segments: int = 0) -> "PlanEmitter":
        """Segment an already-built table plan via ``slice_plan`` (the
        ordering/overlap contract without lazy emission — useful for
        tests and for feeding the serve scheduler incrementally)."""
        from .engine import slice_plan

        return cls(plan.num_pes, lambda lo, hi: slice_plan(plan, lo, hi),
                   segments)

    def segment_bounds(self, D: int) -> Tuple[Tuple[int, int], ...]:
        """The (lo, hi) PE ranges streamed over a D-row mesh: ~equal
        widths, every width a multiple of D, ascending order."""
        if self.num_pes % D:
            raise ValueError(
                f"mesh of {D} devices cannot shard a {self.num_pes}-PE "
                f"emitter: P % devices must be 0")
        nb = self.num_pes // D
        k = max(1, min(self.segments or DEFAULT_SEGMENTS, nb))
        cuts = [nb * s // k * D for s in range(k + 1)]
        return tuple((cuts[s], cuts[s + 1]) for s in range(k)
                     if cuts[s + 1] > cuts[s])


def _plan_feed(emitter: PlanEmitter, D: int, depth: int = 2) -> _queue.Queue:
    """Start the background planner: builds segments in PE order into a
    bounded queue (planning runs at most ``depth`` segments ahead of
    execution).  Items are ``(index, lo, hi, plan)``, then ``None`` at
    exhaustion; a builder exception is forwarded and re-raised by the
    consumer."""
    q: _queue.Queue = _queue.Queue(maxsize=max(1, int(depth)))
    bounds = emitter.segment_bounds(D)

    def planner() -> None:
        try:
            for i, (lo, hi) in enumerate(bounds):
                with obs.trace("plan/overlap", phase="plan", segment=i,
                               segments=len(bounds), lo=lo, hi=hi):
                    seg = emitter.build(lo, hi)
                q.put((i, lo, hi, seg))
            q.put(None)
        except BaseException as e:  # forwarded to the consumer thread
            q.put(e)

    threading.Thread(target=planner, name="repro-plan-emitter",
                     daemon=True).start()
    return q


def _stream_emitter_waves(emitter: PlanEmitter, mesh: Optional[Mesh],
                          batch: int, prefetch: int,
                          check: bool) -> Iterator["Wave"]:
    """stream_waves over a lazily segmented plan: execute segment k's
    waves while the planner thread emits segment k+1."""
    mesh = mesh if mesh is not None else mesh_for(emitter.num_pes)
    D = mesh_size(mesh)
    feed = _plan_feed(emitter, D)
    while True:
        # un-phased span: stall waiting on the planner (nonzero only
        # when planning, not execution, is the bottleneck)
        with obs.trace("plan/overlap/wait"):
            item = feed.get()
        if item is None:
            return
        if isinstance(item, BaseException):
            raise item
        _, lo, _, seg = item
        for wave in stream_waves(seg, mesh=mesh, batch=batch,
                                 prefetch=prefetch, check=check):
            if lo:
                wave = Wave(payload=wave.payload, valid=wave.valid,
                            rows=tuple(None if r is None else (r[0] + lo, r[1])
                                       for r in wave.rows))
            yield wave


# --------------------------------------------------------------------------
# wave streaming: [D, batch] slabs of next slots for the whole mesh
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class WaveSchedule:
    """Host-side dealing of a plan's stream index onto mesh rows.

    ``sched[w, d, b] = (local_pe, slot)`` addresses row ``b`` of wave
    ``w`` on mesh row ``d`` *within that row's table shard* (virtual
    PEs are dealt to mesh rows in contiguous ranges — exactly how the
    ``[P, ...]`` tables shard, so the device-side gather is local by
    construction).  ``valid`` masks ragged padding rows; ``rows[w][d]``
    is ``(pe, slots)`` or ``None`` for an all-padding row.  Batches
    never straddle a PE boundary, so each slab row has one owning PE
    and per-PE stream order equals the plan's stream index order."""
    sched: np.ndarray       # int32 [W, D, B, 2] (local pe, slot)
    valid: np.ndarray       # bool  [W, D, B]
    rows: tuple             # [W][D] -> (pe, slots np.ndarray) | None
    batch: int              # B, clamped to the longest per-PE run

    @property
    def num_waves(self) -> int:
        return self.sched.shape[0]


def wave_schedule(plan: PlanProgram, D: int, batch: int = 1) -> WaveSchedule:
    index = np.asarray(plan.stream_index())
    P = plan.num_pes
    ppd = P // D
    starts = np.searchsorted(index[:, 0], np.arange(P + 1))
    per_pe = [index[starts[pe]: starts[pe + 1], 1] for pe in range(P)]
    B = max(1, min(int(batch), max((len(s) for s in per_pe), default=1)))
    dealt: list = [[] for _ in range(D)]
    for pe, slots in enumerate(per_pe):
        for s in range(0, len(slots), B):
            dealt[pe // ppd].append((pe, slots[s: s + B]))
    W = max((len(b) for b in dealt), default=0)
    sched = np.zeros((W, D, B, 2), np.int32)
    valid = np.zeros((W, D, B), bool)
    rows = [[None] * D for _ in range(W)]
    for d, batches in enumerate(dealt):
        for w, (pe, slots) in enumerate(batches):
            k = len(slots)
            sched[w, d, :k, 0] = pe - d * ppd
            sched[w, d, :k, 1] = slots
            valid[w, d, :k] = True
            rows[w][d] = (pe, np.asarray(slots))
    return WaveSchedule(sched, valid, tuple(tuple(r) for r in rows), B)


def _wave_fn(plan: PlanProgram, mesh: Mesh, n_tables: int):
    """The jitted shard_map'd wave step: gather each mesh row's next
    ``[B]`` slots from its local table shard, run the slot fn, and mask
    padding rows out of the validity output."""
    spec = PartitionSpec(mesh.axis_names)
    one = plan.slot_fn()

    def step(sched, valid, *tables):
        # blocks: sched [1, B, 2], valid [1, B], tables [P/D, C, ...]
        s, v = sched[0], valid[0]
        rows = [t[s[:, 0], s[:, 1]] for t in tables]      # local gather [B, ...]
        payload, ok = jax.vmap(one)(*rows)
        return payload[None], (ok & v[:, None])[None]

    donate = () if jax.default_backend() == "cpu" else (0, 1)  # slab buffers
    return jax.jit(shard_map_compat(
        step, mesh, in_specs=(spec,) * (2 + n_tables), out_specs=(spec, spec)),
        donate_argnums=donate)


@dataclass(frozen=True)
class Wave:
    """One executed ``[D, batch]`` slab: every mesh row's next slots.

    ``payload[d]`` / ``valid[d]`` are mesh row ``d``'s batch of slot
    outputs with the padding already masked; ``rows[d]`` names the
    owning virtual PE and its slot ids (``None`` for an all-padding or
    non-addressable row).  On a single-process mesh the slabs are
    still *device* arrays — the host only blocks when a consumer
    materializes one.  Iterating :meth:`chunks` yields the per-PE view
    in pe order within the wave."""
    payload: object         # [D, B, ...] device array (host if multi-process)
    valid: object           # [D, B, L]
    rows: tuple             # [D] -> (pe, slots) | None

    def chunks(self) -> Iterator[Tuple[int, np.ndarray, object, object]]:
        """Yield ``(pe, slots, payload [B, ...], valid [B, L])`` per
        non-empty mesh row.  Rows keep the full static batch shape —
        ragged tails beyond ``len(slots)`` are masked, never trimmed,
        so jitted downstream consumers see one shape per program and
        never retrace."""
        for d, row in enumerate(self.rows):
            if row is None:
                continue
            pe, slots = row
            yield pe, slots, self.payload[d], self.valid[d]


def lower_wave(plan: PlanProgram, mesh: Optional[Mesh] = None,
               batch: int = 1):
    """The ``jax.stages.Lowered`` of a plan's shard_map'd wave step.

    The streaming analog of :func:`lower_run`: Pass 1 of
    :mod:`repro.analyze` scans this module for every registered plan,
    so the zero-collective / no-host-callback / deterministic-PRNG
    contracts are verified on the program :func:`stream_waves` actually
    dispatches, not a per-slot proxy.  Returns ``None`` for a plan with
    no owned slots (nothing would ever execute)."""
    mesh = _resolve_mesh(plan, mesh)
    D = mesh_size(mesh)
    ws = wave_schedule(plan, D, batch)
    if not ws.num_waves:
        return None
    arrays = plan.input_arrays()
    fn = _wave_fn(plan, mesh, len(arrays))
    ns = _sharding(mesh)
    tables = tuple(_put(a, ns) for a in arrays)
    return fn.lower(_put(ws.sched[0], ns), _put(ws.valid[0], ns), *tables)


def stream_waves(
    plan,
    mesh: Optional[Mesh] = None,
    batch: int = 1,
    prefetch: int = 2,
    check: bool = False,
) -> Iterator[Wave]:
    """Stream a plan (or a lazily segmented one) as :class:`Wave` slabs.

    Each dispatch executes the next ``batch`` slots of *every* mesh row
    simultaneously; ``prefetch`` waves are kept in flight (wave ``k+1``
    dispatches before the host consumes wave ``k`` — JAX's async
    dispatch does the overlapping, the deque here just bounds it), so
    peak memory is O(prefetch · D · batch · capacity), never O(total
    output).  ``check=True`` asserts the zero-collective invariant on
    the lowered wave step itself — the shard_map'd program that actually
    runs, not a single slot's fn — once per program signature.

    Per-PE stream order is exact: concatenating a PE's rows across
    waves reproduces its :func:`run` output prefix bit-for-bit, and on
    a single-row mesh the flattened wave order *is* pe-major run order.

    Passing a :class:`PlanEmitter` streams through the plan/execute
    overlap path: segments are built on a background thread (bounded
    queue, ``plan/overlap`` spans) while earlier segments' waves
    execute, and yielded ``Wave.rows`` carry *global* PE ids — the
    regrouped stream is identical to streaming the full plan.
    """
    if isinstance(plan, PlanEmitter):
        yield from _stream_emitter_waves(plan, mesh, batch, prefetch, check)
        return
    mesh = _resolve_mesh(plan, mesh)
    D = mesh_size(mesh)
    with obs.trace("wave/schedule", phase="exec", D=D, batch=batch):
        ws = wave_schedule(plan, D, batch)
    if not ws.num_waves:
        return
    arrays = plan.input_arrays()
    key = ("wave", plan.signature(), mesh, ws.batch)
    ent = _CACHE.get(key)
    obs.event("compile_cache", kind="wave", hit=ent is not None)
    if ent is None:
        fn = _wave_fn(plan, mesh, len(arrays))
        ent = _CACHE[key] = _Entry(fn, _sharding(mesh))
    ns = ent.sharding
    tables = tuple(_put(a, ns) for a in arrays)
    if check and not ent.checked:
        assert_communication_free(ent.fn.lower(
            _put(ws.sched[0], ns), _put(ws.valid[0], ns), *tables))
        ent.checked = True
    local = _local_rows(mesh)
    traced = obs.is_enabled()

    def emit(rows, out) -> Wave:
        payload, valid = out
        if traced:
            # measurement mode: drain the async dispatch here so device
            # time lands in its own span (costs overlap when disabled)
            with obs.trace("wave/device", phase="exec"):
                jax.block_until_ready((payload, valid))
        with obs.trace("wave/sink", phase="sink"):
            kept = tuple(r if local[d] else None for d, r in enumerate(rows))
            return Wave(payload=_consumable(payload),
                        valid=_consumable(valid), rows=kept)

    pending: deque = deque()
    for w in range(ws.num_waves):
        with obs.trace("wave/dispatch", phase="exec", wave=w):
            out = ent.fn(_put(ws.sched[w], ns), _put(ws.valid[w], ns), *tables)
        pending.append((ws.rows[w], out))
        if len(pending) >= max(1, int(prefetch)):
            yield emit(*pending.popleft())
    while pending:
        yield emit(*pending.popleft())


# --------------------------------------------------------------------------
# slab execution: packed [D, B] rows from *different* plans (repro.serve)
# --------------------------------------------------------------------------
#
# Wave streaming above executes one plan's next slots.  The serving
# scheduler (repro.serve.scheduler) goes one step further: it packs
# ready slots from *many concurrent requests* — different plans, same
# static program — into one [D, B] slab.  The device step is the same
# shard_map'd vmap as _wave_fn minus the table gather: the host already
# assembled each row's inputs (a gather across plans is not expressible
# as a local table index), so the step consumes the row arrays directly.
# Compiles are cached per (signature, row shapes, mesh) — every slab of
# a packing group reuses one executable — and the zero-collective
# contract is asserted on the lowered slab step itself, once per entry.

def _slab_fn(slot_fn, mesh: Mesh, n_rows: int):
    spec = PartitionSpec(mesh.axis_names)

    def step(valid, *rows):
        # blocks: valid [1, B], rows [1, B, ...] — no cross-row indexing
        payload, ok = jax.vmap(slot_fn)(*(r[0] for r in rows))
        return payload[None], (ok & valid[0][:, None])[None]

    donate = () if jax.default_backend() == "cpu" else tuple(range(1 + n_rows))
    return jax.jit(shard_map_compat(
        step, mesh, in_specs=(spec,) * (1 + n_rows), out_specs=(spec, spec)),
        donate_argnums=donate)


def _slab_key(signature: tuple, valid: np.ndarray, rows, mesh: Mesh) -> tuple:
    return ("slab", signature, valid.shape,
            tuple((r.shape[1:], np.asarray(r).dtype.str) for r in rows), mesh)


def run_slab(slot_fn_thunk: Callable, signature: tuple, valid: np.ndarray,
             rows, mesh: Mesh, check: bool = True):
    """Execute one packed ``[D, B]`` slab; returns ``(payload, valid)``.

    ``rows`` are the per-slot input arrays (``[D, B, ...]``, one per
    table the slot fn consumes) assembled by the scheduler from any mix
    of source plans sharing the static program named by ``signature``;
    ``valid`` masks padding rows.  ``slot_fn_thunk`` is only called on
    a compile-cache miss, so steady-state dispatch never rebuilds the
    slot fn.  ``check=True`` asserts the zero-collective contract on
    the lowered slab step once per cache entry — the packed
    mixed-request program itself, not a proxy."""
    valid = np.asarray(valid, bool)
    key = _slab_key(signature, valid, rows, mesh)
    ent = _CACHE.get(key)
    obs.event("compile_cache", kind="slab", hit=ent is not None)
    if ent is None:
        fn = _slab_fn(slot_fn_thunk(), mesh, len(rows))
        ent = _CACHE[key] = _Entry(fn, _sharding(mesh))
    ns = ent.sharding
    inputs = (_put(valid, ns),) + tuple(_put(r, ns) for r in rows)
    if check and not ent.checked:
        assert_communication_free(ent.fn.lower(*inputs))
        ent.checked = True
        inputs = (_put(valid, ns),) + tuple(_put(r, ns) for r in rows)
    with obs.trace("slab/exec", phase="exec", mode="slab"):
        payload, ok = ent.fn(*inputs)
        if obs.is_enabled():
            jax.block_until_ready((payload, ok))
    return _consumable(payload), _consumable(ok)


def lower_slab(slot_fn: Callable, valid: np.ndarray, rows,
               mesh: Optional[Mesh] = None):
    """The ``jax.stages.Lowered`` of a packed slab step — what
    :func:`run_slab`'s ``check`` asserts on and what
    :mod:`repro.analyze.programs` scans for the serve family."""
    mesh = mesh if mesh is not None else mesh_for(np.asarray(valid).shape[0])
    fn = _slab_fn(slot_fn, mesh, len(rows))
    ns = _sharding(mesh)
    inputs = (_put(np.asarray(valid, bool), ns),) + tuple(
        _put(r, ns) for r in rows)
    return fn.lower(*inputs)


def stream_slots(
    plan,
    mesh: Optional[Mesh] = None,
    batch: int = 1,
    prefetch: int = 2,
    check: bool = False,
) -> Iterator[Tuple[int, np.ndarray, np.ndarray, np.ndarray]]:
    """Flattened :func:`stream_waves`: yield ``(pe, slots, payload,
    valid)`` per mesh-row batch, in wave order (pe-major on a
    single-row mesh).  The per-(pe, slot) consumer loop the legacy
    ``stream_*`` facades are built on.  Accepts a :class:`PlanEmitter`
    for the overlapped path (``pe`` is then the global PE id)."""
    for wave in stream_waves(plan, mesh=mesh, batch=batch,
                             prefetch=prefetch, check=check):
        yield from wave.chunks()
