"""Generator-agnostic zero-collective sharded execution engine.

The paper's headline property — embarrassingly parallel, communication-
free generation — is realized here as a *table-driven* SPMD program:

1. ``shard_map_compat``: a version-compatible ``shard_map`` shim
   (``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on
   0.4.x) plus the HLO zero-collective assertion as a reusable
   invariant (``assert_communication_free``).

2. ``ChunkPlan`` / ``PointPlan``: per-PE tables — chunk keys, universes,
   counts, fixed capacities and decode parameters — emitted by the host
   divide-and-conquer recursions (the only O(P)-ish sequential work).

3. One jitted SPMD program for *every* plan type, owned by
   :mod:`repro.distrib.runtime`: each plan implements the
   ``PlanProgram`` protocol (``input_arrays`` / ``slot_fn`` /
   ``stream_index`` / ``signature``) and the runtime supplies
   jit + ``shard_map``, compile caching, the zero-collective
   assertion, materializing runs and mesh-wide wave streaming.  The
   ``edge_executor``/``run_edges``/``stream_chunk_edges``,
   ``point_executor``/``run_points`` and
   ``pair_executor``/``run_pairs``/``stream_pair_edges`` entry points
   below are thin facades over it, kept for their call sites.

Exact union without sorting: each chunk row carries an ``owned`` bit.
Undirected chunk (I, J) is generated bit-identically on PE I and PE J
(the paper's <= 2m recomputation bound) but *kept* only by its
designated owner (the row PE), so the concatenated output is exactly
the global edge set — no O(m log m) ``np.unique`` dedup.

Plan emitters live next to their generators: ``core.er`` (directed and
undirected G(n,m), G(n,p)), ``core.rgg`` (cube vertex plans + GEOM_TORUS
pair plans), ``core.rdg`` (GEOM_CERT simplex-certificate pair plans) and
``core.rhg`` (polar vertex plans + GEOM_HYP pair plans).  The geometric
edge phase is one kind-tagged ``PairPlan`` executor shared by all three
families.
"""
from __future__ import annotations

import dataclasses
import inspect
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..core.prng import counter_uniform, fold_in64
from ..core.sampling import (
    decode_directed,
    decode_rect,
    decode_tri,
    round_up_capacity,
    sample_wo_replacement,
)

try:  # JAX >= 0.5 exposes shard_map at the top level
    _shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # JAX 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """Version-compatible ``shard_map`` (0.4.x and 0.5+/0.6+).

    Replication checking is off by default: the sampler's bounded
    ``while_loop`` has no replication rule on 0.4.x (the parameter is
    ``check_rep`` there, ``check_vma`` on new JAX)."""
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    params = inspect.signature(_shard_map).parameters
    if "check_rep" in params:
        kwargs["check_rep"] = check
    elif "check_vma" in params:
        kwargs["check_vma"] = check
    return _shard_map(f, **kwargs)


# --------------------------------------------------------------------------
# the zero-collective invariant
# --------------------------------------------------------------------------
#
# One scanner, shared verbatim with the static CI gate: the historical
# names below re-export repro.analyze.hloscan (Pass 1 of the contract
# verifier), so the runtime's check=True path and `python -m
# repro.analyze --all-programs` walk lowered modules with the same
# code.  The scanner matches both the StableHLO spelling
# (`stablehlo.all_reduce`) of Lowered.as_text() and the hyphenated HLO
# spelling of Compiled.as_text() — the original engine regex knew only
# the latter, so a planted psum in the StableHLO lowering passed the
# "assertion" unseen (tests/test_analyze.py now plants one to keep the
# scanner honest).

from ..analyze.hloscan import (  # noqa: F401  (re-exported invariant)
    COLLECTIVE_RE,
    assert_communication_free,
    collective_ops_in,
)


def default_mesh(P: int, axis: str = "pe") -> Mesh:
    """1-D mesh over the most local devices that divide P evenly."""
    ndev = len(jax.devices())
    use = max(d for d in range(1, min(ndev, P) + 1) if P % d == 0)
    return Mesh(np.array(jax.devices()[:use]), (axis,))


# --------------------------------------------------------------------------
# edge plans: the unified ER-family table
# --------------------------------------------------------------------------

# chunk kinds understood by the SPMD edge step
KIND_EMPTY, KIND_DIRECTED, KIND_TRI, KIND_RECT, KIND_RMAT, KIND_BA = 0, 1, 2, 3, 4, 5

# kinds whose edges come from the without-replacement index sampler
SAMPLED_KINDS = frozenset({KIND_DIRECTED, KIND_TRI, KIND_RECT})


@dataclass(frozen=True)
class ChunkSpec:
    """One chunk as the host D&C recursion emits it.

    ``params`` is kind-specific: DIRECTED -> (row_lo, n, 0) (the global
    vertex count rides in the table so the decode is data, not a
    compile-time constant — plans for different n share one program);
    TRI -> (lo, 0, 0); RECT -> (width, rlo, clo); RMAT -> (log_n,
    edge_lo, 0); BA -> (d, edge_lo, 0).  ``fparams`` holds kind-specific
    reals (RMAT: the (a, b, c) quadrant probabilities).

    ``key`` is the PRNG key of the chunk's hash path — either a typed
    JAX key or its raw uint32 key data (emitters batch-compute the
    latter to avoid per-chunk dispatches).
    """
    kind: int
    key: object             # jax key or uint32 key-data array
    universe: int
    count: int
    params: Tuple[int, int, int]
    owned: bool = True
    fparams: Tuple[float, ...] = ()


@dataclass(frozen=True)
class ChunkPlan:
    """Host-emitted table driving the unified SPMD edge engine.

    All arrays have leading dims [P, C] (PE x chunk slot, padded with
    KIND_EMPTY rows); the device program is pure table execution.
    """
    kind: np.ndarray        # int32  [P, C]
    key_data: np.ndarray    # uint32 [P, C, W]  (W = key words of rng_impl)
    universe: np.ndarray    # int64  [P, C]
    count: np.ndarray       # int64  [P, C]
    params: np.ndarray      # int64  [P, C, 3]
    fparams: np.ndarray     # float64 [P, C, 4]
    owned: np.ndarray       # bool   [P, C]
    n: int                  # global vertex count (metadata; decode reads params)
    capacity: int           # fixed per-chunk buffer (static shape)
    rng_impl: str = "threefry2x32"
    # seed -> equivalent plan for that seed, closing over the
    # seed-independent structure (see reseed()); excluded from the
    # signature so reseeded plans share compiled programs.
    reseed_fn: Optional[Callable[[int], "ChunkPlan"]] = field(
        default=None, compare=False, repr=False)

    @property
    def num_pes(self) -> int:
        return self.kind.shape[0]

    @property
    def chunks_per_pe(self) -> int:
        return self.kind.shape[1]

    @property
    def total_edges(self) -> int:
        return int(self.count[self.owned].sum())

    @property
    def kinds_present(self) -> Tuple[int, ...]:
        """Distinct non-empty chunk kinds — static per plan, so the
        device program only lowers the decode paths it actually needs."""
        return tuple(sorted(int(k) for k in np.unique(self.kind) if k != KIND_EMPTY))  # repro: allow(no-numpy-unique) O(P*C) static plan metadata, not edge dedup

    @property
    def rmat_log_n(self) -> int:
        """Static descent depth shared by every RMAT chunk in the plan."""
        sel = self.kind == KIND_RMAT
        return int(self.params[sel, 0].max()) if sel.any() else 0

    # ---- PlanProgram protocol (repro.distrib.runtime) ----

    def input_arrays(self) -> Tuple[np.ndarray, ...]:
        return _plan_arrays(self)

    def slot_fn(self):
        return _edge_chunk_fn(self.capacity, self.rng_impl,
                              self.kinds_present, self.rmat_log_n)

    def stream_index(self) -> np.ndarray:
        return owned_chunk_index(self)

    def signature(self) -> tuple:
        # n is deliberately absent: the directed decode reads it from
        # params, so plans differing only in n share one compiled program.
        return ("chunk", self.kind.shape, self.key_data.shape[-1],
                self.capacity, self.rng_impl, self.kinds_present,
                self.rmat_log_n)

    def reseed(self, seed: int) -> "ChunkPlan":
        """The plan this emitter would have produced for ``seed``.

        Costs only the seed-*dependent* work (counts + key columns);
        the structure tables are reused.  The serving plan cache's hit
        path is exactly this call."""
        if self.reseed_fn is None:
            raise ValueError(
                "plan carries no reseed emitter; re-emit from the GraphSpec")
        from .. import obs
        with obs.trace("plan/reseed", phase="plan", reseed=True,
                       plan=type(self).__name__):
            return self.reseed_fn(int(seed))


def _key_data_of(key) -> np.ndarray:
    """Accepts a typed JAX key or precomputed uint32 key data."""
    if isinstance(key, np.ndarray):
        return key.ravel()
    return np.asarray(jax.random.key_data(key)).ravel()


def make_chunk_plan(
    per_pe: Sequence[Sequence[ChunkSpec]],
    n: int,
    capacity: Optional[int] = None,
    rng_impl: str = "threefry2x32",
) -> ChunkPlan:
    """Pad per-PE chunk lists into the rectangular plan tables."""
    P = len(per_pe)
    C = max(1, max((len(row) for row in per_pe), default=1))
    first = next((row[0] for row in per_pe if row), None)
    width = len(_key_data_of(first.key)) if first is not None else 2
    kind = np.zeros((P, C), np.int32)
    key_data = np.zeros((P, C, width), np.uint32)
    universe = np.zeros((P, C), np.int64)
    count = np.zeros((P, C), np.int64)
    params = np.zeros((P, C, 3), np.int64)
    fparams = np.zeros((P, C, 4), np.float64)
    owned = np.zeros((P, C), bool)
    for pe, row in enumerate(per_pe):
        for j, spec in enumerate(row):
            kind[pe, j] = spec.kind
            key_data[pe, j] = _key_data_of(spec.key)
            universe[pe, j] = spec.universe
            count[pe, j] = spec.count
            params[pe, j] = spec.params
            if spec.fparams:
                fparams[pe, j, : len(spec.fparams)] = spec.fparams
            owned[pe, j] = spec.owned
    cap = capacity if capacity is not None else round_up_capacity(int(count.max()) if count.size else 0)
    return ChunkPlan(kind, key_data, universe, count, params, fparams, owned, n, cap, rng_impl)


def chunk_plan_from_columns(
    P: int,
    pe: np.ndarray,
    kind: np.ndarray,
    key_data: np.ndarray,
    universe: np.ndarray,
    count: np.ndarray,
    params: np.ndarray,
    owned: np.ndarray,
    n: int,
    fparams: Optional[np.ndarray] = None,
    capacity: Optional[int] = None,
    rng_impl: str = "threefry2x32",
) -> ChunkPlan:
    """Vectorized :func:`make_chunk_plan`: flat per-chunk columns in.

    ``pe`` [k] assigns each flat row to its PE; within-PE slot order is
    the rows' order of appearance (a stable sort groups them), exactly
    the order a per-PE ``ChunkSpec`` list would have had.  All other
    columns are [k] / [k, W] / [k, 3] / [k, F<=4] arrays.  Capacity
    defaults follow :func:`make_chunk_plan`, so a column-built plan is
    bit-identical to the padded-list path given the same rows."""
    pe = np.asarray(pe, np.int64)
    k = len(pe)
    per = np.bincount(pe, minlength=P) if k else np.zeros(P, np.int64)
    C = max(1, int(per.max()) if per.size else 0)
    W = key_data.shape[-1] if k else 2
    order = np.argsort(pe, kind="stable")
    spe = pe[order]
    starts = np.concatenate(([0], np.cumsum(per)))
    col = np.arange(k, dtype=np.int64) - starts[spe]
    t_kind = np.zeros((P, C), np.int32)
    t_key = np.zeros((P, C, W), np.uint32)
    t_uni = np.zeros((P, C), np.int64)
    t_cnt = np.zeros((P, C), np.int64)
    t_par = np.zeros((P, C, 3), np.int64)
    t_fpar = np.zeros((P, C, 4), np.float64)
    t_own = np.zeros((P, C), bool)
    if k:
        t_kind[spe, col] = np.asarray(kind, np.int32)[order]
        t_key[spe, col] = np.asarray(key_data, np.uint32)[order]
        t_uni[spe, col] = np.asarray(universe, np.int64)[order]
        t_cnt[spe, col] = np.asarray(count, np.int64)[order]
        t_par[spe, col] = np.asarray(params, np.int64)[order]
        if fparams is not None:
            fp = np.asarray(fparams, np.float64)
            t_fpar[spe, col, : fp.shape[-1]] = fp[order]
        t_own[spe, col] = np.asarray(owned, bool)[order]
    cap = capacity if capacity is not None else round_up_capacity(
        int(count.max()) if k else 0)
    return ChunkPlan(t_kind, t_key, t_uni, t_cnt, t_par, t_fpar, t_own,
                     n, cap, rng_impl)


def deal_plan(plan: ChunkPlan, P: int) -> ChunkPlan:
    """Re-deal a plan built for k *virtual* chunks onto P real PEs.

    The generated instance is a function of the virtual chunk grid, not
    of the machine size (KaGen's chunks >= PEs decoupling): the owned
    rows of the k-PE plan are dealt round-robin onto P PEs, so any P
    executes the identical edge set.  Mirror (recomputed, un-owned)
    rows are dropped — ownership already makes the union exact.
    """
    from .. import obs
    with obs.trace("plan/deal", phase="plan", P=P, virtual=plan.num_pes):
        return _deal_plan(plan, P)


def _deal_plan(plan: ChunkPlan, P: int) -> ChunkPlan:
    # np.argwhere walks v-major, c-minor — the exact order the old
    # per-row append loop visited, so dealing by stable sort on v % P
    # reproduces its slot layout without any per-chunk Python work.
    idx = np.argwhere(plan.owned & (plan.kind != KIND_EMPTY))
    src = (idx[:, 0], idx[:, 1])
    dealt = chunk_plan_from_columns(
        P, idx[:, 0] % P, plan.kind[src], plan.key_data[src],
        plan.universe[src], plan.count[src], plan.params[src],
        np.ones(len(idx), bool), plan.n, fparams=plan.fparams[src],
        capacity=plan.capacity, rng_impl=plan.rng_impl)
    reseed = None
    if plan.reseed_fn is not None:
        reseed = lambda s, _p=plan, _P=P: deal_plan(_p.reseed(s), _P)
    return dataclasses.replace(dealt, reseed_fn=reseed)


def reseedable_chunk_plan(plan: ChunkPlan, key_fn: Callable[[int], np.ndarray],
                          count_fn: Optional[Callable[[int], np.ndarray]] = None,
                          ) -> ChunkPlan:
    """Attach a structure/seed-split reseed emitter to a ChunkPlan.

    The kind/universe/params/fparams/owned tables of the ER-family and
    preferential-attachment plans depend only on the *shape* of the spec
    (n, m/p, chunk grid) — never on the seed.  Reseeding therefore
    reduces to recomputing the two seed-dependent columns against the
    cached structure:

    * ``key_fn(seed) -> uint32 [k, W]`` — key data for the k non-empty
      chunks in table (pe-major) order, and
    * ``count_fn(seed) -> int64 [k]`` — their edge counts (omit for
      families like BA/RMAT whose counts are seed-independent, where
      reseeding is a pure key swap).

    The derived capacity follows :func:`make_chunk_plan`'s default rule
    so a reseeded plan is bit-identical to a cold emission."""
    pos = np.argwhere(plan.kind != KIND_EMPTY)
    idx = (pos[:, 0], pos[:, 1])

    def emit(seed: int) -> ChunkPlan:
        if count_fn is None:
            count, cap = plan.count, plan.capacity
        else:
            flat = np.asarray(count_fn(seed), np.int64)
            count = np.zeros_like(plan.count)
            count[idx] = flat
            cap = round_up_capacity(int(flat.max()) if flat.size else 0)
        key_data = np.zeros_like(plan.key_data)
        key_data[idx] = np.asarray(key_fn(seed), np.uint32)
        return dataclasses.replace(plan, key_data=key_data, count=count,
                                   capacity=cap, reseed_fn=emit)

    return dataclasses.replace(plan, reseed_fn=emit)


def _edge_chunk_fn(capacity: int, rng_impl: str,
                   kinds: Sequence[int] = SAMPLED_KINDS, log_n: int = 0):
    """Per-chunk device program, specialized to the kinds in the plan.

    Sampled kinds (DIRECTED/TRI/RECT) share one without-replacement
    index draw + per-kind decode; RMAT runs the per-edge hashed quadrant
    descent (one fold_in per edge id, ``log_n`` uniforms); BA resolves
    the Batagelj-Brandes position chain with a hashed ``while_loop``
    (Sanders-Schulz).  Only the branches for kinds actually present are
    lowered, so an RMAT plan never pays for the sampler's sort and vice
    versa.  All draws are capacity-independent per slot, preserving the
    cross-PE recomputation invariant.
    """
    kinds = frozenset(int(k) for k in kinds) - {KIND_EMPTY}
    sampled = kinds & SAMPLED_KINDS

    def one_chunk(kind, kd, universe, count, params, fparams, owned):
        key = jax.random.wrap_key_data(kd, impl=rng_impl)
        p0, p1 = params[0], params[1]
        idx = jnp.arange(capacity, dtype=jnp.int64)
        u = v = jnp.zeros(capacity, jnp.int64)

        if sampled:
            vals, _ = sample_wo_replacement(key, universe, count, capacity)
            if KIND_DIRECTED in sampled:
                du, dv = decode_directed(vals, p1, p0)  # p1 = global n (traced)
                u = jnp.where(kind == KIND_DIRECTED, du, u)
                v = jnp.where(kind == KIND_DIRECTED, dv, v)
            if KIND_TRI in sampled:
                tu, tv = decode_tri(vals, p0)
                u = jnp.where(kind == KIND_TRI, tu, u)
                v = jnp.where(kind == KIND_TRI, tv, v)
            if KIND_RECT in sampled:
                width = jnp.maximum(jnp.where(kind == KIND_RECT, p0, 1), 1)
                ru, rv = decode_rect(vals, width, params[1], params[2])
                u = jnp.where(kind == KIND_RECT, ru, u)
                v = jnp.where(kind == KIND_RECT, rv, v)

        if KIND_RMAT in kinds:
            a, b, c = fparams[0], fparams[1], fparams[2]

            def one_edge(eid):
                k = fold_in64(key, eid)  # 64-bit safe: ids exceed 2^32 at scale
                uu = jax.random.uniform(k, (log_n,), dtype=jnp.float64)
                quad = (
                    (uu >= a).astype(jnp.int64)
                    + (uu >= a + b).astype(jnp.int64)
                    + (uu >= a + b + c).astype(jnp.int64)
                )
                bits = jnp.arange(log_n - 1, -1, -1, dtype=jnp.int64)
                src = jnp.sum((quad >= 2).astype(jnp.int64) << bits)
                dst = jnp.sum((quad % 2) << bits)
                return src, dst

            ru, rv = jax.vmap(one_edge)(p1 + idx)
            u = jnp.where(kind == KIND_RMAT, ru, u)
            v = jnp.where(kind == KIND_RMAT, rv, v)

        if KIND_BA in kinds:
            d = jnp.maximum(p0, 1)
            is_ba = kind == KIND_BA

            def resolve(eid):
                # non-BA chunks start at an even position: zero iterations
                pos = jnp.where(is_ba, 2 * eid + 1, jnp.int64(0))

                def cond(p):
                    return (p % 2) == 1

                def body(p):
                    kk = fold_in64(key, p)
                    return jax.random.randint(kk, (), 0, p, dtype=jnp.int64)

                pos = jax.lax.while_loop(cond, body, pos)
                return (pos // 2) // d

            eids = p1 + idx
            u = jnp.where(is_ba, eids // d, u)
            v = jnp.where(is_ba, jax.vmap(resolve)(eids), v)

        keep = (idx < count) & owned & (kind != KIND_EMPTY)
        return jnp.stack([u, v], axis=-1), keep

    return one_chunk


_EDGE_INPUTS = ("kind", "key_data", "universe", "count", "params", "fparams", "owned")


def _plan_arrays(plan: ChunkPlan):
    return tuple(getattr(plan, name) for name in _EDGE_INPUTS)


def edge_executor(plan: ChunkPlan, mesh: Mesh):
    """(jitted fn, sharded inputs) for the plan's SPMD edge step.

    fn(*inputs) -> (edges [P, C, cap, 2], keep [P, C, cap]); ``keep``
    already folds in validity masks and canonical chunk ownership.
    Facade over :func:`repro.distrib.runtime.executor`.
    """
    from . import runtime

    return runtime.executor(plan, mesh)


def run_edges(plan: ChunkPlan, mesh: Optional[Mesh] = None, check: bool = True):
    """Execute a ChunkPlan; returns (edges [k, 2] int64, hlo_text).

    The output is the exact global edge set: every chunk is emitted by
    its designated owner only, so no sort/unique dedup is needed.
    Facade over :func:`repro.distrib.runtime.run`.
    """
    from . import runtime

    edges, keep, hlo = runtime.run(plan, mesh, check=check, want_hlo=True)
    return np.asarray(edges)[np.asarray(keep)], hlo


def owned_chunk_index(plan: ChunkPlan) -> np.ndarray:
    """int64 [K, 2] of (pe, slot) for every owned non-empty chunk, in
    stream order (pe-major — exactly :func:`stream_chunk_edges` order).

    This is the plan's *ownership mask* as an index: each global chunk
    appears exactly once (mirrored recomputed chunks are excluded), so
    any per-chunk consumer that walks it — edge writers, the
    :mod:`repro.stats` accumulators — sees the exact global edge
    multiset with no sort/unique dedup; the (pe, slot) rows additionally
    say which PE emitted what (surfaced as ``EdgeChunk.pe``).
    """
    sel = plan.owned & (plan.kind != KIND_EMPTY)
    return np.argwhere(sel).astype(np.int64)


def stream_chunk_edges(plan: ChunkPlan, check: bool = False, with_pe: bool = False,
                       mesh: Optional[Mesh] = None, prefetch: int = 2):
    """Yield (buffer [cap, 2], count) per *owned* chunk.

    The streaming consumer path: per-chunk counts are host data, so a
    2^30-edge plan is emitted chunk-by-chunk into O(capacity) buffers
    instead of a [P, C, cap, 2] materialization.  Valid edges are the
    first ``count`` rows (owned chunks always have a contiguous
    validity prefix).  Facade over
    :func:`repro.distrib.runtime.stream_slots` at batch=1: chunks
    arrive in wave order — on a single-device mesh that is exactly
    :func:`owned_chunk_index` (= :func:`run_edges`) order; on wider
    meshes per-PE order is preserved and grouping by ``pe`` reproduces
    the run output.  ``check`` asserts zero collectives on the lowered
    wave step itself (the shard_map'd dispatch, once per program
    signature).  ``with_pe`` prepends the owning PE to each tuple.
    """
    from . import runtime

    for pe, slots, payload, _ in runtime.stream_slots(
            plan, mesh=mesh, batch=1, prefetch=prefetch, check=check):
        out = (payload[0], int(plan.count[pe, slots[0]]))
        yield (int(pe), *out) if with_pe else out


# --------------------------------------------------------------------------
# point plans: spatial (RGG cube cells) and radial (RHG annulus cells)
# --------------------------------------------------------------------------

POINTS_CUBE, POINTS_POLAR = "cube", "polar"


@dataclass(frozen=True)
class PointPlan:
    """Per-PE cell table for sharded vertex generation.

    kind == 'cube':  point = (cell + u) / scale           (scale = grid g)
    kind == 'polar': r = arccosh(g0 + u0*(g1 - g0)) / scale  (scale = alpha)
                     theta = (cell[1] + u1) * g2
    """
    kind: str               # POINTS_CUBE | POINTS_POLAR (static)
    key_data: np.ndarray    # uint32  [P, C, W] per-cell key
    count: np.ndarray       # int64   [P, C]
    cell: np.ndarray        # int64   [P, C, K] integer cell coordinates
    geom: np.ndarray        # float64 [P, C, G] kind-specific reals
    scale: float
    dim: int                # output dims per point
    capacity: int
    rng_impl: str = "threefry2x32"
    reseed_fn: Optional[Callable[[int], "PointPlan"]] = field(
        default=None, compare=False, repr=False)

    @property
    def num_pes(self) -> int:
        return self.count.shape[0]

    @property
    def total_points(self) -> int:
        return int(self.count.sum())

    # ---- PlanProgram protocol (repro.distrib.runtime) ----

    def input_arrays(self) -> Tuple[np.ndarray, ...]:
        return (self.key_data, self.count, self.cell, self.geom)

    def slot_fn(self):
        return _point_cell_fn(self.kind, self.capacity, self.dim,
                              self.scale, self.rng_impl)

    def stream_index(self) -> np.ndarray:
        """Non-empty cells in pe-major order (cells are globally unique
        by construction, so every populated cell is 'owned')."""
        return np.argwhere(self.count > 0).astype(np.int64)

    def signature(self) -> tuple:
        return ("point", self.kind, self.count.shape,
                self.key_data.shape[-1], self.cell.shape[-1],
                self.geom.shape[-1], self.scale, self.dim, self.capacity,
                self.rng_impl)

    def reseed(self, seed: int) -> "PointPlan":
        """Equivalent plan for ``seed`` from the cached cell structure
        (see :meth:`ChunkPlan.reseed`)."""
        if self.reseed_fn is None:
            raise ValueError(
                "plan carries no reseed emitter; re-emit from the GraphSpec")
        from .. import obs
        with obs.trace("plan/reseed", phase="plan", reseed=True,
                       plan=type(self).__name__):
            return self.reseed_fn(int(seed))


def make_point_plan(
    per_pe: Sequence[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]],
    kind: str,
    scale: float,
    dim: int,
    capacity: Optional[int] = None,
    rng_impl: str = "threefry2x32",
) -> PointPlan:
    """per_pe: one (key_data [Ci,W], counts [Ci], cells [Ci,K], geom [Ci,G])
    tuple per PE; rows are padded to the widest PE with count-0 cells."""
    P = len(per_pe)
    C = max(1, max(int(len(c)) for _, c, _, _ in per_pe))
    first = next((row for row in per_pe if row[0].size), None)
    W = first[0].shape[-1] if first is not None else 2
    K = first[2].shape[-1] if first is not None else 1
    G = first[3].shape[-1] if first is not None else 1
    key_data = np.zeros((P, C, W), np.uint32)
    count = np.zeros((P, C), np.int64)
    cell = np.zeros((P, C, K), np.int64)
    geom = np.ones((P, C, G), np.float64)  # 1s: harmless in both transforms
    for pe, (kd, cnt, cl, gm) in enumerate(per_pe):
        k = len(cnt)
        if k:
            key_data[pe, :k] = kd
            count[pe, :k] = cnt
            cell[pe, :k] = cl
            geom[pe, :k] = gm
    cap = capacity if capacity is not None else max(8, int(count.max()) + 8)
    return PointPlan(kind, key_data, count, cell, geom, scale, dim, cap, rng_impl)


def _point_cell_fn(plan_kind: str, capacity: int, dim: int, scale: float, rng_impl: str):
    def one_cell(kd, cnt, cell, geom):
        key = jax.random.wrap_key_data(kd, impl=rng_impl)
        if plan_kind == POINTS_CUBE:
            u = counter_uniform(key, capacity, dim)
            pts = (cell.astype(jnp.float64) + u) / scale
        else:  # POINTS_POLAR
            u = counter_uniform(key, capacity, 2)
            clo, chi, width = geom[0], geom[1], geom[2]
            r = jnp.arccosh(clo + u[:, 0] * (chi - clo)) / scale
            theta = (cell[1].astype(jnp.float64) + u[:, 1]) * width
            pts = jnp.stack([r, theta], axis=-1)
        return pts, jnp.arange(capacity) < cnt

    return one_cell


def point_executor(plan: PointPlan, mesh: Mesh):
    """(jitted fn, sharded inputs); fn -> (points [P,C,cap,dim], mask).
    Facade over :func:`repro.distrib.runtime.executor`."""
    from . import runtime

    return runtime.executor(plan, mesh)


def run_points(plan: PointPlan, mesh: Optional[Mesh] = None, check: bool = True):
    """Execute a PointPlan; returns (points [P,C,cap,dim], mask, hlo_text).
    Facade over :func:`repro.distrib.runtime.run`."""
    from . import runtime

    pts, mask, hlo = runtime.run(plan, mesh, check=check, want_hlo=True)
    return np.asarray(pts), np.asarray(mask), hlo


def stream_points(plan: PointPlan, check: bool = False, batch: int = 1,
                  with_pe: bool = False, mesh: Optional[Mesh] = None,
                  prefetch: int = 2):
    """Yield point buffers per populated cell, in wave order — the
    PointPlan streaming path (:func:`run_points` materializes
    [P, C, cap, dim]; this emits O(batch · capacity) buffers, so vertex
    positions of huge geometric instances stream like edges do).

    ``batch = 1`` yields (points [cap, dim], mask [cap]) per cell;
    ``batch > 1`` yields up to ``batch`` same-PE cells per dispatch as
    (points [b, cap, dim], mask [b, cap]).  Cell order within each PE
    matches :func:`run_points` exactly, so grouping by PE and
    concatenating the masked rows reproduces its output.  ``with_pe``
    prepends the owning PE; ``check`` asserts zero collectives on the
    lowered wave step (once per program signature).
    """
    from . import runtime

    for pe, slots, payload, mask in runtime.stream_slots(
            plan, mesh=mesh, batch=batch, prefetch=prefetch, check=check):
        out = (payload[0], mask[0]) if batch <= 1 else (payload, mask)
        yield (int(pe), *out) if with_pe else out


# --------------------------------------------------------------------------
# pair plans: the unified geometric edge table (RHG / RGG / RDG)
# --------------------------------------------------------------------------

# geometry kinds understood by the SPMD pair step
GEOM_EMPTY, GEOM_HYP, GEOM_TORUS, GEOM_CERT = 0, 1, 2, 3

# key impls whose draws are a pure function of (key, slot) — invariant
# under vmap batching.  'rbg' (RngBitGenerator) draws *different* values
# for the same key in different vmap rows, so a cell recomputed in two
# candidate-pair rows would disagree with itself: the recomputation
# invariant every pair plan rests on only holds for counter-based impls.
COUNTER_RNGS = frozenset({"threefry2x32"})


def require_counter_rng(rng_impl: str) -> None:
    """Reject non-counter key impls for pair plans (see COUNTER_RNGS)."""
    if rng_impl not in COUNTER_RNGS:
        raise ValueError(
            f"pair plans require a counter-based per-element PRNG, got "
            f"{rng_impl!r}: geometric edge plans recompute cell points from "
            f"hashed keys across candidate-pair rows, and non-counter impls "
            f"('rbg') draw different values for the same key in different "
            f"vmap rows, breaking the recomputation invariant; use rng_impl "
            f"of {sorted(COUNTER_RNGS)} for RGG/RHG/RDG")


def pair_slot_index(i: int, j: int, cap: int):
    """Lexicographic index of slot pair (i, j), i < j, among the
    C(cap, 2) ordered pairs of a row — the bit position GEOM_CERT rows
    use for their per-edge emit masks.  Works on ints and jnp arrays."""
    return i * (cap - 1) - i * (i - 1) // 2 + (j - i - 1)


@dataclass(frozen=True)
class PairSpec:
    """One candidate-pair row as a host geometric emitter produces it.

    ``kind`` selects the device-side geometry test; the two *sides* are
    kind-specific (widths are emitter-derived, see :func:`make_pair_plan`):

    GEOM_HYP (RHG annulus-cell pair) — side = (key_data, count, gid0,
      geom=(cosh(a*lo), cosh(a*hi), cell_index, angular_width));
      fparams = (alpha, cosh R).  The device regenerates each cell's
      points from the hashed key exactly as the polar PointPlan does and
      evaluates the trig-free Eq. 9 threshold on the cross product.

    GEOM_TORUS (RGG cube-cell pair) — side = (key_data, count, gid0,
      geom = integer cell coordinates as floats); fparams =
      (grid_side g, r^2).  Points decode as (cell + u) / g
      (bit-identical to the cube PointPlan) and the squared Euclidean
      threshold runs in float32, matching the pairdist kernel exactly.
      The decode imposes no [0, 1) bound, so an emitter *could* ship
      shifted (unwrapped) coordinates for periodic pairs; the RGG
      emitter is non-periodic ([0,1)^d with boundary, paper §5) and
      never does.

    GEOM_CERT (RDG certified simplex) — ``gid_a`` = the simplex's d+1
      vertex gids (padded to capacity), ``gid_b`` = the per-edge emit
      bitmask (bit :func:`pair_slot_index`(i, j, capacity) set iff this
      simplex is the designated emitter of edge (i, j) — the host's
      combinatorial dedup/ownership pass, the CERT analog of the chunk
      ``owned`` bit), ``geom_a`` = the (d+1) x d vertex coordinates
      flattened, ``geom_b`` = the region box (lo_0..d, hi_0..d).  The
      device recomputes the circumsphere (Cramer, same formula as
      :func:`repro.core.rdg.circumspheres`) and emits the masked simplex
      edges only when the certificate (circumsphere inside the box)
      holds.

    ``self_pair`` restricts a row to slot pairs i < j (cell-vs-itself,
    and all CERT rows).
    """
    kind: int
    key_a: object
    key_b: object
    count_a: int
    count_b: int
    gid_a: object           # int (gid offset) or int sequence (CERT)
    gid_b: object
    geom_a: Sequence[float]
    geom_b: Sequence[float]
    fparams: Tuple[float, ...] = ()
    self_pair: bool = False


@dataclass(frozen=True)
class PairPlan:
    """Host-emitted candidate-pair table for geometric edge generation.

    Every candidate pair appears exactly once globally (canonical
    enumeration), so the concatenated per-PE outputs are the exact edge
    set — the geometric analog of chunk ownership.  All arrays have
    leading dims [P, C] (PE x pair slot, padded with GEOM_EMPTY rows);
    like :class:`ChunkPlan`, rows are kind-tagged and the device program
    only lowers the geometry branches in :attr:`kinds_present`.

    Trailing widths are emitter-derived: W key words, K gid words, G
    geometry features, F float params per row — a TORUS plan carries
    ``dim`` geometry floats, not a hardcoded 4.
    """
    kind: np.ndarray        # int32  [P, C]  (GEOM_*)
    key_a: np.ndarray       # uint32 [P, C, W]
    key_b: np.ndarray       # uint32 [P, C, W]
    count_a: np.ndarray     # int64  [P, C]
    count_b: np.ndarray     # int64  [P, C]
    gid_a: np.ndarray       # int64  [P, C, K]
    gid_b: np.ndarray       # int64  [P, C, K]
    geom_a: np.ndarray      # float64 [P, C, G]
    geom_b: np.ndarray      # float64 [P, C, G]
    fparams: np.ndarray     # float64 [P, C, F]  (kind-specific reals)
    self_pair: np.ndarray   # bool   [P, C]
    active: np.ndarray      # bool   [P, C]
    capacity: int           # per-cell point capacity (static)
    dim: int = 2            # spatial dimension (static; TORUS/CERT decode)
    rng_impl: str = "threefry2x32"
    reseed_fn: Optional[Callable[[int], "PairPlan"]] = field(
        default=None, compare=False, repr=False)

    @property
    def num_pes(self) -> int:
        return self.active.shape[0]

    @property
    def pairs_per_pe(self) -> int:
        return self.active.shape[1]

    @property
    def total_pairs(self) -> int:
        return int(self.active.sum())

    @property
    def kinds_present(self) -> Tuple[int, ...]:
        """Distinct non-empty geometry kinds — static per plan, so the
        device program only lowers the geometry tests it needs."""
        return tuple(sorted(int(k) for k in np.unique(self.kind) if k != GEOM_EMPTY))  # repro: allow(no-numpy-unique) O(P*C) static plan metadata, not edge dedup

    @property
    def fill_fraction(self) -> float:
        """Active rows / table slots.  C = max per-PE row count, so one
        overloaded PE inflates every PE's table with padding; benchmarks
        report this to surface the waste."""
        return float(self.active.sum()) / max(1, self.active.size)

    # ---- PlanProgram protocol (repro.distrib.runtime) ----

    def input_arrays(self) -> Tuple[np.ndarray, ...]:
        return tuple(getattr(self, name) for name in _PAIR_INPUTS)

    def slot_fn(self):
        return _pair_fn(self.capacity, self.rng_impl, self.kinds_present,
                        self.dim)

    def stream_index(self) -> np.ndarray:
        return active_pair_index(self)

    def signature(self) -> tuple:
        return ("pair", self.active.shape, self.key_a.shape[-1],
                self.gid_a.shape[-1], self.geom_a.shape[-1],
                self.fparams.shape[-1], self.capacity, self.kinds_present,
                self.dim, self.rng_impl)

    def reseed(self, seed: int) -> "PairPlan":
        """Equivalent plan for ``seed`` from the cached pair structure
        (see :meth:`ChunkPlan.reseed`)."""
        if self.reseed_fn is None:
            raise ValueError(
                "plan carries no reseed emitter; re-emit from the GraphSpec")
        from .. import obs
        with obs.trace("plan/reseed", phase="plan", reseed=True,
                       plan=type(self).__name__):
            return self.reseed_fn(int(seed))


_PAIR_INPUTS = ("kind", "key_a", "key_b", "count_a", "count_b", "gid_a",
                "gid_b", "geom_a", "geom_b", "fparams", "self_pair", "active")


def make_pair_plan(
    per_pe: Sequence[Sequence[PairSpec]],
    capacity: Optional[int] = None,
    rng_impl: str = "threefry2x32",
    dim: int = 2,
) -> PairPlan:
    """Pad per-PE pair lists into the rectangular plan tables.

    Trailing table widths (key words W, gid words K, geometry features
    G, float params F) are derived from the widest spec the emitters
    hand in — no kind pays for another kind's layout."""
    require_counter_rng(rng_impl)
    P = len(per_pe)
    C = max(1, max((len(row) for row in per_pe), default=1))
    specs = [sp for row in per_pe for sp in row]
    W = len(_key_data_of(specs[0].key_a)) if specs else 2
    K = max([1] + [len(np.atleast_1d(np.asarray(s))) for sp in specs
                   for s in (sp.gid_a, sp.gid_b)])
    G = max([1] + [len(np.atleast_1d(np.asarray(g, np.float64))) for sp in specs
                   for g in (sp.geom_a, sp.geom_b)])
    F = max([1] + [len(sp.fparams) for sp in specs])
    kind = np.zeros((P, C), np.int32)
    key_a = np.zeros((P, C, W), np.uint32)
    key_b = np.zeros((P, C, W), np.uint32)
    count_a = np.zeros((P, C), np.int64)
    count_b = np.zeros((P, C), np.int64)
    gid_a = np.zeros((P, C, K), np.int64)
    gid_b = np.zeros((P, C, K), np.int64)
    geom_a = np.ones((P, C, G), np.float64)  # 1s: harmless in every decode
    geom_b = np.ones((P, C, G), np.float64)
    fparams = np.zeros((P, C, F), np.float64)
    self_pair = np.zeros((P, C), bool)
    active = np.zeros((P, C), bool)
    for pe, row in enumerate(per_pe):
        for j, sp in enumerate(row):
            kind[pe, j] = sp.kind
            key_a[pe, j] = _key_data_of(sp.key_a)
            key_b[pe, j] = _key_data_of(sp.key_b)
            count_a[pe, j] = sp.count_a
            count_b[pe, j] = sp.count_b
            ga = np.atleast_1d(np.asarray(sp.gid_a, np.int64))
            gb = np.atleast_1d(np.asarray(sp.gid_b, np.int64))
            gid_a[pe, j, : len(ga)] = ga
            gid_b[pe, j, : len(gb)] = gb
            va = np.atleast_1d(np.asarray(sp.geom_a, np.float64))
            vb = np.atleast_1d(np.asarray(sp.geom_b, np.float64))
            geom_a[pe, j, : len(va)] = va
            geom_b[pe, j, : len(vb)] = vb
            if sp.fparams:
                fparams[pe, j, : len(sp.fparams)] = sp.fparams
            self_pair[pe, j] = sp.self_pair
            active[pe, j] = True
    cap = capacity
    if cap is None:
        cmax = max(int(count_a.max()) if count_a.size else 0,
                   int(count_b.max()) if count_b.size else 0)
        cap = round_up_capacity(cmax, mult=8)
    return PairPlan(kind, key_a, key_b, count_a, count_b, gid_a, gid_b,
                    geom_a, geom_b, fparams, self_pair, active, cap, dim, rng_impl)


def pair_plan_from_columns(
    P: int,
    pe: np.ndarray,
    kind: np.ndarray,
    key_a: np.ndarray,
    key_b: np.ndarray,
    count_a: np.ndarray,
    count_b: np.ndarray,
    gid_a: np.ndarray,
    gid_b: np.ndarray,
    geom_a: np.ndarray,
    geom_b: np.ndarray,
    fparams: np.ndarray,
    self_pair: np.ndarray,
    capacity: Optional[int] = None,
    rng_impl: str = "threefry2x32",
    dim: int = 2,
) -> PairPlan:
    """Vectorized :func:`make_pair_plan`: flat per-pair columns in.

    ``pe`` [k] assigns each flat candidate-pair row to its PE; within-PE
    slot order is the rows' order of appearance (stable sort), matching
    the per-PE ``PairSpec`` list the loop-based emitters would build.
    ``gid_a``/``gid_b`` are [k, K], ``geom_a``/``geom_b`` [k, G] (G may
    differ from the table width only by right-padding with the same 1.0
    fill), ``fparams`` [k, F].  Capacity defaults follow
    :func:`make_pair_plan` (round up the max side count, mult=8)."""
    require_counter_rng(rng_impl)
    pe = np.asarray(pe, np.int64)
    k = len(pe)
    per = np.bincount(pe, minlength=P) if k else np.zeros(P, np.int64)
    C = max(1, int(per.max()) if per.size else 0)
    W = key_a.shape[-1] if k else 2
    K = gid_a.shape[-1] if k else 1
    G = geom_a.shape[-1] if k else 1
    F = fparams.shape[-1] if k else 1
    order = np.argsort(pe, kind="stable")
    spe = pe[order]
    starts = np.concatenate(([0], np.cumsum(per)))
    col = np.arange(k, dtype=np.int64) - starts[spe]
    t_kind = np.zeros((P, C), np.int32)
    t_ka = np.zeros((P, C, W), np.uint32)
    t_kb = np.zeros((P, C, W), np.uint32)
    t_ca = np.zeros((P, C), np.int64)
    t_cb = np.zeros((P, C), np.int64)
    t_ga = np.zeros((P, C, K), np.int64)
    t_gb = np.zeros((P, C, K), np.int64)
    t_va = np.ones((P, C, G), np.float64)
    t_vb = np.ones((P, C, G), np.float64)
    t_fp = np.zeros((P, C, F), np.float64)
    t_sp = np.zeros((P, C), bool)
    t_act = np.zeros((P, C), bool)
    if k:
        t_kind[spe, col] = np.asarray(kind, np.int32)[order]
        t_ka[spe, col] = np.asarray(key_a, np.uint32)[order]
        t_kb[spe, col] = np.asarray(key_b, np.uint32)[order]
        t_ca[spe, col] = np.asarray(count_a, np.int64)[order]
        t_cb[spe, col] = np.asarray(count_b, np.int64)[order]
        t_ga[spe, col] = np.asarray(gid_a, np.int64)[order]
        t_gb[spe, col] = np.asarray(gid_b, np.int64)[order]
        t_va[spe, col] = np.asarray(geom_a, np.float64)[order]
        t_vb[spe, col] = np.asarray(geom_b, np.float64)[order]
        t_fp[spe, col] = np.asarray(fparams, np.float64)[order]
        t_sp[spe, col] = np.asarray(self_pair, bool)[order]
        t_act[spe, col] = True
    cap = capacity
    if cap is None:
        cmax = max(int(count_a.max()) if k else 0,
                   int(count_b.max()) if k else 0)
        cap = round_up_capacity(cmax, mult=8)
    return PairPlan(t_kind, t_ka, t_kb, t_ca, t_cb, t_ga, t_gb,
                    t_va, t_vb, t_fp, t_sp, t_act, cap, dim, rng_impl)


def slice_plan(plan, lo: int, hi: int):
    """Restrict a plan to the PE range [lo, hi) — every [P, ...] table
    sliced on its leading axis, other fields untouched.

    The generic segmenter behind lazily-overlapped plan emission
    (:class:`repro.distrib.runtime.PlanEmitter`): segment PEs are
    re-indexed to [0, hi - lo), so the caller owns the offset
    bookkeeping.  The slice drops ``reseed_fn`` (a segment is not a
    reseedable whole plan)."""
    P = plan.num_pes
    if not 0 <= lo < hi <= P:
        raise ValueError(f"bad PE range [{lo}, {hi}) for P={P}")
    upd = {}
    for f in dataclasses.fields(plan):
        v = getattr(plan, f.name)
        if isinstance(v, np.ndarray) and v.ndim >= 1 and v.shape[0] == P:
            upd[f.name] = v[lo:hi]
    upd["reseed_fn"] = None
    return dataclasses.replace(plan, **upd)


def _circumsphere_in_box(geom_a, geom_b, dim: int):
    """GEOM_CERT certificate for one simplex row: circumsphere of the
    (d+1) x d vertex block fully inside the region box.  Delegates to
    the shared Cramer predicate
    (:func:`repro.kernels.delaunay.circumsphere_in_box`) — the same
    arithmetic as :func:`repro.core.rdg.circumspheres` (the host
    planning pass) and as the Bowyer-Watson kernel's in-sphere test, so
    every side of the protocol agrees bit-for-bit; degenerate slivers
    (det == 0) fail the certificate."""
    from ..kernels.delaunay import circumsphere_in_box

    V = geom_a[: (dim + 1) * dim].reshape(dim + 1, dim)
    return circumsphere_in_box(V, geom_b[:dim], geom_b[dim: 2 * dim])


def _pair_fn(capacity: int, rng_impl: str,
             kinds: Sequence[int] = (GEOM_HYP,), dim: int = 2):
    """Per-pair device program, specialized to the geometry kinds in the
    plan (mirror of :func:`_edge_chunk_fn`).

    GEOM_HYP regenerates both polar cells' points from their hashed keys
    (bit-identical to the polar PointPlan stream) and evaluates the
    trig-free Eq. 9 threshold; GEOM_TORUS regenerates cube-cell points
    and runs the float32 r^2 test (bit-identical to the pairdist
    kernel); GEOM_CERT re-certifies a Delaunay simplex's circumsphere
    and emits its host-masked edges.  All emit canonical (max gid,
    min gid) edges; only branches for kinds actually present lower.
    """
    kinds = frozenset(int(k) for k in kinds) - {GEOM_EMPTY}
    N = capacity

    def hyp_features(kd, geom, scale):
        key = jax.random.wrap_key_data(kd, impl=rng_impl)
        u = counter_uniform(key, N, 2)
        clo, chi, ci, w = geom[0], geom[1], geom[2], geom[3]
        r = jnp.arccosh(clo + u[:, 0] * (chi - clo)) / scale
        theta = (ci + u[:, 1]) * w
        r = jnp.maximum(r, 1e-12)
        sh = jnp.sinh(r)
        return jnp.stack(
            [jnp.cos(theta), jnp.sin(theta), jnp.cosh(r) / sh, 1.0 / sh], axis=-1)

    def cube_points(kd, geom, g):
        key = jax.random.wrap_key_data(kd, impl=rng_impl)
        u = counter_uniform(key, N, dim)
        return ((geom[:dim] + u) / g).astype(jnp.float32)

    def one_pair(kind, kd_a, kd_b, cnt_a, cnt_b, gid_a, gid_b,
                 geom_a, geom_b, fp, self_pair, active):
        ii = jnp.arange(N, dtype=jnp.int64)
        I = jnp.broadcast_to(ii[:, None], (N, N))
        J = jnp.broadcast_to(ii[None, :], (N, N))
        valid = (ii[:, None] < cnt_a) & (ii[None, :] < cnt_b)
        once = jnp.where(self_pair, ii[:, None] < ii[None, :], True)
        ga = gid_a[0] + I
        gb = gid_b[0] + J
        hit = jnp.zeros((N, N), bool)

        if GEOM_HYP in kinds:
            fa = hyp_features(kd_a, geom_a, fp[0])
            fb = hyp_features(kd_b, geom_b, fp[0])
            acc = fa[:, 0][:, None] * fb[:, 0][None, :]
            acc += fa[:, 1][:, None] * fb[:, 1][None, :]
            acc -= fa[:, 2][:, None] * fb[:, 2][None, :]
            acc += fp[1] * (fa[:, 3][:, None] * fb[:, 3][None, :])
            hit = jnp.where(kind == GEOM_HYP, acc > 0, hit)

        if GEOM_TORUS in kinds:
            pa = cube_points(kd_a, geom_a, fp[0])
            pb = cube_points(kd_b, geom_b, fp[0])
            acc = jnp.zeros((N, N), jnp.float32)
            for d in range(dim):  # static tiny loop, same order as the kernel
                diff = pa[:, d][:, None] - pb[:, d][None, :]
                acc = acc + diff * diff
            hit = jnp.where(kind == GEOM_TORUS, acc <= fp[1].astype(jnp.float32), hit)

        if GEOM_CERT in kinds:
            cert = _circumsphere_in_box(geom_a, geom_b, dim)
            bit = (gid_b[0] >> jnp.clip(pair_slot_index(I, J, N), 0, 62)) & 1
            hit = jnp.where(kind == GEOM_CERT, (bit == 1) & cert, hit)
            kmax = gid_a.shape[0] - 1
            ga = jnp.where(kind == GEOM_CERT, gid_a[jnp.clip(I, 0, kmax)], ga)
            gb = jnp.where(kind == GEOM_CERT, gid_a[jnp.clip(J, 0, kmax)], gb)

        keep = hit & valid & once & active
        u = jnp.maximum(ga, gb)
        v = jnp.minimum(ga, gb)
        return jnp.stack([u, v], axis=-1).reshape(-1, 2), keep.reshape(-1)

    return one_pair


def pair_executor(plan: PairPlan, mesh: Mesh):
    """(jitted fn, sharded inputs); fn -> (edges [P,C,cap^2,2], keep).
    Facade over :func:`repro.distrib.runtime.executor`."""
    from . import runtime

    return runtime.executor(plan, mesh)


def run_pairs(plan: PairPlan, mesh: Optional[Mesh] = None, check: bool = True):
    """Execute a PairPlan; returns (edges [k, 2] int64, hlo_text).

    Works identically for every geometry kind (GEOM_HYP / GEOM_TORUS /
    GEOM_CERT): the output is the exact global edge set, since every
    candidate pair (or certified simplex edge) appears exactly once.
    Facade over :func:`repro.distrib.runtime.run`."""
    from . import runtime

    edges, keep, hlo = runtime.run(plan, mesh, check=check, want_hlo=True)
    return np.asarray(edges)[np.asarray(keep)], hlo


def active_pair_index(plan: PairPlan) -> np.ndarray:
    """int64 [K, 2] of (pe, slot) for every active candidate pair, in
    stream order — the PairPlan analog of :func:`owned_chunk_index`
    (every pair is globally unique by construction, so active == owned)."""
    return np.argwhere(plan.active).astype(np.int64)


def stream_pair_edges(plan: PairPlan, check: bool = False, batch: int = 1,
                      with_pe: bool = False, mesh: Optional[Mesh] = None,
                      prefetch: int = 2):
    """Yield edge buffers per active candidate pair, in wave order
    (streaming analog of stream_chunk_edges; pair validity is a
    scattered mask, not a prefix).  Facade over
    :func:`repro.distrib.runtime.stream_slots`.

    ``batch = 1`` yields (buffer [cap^2, 2], keep [cap^2]) per pair.
    ``batch > 1`` executes up to ``batch`` *same-PE* consecutive pairs
    per wave row and yields (buffer [b, cap^2, 2], keep [b, cap^2]) —
    large geometric plans have 10^4..10^6 candidate pairs, so per-pair
    dispatch overhead would dominate; batches never straddle a PE
    boundary, so per-PE attribution (and per-PE stream order) is
    preserved.  Peak memory is O(devices * batch * cap^2) either way,
    never O(total edges).  ``check`` asserts zero collectives on the
    lowered wave step itself (the shard_map'd dispatch, once per
    program signature).  ``with_pe`` prepends each buffer's owning PE
    (authoritative — consumers must not re-derive the batch grouping).
    """
    from . import runtime

    for pe, slots, payload, keep in runtime.stream_slots(
            plan, mesh=mesh, batch=batch, prefetch=prefetch, check=check):
        out = (payload[0], keep[0]) if batch <= 1 else (payload, keep)
        yield (int(pe), *out) if with_pe else out
