"""Generator-agnostic zero-collective sharded execution engine.

The paper's headline property — embarrassingly parallel, communication-
free generation — is realized here as a *table-driven* SPMD program:

1. ``shard_map_compat``: a version-compatible ``shard_map`` shim
   (``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on
   0.4.x) plus the HLO zero-collective assertion as a reusable
   invariant (``assert_communication_free``).

2. ``ChunkPlan`` / ``PointPlan``: per-PE tables — chunk keys, universes,
   counts, fixed capacities and decode parameters — emitted by the host
   divide-and-conquer recursions (the only O(P)-ish sequential work).

3. A single jitted SPMD ``step`` per plan type that every generator
   family shares.  Devices read their rows of the table and sample/
   decode fully independently; the lowering contains zero collectives
   by construction, and the assertion machine-checks it.

Exact union without sorting: each chunk row carries an ``owned`` bit.
Undirected chunk (I, J) is generated bit-identically on PE I and PE J
(the paper's <= 2m recomputation bound) but *kept* only by its
designated owner (the row PE), so the concatenated output is exactly
the global edge set — no O(m log m) ``np.unique`` dedup.

Plan emitters live next to their generators: ``core.er`` (directed and
undirected G(n,m), G(n,p)), ``core.rgg`` (spatial vertex plans) and
``core.rhg`` (radial/angular vertex plans).
"""
from __future__ import annotations

import inspect
import re
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..core.prng import counter_uniform
from ..core.sampling import (
    decode_directed,
    decode_rect,
    decode_tri,
    round_up_capacity,
    sample_wo_replacement,
)

try:  # JAX >= 0.5 exposes shard_map at the top level
    _shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # JAX 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """Version-compatible ``shard_map`` (0.4.x and 0.5+/0.6+).

    Replication checking is off by default: the sampler's bounded
    ``while_loop`` has no replication rule on 0.4.x (the parameter is
    ``check_rep`` there, ``check_vma`` on new JAX)."""
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    params = inspect.signature(_shard_map).parameters
    if "check_rep" in params:
        kwargs["check_rep"] = check
    elif "check_vma" in params:
        kwargs["check_vma"] = check
    return _shard_map(f, **kwargs)


# --------------------------------------------------------------------------
# the zero-collective invariant
# --------------------------------------------------------------------------

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute"
    r"|all-gather-start|all-reduce-start|collective-broadcast)\b"
)


def collective_ops_in(hlo_text: str) -> List[str]:
    return COLLECTIVE_RE.findall(hlo_text)


def assert_communication_free(lowered) -> None:
    ops = collective_ops_in(lowered.as_text())
    if ops:
        raise AssertionError(f"generator lowering contains collectives: {sorted(set(ops))}")


def default_mesh(P: int, axis: str = "pe") -> Mesh:
    """1-D mesh over the most local devices that divide P evenly."""
    ndev = len(jax.devices())
    use = max(d for d in range(1, min(ndev, P) + 1) if P % d == 0)
    return Mesh(np.array(jax.devices()[:use]), (axis,))


# --------------------------------------------------------------------------
# edge plans: the unified ER-family table
# --------------------------------------------------------------------------

# chunk kinds understood by the SPMD edge step
KIND_EMPTY, KIND_DIRECTED, KIND_TRI, KIND_RECT = 0, 1, 2, 3


@dataclass(frozen=True)
class ChunkSpec:
    """One chunk as the host D&C recursion emits it.

    ``params`` is kind-specific: DIRECTED -> (row_lo, 0, 0);
    TRI -> (lo, 0, 0); RECT -> (width, rlo, clo).

    ``key`` is the PRNG key of the chunk's hash path — either a typed
    JAX key or its raw uint32 key data (emitters batch-compute the
    latter to avoid per-chunk dispatches).
    """
    kind: int
    key: object             # jax key or uint32 key-data array
    universe: int
    count: int
    params: Tuple[int, int, int]
    owned: bool = True


@dataclass(frozen=True)
class ChunkPlan:
    """Host-emitted table driving the unified SPMD edge engine.

    All arrays have leading dims [P, C] (PE x chunk slot, padded with
    KIND_EMPTY rows); the device program is pure table execution.
    """
    kind: np.ndarray        # int32  [P, C]
    key_data: np.ndarray    # uint32 [P, C, W]  (W = key words of rng_impl)
    universe: np.ndarray    # int64  [P, C]
    count: np.ndarray       # int64  [P, C]
    params: np.ndarray      # int64  [P, C, 3]
    owned: np.ndarray       # bool   [P, C]
    n: int                  # global vertex count (directed decode)
    capacity: int           # fixed per-chunk buffer (static shape)
    rng_impl: str = "threefry2x32"

    @property
    def num_pes(self) -> int:
        return self.kind.shape[0]

    @property
    def chunks_per_pe(self) -> int:
        return self.kind.shape[1]

    @property
    def total_edges(self) -> int:
        return int(self.count[self.owned].sum())


def _key_data_of(key) -> np.ndarray:
    """Accepts a typed JAX key or precomputed uint32 key data."""
    if isinstance(key, np.ndarray):
        return key.ravel()
    return np.asarray(jax.random.key_data(key)).ravel()


def make_chunk_plan(
    per_pe: Sequence[Sequence[ChunkSpec]],
    n: int,
    capacity: Optional[int] = None,
    rng_impl: str = "threefry2x32",
) -> ChunkPlan:
    """Pad per-PE chunk lists into the rectangular plan tables."""
    P = len(per_pe)
    C = max(1, max((len(row) for row in per_pe), default=1))
    first = next((row[0] for row in per_pe if row), None)
    width = len(_key_data_of(first.key)) if first is not None else 2
    kind = np.zeros((P, C), np.int32)
    key_data = np.zeros((P, C, width), np.uint32)
    universe = np.zeros((P, C), np.int64)
    count = np.zeros((P, C), np.int64)
    params = np.zeros((P, C, 3), np.int64)
    owned = np.zeros((P, C), bool)
    for pe, row in enumerate(per_pe):
        for j, spec in enumerate(row):
            kind[pe, j] = spec.kind
            key_data[pe, j] = _key_data_of(spec.key)
            universe[pe, j] = spec.universe
            count[pe, j] = spec.count
            params[pe, j] = spec.params
            owned[pe, j] = spec.owned
    cap = capacity if capacity is not None else round_up_capacity(int(count.max()) if count.size else 0)
    return ChunkPlan(kind, key_data, universe, count, params, owned, n, cap, rng_impl)


def _edge_chunk_fn(n: int, capacity: int, rng_impl: str):
    """Per-chunk device program: sample indices, decode by chunk kind."""

    def one_chunk(kind, kd, universe, count, params, owned):
        key = jax.random.wrap_key_data(kd, impl=rng_impl)
        vals, mask = sample_wo_replacement(key, universe, count, capacity)
        p0, p1, p2 = params[0], params[1], params[2]
        du, dv = decode_directed(vals, n, p0)
        tu, tv = decode_tri(vals, p0)
        width = jnp.maximum(jnp.where(kind == KIND_RECT, p0, 1), 1)
        ru, rv = decode_rect(vals, width, p1, p2)
        u = jnp.where(kind == KIND_DIRECTED, du, jnp.where(kind == KIND_TRI, tu, ru))
        v = jnp.where(kind == KIND_DIRECTED, dv, jnp.where(kind == KIND_TRI, tv, rv))
        keep = mask & owned & (kind != KIND_EMPTY)
        return jnp.stack([u, v], axis=-1), keep

    return one_chunk


def edge_executor(plan: ChunkPlan, mesh: Mesh):
    """(jitted fn, sharded inputs) for the plan's SPMD edge step.

    fn(*inputs) -> (edges [P, C, cap, 2], keep [P, C, cap]); ``keep``
    already folds in validity masks and canonical chunk ownership.
    """
    spec = PartitionSpec(mesh.axis_names)
    one = _edge_chunk_fn(plan.n, plan.capacity, plan.rng_impl)

    def step(kind, kd, universe, count, params, owned):
        return jax.vmap(jax.vmap(one))(kind, kd, universe, count, params, owned)

    fn = jax.jit(shard_map_compat(
        step, mesh, in_specs=(spec,) * 6, out_specs=(spec, spec)))
    ns = NamedSharding(mesh, spec)
    inputs = tuple(
        jax.device_put(jnp.asarray(x), ns)
        for x in (plan.kind, plan.key_data, plan.universe, plan.count, plan.params, plan.owned)
    )
    return fn, inputs


def run_edges(plan: ChunkPlan, mesh: Optional[Mesh] = None, check: bool = True):
    """Execute a ChunkPlan; returns (edges [k, 2] int64, hlo_text).

    The output is the exact global edge set: every chunk is emitted by
    its designated owner only, so no sort/unique dedup is needed.
    """
    mesh = mesh if mesh is not None else default_mesh(plan.num_pes)
    fn, inputs = edge_executor(plan, mesh)
    lowered = fn.lower(*inputs)
    if check:
        assert_communication_free(lowered)
    edges, keep = fn(*inputs)
    return np.asarray(edges)[np.asarray(keep)], lowered.as_text()


# --------------------------------------------------------------------------
# point plans: spatial (RGG cube cells) and radial (RHG annulus cells)
# --------------------------------------------------------------------------

POINTS_CUBE, POINTS_POLAR = "cube", "polar"


@dataclass(frozen=True)
class PointPlan:
    """Per-PE cell table for sharded vertex generation.

    kind == 'cube':  point = (cell + u) / scale           (scale = grid g)
    kind == 'polar': r = arccosh(g0 + u0*(g1 - g0)) / scale  (scale = alpha)
                     theta = (cell[1] + u1) * g2
    """
    kind: str               # POINTS_CUBE | POINTS_POLAR (static)
    key_data: np.ndarray    # uint32  [P, C, W] per-cell key
    count: np.ndarray       # int64   [P, C]
    cell: np.ndarray        # int64   [P, C, K] integer cell coordinates
    geom: np.ndarray        # float64 [P, C, G] kind-specific reals
    scale: float
    dim: int                # output dims per point
    capacity: int
    rng_impl: str = "threefry2x32"

    @property
    def num_pes(self) -> int:
        return self.count.shape[0]

    @property
    def total_points(self) -> int:
        return int(self.count.sum())


def make_point_plan(
    per_pe: Sequence[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]],
    kind: str,
    scale: float,
    dim: int,
    capacity: Optional[int] = None,
    rng_impl: str = "threefry2x32",
) -> PointPlan:
    """per_pe: one (key_data [Ci,W], counts [Ci], cells [Ci,K], geom [Ci,G])
    tuple per PE; rows are padded to the widest PE with count-0 cells."""
    P = len(per_pe)
    C = max(1, max(int(len(c)) for _, c, _, _ in per_pe))
    first = next((row for row in per_pe if row[0].size), None)
    W = first[0].shape[-1] if first is not None else 2
    K = first[2].shape[-1] if first is not None else 1
    G = first[3].shape[-1] if first is not None else 1
    key_data = np.zeros((P, C, W), np.uint32)
    count = np.zeros((P, C), np.int64)
    cell = np.zeros((P, C, K), np.int64)
    geom = np.ones((P, C, G), np.float64)  # 1s: harmless in both transforms
    for pe, (kd, cnt, cl, gm) in enumerate(per_pe):
        k = len(cnt)
        if k:
            key_data[pe, :k] = kd
            count[pe, :k] = cnt
            cell[pe, :k] = cl
            geom[pe, :k] = gm
    cap = capacity if capacity is not None else max(8, int(count.max()) + 8)
    return PointPlan(kind, key_data, count, cell, geom, scale, dim, cap, rng_impl)


def _point_cell_fn(plan_kind: str, capacity: int, dim: int, scale: float, rng_impl: str):
    def one_cell(kd, cnt, cell, geom):
        key = jax.random.wrap_key_data(kd, impl=rng_impl)
        if plan_kind == POINTS_CUBE:
            u = counter_uniform(key, capacity, dim)
            pts = (cell.astype(jnp.float64) + u) / scale
        else:  # POINTS_POLAR
            u = counter_uniform(key, capacity, 2)
            clo, chi, width = geom[0], geom[1], geom[2]
            r = jnp.arccosh(clo + u[:, 0] * (chi - clo)) / scale
            theta = (cell[1].astype(jnp.float64) + u[:, 1]) * width
            pts = jnp.stack([r, theta], axis=-1)
        return pts, jnp.arange(capacity) < cnt

    return one_cell


def point_executor(plan: PointPlan, mesh: Mesh):
    """(jitted fn, sharded inputs); fn -> (points [P,C,cap,dim], mask)."""
    spec = PartitionSpec(mesh.axis_names)
    one = _point_cell_fn(plan.kind, plan.capacity, plan.dim, plan.scale, plan.rng_impl)

    def step(kd, cnt, cell, geom):
        return jax.vmap(jax.vmap(one))(kd, cnt, cell, geom)

    fn = jax.jit(shard_map_compat(
        step, mesh, in_specs=(spec,) * 4, out_specs=(spec, spec)))
    ns = NamedSharding(mesh, spec)
    inputs = tuple(
        jax.device_put(jnp.asarray(x), ns)
        for x in (plan.key_data, plan.count, plan.cell, plan.geom)
    )
    return fn, inputs


def run_points(plan: PointPlan, mesh: Optional[Mesh] = None, check: bool = True):
    """Execute a PointPlan; returns (points [P,C,cap,dim], mask, hlo_text)."""
    mesh = mesh if mesh is not None else default_mesh(plan.num_pes)
    fn, inputs = point_executor(plan, mesh)
    lowered = fn.lower(*inputs)
    if check:
        assert_communication_free(lowered)
    pts, mask = fn(*inputs)
    return np.asarray(pts), np.asarray(mask), lowered.as_text()
