"""Gradient compression for cross-pod data parallelism: int8
block-quantized all-reduce with error feedback.

At multi-pod scale the 'pod' axis rides the slow inter-pod fabric; DP
gradient all-reduce is the dominant cross-pod traffic.  Quantizing to
int8 (per-block absmax scaling) cuts those bytes 4x vs f32 / 2x vs bf16;
the residual quantization error is carried to the next step (error
feedback), which preserves convergence (Karimireddy et al.-style EF).

`Codec.roundtrip` is pure and mesh-agnostic: on hardware the quantized
tensor is what enters `psum` on the 'pod' axis; here we verify the
numerics + convergence parity on CPU.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape)


def make_error_feedback_codec():
    """Returns (codec(grads, err) -> (grads', err'), zero_err(params))."""

    def zero_err(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def codec(grads, err):
        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            q, s = _quantize(corrected)
            deq = _dequantize(q, s, g.shape)
            return deq, corrected - deq

        flat_g, td = jax.tree.flatten(grads)
        flat_e = td.flatten_up_to(err)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return td.unflatten([o[0] for o in outs]), td.unflatten([o[1] for o in outs])

    return codec, zero_err


def compression_ratio(dtype_in=jnp.float32) -> float:
    scale_overhead = 4.0 / BLOCK
    return (jnp.dtype(dtype_in).itemsize) / (1.0 + scale_overhead)
