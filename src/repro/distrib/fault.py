"""Fault tolerance & straggler mitigation for communication-free
generation (and the data pipeline built on it).

The paper's paradigm makes fault tolerance almost free: a chunk is a
*pure function* of (seed, chunk id), so recovery = recomputation, never
state transfer.  We exploit this three ways:

* **Over-decomposition**: generate k = c * P_virtual chunks and map
  virtual chunks -> physical workers.  The virtual chunk count is fixed
  at job creation (it determines the graph), the physical worker set is
  elastic.

* **Elastic reassignment**: when workers die (or join), the chunk->worker
  map is recomputed deterministically from the surviving roster — every
  survivor agrees without coordination beyond roster membership.

* **Straggler mitigation**: chunks carry deterministic cost estimates
  (expected edges from the plan); LPT (longest-processing-time-first)
  assignment bounds makespan at (4/3 - 1/(3P)) * OPT, and any idle
  worker may *steal* a pending chunk by recomputing it — no data motion.

The live consumer of this module is the serving scheduler
(:mod:`repro.serve.scheduler`): slab slots are placed by a
:class:`ChunkAssignment`, and when mesh rows die mid-slab the lost
slots retire and reissue onto the surviving rows given by
:func:`reassign_after_failure` — delivered output bit-identical to the
failure-free run (tests/test_serve.py, tests/test_distrib.py).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple


@dataclass(frozen=True)
class ChunkAssignment:
    """Deterministic chunk -> worker map over a (possibly degraded) roster."""
    num_chunks: int
    workers: Tuple[int, ...]          # surviving physical worker ids, sorted
    costs: Tuple[float, ...] | None = None

    def worker_of(self, chunk: int) -> int:
        if self.costs is None:
            return self.workers[chunk % len(self.workers)]
        return self._lpt_map()[chunk]

    def chunks_of(self, worker: int) -> List[int]:
        return [c for c in range(self.num_chunks) if self.worker_of(c) == worker]

    def _lpt_map(self) -> Dict[int, int]:
        # deterministic LPT: ties broken by chunk id then worker id
        order = sorted(range(self.num_chunks), key=lambda c: (-self.costs[c], c))
        heap = [(0.0, w) for w in self.workers]
        heapq.heapify(heap)
        out: Dict[int, int] = {}
        for c in order:
            load, w = heapq.heappop(heap)
            out[c] = w
            heapq.heappush(heap, (load + self.costs[c], w))
        return out

    def makespan(self) -> float:
        loads: Dict[int, float] = {w: 0.0 for w in self.workers}
        for c in range(self.num_chunks):
            loads[self.worker_of(c)] += (self.costs[c] if self.costs else 1.0)
        return max(loads.values())


def reassign_after_failure(
    assignment: ChunkAssignment, dead: Sequence[int]
) -> ChunkAssignment:
    """New deterministic map over survivors.  Lost chunks are recomputed
    from (seed, chunk id) — zero state transfer."""
    survivors = tuple(w for w in assignment.workers if w not in set(dead))
    if not survivors:
        raise RuntimeError("no survivors")
    return ChunkAssignment(assignment.num_chunks, survivors, assignment.costs)
