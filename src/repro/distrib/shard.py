"""shard_map distribution of the generators — the paper's headline
property made machine-checkable: the lowered HLO of a generator step
contains ZERO collective operations.

Each device is one PE.  The host computes the O(P) divide-and-conquer
plan (per-chunk counts/offsets — the only sequential-ish work, O(log P)
per PE on a real deployment); devices then run the bulk sampling fully
independently.  ``assert_communication_free`` greps the lowered module
for collectives and is used by tests and the dry-run.
"""
from __future__ import annotations

import re
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.chunking import directed_counts_all, section_bounds
from ..core.prng import device_key
from ..core.sampling import decode_directed, sample_wo_replacement

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute"
    r"|all-gather-start|all-reduce-start|collective-broadcast)\b"
)


def collective_ops_in(hlo_text: str):
    return COLLECTIVE_RE.findall(hlo_text)


def assert_communication_free(lowered) -> None:
    ops = collective_ops_in(lowered.as_text())
    if ops:
        raise AssertionError(f"generator lowering contains collectives: {sorted(set(ops))}")


# --------------------------------------------------------------------------
# directed G(n,m) as a sharded, communication-free device program
# --------------------------------------------------------------------------

def gnm_directed_sharded(
    seed: int, n: int, m: int, mesh: Mesh, axis: str = "pe",
    capacity: int | None = None, rng_impl: str = "threefry2x32",
):
    """Build (jitted_fn, inputs, shardings) for the sharded generator step.

    Per-device chunk parameters are data (sharded arrays); the device
    program is identical SPMD with no cross-device dependency, so the
    lowering is collective-free by construction — and asserted.

    rng_impl: 'threefry2x32' (default — counter-based, the faithful
    analog of the paper's hash-seeded streams and *stronger* than its
    Mersenne Twister) or 'rbg' (TPU-native RngBitGenerator: one fused op
    instead of ~40 u64 vector ops per draw; weaker fold_in independence
    guarantees — beyond-paper perf option, see EXPERIMENTS.md §Perf).
    """
    P_ = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    counts = directed_counts_all(seed, n, m, P_)
    row_lo = np.array([section_bounds(n, P_, pe)[0] for pe in range(P_)], np.int64)
    row_hi = np.array([section_bounds(n, P_, pe)[1] for pe in range(P_)], np.int64)
    cap = capacity or max(64, int(counts.max()) + 64)
    if rng_impl == "threefry2x32":
        key = device_key(seed, 11)  # matches er._CHUNK_TAG stream
    else:
        key = jax.random.key(seed & 0x7FFFFFFF, impl=rng_impl)
        key = jax.random.fold_in(key, 11)

    spec = P(mesh.axis_names)  # shard leading axis over every mesh axis

    def step(pe_ids_d, counts_d, row_lo_d, row_hi_d):
        # arrays have shape [local_pe_count] inside shard_map
        def per_pe(pe, cnt, lo, hi):
            universe = (hi - lo) * (n - 1)
            # identical stream to er.gnm_directed_pe: fold the *chunk id*
            k = jax.random.fold_in(key, pe.astype(jnp.uint32))
            vals, mask = sample_wo_replacement(k, universe, cnt, cap)
            u, v = decode_directed(vals, n, lo)
            return jnp.stack([u, v], axis=-1), mask

        return jax.vmap(per_pe)(pe_ids_d, counts_d, row_lo_d, row_hi_d)

    sharded = jax.shard_map(
        step,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(spec, spec),
    )
    fn = jax.jit(sharded)
    inputs = (
        jnp.arange(P_, dtype=jnp.int64),
        jnp.asarray(counts),
        jnp.asarray(row_lo),
        jnp.asarray(row_hi),
    )
    ns = NamedSharding(mesh, spec)
    inputs = tuple(jax.device_put(x, ns) for x in inputs)
    return fn, inputs


def run_gnm_directed_sharded(seed: int, n: int, m: int, mesh: Mesh):
    """Execute + gather to host; returns (edges [m,2], lowered_text)."""
    fn, inputs = gnm_directed_sharded(seed, n, m, mesh)
    lowered = fn.lower(*inputs)
    assert_communication_free(lowered)
    edges, mask = fn(*inputs)
    edges = np.asarray(edges)
    mask = np.asarray(mask)
    return edges[mask], lowered.as_text()


# --------------------------------------------------------------------------
# RGG vertex generation as a sharded, communication-free device program
# --------------------------------------------------------------------------

def rgg_points_sharded(seed: int, n: int, radius: float, mesh: Mesh, dim: int = 2):
    """Sharded spatial vertex generation: each device (PE) generates the
    points of its own cells from hashed per-cell keys — the paper's §5
    chunk/cell scheme as a zero-collective SPMD program.

    Returns (fn, inputs); fn yields (points [P, cells/pe, cap, dim],
    mask).  Cell counts come from the hashed binomial recursion on the
    host (the O(log) plan); positions are generated on-device."""
    from ..core.rgg import CellCounter, make_grid
    from ..core.prng import device_key as dk

    P_ = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    grid = make_grid(n, radius, P_, dim)
    counter = CellCounter(seed, grid, n)
    all_cells = [tuple(c) for c in np.ndindex(*([grid.g] * dim))]
    per_pe = (len(all_cells) + P_ - 1) // P_
    counts = np.zeros((P_, per_pe), np.int64)
    ids = np.zeros((P_, per_pe), np.int64)
    coords = np.zeros((P_, per_pe, dim), np.int64)
    for i, cell in enumerate(all_cells):
        pe, j = i % P_, i // P_
        counts[pe, j] = counter.cell_count(cell)
        ids[pe, j] = grid.cell_id(cell)
        coords[pe, j] = cell
    cap = max(8, int(counts.max()) + 8)
    key = dk(seed, 22)  # rgg._TAG_PTS stream

    def step(ids_d, coords_d, counts_d):
        def one(cid, coord, cnt):
            k = jax.random.fold_in(key, cid)
            u = jax.random.uniform(k, (cap, dim), dtype=jnp.float64)
            pos = (coord.astype(jnp.float64) + u) / grid.g
            return pos, jnp.arange(cap) < cnt

        return jax.vmap(one)(ids_d, coords_d, counts_d)

    spec = P(mesh.axis_names)
    fn = jax.jit(jax.shard_map(
        lambda a, b, c: jax.vmap(step)(a, b, c),
        mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=(spec, spec),
    ))
    ns = NamedSharding(mesh, spec)
    inputs = tuple(jax.device_put(jnp.asarray(x), ns) for x in (ids, coords, counts))
    return fn, inputs
