"""shard_map distribution of the generators — the paper's headline
property made machine-checkable: the lowered HLO of a generator step
contains ZERO collective operations.

This module is now a thin facade over :mod:`repro.distrib.engine`: the
host computes the O(P) divide-and-conquer *plan* (a ChunkPlan /
PointPlan table), and a single generator-agnostic jitted SPMD step
executes it.  The legacy entry points below keep their signatures for
callers (launch.dryrun, tests) and delegate to the engine.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh

from ..core.er import gnm_directed_plan
from ..core.rgg import rgg_point_plan
from .engine import (  # noqa: F401  (re-exported public API)
    ChunkPlan,
    ChunkSpec,
    GEOM_CERT,
    GEOM_HYP,
    GEOM_TORUS,
    KIND_BA,
    KIND_DIRECTED,
    KIND_RMAT,
    PairPlan,
    PairSpec,
    PointPlan,
    assert_communication_free,
    collective_ops_in,
    COLLECTIVE_RE,
    deal_plan,
    edge_executor,
    make_chunk_plan,
    make_pair_plan,
    pair_executor,
    point_executor,
    run_edges,
    run_pairs,
    run_points,
    shard_map_compat,
    stream_chunk_edges,
    stream_pair_edges,
)


def _mesh_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


# --------------------------------------------------------------------------
# directed G(n,m) as a sharded, communication-free device program
# --------------------------------------------------------------------------

def gnm_directed_sharded(
    seed: int, n: int, m: int, mesh: Mesh, axis: str = "pe",
    capacity: int | None = None, rng_impl: str = "threefry2x32",
):
    """Build (jitted_fn, inputs) for the sharded generator step.

    Per-device chunk parameters are data (sharded plan tables); the
    device program is identical SPMD with no cross-device dependency, so
    the lowering is collective-free by construction — and asserted.

    rng_impl: 'threefry2x32' (default — counter-based, the faithful
    analog of the paper's hash-seeded streams and *stronger* than its
    Mersenne Twister) or 'rbg' (TPU-native RngBitGenerator: one fused op
    instead of ~40 u64 vector ops per draw; weaker fold_in independence
    guarantees — beyond-paper perf option, see EXPERIMENTS.md §Perf).
    """
    P = _mesh_size(mesh)
    plan = gnm_directed_plan(seed, n, m, P, rng_impl)
    if capacity is not None:
        plan = dataclasses.replace(plan, capacity=capacity)
    return edge_executor(plan, mesh)


def run_gnm_directed_sharded(seed: int, n: int, m: int, mesh: Mesh):
    """Execute + gather to host; returns (edges [m,2], lowered_text)."""
    plan = gnm_directed_plan(seed, n, m, _mesh_size(mesh))
    return run_edges(plan, mesh)


# --------------------------------------------------------------------------
# RGG vertex generation as a sharded, communication-free device program
# --------------------------------------------------------------------------

def rgg_points_sharded(seed: int, n: int, radius: float, mesh: Mesh, dim: int = 2):
    """Sharded spatial vertex generation: each device (PE) generates the
    points of its own cells from hashed per-cell keys — the paper's §5
    chunk/cell scheme as a zero-collective SPMD program.

    Returns (fn, inputs); fn yields (points [P, cells/pe, cap, dim],
    mask).  Cell counts come from the hashed binomial recursion on the
    host (the O(log) plan); positions are generated on-device.
    """
    plan = rgg_point_plan(seed, n, radius, _mesh_size(mesh), dim)
    return point_executor(plan, mesh)
