"""Deprecated legacy facade over :mod:`repro.distrib.runtime`.

The original ``shard_map`` distribution of the generators lived here;
it is now three deprecated shims.  The per-family entry points predate
both the unified engine plans (PR 1/2) and the runtime executor (this
PR): new code should emit a plan (``repro.api`` spec ``.plan()`` or the
``core.*`` plan emitters) and hand it to
:func:`repro.distrib.runtime.run` / :func:`~repro.distrib.runtime.stream_waves`,
which own jit + ``shard_map``, compile caching and the zero-collective
assertion for every plan type.

The engine re-exports below are kept warning-free — they are the
stable names (``launch.dryrun``, benchmarks and tests import them
here) — only the three legacy per-family entry points warn.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np
from jax.sharding import Mesh

from ..core.er import gnm_directed_plan
from ..core.rgg import rgg_point_plan
from .engine import (  # noqa: F401  (re-exported public API)
    ChunkPlan,
    ChunkSpec,
    GEOM_CERT,
    GEOM_HYP,
    GEOM_TORUS,
    KIND_BA,
    KIND_DIRECTED,
    KIND_RMAT,
    PairPlan,
    PairSpec,
    PointPlan,
    assert_communication_free,
    collective_ops_in,
    COLLECTIVE_RE,
    deal_plan,
    edge_executor,
    make_chunk_plan,
    make_pair_plan,
    pair_executor,
    point_executor,
    run_edges,
    run_pairs,
    run_points,
    shard_map_compat,
    stream_chunk_edges,
    stream_pair_edges,
    stream_points,
)


def _mesh_size(mesh: Mesh) -> int:
    from . import runtime

    return runtime.mesh_size(mesh)


def _deprecated(name: str, instead: str) -> None:
    warnings.warn(
        f"repro.distrib.shard.{name} is a deprecated shim; {instead}",
        DeprecationWarning, stacklevel=3)


# --------------------------------------------------------------------------
# deprecated per-family entry points (runtime facades)
# --------------------------------------------------------------------------

def gnm_directed_sharded(
    seed: int, n: int, m: int, mesh: Mesh, axis: str = "pe",
    capacity: int | None = None, rng_impl: str = "threefry2x32",
):
    """Deprecated: build (jitted_fn, inputs) for the sharded G(n,m) step.

    Use ``er.gnm_directed_plan(...)`` + :func:`repro.distrib.runtime.executor`
    (or ``repro.api.generate(GNM(...), mesh=...)``).  Output is
    unchanged: the shim emits the same plan and hands it to the same
    runtime executor."""
    from . import runtime

    _deprecated("gnm_directed_sharded",
                "emit er.gnm_directed_plan and use repro.distrib.runtime.executor")
    P = _mesh_size(mesh)
    plan = gnm_directed_plan(seed, n, m, P, rng_impl)
    if capacity is not None:
        plan = dataclasses.replace(plan, capacity=capacity)
    return runtime.executor(plan, mesh)


def run_gnm_directed_sharded(seed: int, n: int, m: int, mesh: Mesh):
    """Deprecated: execute + gather; returns (edges [m,2], lowered_text).

    Use ``repro.api.generate(GNM(n, m, directed=True, chunks=P), mesh=...)``
    or :func:`repro.distrib.runtime.run` on an ``er.gnm_directed_plan``."""
    from . import runtime

    _deprecated("run_gnm_directed_sharded",
                "use repro.api.generate or repro.distrib.runtime.run")
    plan = gnm_directed_plan(seed, n, m, _mesh_size(mesh))
    edges, keep, hlo = runtime.run(plan, mesh, check=True, want_hlo=True)
    return np.asarray(edges)[np.asarray(keep)], hlo


def rgg_points_sharded(seed: int, n: int, radius: float, mesh: Mesh, dim: int = 2):
    """Deprecated: sharded RGG vertex generation (fn, inputs).

    Use ``rgg.rgg_point_plan(...)`` + :func:`repro.distrib.runtime.executor`,
    or stream positions with ``repro.api.iter_points(RGG(...))``."""
    from . import runtime

    _deprecated("rgg_points_sharded",
                "emit rgg.rgg_point_plan and use repro.distrib.runtime.executor "
                "(or stream via repro.api.iter_points)")
    plan = rgg_point_plan(seed, n, radius, _mesh_size(mesh), dim)
    return runtime.executor(plan, mesh)
