"""Ambient sharding-hint context for model code.

Model layers call `constrain(x, ...axes)` at a handful of strategic
points (attention scores, MoE dispatch buffers, loss logits).  Outside a
`use_hints` context (unit tests, single-device runs) these are no-ops;
under the dry-run/production builder they become
`with_sharding_constraint`s against the active mesh.  Axis tokens:

    'dp'  -> the data-parallel axes ('pod','data') / ('data',)
    'tp'  -> the tensor-parallel axis 'model'
    None  -> unconstrained

Any token whose mesh size does not divide the dimension degrades to
None (replication) instead of erroring — the universal divisibility
fallback."""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


class _Hints:
    def __init__(self, mesh: Mesh, dp: Tuple[str, ...], tp: str):
        self.mesh, self.dp, self.tp = mesh, dp, tp
        self.sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_size(self, token) -> int:
        axes = self.dp if token == "dp" else (self.tp,)
        return int(np.prod([self.sizes[a] for a in axes]))

    def resolve(self, token, dim: int):
        if token is None:
            return None
        if dim % self.axis_size(token) != 0:
            return None
        return self.dp if token == "dp" else self.tp


@contextlib.contextmanager
def use_hints(mesh: Mesh, tp: str = "model"):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    prev = getattr(_state, "hints", None)
    _state.hints = _Hints(mesh, dp, tp)
    try:
        yield
    finally:
        _state.hints = prev


def current() -> Optional[_Hints]:
    return getattr(_state, "hints", None)


def tp_size(default: int = 1) -> int:
    h = current()
    return h.axis_size("tp") if h else default


def constrain(x: jax.Array, *tokens) -> jax.Array:
    h = current()
    if h is None:
        return x
    assert len(tokens) == x.ndim, (tokens, x.shape)
    spec = P(*[h.resolve(t, d) for t, d in zip(tokens, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(h.mesh, spec))


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(1,))
def _pin_grad(x, spec_sharding):
    return x


def _pin_fwd(x, spec_sharding):
    return x, None


def _pin_bwd(spec_sharding, _res, g):
    # force the weight cotangent onto the parameter's sharding at its
    # production site: GSPMD then emits a reduce-scatter instead of a
    # late full all-reduce (ZeRO-2-style wgrad placement)
    return (jax.lax.with_sharding_constraint(g, spec_sharding),)


_pin_grad.defvjp(_pin_fwd, _pin_bwd)


def pin_grad(x: jax.Array, pspec: P) -> jax.Array:
    """Identity in fwd; constrains the cotangent to `pspec` in bwd."""
    h = current()
    if h is None:
        return x
    return _pin_grad(x, NamedSharding(h.mesh, pspec))
