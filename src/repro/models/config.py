"""Architecture configuration schema covering all 10 assigned families:
dense / MoE / MLA / SWA / local-global / qk-norm / M-RoPE / SSD(Mamba2) /
hybrid (Jamba) / encoder-only."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|encoder|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # attention flavor
    attn_kind: str = "full"         # full | swa | local_global
    window: int = 4096
    local_per_global: int = 0       # gemma3: 5 local then 1 global
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False             # qwen2-vl (text positions in dry-run)
    causal: bool = True

    # MLA (deepseek)
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0            # 0 = no q compression (v2-lite)
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1              # jamba: MoE every 2nd layer
    first_dense_layers: int = 0     # deepseek: layer 0 dense
    capacity_factor: float = 1.25

    # SSM (mamba2 SSD)
    ssm: bool = False
    attn_every: int = 0             # jamba: one attention layer per 8
    d_state: int = 128
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    d_conv: int = 4
    expand: int = 2

    # frontend stubs for [vlm]/[audio]: inputs are precomputed embeddings
    frontend: str = "none"          # none | vision | audio

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or (self.d_inner // self.ssm_head_dim)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- layer-pattern helpers -------------------------------------------

    def layer_kind(self, i: int) -> str:
        """'attn' | 'ssm' for mixer at layer i."""
        if not self.ssm:
            return "attn"
        if self.attn_every and (i % self.attn_every == self.attn_every // 2):
            return "attn"
        return "ssm"

    def layer_attn_kind(self, i: int) -> str:
        """'full' | 'swa' for attention layer i (gemma3 5:1 pattern)."""
        if self.attn_kind == "local_global":
            return "full" if (i % (self.local_per_global + 1)
                              == self.local_per_global) else "swa"
        return self.attn_kind

    def layer_is_moe(self, i: int) -> bool:
        if not self.moe:
            return False
        if i < self.first_dense_layers:
            return False
        return (i % self.moe_every) == (self.moe_every - 1) if self.moe_every > 1 else True

    def param_count(self) -> int:
        """Approximate total parameters (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        total = self.vocab * d  # embed (untied lm head counted below)
        total += self.vocab * d
        for i in range(self.n_layers):
            if self.layer_kind(i) == "attn":
                if self.mla:
                    qdim = self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    total += d * qdim
                    total += d * (self.kv_lora_rank + self.qk_rope_dim)
                    total += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    total += self.n_heads * self.v_head_dim * d
                else:
                    total += d * self.n_heads * hd
                    total += 2 * d * self.n_kv_heads * hd
                    total += self.n_heads * hd * d
            else:
                di, N, H = self.d_inner, self.d_state, self.n_ssm_heads
                total += d * (2 * di + 2 * N + H) + di * d + di * self.d_conv
            if self.layer_is_moe(i):
                e_all = self.n_experts + self.n_shared_experts
                total += e_all * 3 * d * self.moe_d_ff + d * self.n_experts
            elif self.d_ff:
                total += 3 * d * self.d_ff
            total += 2 * d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.moe:
            return self.param_count()
        total = self.param_count()
        for i in range(self.n_layers):
            if self.layer_is_moe(i):
                inactive = (self.n_experts - self.top_k) * 3 * self.d_model * self.moe_d_ff
                total -= inactive
        return total
