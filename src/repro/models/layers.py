"""Pure-JAX layer library: GQA/SWA attention, MLA, MoE, Mamba2-SSD,
RMSNorm, RoPE/M-RoPE.  Every layer is an (init, apply) pair over plain
dict pytrees; params live in `param_dtype` (f32 master) and compute is
cast to `dtype` (bf16 on TPU).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import pmesh
from .config import ArchConfig

Params = Dict[str, jax.Array]


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- rmsnorm

def rmsnorm_init(cfg: ArchConfig, dim: int) -> Params:
    return {"scale": jnp.ones((dim,), cfg.param_dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------- rope

def rope_freqs(hd: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float64) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float,
               mrope_sections: Optional[Tuple[int, ...]] = None) -> jax.Array:
    """x: [..., S, H, hd]; pos: [..., S] (or [3, ..., S] for M-RoPE).

    M-RoPE (qwen2-vl): the hd/2 frequency slots are split into
    (t, h, w) sections, each rotated by its own position stream.  With
    text-only positions all three streams coincide (dry-run mode)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)  # [hd/2]
    if mrope_sections is None:
        ang = pos[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    else:
        parts = []
        start = 0
        for s_idx, sec in enumerate(mrope_sections):
            f = freqs[start: start + sec]
            p = pos[s_idx] if pos.ndim > x.ndim - 2 else pos
            parts.append(p[..., :, None].astype(jnp.float32) * f)
            start += sec
        ang = jnp.concatenate(parts, axis=-1)
    cos = jnp.cos(ang)[..., :, None, :]  # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- masks

def attn_mask(q_len: int, kv_len: int, *, causal: bool, window: int,
              q_offset) -> jax.Array:
    """bool [q_len, kv_len]; True = attend.  q_offset aligns decode steps."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    m = jnp.ones((q_len, kv_len), bool)
    if causal:
        m &= kj <= qi
    if window and window > 0:
        m &= kj > qi - window
    return m


# ---------------------------------------------------------------- GQA attn

def attention_init(key, cfg: ArchConfig) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    sc = 0.02
    p = {
        "wq": _init(ks[0], (d, H * hd), sc, cfg.param_dtype),
        "wk": _init(ks[1], (d, KV * hd), sc, cfg.param_dtype),
        "wv": _init(ks[2], (d, KV * hd), sc, cfg.param_dtype),
        "wo": _init(ks[3], (H * hd, d), sc / math.sqrt(2 * cfg.n_layers), cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.param_dtype)
    return p


def _qk_normalize(x, scale):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


_Q_CHUNK = 1024  # q-block size for chunked attention


def cache_write(cache_arr: jax.Array, new: jax.Array, idx) -> jax.Array:
    """Write `new` [B, S, ...] into `cache_arr` [B, Smax, ...] at idx.

    Single-token decode against a *sequence-sharded* cache uses a masked
    (one-hot) write: a dynamic-update-slice on a sharded dim makes GSPMD
    all-gather the whole cache per step (involuntary rematerialization),
    which at 500k context is GBs per layer per token.  The masked write
    is local on every shard — the owner lane takes `new`, all others keep
    their slice.  Prefill (S == Smax) keeps the plain DUS."""
    S = new.shape[1]
    if S == 1 and pmesh.current() is not None:
        iota = jnp.arange(cache_arr.shape[1], dtype=jnp.int32)
        mask = (iota == idx).reshape((1, -1) + (1,) * (cache_arr.ndim - 2))
        return jnp.where(mask, new.astype(cache_arr.dtype), cache_arr)
    start = (jnp.int32(0), idx) + (jnp.int32(0),) * (cache_arr.ndim - 2)
    return jax.lax.dynamic_update_slice(cache_arr, new.astype(cache_arr.dtype), start)


def _sdpa(q, k, v, hd, n_heads, *, causal, window, q_offset):
    """q: [B,S,H,hd]; k,v: [B,T,KV,hd] -> out [B,S,H,hd].

    GQA keys/values are expanded to H heads (cheap: KV*hd << scores) so
    the head axis is a single shardable dimension — this is what lets
    Megatron-style TP work for any (H, KV) combination that divides the
    mesh.  When H does NOT divide the TP axis (e.g. smollm's 15 heads)
    the caller has seq-sharded q instead (context parallelism) and the
    expansion shards nothing — still correct, GSPMD just replicates.

    For S > _Q_CHUNK the q axis is processed in scanned blocks so the
    [S, T] score matrix never materializes (exact softmax per q row —
    full-T scores per block, no running-max needed)."""
    B, S, H, _ = q.shape
    T = k.shape[1]
    G = n_heads // k.shape[2]
    kx = jnp.repeat(k, G, axis=2)
    vx = jnp.repeat(v, G, axis=2)
    kx = pmesh.constrain(kx, "dp", None, "tp", None)
    vx = pmesh.constrain(vx, "dp", None, "tp", None)

    def attend(q_blk, offset):
        scores = jnp.einsum("bshd,bthd->bhst", q_blk, kx).astype(jnp.float32)
        scores = scores / math.sqrt(hd)
        mask = attn_mask(q_blk.shape[1], T, causal=causal, window=window,
                         q_offset=offset)
        scores = jnp.where(mask[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bthd->bshd", w, vx)

    if S <= _Q_CHUNK or S % _Q_CHUNK:
        return attend(q, q_offset)

    nq = S // _Q_CHUNK
    qs = q.reshape(B, nq, _Q_CHUNK, H, hd).swapaxes(0, 1)
    attend_ck = jax.checkpoint(attend)  # recompute scores in bwd

    def body(_, xs):
        q_blk, i = xs
        return None, attend_ck(q_blk, q_offset + i * _Q_CHUNK)

    _, out = jax.lax.scan(body, None, (qs, jnp.arange(nq)))
    return out.swapaxes(0, 1).reshape(B, S, H, hd)


def _sdpa_decode(q, k, v, hd, n_heads, *, window, q_offset, key_pos=None):
    """Decode attention against a (possibly seq-sharded) cache WITHOUT
    GQA head expansion: expanding k/v to H heads would reshard the cache
    seq->heads (a full all-gather of the cache, per layer, per token).
    The grouped einsum keeps the T axis sharded end-to-end; the only
    cross-shard traffic is the softmax max/sum and the tiny output psum
    — distributed flash-decode, expressed through GSPMD.

    key_pos: absolute position of each cache slot (ring buffers); when
    None, slot t holds position t."""
    B, S, H, _ = q.shape
    KV = k.shape[2]
    G = n_heads // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if key_pos is None:
        mask = attn_mask(S, k.shape[1], causal=True, window=window,
                         q_offset=q_offset)
    else:
        qi = jnp.arange(S)[:, None] + q_offset
        mask = (key_pos[None, :] <= qi) & (key_pos[None, :] >= 0)
        if window and window > 0:
            mask &= key_pos[None, :] > qi - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H, hd)


def attention(p: Params, cfg: ArchConfig, x: jax.Array, pos: jax.Array,
              kind: str, *, cache: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    """Full-sequence (cache=None) or single-step decode (cache given).

    cache = {k: [B, Smax, KV, hd], v: ..., idx: scalar}."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, KV, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = _qk_normalize(q, p["q_norm"])
        k = _qk_normalize(k, p["k_norm"])
    # GQA: kv heads rarely divide the TP axis; replicate k/v across TP
    # (they are small) so the head expansion is a local slice — avoids
    # SPMD "involuntary full rematerialization" on 8-way->16-way moves
    k = pmesh.constrain(k, "dp", None, None, None)
    v = pmesh.constrain(v, "dp", None, None, None)
    sections = (16, 24, 24) if (cfg.mrope and hd == 128) else None
    q = apply_rope(q, pos, cfg.rope_theta, sections)
    k = apply_rope(k, pos, cfg.rope_theta, sections)

    # TP strategy: head-sharded when H divides the TP axis; otherwise
    # (ragged head counts, e.g. 15) context-parallel: shard q's seq axis.
    if H % pmesh.tp_size() == 0:
        q = pmesh.constrain(q, "dp", None, "tp", None)
    else:
        q = pmesh.constrain(q, "dp", "tp", None, None)

    window = cfg.window if kind == "swa" else 0
    if cache is None:
        out = _sdpa(q, k, v, hd, H, causal=cfg.causal, window=window, q_offset=0)
        new_cache = None
    else:
        idx = cache["idx"]
        W = cache["k"].shape[1]
        ring = kind == "swa" and W == cfg.window  # ring buffer cache
        if ring and S > 1:
            # prefill a ring cache: attend over the in-flight k/v (full,
            # chunked), then store only the last `window` tokens, rolled
            # so that slot == position % window (single-shot prefill)
            out = _sdpa(q, k, v, hd, H, causal=True, window=window, q_offset=idx)
            if S >= W:
                ck = jnp.roll(k[:, -W:], (idx + S) % W, axis=1)
                cv = jnp.roll(v[:, -W:], (idx + S) % W, axis=1)
            else:
                ck = cache_write(cache["k"], k, idx)
                cv = cache_write(cache["v"], v, idx)
        elif ring:
            # ring decode: slot r holds position idx - ((idx%W - r) mod W)
            slot = idx % W
            ck = cache_write(cache["k"], k, slot)
            cv = cache_write(cache["v"], v, slot)
            r = jnp.arange(W)
            key_pos = idx - jnp.mod(slot - r, W)
            out = _sdpa_decode(q, ck, cv, hd, H, window=window, q_offset=idx,
                               key_pos=key_pos)
        else:
            ck = cache_write(cache["k"], k, idx)
            cv = cache_write(cache["v"], v, idx)
            if S == 1:
                out = _sdpa_decode(q, ck, cv, hd, H, window=window, q_offset=idx)
            else:  # prefill into cache: chunked path, no [S,T] blowup
                out = _sdpa(q, ck, cv, hd, H, causal=True, window=window,
                            q_offset=idx)
        new_cache = {"k": ck, "v": cv, "idx": idx + S}
    out = out.reshape(B, S, H * hd)
    return out @ p["wo"].astype(dt), new_cache


# ---------------------------------------------------------------- MLA

def mla_init(key, cfg: ArchConfig) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    nope, rope, vh, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(key, 5)
    sc = 0.02
    return {
        "wq": _init(ks[0], (d, H * (nope + rope)), sc, cfg.param_dtype),
        "w_dkv": _init(ks[1], (d, r + rope), sc, cfg.param_dtype),      # c_kv + k_rope
        "w_uk": _init(ks[2], (r, H * nope), sc, cfg.param_dtype),
        "w_uv": _init(ks[3], (r, H * vh), sc, cfg.param_dtype),
        "wo": _init(ks[4], (H * vh, d), sc / math.sqrt(2 * cfg.n_layers), cfg.param_dtype),
        "kv_norm": jnp.ones((r,), cfg.param_dtype),
    }


def mla_attention(p: Params, cfg: ArchConfig, x: jax.Array, pos: jax.Array,
                  *, cache: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    """DeepSeek MLA.  Prefill: expanded keys/values.  Decode: *absorbed*
    path — scores against the compressed c_kv cache directly, which is
    the memory win MLA exists for (cache row = kv_lora+rope floats).

    cache = {c: [B, Smax, r], kr: [B, Smax, rope], idx}."""
    B, S, d = x.shape
    H = cfg.n_heads
    nope, rope, vh, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    dt = x.dtype

    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    dkv = x @ p["w_dkv"].astype(dt)
    c_kv = rmsnorm({"scale": p["kv_norm"]}, dkv[..., :r])
    k_rope = apply_rope(dkv[..., r:][:, :, None, :], pos, cfg.rope_theta)[:, :, 0]

    scale = 1.0 / math.sqrt(nope + rope)
    new_cache = None
    if cache is not None:
        idx = cache["idx"]
        cc = cache_write(cache["c"], c_kv, idx)
        ckr = cache_write(cache["kr"], k_rope, idx)
        new_cache = {"c": cc, "kr": ckr, "idx": idx + S}
    if cache is None or S > 1:
        # prefill/training: expanded keys/values, q-chunked (a prefill
        # writes the cache above but attends over the current tokens —
        # identical content, chunk-friendly layout)
        k_nope = (c_kv @ p["w_uk"].astype(dt)).reshape(B, S, H, nope)
        v = (c_kv @ p["w_uv"].astype(dt)).reshape(B, S, H, vh)

        def attend(qn_blk, qr_blk, offset):
            scores = (jnp.einsum("bshn,bthn->bhst", qn_blk, k_nope)
                      + jnp.einsum("bshn,btn->bhst", qr_blk, k_rope)).astype(jnp.float32)
            mask = attn_mask(qn_blk.shape[1], S, causal=True, window=0,
                             q_offset=offset)
            scores = jnp.where(mask[None, None], scores * scale, -1e30)
            w = jax.nn.softmax(scores, axis=-1).astype(dt)
            return jnp.einsum("bhst,bthv->bshv", w, v)

        if S <= _Q_CHUNK or S % _Q_CHUNK:
            out = attend(q_nope, q_rope, 0)
        else:
            nq = S // _Q_CHUNK
            qn = q_nope.reshape(B, nq, _Q_CHUNK, H, nope).swapaxes(0, 1)
            qr = q_rope.reshape(B, nq, _Q_CHUNK, H, rope).swapaxes(0, 1)
            attend_ck = jax.checkpoint(attend)

            def body(_, xs):
                a, b2, i = xs
                return None, attend_ck(a, b2, i * _Q_CHUNK)

            _, out = jax.lax.scan(body, None, (qn, qr, jnp.arange(nq)))
            out = out.swapaxes(0, 1).reshape(B, S, H, vh)
    else:
        # single-token decode: *absorbed* path against the compressed
        # c_kv cache — the memory win MLA exists for (576 floats/token)
        cc, ckr, idx = new_cache["c"], new_cache["kr"], cache["idx"]
        T = cc.shape[1]
        w_uk = p["w_uk"].astype(dt).reshape(r, H, nope)
        q_c = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
        scores = (jnp.einsum("bshr,btr->bhst", q_c, cc)
                  + jnp.einsum("bshn,btn->bhst", q_rope, ckr)).astype(jnp.float32)
        mask = attn_mask(S, T, causal=True, window=0, q_offset=idx)
        scores = jnp.where(mask[None, None], scores * scale, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(dt)
        attn_c = jnp.einsum("bhst,btr->bshr", w, cc)      # attend over c_kv
        w_uv = p["w_uv"].astype(dt).reshape(r, H, vh)
        out = jnp.einsum("bshr,rhv->bshv", attn_c, w_uv)  # absorbed W_UV
    out = out.reshape(B, S, H * vh)
    return out @ p["wo"].astype(dt), new_cache


# ---------------------------------------------------------------- MLP

def mlp_init(key, cfg: ArchConfig, d_ff: int) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init(ks[0], (d, d_ff), 0.02, cfg.param_dtype),
        "w_up": _init(ks[1], (d, d_ff), 0.02, cfg.param_dtype),
        "w_down": _init(ks[2], (d_ff, d), 0.02 / math.sqrt(2 * cfg.n_layers), cfg.param_dtype),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)


# ---------------------------------------------------------------- MoE

def moe_init(key, cfg: ArchConfig) -> Params:
    d, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, E), 0.02, jnp.float32),  # router in f32
        "w_gate": _init(ks[1], (E, d, F), 0.02, cfg.param_dtype),
        "w_up": _init(ks[2], (E, d, F), 0.02, cfg.param_dtype),
        "w_down": _init(ks[3], (E, F, d), 0.02 / math.sqrt(2 * cfg.n_layers), cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def moe(p: Params, cfg: ArchConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k with *grouped-local capacity* dispatch.

    Tokens are processed in G groups aligned with the data-parallel axis
    (G = dp size under hints, 1 otherwise): routing positions and the
    dispatch scatter are computed per group, so every buffer carries a
    leading dp-shardable group dim — a global scatter would force GSPMD
    to replicate the [E, C, d] buffer on every device (~20 GB for
    mixtral at 1M tokens).  Dispatch/combine are memory ops (vmapped
    scatter/gather), never the quadratic one-hot einsum.  Per-group
    capacity C_g = cf*T_g*K/E matches how real EP systems drop tokens
    (local capacity before the all-to-all).

    Returns (out, aux_loss)."""
    B, S, d = x.shape
    E, K, F = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    T = B * S
    dt = x.dtype
    hints = pmesh.current()
    G = hints.axis_size("dp") if hints and T % hints.axis_size("dp") == 0 else 1
    Tg = T // G
    xg = x.reshape(G, Tg, d)

    logits = (xg.astype(jnp.float32) @ p["router"])              # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)                       # [G, Tg, K]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(expert[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    C = max(1, int(cfg.capacity_factor * Tg * K / E))
    flat_e = expert.reshape(G, Tg * K)
    onehot_pos = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # [G, TgK, E]
    pos_in_e = jnp.cumsum(onehot_pos, axis=1) - 1
    slot = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = (slot < C).reshape(G, Tg, K)
    dest = jnp.where(keep.reshape(G, Tg * K), flat_e * C + slot, E * C)
    dest = dest.reshape(G, Tg, K)                                 # overflow row

    # dispatch one top-k slot at a time: materializes [Tg, d], never
    # [Tg*K, d] (the repeat formulation kept several TgK-sized f32
    # copies live in the backward pass — jamba's 63 GB peak)
    def scatter_group(dest_g, x_g):
        buf = jnp.zeros((E * C + 1, d), dt)
        for kk in range(K):
            buf = buf.at[dest_g[:, kk]].add(x_g)
        return buf[:-1]

    buf = jax.vmap(scatter_group)(dest, xg).reshape(G, E, C, d)
    # groups ride the dp axis; experts ride TP when they divide it (EP).
    # Otherwise (mixtral: 8 experts < 16-way TP) shard the expert FFN
    # width over TP and let each TP shard recompute the (small) dispatch
    # buffer redundantly — communication instead of... none: the paper's
    # recompute-don't-communicate trade applied to MoE dispatch.
    ep = E % pmesh.tp_size() == 0
    if ep:
        buf = pmesh.constrain(buf, "dp", "tp", None, None)
    else:
        buf = pmesh.constrain(buf, "dp", None, None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    if not ep:
        h = pmesh.constrain(h, "dp", None, None, "tp")
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))

    def gather_group(y_g, dest_g, keep_g, gate_g):
        rows = y_g.reshape(E * C, d)
        acc = jnp.zeros((dest_g.shape[0], d), dt)
        for kk in range(K):
            r = rows[jnp.minimum(dest_g[:, kk], E * C - 1)]
            w_k = (gate_g[:, kk] * keep_g[:, kk]).astype(dt)[:, None]
            acc = acc + r * w_k
        return acc

    combined = jax.vmap(gather_group)(y, dest, keep, gate)        # [G, Tg, d]
    combined = combined.reshape(T, d)

    if cfg.n_shared_experts:
        combined = combined + mlp(p["shared"], x.reshape(T, d))
    return combined.reshape(B, S, d), aux


# ---------------------------------------------------------------- Mamba2 SSD

def mamba2_init(key, cfg: ArchConfig) -> Params:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 5)
    conv_ch = di + 2 * N  # conv over x, B, C (mamba2 layout)
    return {
        "in_proj": _init(ks[0], (d, 2 * di + 2 * N + H), 0.02, cfg.param_dtype),
        "conv_w": _init(ks[1], (cfg.d_conv, conv_ch), 0.2, cfg.param_dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": jnp.ones((di,), cfg.param_dtype),
        "out_proj": _init(ks[2], (di, d), 0.02 / math.sqrt(2 * cfg.n_layers), cfg.param_dtype),
    }


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Minimal SSD (Mamba2 §6): intra-chunk quadratic + inter-chunk scan.

    xh: [B,S,H,P], dt: [B,S,H] (>=0), A: [H] (<0), Bm/Cm: [B,S,N].
    Returns y: [B,S,H,P]."""
    B_, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    xc = xh.reshape(B_, nc, chunk, H, P)
    dtc = dt.reshape(B_, nc, chunk, H)
    Bc = Bm.reshape(B_, nc, chunk, N)
    Cc = Cm.reshape(B_, nc, chunk, N)

    da = dtc * A  # [B,nc,Q,H] (negative)
    cum = jnp.cumsum(da, axis=2)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # [B,nc,Qi,Qj,H]
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(seg), 0.0)

    # intra-chunk: y_intra[i] = sum_j L[i,j] * (C_i . B_j) * dt_j * x_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                    # [B,nc,Qi,Qj]
    w = cb[..., None] * L                                         # [B,nc,Qi,Qj,H]
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w, dtc, xc)

    # chunk summaries: S_c = sum_j exp(cum_last - cum_j) dt_j x_j B_j^T
    # (the SSM state recurrence runs in f32 for stability; outputs cast back)
    decay_tail = jnp.exp(cum[:, :, -1:, :] - cum)                 # [B,nc,Q,H]
    Sc = jnp.einsum("bcjh,bcjh,bcjhp,bcjn->bchpn", decay_tail, dtc, xc, Bc)
    Sc = Sc.astype(jnp.float32)

    # inter-chunk recurrence over nc
    total = jnp.exp(cum[:, :, -1, :])                             # [B,nc,H]

    def step(h, inp):
        tot, s = inp
        h_new = h * tot[..., None, None] + s
        return h_new, h

    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    _, h_prev = jax.lax.scan(
        step, h0, (total.swapaxes(0, 1), Sc.swapaxes(0, 1))
    )
    h_prev = h_prev.swapaxes(0, 1)                                # [B,nc,H,P,N] state before chunk

    # inter-chunk contribution: y_inter[i] = C_i . (exp(cum_i) * h_prev)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cc.astype(jnp.float32), jnp.exp(cum), h_prev)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(B_, S, H, P)
    return y.astype(xh.dtype)


def mamba2(p: Params, cfg: ArchConfig, x: jax.Array, *,
           cache: Optional[dict] = None, chunk: int = 128) -> Tuple[jax.Array, Optional[dict]]:
    """Mamba2 SSD mixer.  cache = {conv: [B, d_conv-1, ch], h: [B,H,P,N], idx}."""
    B, S, d = x.shape
    di, N, H = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    P = di // H
    dt_model = x.dtype

    proj = x @ p["in_proj"].astype(dt_model)                      # [B,S,2di+2N+H]
    z, xbc, dt_raw = jnp.split(proj, [di, di + di + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])                                      # [H]

    conv_w = p["conv_w"].astype(dt_model)                         # [K, ch]
    K = cfg.d_conv
    if cache is None:
        pad = jnp.zeros((B, K - 1, xbc.shape[-1]), dt_model)
        xin = jnp.concatenate([pad, xbc], axis=1)
        conv = sum(xin[:, i: i + S] * conv_w[i] for i in range(K))
        new_conv_state = None
    else:
        xin = jnp.concatenate([cache["conv"], xbc], axis=1)       # [B, K-1+S, ch]
        conv = sum(xin[:, i: i + S] * conv_w[i] for i in range(K))
        new_conv_state = xin[:, -(K - 1):]
    conv = jax.nn.silu(conv)
    xh, Bm, Cm = jnp.split(conv, [di, di + N], axis=-1)
    xh = xh.reshape(B, S, H, P)

    if cache is None:
        pad_s = (-S) % chunk
        if pad_s:
            zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad_s)] + [(0, 0)] * (a.ndim - 2))
            y = _ssd_chunked(zpad(xh), zpad(dt.astype(dt_model)).astype(jnp.float32),
                             A, zpad(Bm), zpad(Cm), chunk)[:, :S]
        else:
            y = _ssd_chunked(xh, dt, A, Bm, Cm, chunk)
        new_cache = None
    else:
        # recurrent decode: h <- h * exp(dt A) + dt * x B^T ; y = C.h
        h = cache["h"]
        dts = dt[:, 0]                                            # [B,H]
        decay = jnp.exp(dts * A)                                  # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dts.astype(dt_model), xh[:, 0], Bm[:, 0])
        h = h * decay[..., None, None].astype(dt_model) + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], h)[:, None]      # [B,1,H,P]
        new_cache = {"conv": new_conv_state, "h": h, "idx": cache["idx"] + S}

    y = y + p["D"].astype(dt_model)[:, None] * xh
    y = y.reshape(B, S, di)
    y = rmsnorm({"scale": p["out_norm"]}, y * jax.nn.silu(z))
    return y @ p["out_proj"].astype(dt_model), new_cache


# ---------------------------------------------------------------- embed

def embed_init(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "tok": _init(ks[0], (cfg.vocab, cfg.d_model), 1.0, cfg.param_dtype),
        "head": _init(ks[1], (cfg.d_model, cfg.vocab), 0.02, cfg.param_dtype),
    }
