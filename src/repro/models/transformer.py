"""Model assembly: layer-pattern detection -> scanned superblocks.

Heterogeneous layer stacks (gemma3's 5 local:1 global, jamba's 7 ssm:1
attn with MoE every 2nd layer, deepseek's dense first layer) are
compiled as: [unrolled prefix] + scan(superblock of `period` layers) +
[unrolled remainder].  Scanning keeps the HLO size O(period) instead of
O(n_layers) — essential for 512-device dry-run compiles — and remat is
applied per superblock.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from . import pmesh
from .config import ArchConfig

Params = Dict[str, Any]


# ------------------------------------------------------------- patterns

def layer_signature(cfg: ArchConfig, i: int) -> tuple:
    kind = cfg.layer_kind(i)
    return (
        kind,
        cfg.layer_attn_kind(i) if kind == "attn" else "",
        cfg.layer_is_moe(i),
    )


def detect_layout(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    """(prefix, period, reps, remainder) covering n_layers."""
    sigs = [layer_signature(cfg, i) for i in range(cfg.n_layers)]
    best = None
    for prefix in range(0, min(5, cfg.n_layers)):
        for period in range(1, min(9, cfg.n_layers - prefix + 1)):
            reps = (cfg.n_layers - prefix) // period
            if reps < 2:
                continue
            rem = cfg.n_layers - prefix - reps * period
            body = sigs[prefix: prefix + period]
            ok = all(
                sigs[prefix + j] == body[j % period]
                for j in range(reps * period + rem)
            )
            if ok:
                cand = (prefix, period, reps, rem)
                if best is None or (cand[0], cand[1]) < (best[0], best[1]):
                    best = cand
        if best and best[0] == prefix:
            break
    if best is None:
        return 0, cfg.n_layers, 1, 0  # fully unrolled fallback
    return best


# ------------------------------------------------------------- blocks

def block_init(key, cfg: ArchConfig, i: int) -> Params:
    ks = jax.random.split(key, 4)
    sig = layer_signature(cfg, i)
    p: Params = {"norm1": L.rmsnorm_init(cfg, cfg.d_model),
                 "norm2": L.rmsnorm_init(cfg, cfg.d_model)}
    if sig[0] == "attn":
        p["mixer"] = L.mla_init(ks[0], cfg) if cfg.mla else L.attention_init(ks[0], cfg)
    else:
        p["mixer"] = L.mamba2_init(ks[0], cfg)
    if sig[2]:
        p["ffn"] = L.moe_init(ks[1], cfg)
    elif cfg.d_ff:
        p["ffn"] = L.mlp_init(ks[1], cfg, cfg.d_ff)
    return p


def block_apply(p: Params, cfg: ArchConfig, i: int, x, pos,
                cache: Optional[dict] = None):
    """Returns (x, aux_loss, new_cache)."""
    sig = layer_signature(cfg, i)
    # cast+grad-pin here, INSIDE the scan body: the wgrad reduce-scatter
    # must be emitted per iteration, not on the stacked tensor outside
    p = cast_params(p, cfg.dtype)
    h = L.rmsnorm(p["norm1"], x)
    if sig[0] == "attn":
        if cfg.mla:
            mix, new_cache = L.mla_attention(p["mixer"], cfg, h, pos, cache=cache)
        else:
            mix, new_cache = L.attention(p["mixer"], cfg, h, pos, sig[1], cache=cache)
    else:
        mix, new_cache = L.mamba2(p["mixer"], cfg, h, cache=cache)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h2 = L.rmsnorm(p["norm2"], x)
        if sig[2]:
            f, aux = L.moe(p["ffn"], cfg, h2)
        else:
            f = L.mlp(p["ffn"], h2)
        x = x + f
    x = pmesh.constrain(x, "dp", "tp", None)
    return x, aux, new_cache


def block_cache_init(cfg: ArchConfig, i: int, batch: int, s_max: int, dtype) -> dict:
    sig = layer_signature(cfg, i)
    if sig[0] == "attn":
        if cfg.mla:
            return {
                "c": jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((batch, s_max, cfg.qk_rope_dim), dtype),
                "idx": jnp.zeros((), jnp.int32),
            }
        # sliding-window layers only ever attend to the last `window`
        # tokens: a ring buffer of that size replaces the full cache
        # (gemma3 62L x 500k would otherwise not fit any machine)
        s_cache = min(s_max, cfg.window) if sig[1] == "swa" else s_max
        return {
            "k": jnp.zeros((batch, s_cache, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, s_cache, cfg.n_kv_heads, cfg.hd), dtype),
            "idx": jnp.zeros((), jnp.int32),
        }
    di, N, H = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di + 2 * N), dtype),
        "h": jnp.zeros((batch, H, di // H, N), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


# ------------------------------------------------------------- model

def model_init(key, cfg: ArchConfig) -> Params:
    prefix, period, reps, rem = detect_layout(cfg)
    keys = jax.random.split(key, cfg.n_layers + 2)
    p: Params = {"embed": L.embed_init(keys[-1], cfg),
                 "final_norm": L.rmsnorm_init(cfg, cfg.d_model)}
    p["prefix"] = [block_init(keys[i], cfg, i) for i in range(prefix)]
    body = []
    for j in range(period):
        per_rep = [
            block_init(keys[prefix + r * period + j], cfg, prefix + j)
            for r in range(reps)
        ]
        body.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    p["body"] = body
    p["remainder"] = [
        block_init(keys[prefix + reps * period + j], cfg, prefix + j)
        for j in range(rem)
    ]
    return p


def cast_params(p: Params, dtype) -> Params:
    """Mixed precision: cast f32 masters to compute dtype *before* the
    FSDP all-gathers so gathered weights move/live in bf16 (autodiff
    through the convert yields f32 grads).  Under mesh hints, each weight
    is also grad-pinned: its cotangent is constrained to the parameter
    sharding at production, turning late wgrad all-reduces into
    reduce-scatters."""
    dt = jnp.dtype(dtype)
    hints = pmesh.current()
    specs = None
    if hints is not None:
        from . import shardings as SH
        specs = SH.param_specs(jax.tree.map(lambda x: x, p), hints.mesh, None)

    def leaf(x, s=None):
        if not (hasattr(x, "dtype") and x.dtype == jnp.float32 and x.ndim >= 2):
            return x
        x = x.astype(dt)
        if s is not None:
            # pin AFTER the cast: the reduce-scatter then moves bf16 bytes
            x = pmesh.pin_grad(x, s)
        return x

    if specs is None:
        return jax.tree.map(leaf, p)
    return jax.tree.map(leaf, p, specs,
                        is_leaf=lambda x: hasattr(x, "dtype"))


def _embed_tokens(p: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]):
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend != "none":
        return batch["embeds"].astype(dt)
    return p["embed"]["tok"].astype(dt)[batch["tokens"]]


def forward(p: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            caches: Optional[dict] = None):
    """hidden states [B, S, D]; returns (h, total_aux, new_caches)."""
    prefix, period, reps, rem = detect_layout(cfg)
    p = dict(p, embed=cast_params(p["embed"], cfg.dtype),
             final_norm=p["final_norm"])
    x = _embed_tokens(p, cfg, batch)
    # residual stream sequence-sharded between blocks (Megatron-SP):
    # bounds remat-saved activations AND turns per-layer TP all-reduces
    # into reduce-scatter/all-gather pairs
    x = pmesh.constrain(x, "dp", "tp", None)
    pos = batch["positions"]
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {"prefix": [], "body": [], "remainder": []}

    for i in range(prefix):
        c = caches["prefix"][i] if caches else None
        x, aux, nc = block_apply(p["prefix"][i], cfg, i, x, pos, c)
        aux_total += aux
        if caches:
            new_caches["prefix"].append(nc)

    if reps >= 2 and period >= 1 and reps * period > 0:
        # NOTE on multi-layer superblocks (gemma period 6, jamba period
        # 8): the backward holds all `period` recomputed layer interiors
        # at once.  A nested per-block checkpoint was tried and REFUTED
        # (peak grew 60.6 -> 69.0 GB under XLA-CPU's scheduler; see
        # EXPERIMENTS.md §Perf).  The supported fix is gradient
        # accumulation (make_train_step(accum=...)), which divides every
        # activation term by `accum`.
        def superblock(carry, xs):
            x, aux_in = carry
            params_j, cache_j = xs
            new_cache_j = []
            for j in range(period):
                cj = cache_j[j] if cache_j is not None else None
                x, aux, nc = block_apply(params_j[j], cfg, prefix + j, x, pos, cj)
                aux_in = aux_in + aux
                new_cache_j.append(nc)
            out = tuple(new_cache_j) if cache_j is not None else None
            return (x, aux_in), out

        body_params = tuple(p["body"])
        if caches is not None:
            body_caches = tuple(caches["body"])
            sb = superblock
        else:
            body_caches = None
            sb = jax.checkpoint(
                superblock,
                policy=jax.checkpoint_policies.nothing_saveable,
            )
        (x, aux_total), scan_caches = jax.lax.scan(
            sb, (x, aux_total),
            (body_params, body_caches) if caches is not None else (body_params, None),
            length=reps,
        )
        if caches is not None:
            new_caches["body"] = list(scan_caches)
    else:
        # degenerate: single rep — unroll, preserving the stacked layout
        new_body: List[List[Any]] = [[] for _ in range(period)]
        for r in range(reps):
            for j in range(period):
                params_rj = jax.tree.map(lambda a: a[r], p["body"][j])
                c = (jax.tree.map(lambda a: a[r], caches["body"][j])
                     if caches else None)
                x, aux, nc = block_apply(params_rj, cfg, prefix + j, x, pos, c)
                aux_total += aux
                if caches:
                    new_body[j].append(nc)
        if caches:
            new_caches["body"] = [
                jax.tree.map(lambda *xs: jnp.stack(xs), *reps_list)
                for reps_list in new_body
            ]

    for j in range(rem):
        c = caches["remainder"][j] if caches else None
        x, aux, nc = block_apply(p["remainder"][j], cfg, prefix + j, x, pos, c)
        aux_total += aux
        if caches:
            new_caches["remainder"].append(nc)

    x = L.rmsnorm(p["final_norm"], x)
    return x, aux_total, (new_caches if caches is not None else None)


def caches_init(cfg: ArchConfig, batch: int, s_max: int, dtype) -> dict:
    prefix, period, reps, rem = detect_layout(cfg)
    out: Dict[str, Any] = {}
    out["prefix"] = [block_cache_init(cfg, i, batch, s_max, dtype) for i in range(prefix)]
    body = []
    for j in range(period):
        per_rep = [block_cache_init(cfg, prefix + j, batch, s_max, dtype)
                   for _ in range(reps)]
        body.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    out["body"] = body
    out["remainder"] = [block_cache_init(cfg, prefix + reps * period + j, batch, s_max, dtype)
                        for j in range(rem)]
    return out


# ------------------------------------------------------------- loss

def lm_loss(p: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            loss_chunk: int = 512) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked cross-entropy: logits are materialized loss_chunk tokens at
    a time so the [tokens, vocab] tensor never exists in full."""
    h, aux, _ = forward(p, cfg, batch)
    B, S, D = h.shape
    labels = batch["labels"]
    head = p["embed"]["head"].astype(h.dtype)

    ck = min(loss_chunk, S)
    while S % ck:
        ck -= 1
    nch = S // ck
    hc = h.reshape(B, nch, ck, D).swapaxes(0, 1)           # [nch, B, ck, D]
    lc = labels.reshape(B, nch, ck).swapaxes(0, 1)

    def chunk_ce(hi, li):
        logits = (hi @ head).astype(jnp.float32)           # [B, ck, V]
        logits = pmesh.constrain(logits, "dp", None, "tp")  # vocab-sharded
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - tgt)

    chunk_ce = jax.checkpoint(chunk_ce)  # logits recomputed in bwd

    def chunk_loss(carry, xs):
        hi, li = xs
        return carry + chunk_ce(hi, li), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hc, lc))
    loss = total / (B * S)
    metrics = {"ce": loss, "aux": aux}
    return loss + 0.01 * aux, metrics


def decode_step(p: Params, cfg: ArchConfig, tokens, positions, caches):
    """One-token decode: tokens [B,1] -> (logits [B,1,V], new caches)."""
    batch = {"tokens": tokens, "positions": positions}
    if cfg.frontend != "none":
        dt = jnp.dtype(cfg.dtype)
        batch = {"embeds": p["embed"]["tok"].astype(dt)[tokens], "positions": positions}
    h, _, new_caches = forward(p, cfg, batch, caches=caches)
    logits = h @ p["embed"]["head"].astype(h.dtype)
    return logits, new_caches


def param_shapes(cfg: ArchConfig) -> Params:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: model_init(jax.random.key(0), cfg))
