"""Sharding rules: FSDP(+ZeRO) over 'data', tensor parallel over 'model',
pure data parallel over 'pod' (params replicated across pods; gradient
all-reduce rides the slower inter-pod fabric, optionally int8-compressed).

Attention/FFN projections are stored flat [d_in, H*hd] so the TP axis
always divides (e.g. smollm's 15 heads x 64 = 960).  Any dimension that
does not divide its mesh axis falls back to replication (`_maybe`).

KV caches shard (batch -> dp, seq -> 'model'): sequence-sharded decode is
what scales to 500k contexts; see train/serve and EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ArchConfig

FSDP = "data"
TP = "model"


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _maybe(axis, dim_size, mesh: Mesh):
    if axis is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axis, tuple):
        total = int(np.prod([sizes[a] for a in axis]))
    else:
        total = sizes[axis]
    return axis if dim_size % total == 0 else None


def _leaf_spec(name: str, shape, mesh: Mesh, cfg: ArchConfig, stacked: bool) -> P:
    nd = len(shape) - (1 if stacked else 0)
    dims = shape[1:] if stacked else shape
    tp_sz = dict(zip(mesh.axis_names, mesh.devices.shape))[TP]

    def spec(*axes):
        fixed = tuple(_maybe(a, d, mesh) for a, d in zip(axes, dims))
        return P(*((None,) + fixed)) if stacked else P(*fixed)

    if nd <= 1:
        return P(None) if not stacked else P(None, None)
    if name == "tok":
        return spec(TP, FSDP)
    if name == "head":
        return spec(FSDP, TP)
    if name in ("wq", "wk", "wv", "w_dkv", "w_uk", "w_uv", "in_proj"):
        return spec(FSDP, TP)
    if name in ("wo", "out_proj"):
        return spec(TP, FSDP)
    if name == "router":
        return spec(FSDP, None)
    if name == "conv_w":
        return spec(None, TP)
    if name in ("w_gate", "w_up"):
        if nd == 3:  # MoE experts [E, d, F]
            if dims[0] % tp_sz == 0:
                return spec(TP, FSDP, None)        # expert parallel
            return spec(None, FSDP, TP)            # TP inside each expert
        return spec(FSDP, TP)
    if name == "w_down":
        if nd == 3:
            if dims[0] % tp_sz == 0:
                return spec(TP, None, FSDP)
            return spec(None, TP, FSDP)
        return spec(TP, FSDP)
    return spec(*([None] * nd))


def param_specs(params_shapes: Any, mesh: Mesh, cfg: ArchConfig) -> Any:
    """PartitionSpec pytree matching the params tree."""

    def walk(tree, in_body: bool):
        if isinstance(tree, dict):
            return {k: walk_named(k, v, in_body) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, in_body) for v in tree]
            return type(tree)(t) if not isinstance(tree, tuple) else tuple(t)
        raise TypeError(type(tree))

    def walk_named(name, tree, in_body):
        if isinstance(tree, dict):
            return {k: walk_named(k, v, in_body) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            body = name == "body"
            return type(tree)(walk_named(name, v, in_body or body) for v in tree)
        return _leaf_spec(name, tree.shape, mesh, cfg, stacked=in_body)

    return walk(params_shapes, False)


def batch_specs(cfg: ArchConfig, mesh: Mesh, batch_shapes: Dict) -> Dict:
    dp = dp_axes(mesh)
    dp_sz = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in dp]))
    out = {}
    for k, v in batch_shapes.items():
        b = dp if v.shape[0] % dp_sz == 0 else None
        out[k] = P(*((b,) + (None,) * (len(v.shape) - 1)))
    return out


def cache_specs(cfg: ArchConfig, mesh: Mesh, caches_shapes: Any) -> Any:
    """(batch->dp, seq->'model') for KV caches; SSM states (batch->dp,
    heads->'model')."""
    dp = dp_axes(mesh)
    dp_sz = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in dp]))
    tp_sz = dict(zip(mesh.axis_names, mesh.devices.shape))[TP]

    def leaf(path, x):
        name = None
        for e in reversed(path):
            if hasattr(e, "key"):
                name = e.key
                break
        shape = x.shape
        # stacked body caches carry a leading reps axis
        stacked = len(path) >= 2 and any(
            getattr(e, "key", None) == "body" for e in path
        )
        dims = shape[1:] if stacked else shape
        pre = (None,) if stacked else ()
        if name == "idx" or len(dims) == 0:
            return P(*(pre + (None,) * len(dims)))
        bspec = dp if dims[0] % dp_sz == 0 else None
        if name in ("k", "v"):        # [B, S, KV, hd]
            sspec = TP if dims[1] % tp_sz == 0 else None
            return P(*(pre + (bspec, sspec, None, None)))
        if name in ("c", "kr"):       # MLA [B, S, r]
            sspec = TP if dims[1] % tp_sz == 0 else None
            return P(*(pre + (bspec, sspec, None)))
        if name == "h":               # SSM [B, H, P, N]
            hspec = TP if dims[1] % tp_sz == 0 else None
            return P(*(pre + (bspec, hspec, None, None)))
        if name == "conv":            # [B, K-1, ch]
            cspec = TP if dims[2] % tp_sz == 0 else None
            return P(*(pre + (bspec, None, cspec)))
        return P(*(pre + (bspec,) + (None,) * (len(dims) - 1)))

    return jax.tree_util.tree_map_with_path(leaf, caches_shapes)


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
