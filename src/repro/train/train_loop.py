"""Train step factory: loss -> grad -> (optional int8-compressed DP
all-reduce) -> AdamW, with optional microbatch gradient accumulation
(scan) so large global batches fit activation memory."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..models.config import ArchConfig
from . import optimizer as O


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: O.OptConfig,
    *,
    accum: int = 1,
    compress=None,   # Optional[(tree)->tree] gradient codec (distrib.compress)
    loss_chunk: int = 512,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With accum > 1, the batch's leading dim is split into `accum`
    microbatches and gradients are averaged via a scan — identical
    numerics to one big batch, bounded activation memory."""

    def loss_fn(p, b):
        return T.lm_loss(p, cfg, b, loss_chunk=loss_chunk)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc_g, acc_l = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), None

            split = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
            )
            zero_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zero_g, jnp.zeros((), jnp.float32)), split)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {}
        if compress is not None:
            grads = compress(grads)
        params, opt_state, om = O.opt_update(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    return train_step
