"""Sharded, atomic, elastic checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
            manifest.json       (step, config hash, mesh shape, tree spec)
            shard_<p>.npz       (flat param/opt arrays owned by process p)

* atomic: written to step_<N>.tmp then os.replace()'d.
* elastic: restore concatenates whatever shard files exist and reshards
  to the *current* mesh — process counts may differ between save/load.
* the data pipeline needs no state file at all: batches are a pure
  function of (seed, step) — the paper's recompute-don't-communicate
  paradigm applied to input, so restart only needs `step` from the
  manifest.
* async: `save(..., background=True)` snapshots to host memory
  synchronously and writes in a thread (train step continues).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    meta: Optional[Dict] = None,
    num_shards: int = 1,
    background: bool = False,
    keep: int = 3,
) -> threading.Thread | None:
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": int(step),
        "num_leaves": len(leaves),
        "num_shards": int(num_shards),
        "treedef": str(treedef),
        "meta": meta or {},
    }

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        for p in range(num_shards):
            arrs = {f"leaf_{i}": leaves[i] for i in range(p, len(leaves), num_shards)}
            np.savez(os.path.join(tmp, f"shard_{p}.npz"), **arrs)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep)

    if background:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore into the structure (and shardings) of `tree_like`.

    Works across process/mesh changes: shards are merged by leaf index,
    then device_put against tree_like's shardings (if concrete arrays) —
    elastic restart."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves: Dict[int, np.ndarray] = {}
    for p in range(manifest["num_shards"]):
        with np.load(os.path.join(d, f"shard_{p}.npz")) as z:
            for k in z.files:
                leaves[int(k.split("_")[1])] = z[k]
    flat = [leaves[i] for i in range(manifest["num_leaves"])]
    ref_leaves, treedef = jax.tree.flatten(tree_like)
    out = []
    for ref, arr in zip(ref_leaves, flat):
        if hasattr(ref, "sharding") and not isinstance(ref, jax.ShapeDtypeStruct):
            out.append(jax.device_put(arr, ref.sharding))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest
