"""AdamW + global-norm clipping + warmup-cosine schedule (pure JAX;
optimizer state inherits the parameters' sharding, i.e. ZeRO comes free
from the FSDP param specs)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip: float = 1.0


def schedule(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / jnp.maximum(1.0, cfg.warmup)
    t = (step - cfg.warmup) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup)
    cos = 0.5 * cfg.lr * (1.0 + jnp.cos(jnp.pi * jnp.clip(t, 0.0, 1.0)))
    return jnp.where(step < cfg.warmup, warm, cos)


def opt_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(tree)))


def opt_update(cfg: OptConfig, params, grads, state) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip / (gn + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gn, "lr": lr}
