"""Serving: prefill + batched greedy decode over KV/SSM caches."""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as T
from ..models.config import ArchConfig


def prefill(params, cfg: ArchConfig, tokens, s_max: int, embeds=None):
    """Run the prompt through the model, filling caches sized s_max.

    Returns (caches, last_token_logits [B, V])."""
    B, S = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    caches = T.caches_init(cfg, B, s_max, dtype)
    pos = jnp.tile(jnp.arange(S, dtype=jnp.int32), (B, 1))
    batch = {"tokens": tokens, "positions": pos}
    if cfg.frontend != "none":
        batch = {"embeds": embeds if embeds is not None
                 else params["embed"]["tok"].astype(dtype)[tokens],
                 "positions": pos}
    h, _, caches = T.forward(params, cfg, batch, caches=caches)
    logits = h[:, -1] @ params["embed"]["head"].astype(h.dtype)
    return caches, logits


@partial(jax.jit, static_argnames=("cfg",))
def _decode_jit(params, cfg, tokens, positions, caches):
    return T.decode_step(params, cfg, tokens, positions, caches)


def generate(params, cfg: ArchConfig, prompts: np.ndarray, steps: int):
    """Greedy generation for a batch of prompts; returns [B, steps]."""
    B, S = prompts.shape
    caches, logits = prefill(params, cfg, jnp.asarray(prompts), S + steps)
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for t in range(steps):
        out.append(np.asarray(tok))
        pos = jnp.full((B, 1), S + t, jnp.int32)
        logits, caches = _decode_jit(params, cfg, tok, pos, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return np.concatenate(out, axis=1)
