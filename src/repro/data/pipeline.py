"""Communication-free data pipeline — the paper's paradigm applied to LM
input: every data-parallel shard synthesizes its own token stream from a
KaGen graph it generates locally (hash-seeded, zero communication), and
any batch is a pure function of (seed, step, shard):

* deterministic resume: restart needs only `step` — no data-state
  checkpoint, no shard re-synchronization (recompute, don't communicate);
* elastic: shard count changes re-map streams without data movement;
* infinite: the underlying graph family scales to 2^43 vertices (paper),
  so the corpus never repeats.

Corpus: random walks over the shard's local RHG/ER subgraph, tokenized
by vertex id (mod vocab) with a separator token between walks.  Scale-free
RHG walks give a Zipf-like token distribution — a reasonable synthetic
stand-in for language tokens.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, Tuple

import numpy as np

from ..core import er, rhg
from ..core.prng import host_rng


@dataclass(frozen=True)
class DataConfig:
    kind: str = "rhg_walk"      # rhg_walk | er_walk
    n_vertices: int = 4096
    avg_deg: float = 16.0
    gamma: float = 2.6
    vocab: int = 256
    seq_len: int = 128
    batch_per_shard: int = 4
    num_shards: int = 1         # virtual DP shards (elastic-safe)
    seed: int = 0


@lru_cache(maxsize=64)
def _local_graph(cfg: DataConfig, shard: int) -> Tuple[np.ndarray, np.ndarray]:
    """CSR adjacency of the shard's locally generated subgraph."""
    if cfg.kind == "rhg_walk":
        params = rhg.RHGParams(cfg.n_vertices, cfg.avg_deg, cfg.gamma, cfg.seed)
        edges, _, _, _ = rhg.rhg_pe(params, cfg.num_shards, shard)
    else:
        m = int(cfg.n_vertices * cfg.avg_deg / 2)
        edges = er.gnm_undirected_pe(cfg.seed, cfg.n_vertices, m, cfg.num_shards, shard)
    # symmetrize -> CSR over the vertices present locally
    both = np.concatenate([edges, edges[:, ::-1]], axis=0)
    order = np.lexsort((both[:, 1], both[:, 0]))
    both = both[order]
    indptr = np.zeros(cfg.n_vertices + 1, np.int64)
    np.add.at(indptr, both[:, 0] + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, both[:, 1]


def make_batch(cfg: DataConfig, step: int, shard: int) -> Dict[str, np.ndarray]:
    """Pure function (seed, step, shard) -> {tokens, labels, positions}."""
    indptr, nbrs = _local_graph(cfg, shard)
    rng = host_rng(cfg.seed, 71, step, shard)
    B, S = cfg.batch_per_shard, cfg.seq_len
    deg = np.diff(indptr)
    starts_pool = np.nonzero(deg > 0)[0]
    toks = np.zeros((B, S + 1), np.int64)
    sep = cfg.vocab - 1
    for b in range(B):
        t = 0
        while t < S + 1:
            v = int(starts_pool[rng.integers(len(starts_pool))])
            walk_len = min(S + 1 - t, int(rng.integers(8, 64)))
            for _ in range(walk_len):
                toks[b, t] = v % (cfg.vocab - 1)
                t += 1
                d = indptr[v + 1] - indptr[v]
                if d == 0 or t >= S + 1:
                    break
                v = int(nbrs[indptr[v] + rng.integers(d)])
            if t < S + 1:
                toks[b, t] = sep
                t += 1
    return {
        "tokens": toks[:, :S].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
        "positions": np.tile(np.arange(S, dtype=np.int32), (B, 1)),
    }


def make_global_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Concatenated over shards (single-host testing convenience)."""
    parts = [make_batch(cfg, step, s) for s in range(cfg.num_shards)]
    return {k: np.concatenate([p[k] for p in parts], axis=0) for k in parts[0]}
