"""repro.analyze — static contract verification for the paper's
communication-free invariants.

Two cooperating passes behind one CLI (``python -m repro.analyze``):

* **Pass 1** (:mod:`repro.analyze.hloscan` + :mod:`repro.analyze.programs`):
  lower every registered device program (8 families x plan types x the
  runtime's run + wave steps, plus the float32 kernels) and walk the
  module text for collectives, host callbacks, nondeterministic RNG on
  recompute paths, f64 promotion in pinned-float32 paths and
  dynamic-shape escapes — attaching static FLOP/byte estimates from
  :mod:`repro.launch.hlocost`.  The runtime's ``check=True`` assertion
  calls the same scanner (:func:`assert_communication_free`).

* **Pass 2** (:mod:`repro.analyze.lint`): an AST linter over the repo
  encoding the source-level rules (no ``np.unique`` in emitters, no
  stdlib ``random`` / wall-clock state, no collectives in ``kernels/``,
  no raw ``PRNGKey`` outside ``core/prng.py``, no deprecated shims, no
  non-counter RNG on pair-plan families), with inline
  ``# repro: allow(<rule>)`` suppressions.

This package's import surface is deliberately layered:
:mod:`~repro.analyze.hloscan` and :mod:`~repro.analyze.lint` import
neither JAX nor the engine (so :mod:`repro.distrib.engine` can import
the scanner without a cycle); :mod:`~repro.analyze.programs` — which
imports the full API — loads lazily via ``__getattr__``.
"""
from __future__ import annotations

from .hloscan import (  # noqa: F401
    COLLECTIVE_RE,
    Contract,
    Finding,
    IR_RULES,
    ScanReport,
    assert_communication_free,
    collective_ops_in,
    scan_lowered,
    scan_text,
)
from .lint import (  # noqa: F401
    LINT_RULES,
    LintFinding,
    lint_paths,
    lint_source,
)

__all__ = [
    "COLLECTIVE_RE", "Contract", "Finding", "IR_RULES", "ScanReport",
    "assert_communication_free", "collective_ops_in", "scan_lowered",
    "scan_text", "LINT_RULES", "LintFinding", "lint_paths", "lint_source",
    "programs",
]


def __getattr__(name: str):
    if name == "programs":
        import importlib

        return importlib.import_module(".programs", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
