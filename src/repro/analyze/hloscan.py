"""Pass 1 — the IR-level contract scanner.

The paper's central claim is that generation is *communication-free and
pseudorandomly recomputable* (Funke et al., 2017, §2): every PE derives
its share of the graph from hashed recursion-tree seeds alone, so the
lowered device program must contain **no collective ops, no host
callbacks, no nondeterministic RNG on recomputed paths, and no dynamic
shapes**.  Those invariants are exactly what rots silently as a
generator grows features (Penschuck et al., 2020) — so this module
checks them *statically*, by walking the lowered module text, and is
the one implementation behind both

* the runtime's once-per-signature ``check=True`` assertion
  (:func:`assert_communication_free`, called from
  :mod:`repro.distrib.runtime`), and
* the CI gate (``python -m repro.analyze --all-programs``, via
  :mod:`repro.analyze.programs`).

The scanner accepts **both IR spellings**: the StableHLO text that
``jax.stages.Lowered.as_text()`` emits (``stablehlo.all_reduce``,
underscores) and the optimized HLO text of ``Compiled.as_text()``
(``all-reduce``, hyphens).  That duality is load-bearing: the seed's
original regex knew only the hyphenated HLO spelling, so a planted
``jax.lax.psum`` in the StableHLO lowering sailed straight through the
"assertion" — the planted-violation self-test in
``tests/test_analyze.py`` is what pins this scanner to reality.

Rules (ids are shared with the JSON report and the runtime error path):

==========================  ================================================
``collective-op``           all-reduce / all-gather / reduce-scatter /
                            all-to-all / collective-permute / broadcast
                            (any spelling, including ``-start`` phases)
``host-callback``           custom calls into the Python host
                            (``xla_python_cpu_callback`` & friends),
                            infeed / outfeed / send / recv
``nondeterministic-rng``    ``rng_bit_generator`` ops — stateful block
                            RNG whose draws depend on vmap row / backend,
                            breaking the recomputation invariant on pair
                            and point paths (ChunkPlans may opt in: the
                            'rbg' perf path never recomputes a slot twice)
``f64-op``                  f64-typed ops — a violation only where the
                            contract pins a float32 path (the pairmask
                            kernels, the TORUS r² test); always counted
``dynamic-shape``           dynamic-shape escapes: ``tensor<?x...>``,
                            bounded ``[<=n]`` dims, ``dynamic_reshape``
                            et al. — capacity-padded static shapes are
                            what make plans recomputable and cacheable
==========================  ================================================
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# --------------------------------------------------------------------------
# rule ids
# --------------------------------------------------------------------------

RULE_COLLECTIVE = "collective-op"
RULE_HOST_CALLBACK = "host-callback"
RULE_NONDET_RNG = "nondeterministic-rng"
RULE_F64 = "f64-op"
RULE_DYNAMIC_SHAPE = "dynamic-shape"

IR_RULES = (RULE_COLLECTIVE, RULE_HOST_CALLBACK, RULE_NONDET_RNG,
            RULE_F64, RULE_DYNAMIC_SHAPE)

# --------------------------------------------------------------------------
# op patterns — both StableHLO (underscore) and HLO (hyphen) spellings
# --------------------------------------------------------------------------

_COLLECTIVE_NAMES = [
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
]


def _both_spellings(names) -> str:
    alts = []
    for n in names:
        alts.append(n)                      # HLO: all-reduce(, all-reduce-start(
        alts.append(n + "-start")
        alts.append(n.replace("-", "_"))    # StableHLO: stablehlo.all_reduce
    # longest-first so "all-gather-start" wins over "all-gather"
    alts.sort(key=len, reverse=True)
    return "|".join(re.escape(a) for a in alts)


COLLECTIVE_RE = re.compile(r"\b(" + _both_spellings(_COLLECTIVE_NAMES) + r")\b")

_HOST_CALLBACK_RE = re.compile(
    r"\b(xla_(?:ffi_)?python_(?:cpu|gpu|tpu)_callback"
    r"|callback_custom_call"
    r"|infeed|outfeed"
    r"|stablehlo\.send|stablehlo\.recv"
    r"|send-start|recv-start)\b"
)

_RNG_BIT_GENERATOR_RE = re.compile(r"\brng[-_]bit[-_]generator\b")
_RNG_ALGORITHM_RE = re.compile(
    r"rng_bit_generator[^\n]*?algorithm\s*=\s*(\w+)"   # stablehlo.rng_bit_generator ..., algorithm = DEFAULT
    r"|algorithm=rng_(\w+)")                            # HLO: algorithm=rng_default
# the legacy sample-from-distribution op (never deterministic per slot)
_RNG_OP_RE = re.compile(r"\bstablehlo\.rng\b|^\s*%?[\w.\-]+\s*=\s*\w+\[[0-9,]*\]\S*\s+rng\(",
                        re.MULTILINE)

_F64_RE = re.compile(r"\btensor<(?:[0-9?x]*x)?f64>?|\bf64\[")

_DYNAMIC_RE = re.compile(
    r"\btensor<\?|\[<=\d"
    r"|\b(?:stablehlo\.)?(?:dynamic_reshape|real_dynamic_slice|dynamic_pad"
    r"|dynamic_broadcast_in_dim|dynamic_iota|set_dimension_size)\b"
)


# --------------------------------------------------------------------------
# contracts & reports
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Contract:
    """Which Pass-1 rules are *violations* for a given program.

    Collectives, host callbacks and dynamic shapes are forbidden for
    every generator program — they are the paper's invariant itself.
    ``forbid_rng_bit_generator`` is set on pair/point programs, whose
    slot fns recompute cells across vmap rows (the 'rbg' impl draws
    different values for the same key in different rows — the reason
    :func:`repro.distrib.engine.make_pair_plan` rejects it at plan
    time; this is the same rule enforced statically).  ``forbid_f64``
    pins declared-float32 paths (the pairmask kernels) against silent
    x64 promotion."""
    forbid_collectives: bool = True
    forbid_host_callbacks: bool = True
    forbid_dynamic_shapes: bool = True
    forbid_rng_bit_generator: bool = False
    forbid_f64: bool = False


# every generator program's baseline contract
GENERATOR_CONTRACT = Contract()
# pair/point programs additionally pin deterministic counter RNG
RECOMPUTE_CONTRACT = Contract(forbid_rng_bit_generator=True)
# declared-float32 kernel paths additionally pin no f64 promotion
FLOAT32_KERNEL_CONTRACT = Contract(forbid_rng_bit_generator=True, forbid_f64=True)


@dataclass(frozen=True)
class Finding:
    """One contract violation found in a lowered module."""
    rule: str
    detail: str
    count: int = 1

    def to_json(self) -> dict:
        return {"rule": self.rule, "detail": self.detail, "count": self.count}


@dataclass
class ScanReport:
    """Raw op census of one lowered module + the contract verdict."""
    counts: Dict[str, int] = field(default_factory=dict)
    collectives: List[str] = field(default_factory=list)
    host_callbacks: List[str] = field(default_factory=list)
    rng_algorithms: List[str] = field(default_factory=list)
    findings: List[Finding] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> dict:
        return {
            "counts": dict(self.counts),
            "collectives": sorted(set(self.collectives)),
            "host_callbacks": sorted(set(self.host_callbacks)),
            "rng_algorithms": sorted(set(self.rng_algorithms)),
            "violations": [f.to_json() for f in self.findings],
            "ok": self.ok,
        }


def _as_text(lowered_or_text) -> str:
    if isinstance(lowered_or_text, str):
        return lowered_or_text
    return lowered_or_text.as_text()


def collective_ops_in(hlo_text: str) -> List[str]:
    """All collective-op mentions in a lowered module, either spelling.

    The historical engine entry point (every zero-collective test goes
    through it); kept list-valued — empty means communication-free."""
    return COLLECTIVE_RE.findall(_as_text(hlo_text))


def scan_text(text: str, contract: Contract = GENERATOR_CONTRACT) -> ScanReport:
    """Walk one lowered module's text and report contract violations.

    ``text`` may be StableHLO (``Lowered.as_text()``) or optimized HLO
    (``Compiled.as_text()``); all op patterns match both spellings."""
    rep = ScanReport()

    rep.collectives = COLLECTIVE_RE.findall(text)
    rep.counts[RULE_COLLECTIVE] = len(rep.collectives)
    if rep.collectives and contract.forbid_collectives:
        rep.findings.append(Finding(
            RULE_COLLECTIVE,
            f"collective ops in lowering: {sorted(set(rep.collectives))}",
            len(rep.collectives)))

    rep.host_callbacks = _HOST_CALLBACK_RE.findall(text)
    rep.counts[RULE_HOST_CALLBACK] = len(rep.host_callbacks)
    if rep.host_callbacks and contract.forbid_host_callbacks:
        rep.findings.append(Finding(
            RULE_HOST_CALLBACK,
            f"host callbacks in lowering: {sorted(set(rep.host_callbacks))}",
            len(rep.host_callbacks)))

    rbg = _RNG_BIT_GENERATOR_RE.findall(text)
    legacy_rng = _RNG_OP_RE.findall(text)
    rep.rng_algorithms = [a or b for a, b in _RNG_ALGORITHM_RE.findall(text)]
    rep.counts[RULE_NONDET_RNG] = len(rbg) + len(legacy_rng)
    if (rbg or legacy_rng) and contract.forbid_rng_bit_generator:
        algos = sorted(set(rep.rng_algorithms)) or ["?"]
        rep.findings.append(Finding(
            RULE_NONDET_RNG,
            f"rng_bit_generator on a recompute path (algorithms {algos}): "
            f"draws are not a pure function of (key, slot), so recomputed "
            f"cells disagree across vmap rows",
            len(rbg) + len(legacy_rng)))

    f64 = _F64_RE.findall(text)
    rep.counts[RULE_F64] = len(f64)
    if f64 and contract.forbid_f64:
        rep.findings.append(Finding(
            RULE_F64,
            f"{len(f64)} f64-typed values in a declared-float32 path "
            f"(unintended x64 promotion)",
            len(f64)))

    dyn = _DYNAMIC_RE.findall(text)
    rep.counts[RULE_DYNAMIC_SHAPE] = len(dyn)
    if dyn and contract.forbid_dynamic_shapes:
        rep.findings.append(Finding(
            RULE_DYNAMIC_SHAPE,
            f"dynamic-shape escapes in lowering: {sorted(set(dyn))[:4]}",
            len(dyn)))

    return rep


def scan_lowered(lowered, contract: Contract = GENERATOR_CONTRACT) -> ScanReport:
    """:func:`scan_text` over a ``jax.stages.Lowered`` (or Compiled)."""
    return scan_text(_as_text(lowered), contract)


def assert_communication_free(lowered) -> None:
    """Raise if a lowered program contains any collective op.

    The runtime's once-per-signature ``check=True`` path *is* this
    function — same scanner, same error text, as the CI gate."""
    ops = collective_ops_in(_as_text(lowered))
    if ops:
        raise AssertionError(
            f"generator lowering contains collectives: {sorted(set(ops))}")
