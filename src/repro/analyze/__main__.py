"""CLI gate: ``python -m repro.analyze``.

    python -m repro.analyze --all-programs --lint src/ --fail-on-violation

Pass 1 lowers every registered program (all families x plan types x
run/wave, plus the float32 kernels) and scans the modules for contract
violations; Pass 2 lints the given paths.  ``--json`` writes the full
machine-readable report (the CI artifact); ``--fail-on-violation``
exits 1 if either pass found anything — that exit code *is* the CI
gate, and ``tests/test_analyze.py`` plants violations to prove it
fires.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="static contract verifier for the communication-free "
                    "invariants (IR scan + AST lint)")
    ap.add_argument("--all-programs", action="store_true",
                    help="Pass 1 over every registered program "
                         "(families x plan types x run/wave + kernels)")
    ap.add_argument("--families", default=None,
                    help="comma-separated family subset for Pass 1 "
                         "(e.g. gnm,rgg,kernels); implies Pass 1")
    ap.add_argument("--pes", type=int, default=4,
                    help="virtual PEs per plan (default 4)")
    ap.add_argument("--batch", type=int, default=4,
                    help="wave-step batch per mesh row (default 4)")
    ap.add_argument("--lint", nargs="*", default=None, metavar="PATH",
                    help="Pass 2 paths (files or directories)")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the HloCost FLOP/byte attachment "
                         "(faster: no XLA compile per program)")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="write the machine-readable report here")
    ap.add_argument("--fail-on-violation", action="store_true",
                    help="exit 1 if any pass reports a violation")
    return ap


def main(argv: List[str] = None) -> int:
    args = build_parser().parse_args(argv)
    run_programs = args.all_programs or args.families is not None
    run_lint = args.lint is not None
    if not run_programs and not run_lint:
        # bare invocation: the full gate over the default surfaces
        run_programs, run_lint = True, True
        args.lint = ["src/repro", "examples", "benchmarks"]

    report = {"programs": [], "lint": [], "summary": {}}
    violations = 0

    if run_programs:
        from . import programs as _programs

        families = args.families.split(",") if args.families else None
        reports = _programs.scan_programs(
            families, P=args.pes, batch=args.batch,
            with_cost=not args.no_cost)
        for r in reports:
            report["programs"].append(r.to_json())
            flag = "ok" if r.ok else "VIOLATION"
            cost = (f"  flops={r.flops:,}  bytes={r.bytes:,}"
                    if r.flops is not None else "")
            print(f"[pass1] {r.name:<28} {flag}{cost}")
            if r.error:
                print(f"        error: {r.error}")
                violations += 1
            for f in r.scan.findings:
                print(f"        {f.rule}: {f.detail}")
                violations += 1

    if run_lint:
        from .lint import lint_paths

        findings = lint_paths(args.lint)
        for f in findings:
            report["lint"].append(f.to_json())
            print(f"[pass2] {f.format()}")
        violations += len(findings)

    report["summary"] = {
        "programs_scanned": len(report["programs"]),
        "lint_findings": len(report["lint"]),
        "violations": violations,
        "ok": violations == 0,
    }
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"report written to {args.json}")

    if violations:
        print(f"{violations} contract violation(s) found")
        return 1 if args.fail_on_violation else 0
    print("all contracts verified: zero collectives, no host callbacks, "
          "deterministic PRNG, static shapes")
    return 0


if __name__ == "__main__":
    sys.exit(main())
