"""The registered-program inventory Pass 1 walks.

Every device program the generator stack can execute is enumerated
here: all eight spec families' plans (ChunkPlan for the sampled
families, PairPlan + PointPlan for the geometric ones), each lowered
through *both* runtime paths — the materializing full-table ``run``
step and the shard_map'd **wave** step that streaming dispatches — on a
representative mesh, plus the declared-float32 kernel entry points.
The specs are deliberately tiny (n ≈ 64): contract violations are
properties of the lowered *structure* (a collective lowers at n = 64
exactly as it does at n = 2^30), so the gate stays cheap enough to run
on every push.

Each case carries a :class:`~repro.analyze.hloscan.Contract`:

* chunk programs — the baseline generator contract (no collectives /
  host callbacks / dynamic shapes; ``rng_bit_generator`` allowed — the
  'rbg' perf path never recomputes a slot twice),
* pair & point programs — additionally no ``rng_bit_generator``
  (recomputed cells must draw identically in every vmap row), and
* float32 kernels — additionally no f64 promotion.

:func:`scan_programs` attaches static FLOP / HBM-byte estimates from
:class:`repro.launch.hlocost.HloCost` to every program signature, so
the same report that proves the contracts also seeds the roofline
model (``repro.tune``'s cost tables start here).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .hloscan import (Contract, FLOAT32_KERNEL_CONTRACT, GENERATOR_CONTRACT,
                      RECOMPUTE_CONTRACT, ScanReport, scan_text)

FAMILIES = ("gnm", "gnp", "ba", "rmat", "sbm", "rgg", "rhg", "rdg", "serve")

# modes a plan lowers through: the materializing run step and the
# shard_map'd wave step (what streaming actually executes)
MODES = ("run", "wave")

DEFAULT_P = 4
DEFAULT_BATCH = 4


def small_specs() -> Dict[str, object]:
    """One tiny spec per family — structure-representative lowerings."""
    from ..api import BA, GNM, GNP, RDG, RGG, RHG, RMAT, SBM

    n = 64
    return {
        "gnm": GNM(n=n, m=2 * n, seed=7, chunks=8),
        "gnp": GNP(n=n, p=0.05, seed=7, chunks=8),
        "ba": BA(n=n, d=2, seed=7),
        "rmat": RMAT(log_n=6, m=2 * n, seed=7),
        "sbm": SBM(n=n, blocks=2, p_in=0.2, p_out=0.02, seed=7),
        "rgg": RGG(n=n, radius=0.25, seed=7, chunks=8),
        "rhg": RHG(n=n, avg_deg=4.0, gamma=2.7, seed=7),
        "rdg": RDG(n=32, seed=7, chunks=8),
    }


@dataclass(frozen=True)
class ProgramCase:
    """One lowerable program: a plan (or kernel) on a mesh, with its
    contract.  ``lower()`` returns the ``jax.stages.Lowered``."""
    name: str               # e.g. "rgg/pair/wave"
    family: str
    plan_kind: str          # chunk | point | pair | kernel
    mode: str               # run | wave | call
    contract: Contract
    lower: Callable[[], object]
    signature: tuple = ()


def _plan_cases(family: str, spec, P: int, batch: int,
                mesh=None) -> Iterator[ProgramCase]:
    from ..distrib import engine, runtime

    plans: List[Tuple[str, object]] = []
    plan = spec.plan(P)
    kind = {engine.ChunkPlan: "chunk", engine.PairPlan: "pair",
            engine.PointPlan: "point"}[type(plan)]
    plans.append((kind, plan))
    point_plan = getattr(spec, "point_plan", None)
    if point_plan is not None:
        plans.append(("point", point_plan(P)))

    for kind, p in plans:
        contract = GENERATOR_CONTRACT if kind == "chunk" else RECOMPUTE_CONTRACT
        for mode in MODES:
            if mode == "run":
                low = (lambda p=p: runtime.lower_run(p, mesh))
            else:
                low = (lambda p=p: runtime.lower_wave(p, mesh, batch=batch))
            yield ProgramCase(
                name=f"{family}/{kind}/{mode}", family=family, plan_kind=kind,
                mode=mode, contract=contract, lower=low,
                signature=p.signature())


def _serve_cases(P: int, mesh=None) -> Iterator[ProgramCase]:
    """The serving tier's packed mixed-request slab programs.

    The scheduler packs ready slots from *different* requests into one
    [D, B] slab; the programs lowered here are exactly what
    ``runtime.run_slab`` executes (and ``check``-asserts) in
    production: a chunk slab mixing G(n,m) and BA rows under the
    GENERATOR contract, and a pair slab mixing RGG (GEOM_TORUS) and
    RHG (GEOM_HYP) rows under the RECOMPUTE contract (packed cells are
    recomputed across rows, so nondeterministic RNG is a violation).
    """
    from ..api import BA, GNM, RGG, RHG
    from ..distrib import runtime
    from ..serve.scheduler import Scheduler
    from ..serve.sinks import Sink

    n = 64
    mixes = {
        "chunk": (GENERATOR_CONTRACT,
                  [GNM(n=n, m=2 * n, seed=7, chunks=8),
                   BA(n=n, d=2, seed=9)]),
        "pair": (RECOMPUTE_CONTRACT,
                 [RGG(n=n, radius=0.25, seed=7, chunks=8),
                  RHG(n=n, avg_deg=4.0, gamma=2.7, seed=9)]),
    }
    use_mesh = mesh if mesh is not None else runtime.mesh_for(P)
    for kind, (contract, specs) in mixes.items():
        sch = Scheduler(use_mesh, slab_batch=4, check=False)
        for spec in specs:
            sch.enqueue(spec.plan(P), Sink())
        prog, valid, rows = sch.peek_slab()

        def low(prog=prog, valid=valid, rows=rows, m=use_mesh):
            return runtime.lower_slab(prog.slot_fn(), valid, rows, m)

        yield ProgramCase(
            name=f"serve/{kind}/slab", family="serve", plan_kind=kind,
            mode="slab", contract=contract, lower=low,
            signature=prog.signature())


def _kernel_cases() -> Iterator[ProgramCase]:
    """The kernel entry points.

    The pairmask tiles are declared float32 (f64 promotion is a
    violation: the TORUS r² test is pinned so engine and kernel agree
    bit-for-bit).  The batched Delaunay triangulator is the opposite —
    f64 *by design* (its Cramer circumsphere predicate must match the
    engine's GEOM_CERT re-check bit-for-bit), so it carries the
    RECOMPUTE contract: no collectives, host callbacks, dynamic shapes,
    or rng_bit_generator (the kernel draws nothing; points arrive
    pre-generated)."""
    import jax
    import jax.numpy as jnp

    from ..kernels.delaunay import (cavity_capacity, group_size,
                                    simplex_capacity)
    from ..kernels.delaunay.ref import delaunay_ref
    from ..kernels.pairmask.ops import pair_mask

    def lower_euclid():
        a = jax.ShapeDtypeStruct((128, 8), jnp.float32)
        s = jax.ShapeDtypeStruct((), jnp.float32)
        return pair_mask.lower(a, a, s, tile="euclid", dim=2)

    yield ProgramCase(
        name="kernels/pairmask/euclid", family="kernels", plan_kind="kernel",
        mode="call", contract=FLOAT32_KERNEL_CONTRACT, lower=lower_euclid,
        signature=("pairmask", "euclid", 128, 8))

    for dim, n in ((2, 64), (3, 64)):
        def lower_dt(dim=dim, n=n):
            pts = jax.ShapeDtypeStruct((4, n, dim), jnp.float64)
            cnt = jax.ShapeDtypeStruct((4,), jnp.int32)
            return delaunay_ref.lower(
                pts, cnt, dim=dim, num_simplices=simplex_capacity(n, dim),
                cavity=cavity_capacity(dim), group=group_size(dim))

        yield ProgramCase(
            name=f"kernels/delaunay/ref{dim}d", family="kernels",
            plan_kind="kernel", mode="call", contract=RECOMPUTE_CONTRACT,
            lower=lower_dt, signature=("delaunay", "ref", dim, n))


def iter_programs(
    families: Optional[Sequence[str]] = None,
    P: int = DEFAULT_P,
    batch: int = DEFAULT_BATCH,
    mesh=None,
    kernels: bool = True,
) -> Iterator[ProgramCase]:
    """Yield every registered program case (filtered by ``families``)."""
    want = list(families) if families else list(FAMILIES)
    unknown = [f for f in want if f not in FAMILIES + ("kernels",)]
    if unknown:
        raise ValueError(f"unknown families {unknown}; know {FAMILIES}")
    specs = small_specs()
    for family in want:
        if family == "kernels":
            continue
        if family == "serve":
            yield from _serve_cases(P, mesh)
            continue
        yield from _plan_cases(family, specs[family], P, batch, mesh)
    if kernels and (families is None or "kernels" in want):
        yield from _kernel_cases()


@dataclass
class ProgramReport:
    """Pass-1 verdict + static cost estimate for one program."""
    name: str
    plan_kind: str
    mode: str
    signature: tuple
    scan: ScanReport
    flops: Optional[int] = None
    bytes: Optional[int] = None
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None and self.scan.ok

    def to_json(self) -> dict:
        out = {
            "name": self.name,
            "plan_kind": self.plan_kind,
            "mode": self.mode,
            "signature": [str(s) for s in self.signature],
            "flops": self.flops,
            "bytes": self.bytes,
            "ok": self.ok,
        }
        out.update(self.scan.to_json())
        if self.error:
            out["error"] = self.error
        return out


def scan_case(case: ProgramCase, with_cost: bool = True) -> ProgramReport:
    """Lower one case, scan its module, optionally attach HLO costs."""
    try:
        lowered = case.lower()
        if lowered is None:  # empty plan: no program will ever execute
            return ProgramReport(case.name, case.plan_kind, case.mode,
                                 case.signature, ScanReport())
        scan = scan_text(lowered.as_text(), case.contract)
    except Exception as e:  # lowering itself failing is a finding, not a crash
        return ProgramReport(case.name, case.plan_kind, case.mode,
                             case.signature, ScanReport(), error=f"{e!r}")
    flops = nbytes = None
    if with_cost:
        try:
            from ..launch.hlocost import HloCost

            cost = HloCost.from_lowered(lowered)
            flops, nbytes = cost.flops, cost.bytes
        except Exception as e:
            return ProgramReport(case.name, case.plan_kind, case.mode,
                                 case.signature, scan, error=f"cost: {e!r}")
    return ProgramReport(case.name, case.plan_kind, case.mode,
                         case.signature, scan, flops=flops, bytes=nbytes)


def scan_programs(
    families: Optional[Sequence[str]] = None,
    P: int = DEFAULT_P,
    batch: int = DEFAULT_BATCH,
    mesh=None,
    with_cost: bool = True,
    kernels: bool = True,
) -> List[ProgramReport]:
    """Pass 1 over the whole registered inventory."""
    return [scan_case(c, with_cost=with_cost)
            for c in iter_programs(families, P=P, batch=batch, mesh=mesh,
                                   kernels=kernels)]


def scan_spec(spec, P: int = DEFAULT_P, *, mesh=None, batch: int = DEFAULT_BATCH,
              with_cost: bool = False, name: str = "spec") -> List[ProgramReport]:
    """Pass 1 for one user-supplied spec (the :func:`repro.api.verify_contracts`
    backend): every plan the spec emits, through both runtime paths."""
    return [scan_case(c, with_cost=with_cost)
            for c in _plan_cases(name, spec, P, batch, mesh)]
