"""Pass 2 — the repo-specific AST linter.

Pass 1 (:mod:`repro.analyze.hloscan`) proves the *lowered* programs
honor the paper's contracts; this pass catches the violations that
never reach a lowering — host-side plan emitters quietly reintroducing
``np.unique`` dedup, wall-clock seeding, raw ``PRNGKey`` construction
outside the hashed recursion-tree scheme, collectives creeping into
``kernels/``, and deprecated shims or non-counter pair-plan RNG in
examples and configs.  It is a plain ``ast`` walk (no imports of the
checked code), emits machine-readable findings, and honors an inline
suppression syntax::

    edges = np.unique(e, axis=0)  # repro: allow(no-numpy-unique) oracle dedup

Rules and scopes are documented in ``src/repro/analyze/README.md``;
rule ids are stable (they are the suppression tokens and the JSON
``rule`` field).
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

# --------------------------------------------------------------------------
# rule ids
# --------------------------------------------------------------------------

RULE_NP_UNIQUE = "no-numpy-unique"
RULE_PY_RANDOM = "no-python-random"
RULE_WALLCLOCK = "no-wallclock-state"
RULE_KERNEL_COLLECTIVE = "no-collectives-in-kernels"
RULE_RAW_PRNGKEY = "no-raw-prngkey"
RULE_DEPRECATED = "no-deprecated-shim"
RULE_NONCOUNTER_PAIR = "no-noncounter-pair-rng"
RULE_PER_CHUNK_LOOP = "no-per-chunk-host-loop"

LINT_RULES = (RULE_NP_UNIQUE, RULE_PY_RANDOM, RULE_WALLCLOCK,
              RULE_KERNEL_COLLECTIVE, RULE_RAW_PRNGKEY, RULE_DEPRECATED,
              RULE_NONCOUNTER_PAIR, RULE_PER_CHUNK_LOOP)

# counter-based key impls whose draws are pure in (key, slot); mirrors
# repro.distrib.engine.COUNTER_RNGS without importing jax at lint time
COUNTER_RNGS = frozenset({"threefry2x32"})

# geometric families whose edge phase runs on a PairPlan (recomputed
# cells => counter RNG only)
PAIR_PLAN_FAMILIES = frozenset({"RGG", "RHG", "RDG"})
PAIR_PLAN_EMITTERS = frozenset({
    "make_pair_plan", "rgg_pair_plan", "rhg_pair_plan", "rdg_pair_plan"})
SPEC_CONSUMERS = frozenset({
    "generate", "iter_edge_chunks", "iter_points", "collect", "validate",
    "plan", "point_plan"})

# the legacy per-family union / sharded entry points (DeprecationWarning
# shims onto repro.api); production code must call the front door
DEPRECATED_SHIMS = frozenset({
    "gnm_directed", "gnm_undirected", "gnp_undirected",
    "ba_union", "rmat_union", "sbm_union",
    "gnm_directed_sharded", "run_gnm_directed_sharded", "rgg_points_sharded",
})

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(([^)]*)\)")

# per-chunk constructors: one Python call per chunk inside a loop means
# plan emission scales as interpreter time, not array time.  The
# vectorized level-synchronous emitters (chunk_plan_from_columns,
# hash_paths + PhiloxReplayer) replaced these loops; retained oracles
# suppress per line.  Replay loops drawing `binomial(rep.at(h), ...)`
# are intentionally NOT matched — they vectorize the hash, which is the
# per-chunk cost, and keep only the variate draw in Python.
# `Delaunay` (scipy Qhull) and `circumspheres` joined the set when the
# RDG emitter went level-synchronous: a per-chunk host triangulation or
# per-chunk certificate batch inside a loop is the retired pattern the
# batched device DT (repro.kernels.delaunay.batched_delaunay) replaced.
PER_CHUNK_CALLS = frozenset({
    "host_rng", "device_key", "ChunkSpec", "PairSpec",
    "_make_chunk", "_chunk_key", "Delaunay", "circumspheres"})

_COLLECTIVE_LAX = frozenset({
    "psum", "pmean", "pmax", "pmin", "all_gather", "all_to_all",
    "ppermute", "pshuffle", "psum_scatter", "pbroadcast", "axis_index",
})

_WALLCLOCK_CALLS = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "numpy.random.seed", "numpy.random.default_rng",
    "os.urandom", "uuid.uuid4", "secrets.token_bytes",
})


# --------------------------------------------------------------------------
# path roles — which rules apply where
# --------------------------------------------------------------------------

def role_of(path: str) -> str:
    """Coarse role of a file: which rule scopes apply.

    ``emitter``  — plan emitters + device paths (core/, distrib/, api.py,
                   stats/): the communication-free generation machinery
    ``kernels``  — src/repro/kernels/: pure device tiles, no distrib
    ``obs``      — src/repro/obs/: the host-side tracer/metrics layer.
                   Its *job* is reading monotonic clocks, so the
                   wall-clock rule never applies there; everything it
                   measures stays outside lowered programs (Pass 1
                   still proves no host callback reaches the HLO)
    ``tests``    — tests are allowed to exercise deprecated shims and
                   plant violations on purpose
    ``support``  — everything else (launch/, models/, train/, examples/,
                   benchmarks/, configs/): only the portable rules
    """
    parts = os.path.normpath(path).replace("\\", "/").split("/")
    name = parts[-1]
    if "tests" in parts or name.startswith("test_") or name == "conftest.py":
        return "tests"
    if "kernels" in parts:
        return "kernels"
    if "obs" in parts:
        return "obs"
    if "core" in parts or "distrib" in parts or "stats" in parts \
            or name == "api.py":
        return "emitter"
    return "support"


# which roles each rule fires in (obs: every portable rule except the
# wall-clock one — monotonic timestamps are the tracer's purpose)
_RULE_ROLES: Dict[str, Set[str]] = {
    RULE_NP_UNIQUE: {"emitter", "kernels"},
    RULE_PY_RANDOM: {"emitter", "kernels", "obs", "support"},
    RULE_WALLCLOCK: {"emitter", "kernels"},
    RULE_KERNEL_COLLECTIVE: {"kernels"},
    RULE_RAW_PRNGKEY: {"emitter", "kernels"},
    RULE_DEPRECATED: {"emitter", "kernels", "obs", "support"},
    RULE_NONCOUNTER_PAIR: {"emitter", "kernels", "obs", "support"},
    RULE_PER_CHUNK_LOOP: {"emitter"},
}

# files exempt from specific rules (the rule's own implementation site)
_RULE_EXEMPT_FILES: Dict[str, Set[str]] = {
    RULE_RAW_PRNGKEY: {"prng.py"},
}


@dataclass(frozen=True)
class LintFinding:
    """One Pass-2 violation."""
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}


# --------------------------------------------------------------------------
# name resolution
# --------------------------------------------------------------------------

class _Names:
    """Resolve local names to canonical dotted module paths.

    Tracks ``import numpy as np`` / ``from jax import lax`` /
    ``from repro.core.rgg import rgg_pair_plan`` so the rule tables can
    match on canonical names (``numpy.unique``, ``jax.lax.psum``)
    regardless of aliasing at the use site."""

    def __init__(self, tree: ast.AST):
        self.alias: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.alias[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.alias[a.asname or a.name] = f"{node.module}.{a.name}"
        # canonical spellings for the usual suspects
        self.alias.setdefault("np", "numpy")
        self.alias.setdefault("jnp", "jax.numpy")

    def dotted(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name of a Name/Attribute chain, or None."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.alias.get(node.id, node.id)
        return ".".join([head] + list(reversed(parts)))


def _last_name(dotted: Optional[str]) -> Optional[str]:
    return dotted.rsplit(".", 1)[-1] if dotted else None


# --------------------------------------------------------------------------
# the linter
# --------------------------------------------------------------------------

def _spec_families(tree: ast.AST, names: _Names) -> Dict[str, str]:
    """``var -> family`` for simple ``spec = RGG(...)``-style assigns."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fam = _last_name(names.dotted(node.value.func))
            if fam in PAIR_PLAN_FAMILIES:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        out[tgt.id] = fam
    return out


def _allowed_rules(line_text: str) -> Set[str]:
    m = _ALLOW_RE.search(line_text)
    if not m:
        return set()
    return {tok.strip() for tok in m.group(1).split(",") if tok.strip()}


def lint_source(src: str, path: str, role: Optional[str] = None) -> List[LintFinding]:
    """Lint one module's source text; returns unsuppressed findings."""
    role = role if role is not None else role_of(path)
    if role == "tests":
        return []
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [LintFinding("syntax-error", path, e.lineno or 0, 0, str(e))]
    names = _Names(tree)
    lines = src.splitlines()
    fname = os.path.basename(path)
    spec_vars = _spec_families(tree, names)
    # names this module defines — a shim's defining module is not a use
    defined = {n.name for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    raw: List[LintFinding] = []

    def hit(rule: str, node: ast.AST, message: str) -> None:
        if role not in _RULE_ROLES.get(rule, set()):
            return
        if fname in _RULE_EXEMPT_FILES.get(rule, set()):
            return
        raw.append(LintFinding(rule, path, getattr(node, "lineno", 0),
                               getattr(node, "col_offset", 0), message))

    for node in ast.walk(tree):
        # ---- imports -----------------------------------------------------
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "random" or a.name.startswith("random."):
                    hit(RULE_PY_RANDOM, node,
                        "stdlib `random` is process-global mutable state; "
                        "use repro.core.prng hashed streams")
                if a.name.startswith("repro.distrib"):
                    hit(RULE_KERNEL_COLLECTIVE, node,
                        "kernels/ must stay below distrib/: import of "
                        f"`{a.name}` inverts the layering")
        elif isinstance(node, ast.ImportFrom) and node.module:
            mod = node.module
            if mod == "random" or mod.startswith("random."):
                hit(RULE_PY_RANDOM, node,
                    "stdlib `random` is process-global mutable state; "
                    "use repro.core.prng hashed streams")
            if mod.startswith("repro.distrib") or (
                    role == "kernels" and "distrib" in mod.split(".")):
                hit(RULE_KERNEL_COLLECTIVE, node,
                    f"kernels/ must stay below distrib/: import of `{mod}` "
                    f"inverts the layering")
            for a in node.names:
                if a.name in DEPRECATED_SHIMS and a.name not in defined \
                        and fname != "__init__.py":
                    hit(RULE_DEPRECATED, node,
                        f"`{a.name}` is a deprecated shim; call the "
                        f"repro.api front door instead")

        # ---- calls -------------------------------------------------------
        elif isinstance(node, ast.Call):
            dn = names.dotted(node.func)
            last = _last_name(dn)

            if dn == "numpy.unique":
                hit(RULE_NP_UNIQUE, node,
                    "np.unique in an emitter/device path reintroduces the "
                    "O(m log m) sort/dedup that chunk ownership removed "
                    "(paper §4: the union of owned chunks is already exact)")

            if dn and (dn.startswith("random.") or dn == "random"):
                hit(RULE_PY_RANDOM, node,
                    "stdlib `random` draw: not a pure function of the "
                    "recursion-tree position")

            if dn in _WALLCLOCK_CALLS:
                if dn == "numpy.random.default_rng" and node.args:
                    pass  # seeded generator: deterministic
                else:
                    hit(RULE_WALLCLOCK, node,
                        f"`{dn}` is wall-clock / entropy-seeded state: two "
                        f"PEs recomputing the same chunk would disagree")

            if dn and dn.startswith("jax.lax.") and last in _COLLECTIVE_LAX:
                hit(RULE_KERNEL_COLLECTIVE, node,
                    f"`{dn}` inside kernels/: device tiles must be "
                    f"communication-free (collectives live nowhere — the "
                    f"paper's invariant — and mesh context only in distrib/)")

            if dn in ("jax.random.PRNGKey", "jax.random.key"):
                hit(RULE_RAW_PRNGKEY, node,
                    "raw key construction outside core/prng.py: all keys "
                    "must derive from device_key's hashed recursion-tree "
                    "path so every PE recomputes identical streams")

            if last in DEPRECATED_SHIMS and last not in defined:
                hit(RULE_DEPRECATED, node,
                    f"`{last}` is a deprecated shim; call the repro.api "
                    f"front door instead")

            # non-counter PRNG reaching a pair-plan path, statically
            for kw in node.keywords:
                if kw.arg != "rng_impl" or not isinstance(kw.value, ast.Constant):
                    continue
                impl = kw.value.value
                if not isinstance(impl, str) or impl in COUNTER_RNGS:
                    continue
                pairish = last in PAIR_PLAN_EMITTERS
                if not pairish and last in SPEC_CONSUMERS:
                    for arg in list(node.args) + [
                            k.value for k in node.keywords if k.arg != "rng_impl"]:
                        if isinstance(arg, ast.Call) and _last_name(
                                names.dotted(arg.func)) in PAIR_PLAN_FAMILIES:
                            pairish = True
                        elif isinstance(arg, ast.Name) and arg.id in spec_vars:
                            pairish = True
                    if isinstance(node.func, ast.Attribute) and isinstance(
                            node.func.value, ast.Name) \
                            and node.func.value.id in spec_vars:
                        pairish = True  # spec.plan(P, rng_impl=...)
                if pairish:
                    hit(RULE_NONCOUNTER_PAIR, node,
                        f"rng_impl={impl!r} on a pair-plan family: "
                        f"non-counter impls draw different values for the "
                        f"same key across vmap rows, so recomputed cells "
                        f"disagree with themselves; use one of "
                        f"{sorted(COUNTER_RNGS)} (make_pair_plan raises the "
                        f"same error at plan time)")

        # ---- per-chunk host loops ---------------------------------------
        elif isinstance(node, (ast.For, ast.While, ast.ListComp,
                               ast.SetComp, ast.DictComp, ast.GeneratorExp)):
            if isinstance(node, (ast.For, ast.While)):
                # calls in a For's `iter` run once, not per iteration
                body: List[ast.AST] = list(node.body) + list(node.orelse)
            else:
                body = [node]
            for sub in body:
                for inner in ast.walk(sub):
                    if not isinstance(inner, ast.Call):
                        continue
                    last_inner = _last_name(names.dotted(inner.func))
                    if last_inner in PER_CHUNK_CALLS:
                        # anchored to the Call so oracles suppress in place
                        hit(RULE_PER_CHUNK_LOOP, inner,
                            f"`{last_inner}` called once per chunk inside a "
                            f"host loop: plan emission pays interpreter time "
                            f"per chunk; emit level-synchronously "
                            f"(chunk_plan_from_columns / hash_paths) and "
                            f"keep loops for replayed variate draws only")

    out = []
    seen: Set[Tuple[str, int, int]] = set()
    for f in raw:
        key = (f.rule, f.line, f.col)
        if key in seen:  # nested loops re-visit the same call
            continue
        seen.add(key)
        line_text = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
        if f.rule in _allowed_rules(line_text):
            continue
        out.append(f)
    return out


def iter_python_files(paths: Sequence[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in sorted(dirnames)
                           if d not in {"__pycache__", ".git"}]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def lint_paths(paths: Sequence[str]) -> List[LintFinding]:
    """Lint every ``.py`` file under ``paths`` (files or directories)."""
    findings: List[LintFinding] = []
    for path in iter_python_files(paths):
        try:
            with open(path, "r", encoding="utf-8") as fh:
                src = fh.read()
        except OSError as e:
            findings.append(LintFinding("io-error", path, 0, 0, str(e)))
            continue
        findings.extend(lint_source(src, path))
    return findings
