"""Distributed sampling without replacement + index->edge decoding.

Device-side bulk sampler used inside ``jit``/``shard_map``: fixed
``capacity`` buffers + validity masks (XLA needs static shapes; the C++
code preallocates by expectation + slack in the same way).

Two samplers behind :func:`sample_wo_replacement`:

* ``method="collision"`` (default): draw iid uniforms, sort, resample
  collisions until none remain (bounded ``while_loop``).  Conditions on
  distinctness; the residual bias vs a perfect uniform k-subset is
  O(k^2/U) in TV distance — negligible for the engine's k << sqrt(U)
  chunks, measurable at k ~ sqrt(U).
* ``method="gumbel"``: exact Gumbel-top-k over a concrete universe —
  zero bias at O(U) memory, for small-universe / bias-sensitive work.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .prng import counter_bits64, host_rng

_SMALL_UNIVERSE = 1 << 20
_MAX_FIX_ROUNDS = 64


def round_up_capacity(x: int, mult: int = 64) -> int:
    """Static buffer capacity: x rounded up to a multiple of `mult`.

    Shared by the per-PE generators and the sharded engine so both
    derive identical plan capacities."""
    return max(mult, (int(x) + mult - 1) // mult * mult)


def sample_wo_replacement(key, universe, count, capacity: int, *,
                          method: str = "collision"):
    """`count` distinct sorted int64 samples from [0, universe).

    Returns (vals[capacity] sorted, mask[capacity]).  Padding slots hold
    distinct sentinels >= universe so they never collide with samples.

    ``method`` selects the sampler:

    * ``"collision"`` (default): collision-resampling ``while_loop``.
      `universe` and `count` may be traced (dynamic); residual bias vs a
      perfect uniform k-subset is O(count^2/universe) in TV distance.
    * ``"gumbel"``: exact Gumbel-top-k — one Gumbel variate per universe
      element, the ``count`` largest win.  *Zero* bias (a uniform random
      k-subset by the Gumbel-max argument), at O(universe) memory:
      `universe` must be a concrete int small enough to materialize.
      Use for k ~ sqrt(U) workloads where collision bias is measurable.
    """
    if method == "gumbel":
        universe = int(universe)
        if isinstance(count, (int, np.integer)) and count > min(capacity, universe):
            raise ValueError(
                f"gumbel path holds min(capacity, universe) = "
                f"{min(capacity, universe)} samples, got count={count}")
        return _sample_gumbel(key, universe, count, capacity)
    if method != "collision":
        raise ValueError(f"unknown sampling method {method!r}")
    return _sample_collision(key, universe, count, capacity)


@partial(jax.jit, static_argnames=("universe", "capacity"))
def _sample_gumbel(key, universe: int, count, capacity: int):
    """Exact uniform k-subset via Gumbel-top-k (equal weights).

    Each element i holds an iid Gumbel score; the indices of the largest
    ``count`` scores are a uniform without-replacement sample — exactly,
    not asymptotically.  Scores depend only on (key, universe), so two
    PEs recomputing the same chunk at different capacities still agree
    (the cross-PE recomputation invariant)."""
    count = jnp.asarray(count, jnp.int64)
    k = min(capacity, universe)
    z = jax.random.gumbel(key, (universe,), dtype=jnp.float64)
    _, top = jax.lax.top_k(z, k)
    idx = jnp.arange(capacity, dtype=jnp.int64)
    # sentinel fill (not zeros): a traced count > k that slipped past the
    # host guard yields detectable out-of-range values, never duplicates
    vals = (universe + idx).at[:k].set(top.astype(jnp.int64))
    vals = jnp.sort(jnp.where(idx < count, vals, universe + idx))
    return vals, idx < count


@partial(jax.jit, static_argnames=("capacity",))
def _sample_collision(key, universe, count, capacity: int):
    """Collision-resampling sampler (the traced-universe bulk path).

    The loop state carries the *sorted* array + a has-duplicates flag, so
    the common sparse case (P[dup] ~ count^2/2U ~ 0) costs exactly one
    draw + one sort — the duplicate-fix body only executes on collision.
    (Perf iteration log: EXPERIMENTS.md §Perf, generator cell.)

    Slot i's draw is counter-indexed per slot (:func:`counter_bits64`),
    so the sampled set is independent of ``capacity``: two PEs padding
    the same chunk to different capacities recompute identical values —
    the cross-PE recomputation invariant the undirected generators and
    the sharded engine rely on.
    """
    universe = jnp.asarray(universe, jnp.int64)
    count = jnp.asarray(count, jnp.int64)
    idx = jnp.arange(capacity, dtype=jnp.int64)
    mask = idx < count

    def draw(k, m):
        w = counter_bits64(k, capacity, 1)[:, 0]
        u = (w % jnp.maximum(universe, 1).astype(jnp.uint64)).astype(jnp.int64)
        return jnp.where(m, u, universe + idx)  # sentinels are unique & out of range

    def sort_and_flag(v):
        s = jnp.sort(v)
        return s, jnp.any(s[1:] == s[:-1])

    s0, dup0 = sort_and_flag(draw(jax.random.fold_in(key, 0), mask))

    def cond(state):
        t, _, has_dup = state
        return jnp.logical_and(t < _MAX_FIX_ROUNDS, has_dup)

    def body(state):
        t, s, _ = state
        dup = jnp.concatenate([jnp.zeros((1,), bool), s[1:] == s[:-1]])
        fresh = draw(jax.random.fold_in(key, t), mask)
        s, has_dup = sort_and_flag(jnp.where(dup, fresh, s))
        return t + 1, s, has_dup

    _, vals, _ = jax.lax.while_loop(cond, body, (jnp.int64(1), s0, dup0))
    return vals, jnp.arange(capacity) < count


def sample_wo_replacement_host(seed: int, path, universe: int, count: int) -> np.ndarray:
    """Host-side exact counterpart (plans, tests)."""
    rng = host_rng(seed, *path)
    if universe <= _SMALL_UNIVERSE:
        return np.sort(rng.choice(universe, size=count, replace=False)).astype(np.int64)
    vals = rng.integers(0, universe, size=count, dtype=np.int64)
    for _ in range(_MAX_FIX_ROUNDS):
        vals = np.sort(vals)
        dup = np.concatenate([[False], vals[1:] == vals[:-1]])
        if not dup.any():
            return vals
        vals[dup] = rng.integers(0, universe, size=int(dup.sum()), dtype=np.int64)
    raise RuntimeError("sampler failed to converge (k too close to U?)")


# --------------------------------------------------------------------------
# index -> edge decoding (paper's "offset computations")
# --------------------------------------------------------------------------

def decode_directed(idx, n, row_lo):
    """Chunk-local universe index -> directed edge (u, v), u != v.

    Chunk = vertex rows [row_lo, row_hi); each row has n-1 slots (self
    loop excluded)."""
    row = row_lo + idx // (n - 1)
    c = idx % (n - 1)
    col = c + (c >= row)
    return row, col


def decode_rect(idx, width, row_lo, col_lo):
    """Rect chunk index -> undirected edge (u, v) with u > v."""
    return row_lo + idx // width, col_lo + idx % width


def decode_tri(idx, lo):
    """Strictly-lower-tri chunk index -> undirected edge (u, v), u > v.

    Row r (local) holds tri(r) .. tri(r+1)-1 with tri(r)=r(r-1)/2.  The
    float64 isqrt estimate is Newton-corrected in int64 so it is exact
    even when idx ~ 2^62 exceeds float53 resolution.
    """
    idx = jnp.asarray(idx, jnp.int64)
    r = jnp.floor((1.0 + jnp.sqrt(1.0 + 8.0 * idx.astype(jnp.float64))) / 2.0).astype(jnp.int64)
    tri = lambda k: k * (k - 1) // 2
    for _ in range(3):  # fix float rounding; |error| <= 1 after one step
        r = r - (tri(r) > idx) + (tri(r + 1) <= idx)
    c = idx - tri(r)
    return lo + r, lo + c


def decode_tri_host(idx: np.ndarray, lo: int):
    idx = np.asarray(idx, np.int64)
    r = np.floor((1.0 + np.sqrt(1.0 + 8.0 * idx.astype(np.float64))) / 2.0).astype(np.int64)
    tri = lambda k: k * (k - 1) // 2
    for _ in range(3):
        r = r - (tri(r) > idx) + (tri(r + 1) <= idx)
    c = idx - tri(r)
    return lo + r, lo + c
