"""Erdős-Rényi generators: G(n,m) directed/undirected, G(n,p) (paper §4).

Every PE generates *exactly* the edges incident to its local vertices
with zero communication:

* directed G(n,m): PE's chunk = a block of adjacency-matrix rows; its
  edge count comes from the O(log P) hypergeometric descent.
* undirected G(n,m): PE i generates chunk-matrix row i and column i;
  shared chunk (i,j) is recomputed bit-identically by PE i and PE j from
  the chunk-hashed key (recomputation overhead <= 2m, Theorem 2).
* G(n,p): chunk edge counts are independent Binomial(U_chunk, p) variates
  seeded by the chunk id — no recursion needed (§4.3).

Edges of undirected graphs are canonically (u, v) with u > v.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .chunking import (
    Chunk,
    directed_counts_all,
    directed_counts_for_pe,
    section_bounds,
    tri_size,
    undirected_chunks_for_pe,
)
from .prng import device_key, host_rng
from .sampling import decode_directed, decode_rect, decode_tri, sample_wo_replacement
from .variates import binomial

_CHUNK_TAG = 11  # mixed into per-chunk hashes


def _round_up(x: int, mult: int = 64) -> int:
    return max(mult, (int(x) + mult - 1) // mult * mult)


# --------------------------------------------------------------------------
# directed G(n,m)
# --------------------------------------------------------------------------

def gnm_directed_pe(seed: int, n: int, m: int, P: int, pe: int) -> np.ndarray:
    """Edges of PE `pe`'s row chunk; int64 [k, 2]."""
    cnt = directed_counts_for_pe(seed, n, m, P, pe)
    row_lo, row_hi = section_bounds(n, P, pe)
    universe = (row_hi - row_lo) * (n - 1)
    cap = _round_up(cnt)
    key = device_key(seed, _CHUNK_TAG, pe)
    vals, mask = sample_wo_replacement(key, universe, cnt, cap)
    u, v = decode_directed(vals, n, row_lo)
    edges = np.stack([np.asarray(u), np.asarray(v)], axis=1)
    return edges[np.asarray(mask)]


def gnm_directed(seed: int, n: int, m: int, P: int = 1) -> np.ndarray:
    """Union over all PEs (exactly m distinct edges)."""
    return np.concatenate([gnm_directed_pe(seed, n, m, P, pe) for pe in range(P)], axis=0)


# --------------------------------------------------------------------------
# undirected G(n,m)
# --------------------------------------------------------------------------

def _chunk_key(seed: int, ch: Chunk):
    return device_key(seed, _CHUNK_TAG, ch.row_sec, ch.col_sec)


@jax.jit
def _sample_many(keys, universes, counts, caps_mask_shape):
    return jax.vmap(
        lambda k, u, c: sample_wo_replacement(k, u, c, caps_mask_shape.shape[0])
    )(keys, universes, counts)


def _gen_chunks(seed: int, n: int, chunks: List[Tuple[Chunk, int]]) -> np.ndarray:
    """Generate the edges of a list of (chunk, count), batched by kind."""
    if not chunks:
        return np.zeros((0, 2), dtype=np.int64)
    out = []
    for kind in ("tri", "rect"):
        sel = [(ch, c) for ch, c in chunks if ch.kind == kind]
        if not sel:
            continue
        cap = _round_up(max(c for _, c in sel))
        keys = jnp.stack([_chunk_key(seed, ch) for ch, _ in sel])
        universes = jnp.array([ch.universe for ch, _ in sel], dtype=jnp.int64)
        counts = jnp.array([c for _, c in sel], dtype=jnp.int64)
        vals, mask = _sample_many(keys, universes, counts, jnp.zeros((cap,)))
        if kind == "tri":
            los = jnp.array([ch.rlo for ch, _ in sel], dtype=jnp.int64)
            u, v = jax.vmap(decode_tri)(vals, los)
        else:
            widths = jnp.array([ch.chi - ch.clo for ch, _ in sel], dtype=jnp.int64)
            rlos = jnp.array([ch.rlo for ch, _ in sel], dtype=jnp.int64)
            clos = jnp.array([ch.clo for ch, _ in sel], dtype=jnp.int64)
            u, v = jax.vmap(decode_rect)(vals, widths, rlos, clos)
        e = np.stack([np.asarray(u).ravel(), np.asarray(v).ravel()], axis=1)
        out.append(e[np.asarray(mask).ravel()])
    return np.concatenate(out, axis=0)


def gnm_undirected_pe(seed: int, n: int, m: int, P: int, pe: int) -> np.ndarray:
    """All edges incident to PE `pe`'s vertex range, as (u, v) with u > v.

    Includes redundantly recomputed cross-chunk edges (the paper's 2m
    recomputation bound): every edge appears on both endpoint PEs.
    """
    chunks = undirected_chunks_for_pe(seed, n, m, P, pe)
    return _gen_chunks(seed, n, chunks)


def gnm_undirected(seed: int, n: int, m: int, P: int = 1) -> np.ndarray:
    """Distinct union over PEs — exactly m undirected edges."""
    if P == 1:
        return gnm_undirected_pe(seed, n, m, 1, 0)
    all_e = np.concatenate(
        [gnm_undirected_pe(seed, n, m, P, pe) for pe in range(P)], axis=0
    )
    return np.unique(all_e, axis=0)


# --------------------------------------------------------------------------
# G(n,p)
# --------------------------------------------------------------------------

def gnp_directed_pe(seed: int, n: int, p: float, P: int, pe: int) -> np.ndarray:
    row_lo, row_hi = section_bounds(n, P, pe)
    universe = (row_hi - row_lo) * (n - 1)
    cnt = binomial(host_rng(seed, _CHUNK_TAG, pe), universe, p)
    cap = _round_up(cnt)
    vals, mask = sample_wo_replacement(device_key(seed, _CHUNK_TAG, pe), universe, cnt, cap)
    u, v = decode_directed(vals, n, row_lo)
    edges = np.stack([np.asarray(u), np.asarray(v)], axis=1)
    return edges[np.asarray(mask)]


def gnp_undirected_pe(seed: int, n: int, p: float, P: int, pe: int) -> np.ndarray:
    """Binomial count per chunk, seeded on the chunk id (§4.3)."""
    chunks: List[Tuple[Chunk, int]] = []
    from .chunking import _make_chunk  # chunk geometry helper

    for j in range(P):
        I, J = (pe, j) if j <= pe else (j, pe)
        ch = _make_chunk(n, P, I, J)
        cnt = binomial(host_rng(seed, _CHUNK_TAG, I, J), ch.universe, p)
        if (I, J) != (pe, pe) or j <= pe:  # row i and col i; diagonal once
            chunks.append((ch, cnt))
    # drop the duplicate diagonal entry when j loop hits pe twice
    seen = set()
    uniq = []
    for ch, c in chunks:
        if (ch.row_sec, ch.col_sec) in seen:
            continue
        seen.add((ch.row_sec, ch.col_sec))
        uniq.append((ch, c))
    return _gen_chunks(seed, n, uniq)


def gnp_undirected(seed: int, n: int, p: float, P: int = 1) -> np.ndarray:
    all_e = np.concatenate(
        [gnp_undirected_pe(seed, n, p, P, pe) for pe in range(P)], axis=0
    )
    return np.unique(all_e, axis=0) if P > 1 else all_e


def expected_gnm_universe(n: int, directed: bool) -> int:
    return n * (n - 1) if directed else tri_size(n)
