"""Random Delaunay graphs on the unit torus [0,1)^d, d in {2,3} (paper §6).

Point generation reuses the RGG grid/recursion with cell side
c ≈ ((d+1)/n)^(1/d) (mean (d+1)-th-nearest-neighbor distance).  Each PE
triangulates its chunk plus an expanding *halo* of recomputed neighbor
cells, and accepts the result only when

  (a) no convex-hull vertex of the local triangulation is chunk-local, and
  (b) every simplex containing a chunk-interior point has its
      circumsphere fully inside the chunk+halo region,

which guarantees those simplices belong to the global periodic Delaunay
triangulation (any point that could invalidate them would lie inside the
generated region and therefore has been generated).  Otherwise the halo
is expanded by one cell ring and the DT recomputed (paper: update).

Periodicity: halo cells are *unwrapped* — a cell may enter multiple
times under different ±1 translations, which also covers the P=1 case
(a chunk neighboring its own copies).

Division of labor: nothing stays on the host.  The local DT engine is
the batched Bowyer-Watson kernel (:mod:`repro.kernels.delaunay`): each
halo round, *every* pending chunk's chunk+halo point row triangulates
in one device dispatch (:class:`RdgStructure`), and certification is
one vectorized Cramer solve across all pending chunks
(:func:`circumspheres`).  The edge phase ships every certified simplex
through the engine's GEOM_CERT PairPlan executor
(:func:`rdg_pair_plan`), which re-derives the certificates on device —
the same Cramer arithmetic as the kernel's in-sphere predicate, so
planning-time and execution-time certificates agree bit-for-bit — and
emits the canonical edge set.  Qhull (scipy) is demoted to the test
oracle (:func:`rdg_pe` per-PE host loop, :func:`rdg_pair_plan_specs`
scalar designation walk, :func:`rdg_brute_edges` global tiling).
"""
from __future__ import annotations

import functools
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from scipy.spatial import Delaunay

from .rgg import (CellCounter, CellGrid, CellSplitTree, local_cells_for_pe,
                  make_grid, points_for_cells)

Cell = Tuple[int, ...]


def rdg_grid(n: int, P: int, dim: int) -> CellGrid:
    c = ((dim + 1) / n) ** (1.0 / dim)
    return make_grid(n, c, P, dim)


def default_chunk_P(P: int, dim: int) -> int:
    """Default virtual-chunk count for the RDG grid.

    Fewer, fatter chunks cut halo duplication (each chunk recomputes its
    one-ring; at K=64 chunks a 3d region re-generates ~12x the chunk's
    own points, at K=8 only ~3.5x), which is what the batched device DT's
    cost tracks.  2d keeps the legacy 16 (instance-compatible with the
    old ``DEFAULT_CHUNKS`` grid); 3d drops to 8, where the round's
    [B, N] work area is smallest.  Never below P so every PE owns work.
    """
    return max(P, 16 if dim == 2 else 8)


def rdg_point_plan(seed: int, n: int, P: int, dim: int = 2,
                   rng_impl: str = "threefry2x32", chunk_P: int = 0):
    """PointPlan for the sharded engine over the RDG cell grid (the
    RGG grid with cell side ~ the (d+1)-th-nearest-neighbor distance);
    the triangulation phase consumes these cells via the halo protocol."""
    from .. import obs
    from .rgg import grid_point_plan

    with obs.trace("plan/rdg", phase="plan", family="rdg", reseed=False, P=P):
        grid = rdg_grid(n, chunk_P or default_chunk_P(P, dim), dim)
        return grid_point_plan(seed, grid, CellCounter(seed, grid, n), P, rng_impl)


def _torus_canonical(cell: Cell, g: int) -> Tuple[Cell, Tuple[int, ...]]:
    canon = tuple(c % g for c in cell)
    shift = tuple((c - cc) // g for c, cc in zip(cell, canon))
    return canon, shift


def _ring(cells: set, dim: int) -> set:
    """All unwrapped cells adjacent to the given set (excluded)."""
    out = set()
    offs = [o for o in itertools.product((-1, 0, 1), repeat=dim) if any(o)]
    for c in cells:
        for o in offs:
            nb = tuple(a + b for a, b in zip(c, o))
            if nb not in cells:
                out.add(nb)
    return out


def circumspheres(simp: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Batched circumcenters + radii of [S, d+1, d] simplices.

    Thin host wrapper over the *shared* device predicate
    (:func:`repro.kernels.delaunay.circumsphere`): planning-time
    certificates, the insertion kernel's in-sphere test, and the
    engine's GEOM_CERT re-check (:func:`repro.distrib.engine.\
_circumsphere_in_box`) all execute the one jitted Cramer solve, so
    they agree bit-for-bit by construction.  A numpy twin with the same
    operation *order* is not enough — XLA may contract multiply-adds
    into FMAs, drifting an ulp from numpy's rounding, and an ulp at a
    region-box boundary is an edge lost to a host/device certificate
    disagreement.  Degenerate slivers (det == 0) get radius = inf,
    which fails every containment test and forces a halo expansion.

    The batch is padded to a power-of-two bucket (>= 256) so the jit
    cache stays small across rounds of varying simplex counts.
    """
    from ..kernels.delaunay import circumsphere

    S = len(simp)
    if S == 0:
        d = simp.shape[2] if simp.ndim == 3 else 2
        return np.zeros((0, d), simp.dtype), np.zeros(0, simp.dtype)
    cap = 1 << max(8, (S - 1).bit_length())
    pad = np.zeros((cap,) + simp.shape[1:], simp.dtype)
    pad[:S] = simp
    center, r2, nondeg = circumsphere(pad)
    center, r2, nondeg = (np.asarray(center)[:S], np.asarray(r2)[:S],
                          np.asarray(nondeg)[:S])
    rad = np.where(nondeg, np.sqrt(r2), np.inf)
    return center, rad


class _PointBank:
    """Deterministic point lookup per unwrapped cell (recompute-on-demand)."""

    def __init__(self, seed: int, grid: CellGrid, counter: CellCounter,
                 rng_impl: str | None = None):
        self.seed, self.grid, self.counter = seed, grid, counter
        self.rng_impl = rng_impl
        self._cache: Dict[Cell, Tuple[np.ndarray, np.ndarray]] = {}

    def get(self, cell: Cell) -> Tuple[np.ndarray, np.ndarray]:
        """(positions (k,d) unwrapped, gids (k,)) for one unwrapped cell."""
        if cell not in self._cache:
            self.prefetch([cell])
        return self._cache[cell]

    def prefetch(self, cells: Sequence[Cell]) -> None:
        """Batch-generate every uncached cell in one device dispatch
        (the per-slot draws are capacity-independent, so batching cells
        of different counts yields the identical per-cell streams)."""
        missing = [c for c in cells if c not in self._cache]
        if not missing:
            return
        canon_shift = [_torus_canonical(c, self.grid.g) for c in missing]
        pos, counts, offsets, _ = points_for_cells(
            self.seed, self.grid, self.counter,
            [cs[0] for cs in canon_shift], self.rng_impl
        )
        for i, (cell, (_, shift)) in enumerate(zip(missing, canon_shift)):
            k = counts[i]
            p = pos[i][:k] + np.asarray(shift, dtype=np.float64)
            self._cache[cell] = (p, offsets[i] + np.arange(k))


class _GridBank:
    """Whole-grid point bank: one tight-capacity device dispatch per
    seed generates *every* canonical cell's points at once, and
    unwrapped halo images are served as a numpy lattice shift of the
    cached canonical row.

    Bit-compatible with :class:`_PointBank` (the per-slot draws of
    :func:`repro.core.rgg._points_for_cells` are keyed by cell id and
    capacity-independent, so a tight pad and the 128-padded on-demand
    path yield identical first-k slots) but without its per-request
    Python count loop, 128-slot overgeneration, or per-canonical-cell
    duplicate regeneration — the prefetch cost that used to rival the
    triangulation itself.  Memory is counts.max()-padded over g^dim
    cells, fine for any grid the batched DT itself can handle.
    """

    def __init__(self, seed: int, grid: CellGrid, n: int,
                 tree: CellSplitTree, rng_impl: str | None = None):
        import jax.numpy as jnp

        from .prng import device_key
        from .rgg import _TAG_PTS, _points_for_cells

        self.seed, self.grid = seed, grid
        counts, offsets = tree.counts_offsets(seed, n)
        cap = _round_up(max(1, int(counts.max())), 8)
        g, dim = grid.g, grid.dim
        coords = np.stack(np.meshgrid(*([np.arange(g)] * dim), indexing="ij"),
                          axis=-1).reshape(-1, dim)
        pos, _ = _points_for_cells(
            device_key(seed, _TAG_PTS, impl=rng_impl),
            jnp.arange(g ** dim, dtype=jnp.int64), jnp.asarray(coords),
            jnp.asarray(counts), cap, dim, g)
        self._pos = np.asarray(pos)
        self._counts, self._offsets = counts, offsets
        self._cache: Dict[Cell, Tuple[np.ndarray, np.ndarray]] = {}

    def get(self, cell: Cell) -> Tuple[np.ndarray, np.ndarray]:
        """(positions (k,d) unwrapped, gids (k,)) for one unwrapped cell."""
        hit = self._cache.get(cell)
        if hit is None:
            canon, shift = _torus_canonical(cell, self.grid.g)
            cid = self.grid.cell_id(canon)
            k = int(self._counts[cid])
            hit = self._cache[cell] = (
                self._pos[cid, :k] + np.asarray(shift, np.float64),
                self._offsets[cid] + np.arange(k))
        return hit

    def region(self, cells: Sequence[Cell], local: set) -> \
            Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(pts, gids, is_local) for a whole cell sequence in one numpy
        gather — identical concatenation order to per-cell :meth:`get`
        calls, without the per-cell Python cost (a 2d bench region is
        ~300 cells x 16 chunks, where per-cell calls are ~0.1s/plan)."""
        g, dim = self.grid.g, self.grid.dim
        arr = np.asarray(cells, np.int64)              # [R, d]
        canon = np.mod(arr, g)
        shift = ((arr - canon) // g).astype(np.float64)
        cid = canon[:, 0]
        for a in range(1, dim):
            cid = cid * g + canon[:, a]
        k = self._counts[cid]                          # [R]
        cap = self._pos.shape[1]
        sel = np.arange(cap)[None, :] < k[:, None]     # [R, cap]
        pts = (self._pos[cid] + shift[:, None, :])[sel]
        gids = (self._offsets[cid][:, None] + np.arange(cap)[None, :])[sel]
        is_local = np.fromiter((c in local for c in cells), bool, len(arr))
        return pts, gids, np.repeat(is_local, k)

    def prefetch(self, cells: Sequence[Cell]) -> None:
        """No-op: the whole grid is resident from construction."""


def _certified_triangulation(
    bank, local_cells: set, dim: int, max_expand: int,
    region: Optional[set] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
           np.ndarray, np.ndarray, int]:
    """Run the halo protocol for one cell set until the triangulation is
    certified; returns (pts, gids, loc, simplices, box_lo, box_hi,
    expansions).  Circumsphere certificates are evaluated in one
    vectorized :func:`circumspheres` batch per iteration, never one
    simplex at a time.

    Test oracle: the production emitter (:class:`RdgStructure`) runs the
    same protocol level-synchronously on device, one batched kernel
    dispatch per halo round across all pending chunks.  ``region`` lets
    a caller resume from an already-expanded halo (a superset region can
    only certify earlier — the box check gets easier and every accepted
    simplex is still a global-DT simplex); default is the classic
    chunk + one ring start."""
    grid = bank.grid
    if region is None:
        region = set(local_cells)
        region |= _ring(region, dim)
    else:
        region = set(region)

    expansions = 0
    while True:
        pts_list, gid_list, is_local = [], [], []
        bank.prefetch(sorted(region))
        for cell in sorted(region):
            p, g = bank.get(cell)
            pts_list.append(p)
            gid_list.append(g)
            is_local.append(np.full(len(g), cell in local_cells))
        pts = np.concatenate(pts_list)
        gids = np.concatenate(gid_list)
        loc = np.concatenate(is_local)

        if len(pts) < dim + 2:
            raise ValueError("too few points for a Delaunay triangulation")

        tri = Delaunay(pts)  # repro: allow(no-per-chunk-host-loop) retained Qhull oracle

        # region bounding box (unwrapped cells are axis-aligned unit/g boxes)
        cells_arr = np.array(sorted(region))
        box_lo = cells_arr.min(axis=0) / grid.g
        box_hi = (cells_arr.max(axis=0) + 1) / grid.g

        ok = not loc[tri.convex_hull.ravel()].any()
        if ok:
            sel = tri.simplices[loc[tri.simplices].any(axis=1)]
            if len(sel):
                center, rad = circumspheres(pts[sel])  # repro: allow(no-per-chunk-host-loop) retained Qhull oracle
                ok = bool(((center - rad[:, None] >= box_lo).all()
                           & (center + rad[:, None] <= box_hi).all()))
        if ok:
            return pts, gids, loc, tri.simplices, box_lo, box_hi, expansions
        expansions += 1
        if expansions > max_expand:
            raise RuntimeError("halo did not converge")
        region |= _ring(region, dim)


def rdg_pe(
    seed: int, n: int, P: int, pe: int, dim: int = 2, max_expand: int = 8,
    chunk_P: int = 0,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Delaunay edges incident to PE `pe`'s vertices on the torus — the
    per-PE *host loop*, retired as the production edge phase (the engine
    executes :func:`rdg_pair_plan` instead) and kept as the independent
    test oracle for it.

    Returns (edges [k,2] gids u>v, local gids, #halo expansions used).
    ``chunk_P`` sizes the virtual chunk grid independently of P (the
    instance is a function of the grid; default:
    :func:`default_chunk_P`, matching the production emitter).
    """
    grid = rdg_grid(n, chunk_P or default_chunk_P(P, dim), dim)
    counter = CellCounter(seed, grid, n)
    bank = _PointBank(seed, grid, counter)
    local_cells = set(local_cells_for_pe(grid, P, pe))
    pts, gids, loc, simplices, _, _, expansions = _certified_triangulation(
        bank, local_cells, dim, max_expand)

    # edges: simplex edges with >= 1 local endpoint
    edges = set()
    for simplex in simplices:
        for i, j in itertools.combinations(simplex, 2):
            if loc[i] or loc[j]:
                u, v = int(gids[i]), int(gids[j])
                if u == v:
                    continue  # a point adjacent to its own periodic image
                edges.add((max(u, v), min(u, v)))

    local_gids = np.unique(gids[loc])  # repro: allow(no-numpy-unique) O(cell) plan-time gid metadata, not edge dedup
    e = np.array(sorted(edges), dtype=np.int64) if edges else np.zeros((0, 2), np.int64)
    return e, local_gids, expansions


def _designated_rows(simplices: np.ndarray, loc: np.ndarray, gids: np.ndarray,
                     n: int, dim: int, cap: int) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized edge-designation pass for one chunk's triangulation:
    (ascending simplex indices that emit, per-simplex edge bitmask).

    Batches what the per-simplex walk did scalar-wise: candidate edges
    as [S, combos] grids, ownership via sorted-gid membership, and
    first-designation dedup by stable-sorting edge codes — the same
    (simplex-major, combo-minor) first occurrence the ``seen`` set
    picked, so the masks are bit-identical."""
    from ..distrib.engine import pair_slot_index

    S = len(simplices)
    lg = np.sort(gids[loc])
    if S == 0 or len(lg) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    combos = [(i, j) for i in range(dim + 1) for j in range(i + 1, dim + 1)]
    ci = np.array([i for i, _ in combos])
    cj = np.array([j for _, j in combos])
    bits = np.array([1 << pair_slot_index(i, j, cap) for i, j in combos],
                    np.int64)
    M = len(combos)
    ls = loc[simplices]                                   # [S, d+1]
    gs = gids[simplices]                                  # [S, d+1]
    a, b = gs[:, ci], gs[:, cj]                           # [S, M]
    hi, lo = np.maximum(a, b), np.minimum(a, b)
    keep = ls.any(axis=1)[:, None] & (ls[:, ci] | ls[:, cj]) & (a != b)
    pos = np.minimum(np.searchsorted(lg, hi), len(lg) - 1)
    keep &= lg[pos] == hi                                 # max-gid owner is ours
    idx = np.nonzero(keep.ravel())[0]   # ascending == the scalar walk order
    code = hi.ravel()[idx] * np.int64(n) + lo.ravel()[idx]
    order = np.argsort(code, kind="stable")
    sc = code[order]
    first = np.ones(len(sc), bool)
    first[1:] = sc[1:] != sc[:-1]
    chosen = idx[order[first]]          # first designation of each edge
    mask = np.zeros(S, np.int64)
    np.bitwise_or.at(mask, chosen // M, bits[chosen % M])
    rows = np.nonzero(mask)[0]
    return rows, mask[rows]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


class RdgStructure:
    """Seed-independent RDG planning structure (PR-9 fast-path pattern).

    Caches everything the halo protocol needs that does not depend on
    the seed — the cell grid, the per-virtual-chunk cell sets, and their
    initial one-ring regions — so :meth:`emit` is the cheap
    ``reseed_fn`` the serve plan cache calls on seed rotation.

    :meth:`emit` runs the halo protocol *level-synchronously*: each
    round, every still-uncertified chunk's chunk+halo point row is
    padded into one ``[B, N, d]`` batch and triangulated in a single
    :func:`repro.kernels.delaunay.batched_delaunay` dispatch (no
    per-chunk host loop, no Qhull).  Certification is one vectorized
    :func:`circumspheres` call per round across all pending chunks.
    A chunk passes when

      (a) no alive simplex joins a chunk-local vertex to a super-simplex
          vertex (local id >= the row's point count) — the bounding
          super-simplex encloses everything, so hull vertices are
          exactly the points adjacent to super vertices — and
      (b) every super-free simplex touching a local point has its
          circumsphere inside the region box.

    Degenerate/cocircular configurations surface either as a cleared
    kernel ``ok`` flag or as an infinite certificate radius; both fail
    the round and expand the halo, like the Qhull oracle.  Certified
    simplices are genuine global-DT simplices, so the emitted edge set
    equals the oracle's even where the two paths pick different
    designated rows per edge.

    Tiny-grid exception: when a region *wraps* the torus on two axes
    (span > g cells, so the same canonical point enters under two
    lattice shifts per axis), the four images of one point form an
    exact rectangle — exactly cocircular in 2d, exactly coplanar in 3d,
    and any sphere through three corners passes exactly through the
    fourth.  These guaranteed ties would clear ``ok`` forever, so such
    chunks run the merged-facet Qhull oracle
    (:func:`_certified_triangulation`) instead; production-scale grids
    never wrap, so the device batch is the only path that runs there.
    """

    def __init__(self, n: int, P: int, dim: int = 2,
                 rng_impl: str = "threefry2x32", chunk_P: int = 0,
                 max_expand: int = 8):
        self.n, self.P, self.dim = int(n), int(P), int(dim)
        if self.n < self.dim + 2:
            raise ValueError("too few points for a Delaunay triangulation")
        self.rng_impl, self.max_expand = rng_impl, int(max_expand)
        self.grid = rdg_grid(n, chunk_P or default_chunk_P(P, dim), dim)
        self.K = self.grid.cpd ** self.dim
        self.chunk_cells: List[set] = [
            set(local_cells_for_pe(self.grid, self.K, v))
            for v in range(self.K)]
        self._tree = CellSplitTree(self.grid)   # seed-independent counts
        # start every chunk at chunk + TWO rings: a one-ring halo is a
        # single cell side ~ the (d+1)-NN distance, which the boundary
        # simplices' circumspheres essentially always overrun (measured:
        # 16/16 2d and 7/8 3d bench chunks fail ring 1), so starting at
        # ring 2 folds the guaranteed expansion into the first device
        # round.  A larger start is always sound: certification only
        # gets easier, and accepted simplices are global-DT either way.
        self._init_regions: List[set] = []
        for c in self.chunk_cells:
            r = set(c) | _ring(c, self.dim)
            self._init_regions.append(r | _ring(r, self.dim))
        self._col_cache: Dict[int, tuple] = {}

    def _wraps(self, region: set) -> bool:
        """True when the region's periodic images can be exactly
        degenerate: the cell box spans more than the torus on >= 2 axes
        (image rectangles) or more than two full turns on one (collinear
        image triples)."""
        arr = np.array(sorted(region))
        span = arr.max(axis=0) - arr.min(axis=0) + 1
        return bool(((span > self.grid.g).sum() >= 2)
                    or (span > 2 * self.grid.g).any())

    # -- halo protocol, one device batch per round ----------------------
    def _triangulate_chunks(self, seed: int) -> List[tuple]:
        """(pts, gids, loc, interior simplices, box_lo, box_hi) per
        virtual chunk."""
        from ..kernels.delaunay import batched_delaunay

        dim, grid = self.dim, self.grid
        bank = _GridBank(seed, grid, self.n, self._tree, self.rng_impl)
        regions = [set(r) for r in self._init_regions]
        pending = list(range(self.K))
        expansions = [0] * self.K
        done: Dict[int, tuple] = {}
        while pending:
            # torus-wrapping regions hold exact periodic degeneracies the
            # abort-on-tie kernel cannot resolve -> Qhull oracle, resumed
            # from the already-expanded region (tiny grids only; see the
            # class docstring)
            wrapped = [v for v in pending if self._wraps(regions[v])]
            for v in wrapped:
                pts, gids, loc, simplices, box_lo, box_hi, _ = \
                    _certified_triangulation(bank, self.chunk_cells[v], dim,
                                             self.max_expand,
                                             region=regions[v])
                done[v] = (pts, gids, loc, simplices, box_lo, box_hi)
            if wrapped:
                pending = [v for v in pending if v not in set(wrapped)]
                if not pending:
                    break
            rows, boxes = [], []
            for v in pending:
                cells = sorted(regions[v])
                rows.append(bank.region(cells, self.chunk_cells[v]))
                cells_arr = np.array(cells)
                boxes.append((cells_arr.min(axis=0) / grid.g,
                              (cells_arr.max(axis=0) + 1) / grid.g))
            if min(len(r[0]) for r in rows) < dim + 2:
                raise ValueError("too few points for a Delaunay triangulation")
            # pad to a (pow2 rows) x (128-multiple points) bucket so the
            # kernel recompiles at most a few times across halo rounds
            N = _round_up(max(len(r[0]) for r in rows), 128)
            B = 1 << max(0, len(pending) - 1).bit_length()
            ptsb = np.zeros((B, N, dim))
            cnt = np.zeros(B, np.int64)
            for i, (p, _, _) in enumerate(rows):
                ptsb[i, : len(p)] = p
                cnt[i] = len(p)
            simp, alive, ok = batched_delaunay(ptsb, cnt, dim=dim)
            simp, alive, ok = np.asarray(simp), np.asarray(alive), np.asarray(ok)

            # collect every pending chunk's local-touching interior
            # simplices, then certify them in ONE circumsphere batch
            per_chunk, seg_pts, offs = [], [], [0]
            for i, v in enumerate(pending):
                pts, gids, loc = rows[i]
                nb = int(cnt[i])
                live = simp[i][alive[i]]
                sup = (live >= nb).any(axis=1)
                lv = np.where(live < nb, loc[np.minimum(live, nb - 1)], False)
                hull_ok = bool(ok[i]) and not (lv.any(axis=1) & sup).any()
                interior = live[~sup]
                sel = interior[loc[interior].any(axis=1)] if len(interior) \
                    else interior
                per_chunk.append((v, hull_ok, interior, sel))
                seg_pts.append(pts[sel] if len(sel)
                               else np.zeros((0, dim + 1, dim)))
                offs.append(offs[-1] + len(sel))
            allsimp = np.concatenate(seg_pts)
            center, rad = (circumspheres(allsimp) if len(allsimp)  # repro: allow(no-per-chunk-host-loop) one batch per halo round, never per chunk
                           else (np.zeros((0, dim)), np.zeros(0)))
            inside = np.ones(len(allsimp), bool)
            for i, (v, _, _, _) in enumerate(per_chunk):
                lo, hi = boxes[i]
                s = slice(offs[i], offs[i + 1])
                inside[s] = ((center[s] - rad[s, None] >= lo).all(axis=1)
                             & (center[s] + rad[s, None] <= hi).all(axis=1))

            still = []
            for i, (v, hull_ok, interior, _) in enumerate(per_chunk):
                if hull_ok and inside[offs[i]:offs[i + 1]].all():
                    pts, gids, loc = rows[i]
                    done[v] = (pts, gids, loc, interior) + boxes[i]
                    continue
                expansions[v] += 1
                if expansions[v] > self.max_expand:
                    raise RuntimeError("halo did not converge")
                regions[v] |= _ring(regions[v], dim)
                still.append(v)
            pending = still
        return [done[v] for v in range(self.K)]

    # -- plan columns (seed-cached so segments share one device pass) ---
    def _columns(self, seed: int) -> tuple:
        if seed in self._col_cache:
            return self._col_cache[seed]
        n, dim, cap = self.n, self.dim, 4
        G = (dim + 1) * dim
        vg_l: List[np.ndarray] = []
        bits_l: List[np.ndarray] = []
        geom_l: List[np.ndarray] = []
        box_l: List[np.ndarray] = []
        for pts, gids, loc, simplices, box_lo, box_hi in \
                self._triangulate_chunks(seed):
            rows, mask = _designated_rows(simplices, loc, gids, n, dim, cap)
            if not len(rows):
                continue
            sel = simplices[rows]
            vg = np.zeros((len(rows), cap), np.int64)
            vg[:, : dim + 1] = gids[sel]
            vg_l.append(vg)
            bits_l.append(mask)
            geom_l.append(pts[sel].reshape(len(rows), G))
            box_l.append(np.broadcast_to(
                np.concatenate([box_lo, box_hi]), (len(rows), 2 * dim)))
        k = sum(len(v) for v in vg_l)
        gid_a = np.concatenate(vg_l) if k else np.zeros((0, cap), np.int64)
        gid_b = np.zeros((k, cap), np.int64)
        gid_b[:, 0] = np.concatenate(bits_l) if k else 0
        geom_a = np.concatenate(geom_l) if k else np.zeros((0, G))
        geom_b = np.ones((k, G))       # right-padded with the table fill
        geom_b[:, : 2 * dim] = np.concatenate(box_l) if k else 0
        cols = (k, gid_a, gid_b, geom_a, geom_b)
        if len(self._col_cache) >= 4:   # serve rotates seeds; keep it tiny
            self._col_cache.pop(next(iter(self._col_cache)))
        self._col_cache[seed] = cols
        return cols

    def _emit(self, seed: int, P_out: int, pe: np.ndarray, cols: tuple):
        from ..distrib.engine import GEOM_CERT, pair_plan_from_columns

        k = len(pe)
        _, gid_a, gid_b, geom_a, geom_b = cols
        dpl = np.full(k, self.dim + 1, np.int64)
        return pair_plan_from_columns(
            P_out, pe, np.full(k, GEOM_CERT, np.int32),
            np.zeros((k, 2), np.uint32), np.zeros((k, 2), np.uint32),
            dpl, dpl, gid_a, gid_b, geom_a, geom_b,
            np.zeros((k, 1)), np.ones(k, bool),
            capacity=4, rng_impl=self.rng_impl, dim=self.dim)

    def emit(self, seed: int):
        """Full PairPlan for this structure's (P, grid); also the plan's
        ``reseed_fn`` — reseeding re-runs only the device passes."""
        from .. import obs

        with obs.trace("plan/rdg", phase="plan", family="rdg",
                       reseed=False, P=self.P):
            cols = self._columns(seed)
            k = cols[0]
            out = self._emit(seed, self.P,
                             np.arange(k, dtype=np.int64) % self.P, cols)
        import dataclasses as _dc
        return _dc.replace(out, reseed_fn=self.emit)

    def segment(self, seed: int, lo: int, hi: int):
        """Native PlanEmitter segment: global PEs [lo, hi) re-indexed to
        [0, hi - lo); concatenating segments reproduces :meth:`emit`'s
        per-PE row order (the deal is stable in global row order)."""
        from .. import obs

        with obs.trace("plan/rdg", phase="plan", family="rdg",
                       reseed=False, P=self.P, lo=lo, hi=hi):
            cols = self._columns(seed)
            k, gid_a, gid_b, geom_a, geom_b = cols
            pe = np.arange(k, dtype=np.int64) % self.P
            sel = (pe >= lo) & (pe < hi)
            sub = (int(sel.sum()), gid_a[sel], gid_b[sel],
                   geom_a[sel], geom_b[sel])
            return self._emit(seed, hi - lo, pe[sel] - lo, sub)


@functools.lru_cache(maxsize=None)
def rdg_structure(n: int, P: int, dim: int = 2,
                  rng_impl: str = "threefry2x32", chunk_P: int = 0,
                  max_expand: int = 8) -> RdgStructure:
    return RdgStructure(n, P, dim, rng_impl, chunk_P, max_expand)


def rdg_pair_plan(seed: int, n: int, P: int, dim: int = 2,
                  rng_impl: str = "threefry2x32", chunk_P: int = 0,
                  max_expand: int = 8):
    """GEOM_CERT PairPlan: certified Delaunay simplices, dealt to PEs.

    The halo protocol runs once per *virtual chunk* of the grid
    (level-synchronously, one batched device triangulation per round —
    see :class:`RdgStructure`), so the plan is a pure function of the
    spec: identical rows for every P, with P only deciding which PE
    executes which rows.  Every shipped simplex carries its certificate
    inputs so the executor re-derives it on device with the same Cramer
    arithmetic the kernel used to build it.

    Each plan row is one simplex that is the *designated emitter* of at
    least one edge: the combinatorial pass dedups simplex edges (an
    interior edge lies in 2+ simplices), applies canonical ownership
    (the chunk owning the max-gid endpoint emits), and drops periodic
    self-images — the CERT analog of the chunk ``owned`` bit, encoded as
    a per-edge bitmask.  The device re-certifies the circumsphere and
    emits the masked edges, so concatenated per-PE outputs are the exact
    global Delaunay edge set with no sort/unique dedup.

    Designation is vectorized (:func:`_designated_rows`) and the rows —
    self-contained: every row carries its full certificate — are dealt
    round-robin by global row index, not by owning chunk, so per-PE row
    counts differ by at most one and the table's fill_fraction stays
    near 1 even when chunk sizes are skewed.  The chunk-dealt scalar
    Qhull walk is retained as :func:`rdg_pair_plan_specs`, the
    edge-content oracle (it may pick different designated rows per edge;
    the emitted edge sets are equal).
    """
    return rdg_structure(n, P, dim, rng_impl, chunk_P, max_expand).emit(seed)


def rdg_plan_segment(seed: int, n: int, P: int, lo: int, hi: int,
                     dim: int = 2, rng_impl: str = "threefry2x32",
                     chunk_P: int = 0, max_expand: int = 8):
    """Segment [lo, hi) of :func:`rdg_pair_plan` for the native
    :class:`repro.distrib.runtime.PlanEmitter` path; the device passes
    run once per seed (cached on the structure) and each segment just
    re-deals its slice."""
    return rdg_structure(n, P, dim, rng_impl, chunk_P,
                         max_expand).segment(seed, lo, hi)


def rdg_pair_plan_specs(seed: int, n: int, P: int, dim: int = 2,
                        rng_impl: str = "threefry2x32", chunk_P: int = 0,
                        max_expand: int = 8):
    """Retained oracle: the original scalar designation walk of
    :func:`rdg_pair_plan`, dealt by owning chunk (``v % P``).  Defines
    the row *content* and per-chunk row order the vectorized path must
    reproduce; the production path only re-deals the same rows for
    balance.  Not a production path."""
    from ..distrib.engine import GEOM_CERT, PairSpec, make_pair_plan, pair_slot_index

    grid = rdg_grid(n, chunk_P or default_chunk_P(P, dim), dim)
    counter = CellCounter(seed, grid, n)
    bank = _PointBank(seed, grid, counter, rng_impl)
    K = grid.cpd ** dim            # virtual chunks, one protocol run each
    cap = 4                        # d+1 <= 4 vertex slots per simplex row
    zero_key = np.zeros(2, np.uint32)

    per_pe: List[List[PairSpec]] = [[] for _ in range(P)]
    for v in range(K):
        local_cells = set(local_cells_for_pe(grid, K, v))
        pts, gids, loc, simplices, box_lo, box_hi, _ = _certified_triangulation(
            bank, local_cells, dim, max_expand)
        local_gids = set(np.unique(gids[loc]).tolist())  # repro: allow(no-numpy-unique) O(cell) plan-time gid metadata, not edge dedup
        box = tuple(box_lo) + tuple(box_hi)

        seen: set = set()
        emit_mask: Dict[int, int] = {}
        for s_idx, simplex in enumerate(simplices):
            ls = loc[simplex]
            if not ls.any():
                continue
            for i in range(dim + 1):
                for j in range(i + 1, dim + 1):
                    if not (ls[i] or ls[j]):
                        continue
                    a, b = int(gids[simplex[i]]), int(gids[simplex[j]])
                    if a == b:
                        continue  # periodic self-image
                    edge = (max(a, b), min(a, b))
                    if edge[0] not in local_gids or edge in seen:
                        continue  # not ours / already designated
                    seen.add(edge)
                    emit_mask[s_idx] = emit_mask.get(s_idx, 0) | (
                        1 << pair_slot_index(i, j, cap))

        for s_idx, bits in sorted(emit_mask.items()):
            simplex = simplices[s_idx]
            vg = np.zeros(cap, np.int64)
            vg[: dim + 1] = gids[simplex]
            per_pe[v % P].append(PairSpec(  # repro: allow(no-per-chunk-host-loop) retained oracle
                GEOM_CERT, zero_key, zero_key, dim + 1, dim + 1,
                vg, bits, tuple(pts[simplex].ravel()), box,
                self_pair=True))
    return make_pair_plan(per_pe, capacity=cap, rng_impl=rng_impl, dim=dim)


def rdg_union(seed: int, n: int, P: int, dim: int = 2) -> np.ndarray:
    es = []
    for pe in range(P):
        e, _, _ = rdg_pe(seed, n, P, pe, dim)
        es.append(e)
    e = np.concatenate(es, axis=0)
    return np.unique(e, axis=0) if e.size else e.reshape(0, 2)  # repro: allow(no-numpy-unique) test-oracle union (engine dedups by simplex ownership)


def rdg_brute_edges(points: np.ndarray, dim: int) -> np.ndarray:
    """Global periodic DT oracle: triangulate the 3^d tiling, keep edges
    with an endpoint in the canonical copy, fold gids mod n."""
    n = len(points)
    shifts = list(itertools.product((-1.0, 0.0, 1.0), repeat=dim))
    tiles = np.concatenate([points + np.array(s) for s in shifts])
    base = np.tile(np.arange(n), len(shifts))
    canonical = np.zeros(len(tiles), dtype=bool)
    center = shifts.index(tuple([0.0] * dim))
    canonical[center * n: (center + 1) * n] = True

    tri = Delaunay(tiles)
    edges = set()
    for simplex in tri.simplices:
        for i, j in itertools.combinations(simplex, 2):
            if canonical[i] or canonical[j]:
                u, v = int(base[i]), int(base[j])
                if u == v:
                    continue
                edges.add((max(u, v), min(u, v)))
    return np.array(sorted(edges), dtype=np.int64)
