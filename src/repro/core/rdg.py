"""Random Delaunay graphs on the unit torus [0,1)^d, d in {2,3} (paper §6).

Point generation reuses the RGG grid/recursion with cell side
c ≈ ((d+1)/n)^(1/d) (mean (d+1)-th-nearest-neighbor distance).  Each PE
triangulates its chunk plus an expanding *halo* of recomputed neighbor
cells, and accepts the result only when

  (a) no convex-hull vertex of the local triangulation is chunk-local, and
  (b) every simplex containing a chunk-interior point has its
      circumsphere fully inside the chunk+halo region,

which guarantees those simplices belong to the global periodic Delaunay
triangulation (any point that could invalidate them would lie inside the
generated region and therefore has been generated).  Otherwise the halo
is expanded by one cell ring and the DT recomputed (paper: update).

Periodicity: halo cells are *unwrapped* — a cell may enter multiple
times under different ±1 translations, which also covers the P=1 case
(a chunk neighboring its own copies).  The local DT engine is Qhull
(scipy), the analog of the paper's CGAL backend; the paper's
contribution — the communication-free halo protocol — is implemented
here, and an independent Bowyer-Watson oracle lives in the tests.

Division of labor: only the Qhull triangulation itself stays on the
host.  Circumsphere certification is batched (:func:`circumspheres`,
one vectorized Cramer solve per halo iteration), and the edge phase
ships every certified simplex through the engine's GEOM_CERT PairPlan
executor (:func:`rdg_pair_plan`), which re-derives the certificates on
device and emits the canonical edge set.  :func:`rdg_pe` remains as the
per-PE host-loop test oracle.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Sequence, Tuple

import numpy as np
from scipy.spatial import Delaunay

from .rgg import CellCounter, CellGrid, local_cells_for_pe, make_grid, points_for_cells

Cell = Tuple[int, ...]


def rdg_grid(n: int, P: int, dim: int) -> CellGrid:
    c = ((dim + 1) / n) ** (1.0 / dim)
    return make_grid(n, c, P, dim)


def rdg_point_plan(seed: int, n: int, P: int, dim: int = 2,
                   rng_impl: str = "threefry2x32", chunk_P: int = 0):
    """PointPlan for the sharded engine over the RDG cell grid (the
    RGG grid with cell side ~ the (d+1)-th-nearest-neighbor distance);
    the triangulation phase consumes these cells via the halo protocol."""
    from .. import obs
    from .rgg import grid_point_plan

    with obs.trace("plan/rdg", phase="plan", family="rdg", reseed=False, P=P):
        grid = rdg_grid(n, chunk_P or P, dim)
        return grid_point_plan(seed, grid, CellCounter(seed, grid, n), P, rng_impl)


def _torus_canonical(cell: Cell, g: int) -> Tuple[Cell, Tuple[int, ...]]:
    canon = tuple(c % g for c in cell)
    shift = tuple((c - cc) // g for c, cc in zip(cell, canon))
    return canon, shift


def _ring(cells: set, dim: int) -> set:
    """All unwrapped cells adjacent to the given set (excluded)."""
    out = set()
    offs = [o for o in itertools.product((-1, 0, 1), repeat=dim) if any(o)]
    for c in cells:
        for o in offs:
            nb = tuple(a + b for a, b in zip(c, o))
            if nb not in cells:
                out.add(nb)
    return out


def circumspheres(simp: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Batched circumcenters + radii of [S, d+1, d] simplices.

    One vectorized Cramer solve for the whole batch — the certification
    bottleneck the per-simplex ``np.linalg.solve`` loop used to be.  The
    *identical* formula runs on device in the engine's GEOM_CERT pair
    program (:func:`repro.distrib.engine._circumsphere_in_box`), so the
    host's planning-time certificates and the executor's re-check agree
    bit-for-bit.  Degenerate slivers (det == 0) get radius = inf, which
    fails every containment test and forces a halo expansion.
    """
    a0 = simp[:, 0, :]
    rows = simp[:, 1:, :] - a0[:, None, :]
    rhs = 0.5 * (rows * rows).sum(axis=2)
    d = simp.shape[2]
    if d == 2:
        det = rows[:, 0, 0] * rows[:, 1, 1] - rows[:, 0, 1] * rows[:, 1, 0]
        num = np.stack([rhs[:, 0] * rows[:, 1, 1] - rows[:, 0, 1] * rhs[:, 1],
                        rows[:, 0, 0] * rhs[:, 1] - rhs[:, 0] * rows[:, 1, 0]],
                       axis=1)
    else:
        c0, c1, c2 = rows[:, :, 0], rows[:, :, 1], rows[:, :, 2]

        def det3(x, y, z):
            return (x[:, 0] * (y[:, 1] * z[:, 2] - y[:, 2] * z[:, 1])
                    - y[:, 0] * (x[:, 1] * z[:, 2] - x[:, 2] * z[:, 1])
                    + z[:, 0] * (x[:, 1] * y[:, 2] - x[:, 2] * y[:, 1]))

        det = det3(c0, c1, c2)
        num = np.stack([det3(rhs, c1, c2), det3(c0, rhs, c2),
                        det3(c0, c1, rhs)], axis=1)
    nondeg = det != 0
    with np.errstate(divide="ignore", invalid="ignore"):
        off = num / np.where(nondeg, det, 1.0)[:, None]
    center = a0 + off
    rad = np.where(nondeg, np.sqrt((off * off).sum(axis=1)), np.inf)
    return center, rad


class _PointBank:
    """Deterministic point lookup per unwrapped cell (recompute-on-demand)."""

    def __init__(self, seed: int, grid: CellGrid, counter: CellCounter,
                 rng_impl: str | None = None):
        self.seed, self.grid, self.counter = seed, grid, counter
        self.rng_impl = rng_impl
        self._cache: Dict[Cell, Tuple[np.ndarray, np.ndarray]] = {}

    def get(self, cell: Cell) -> Tuple[np.ndarray, np.ndarray]:
        """(positions (k,d) unwrapped, gids (k,)) for one unwrapped cell."""
        if cell not in self._cache:
            self.prefetch([cell])
        return self._cache[cell]

    def prefetch(self, cells: Sequence[Cell]) -> None:
        """Batch-generate every uncached cell in one device dispatch
        (the per-slot draws are capacity-independent, so batching cells
        of different counts yields the identical per-cell streams)."""
        missing = [c for c in cells if c not in self._cache]
        if not missing:
            return
        canon_shift = [_torus_canonical(c, self.grid.g) for c in missing]
        pos, counts, offsets, _ = points_for_cells(
            self.seed, self.grid, self.counter,
            [cs[0] for cs in canon_shift], self.rng_impl
        )
        for i, (cell, (_, shift)) in enumerate(zip(missing, canon_shift)):
            k = counts[i]
            p = pos[i][:k] + np.asarray(shift, dtype=np.float64)
            self._cache[cell] = (p, offsets[i] + np.arange(k))


def _certified_triangulation(
    bank: _PointBank, local_cells: set, dim: int, max_expand: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
           np.ndarray, np.ndarray, int]:
    """Run the halo protocol for one cell set until the triangulation is
    certified; returns (pts, gids, loc, simplices, box_lo, box_hi,
    expansions).  Circumsphere certificates are evaluated in one
    vectorized :func:`circumspheres` batch per iteration, never one
    simplex at a time."""
    grid = bank.grid
    region = set(local_cells)
    region |= _ring(region, dim)

    expansions = 0
    while True:
        pts_list, gid_list, is_local = [], [], []
        bank.prefetch(sorted(region))
        for cell in sorted(region):
            p, g = bank.get(cell)
            pts_list.append(p)
            gid_list.append(g)
            is_local.append(np.full(len(g), cell in local_cells))
        pts = np.concatenate(pts_list)
        gids = np.concatenate(gid_list)
        loc = np.concatenate(is_local)

        if len(pts) < dim + 2:
            raise ValueError("too few points for a Delaunay triangulation")

        tri = Delaunay(pts)

        # region bounding box (unwrapped cells are axis-aligned unit/g boxes)
        cells_arr = np.array(sorted(region))
        box_lo = cells_arr.min(axis=0) / grid.g
        box_hi = (cells_arr.max(axis=0) + 1) / grid.g

        ok = not loc[tri.convex_hull.ravel()].any()
        if ok:
            sel = tri.simplices[loc[tri.simplices].any(axis=1)]
            if len(sel):
                center, rad = circumspheres(pts[sel])
                ok = bool(((center - rad[:, None] >= box_lo).all()
                           & (center + rad[:, None] <= box_hi).all()))
        if ok:
            return pts, gids, loc, tri.simplices, box_lo, box_hi, expansions
        expansions += 1
        if expansions > max_expand:
            raise RuntimeError("halo did not converge")
        region |= _ring(region, dim)


def rdg_pe(
    seed: int, n: int, P: int, pe: int, dim: int = 2, max_expand: int = 8,
    chunk_P: int = 0,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Delaunay edges incident to PE `pe`'s vertices on the torus — the
    per-PE *host loop*, retired as the production edge phase (the engine
    executes :func:`rdg_pair_plan` instead) and kept as the independent
    test oracle for it.

    Returns (edges [k,2] gids u>v, local gids, #halo expansions used).
    ``chunk_P`` sizes the virtual chunk grid independently of P (the
    instance is a function of the grid; default: the legacy P-coupled
    grid).
    """
    grid = rdg_grid(n, chunk_P or P, dim)
    counter = CellCounter(seed, grid, n)
    bank = _PointBank(seed, grid, counter)
    local_cells = set(local_cells_for_pe(grid, P, pe))
    pts, gids, loc, simplices, _, _, expansions = _certified_triangulation(
        bank, local_cells, dim, max_expand)

    # edges: simplex edges with >= 1 local endpoint
    edges = set()
    for simplex in simplices:
        for i, j in itertools.combinations(simplex, 2):
            if loc[i] or loc[j]:
                u, v = int(gids[i]), int(gids[j])
                if u == v:
                    continue  # a point adjacent to its own periodic image
                edges.add((max(u, v), min(u, v)))

    local_gids = np.unique(gids[loc])  # repro: allow(no-numpy-unique) O(cell) plan-time gid metadata, not edge dedup
    e = np.array(sorted(edges), dtype=np.int64) if edges else np.zeros((0, 2), np.int64)
    return e, local_gids, expansions


def _designated_rows(simplices: np.ndarray, loc: np.ndarray, gids: np.ndarray,
                     n: int, dim: int, cap: int) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized edge-designation pass for one chunk's triangulation:
    (ascending simplex indices that emit, per-simplex edge bitmask).

    Batches what the per-simplex walk did scalar-wise: candidate edges
    as [S, combos] grids, ownership via sorted-gid membership, and
    first-designation dedup by stable-sorting edge codes — the same
    (simplex-major, combo-minor) first occurrence the ``seen`` set
    picked, so the masks are bit-identical."""
    from ..distrib.engine import pair_slot_index

    S = len(simplices)
    lg = np.sort(gids[loc])
    if S == 0 or len(lg) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    combos = [(i, j) for i in range(dim + 1) for j in range(i + 1, dim + 1)]
    ci = np.array([i for i, _ in combos])
    cj = np.array([j for _, j in combos])
    bits = np.array([1 << pair_slot_index(i, j, cap) for i, j in combos],
                    np.int64)
    M = len(combos)
    ls = loc[simplices]                                   # [S, d+1]
    gs = gids[simplices]                                  # [S, d+1]
    a, b = gs[:, ci], gs[:, cj]                           # [S, M]
    hi, lo = np.maximum(a, b), np.minimum(a, b)
    keep = ls.any(axis=1)[:, None] & (ls[:, ci] | ls[:, cj]) & (a != b)
    pos = np.minimum(np.searchsorted(lg, hi), len(lg) - 1)
    keep &= lg[pos] == hi                                 # max-gid owner is ours
    idx = np.nonzero(keep.ravel())[0]   # ascending == the scalar walk order
    code = hi.ravel()[idx] * np.int64(n) + lo.ravel()[idx]
    order = np.argsort(code, kind="stable")
    sc = code[order]
    first = np.ones(len(sc), bool)
    first[1:] = sc[1:] != sc[:-1]
    chosen = idx[order[first]]          # first designation of each edge
    mask = np.zeros(S, np.int64)
    np.bitwise_or.at(mask, chosen // M, bits[chosen % M])
    rows = np.nonzero(mask)[0]
    return rows, mask[rows]


def rdg_pair_plan(seed: int, n: int, P: int, dim: int = 2,
                  rng_impl: str = "threefry2x32", chunk_P: int = 0,
                  max_expand: int = 8):
    """GEOM_CERT PairPlan: certified Delaunay simplices, dealt to PEs.

    The host keeps only what cannot leave it — the per-chunk Qhull
    triangulation (the paper uses CGAL; no device-side DT yet) — and
    runs the halo protocol once per *virtual chunk* of the grid, so the
    plan is a pure function of the spec: identical rows for every P,
    with P only deciding which PE executes which chunk's simplices.
    Certification is batched (:func:`circumspheres`) during the halo
    loop, and every shipped simplex carries its certificate inputs so
    the executor re-derives it on device.

    Each plan row is one simplex that is the *designated emitter* of at
    least one edge: the host's combinatorial pass dedups simplex edges
    (an interior edge lies in 2+ simplices), applies canonical ownership
    (the chunk owning the max-gid endpoint emits), and drops periodic
    self-images — the CERT analog of the chunk ``owned`` bit, encoded as
    a per-edge bitmask.  The device re-certifies the circumsphere and
    emits the masked edges, so concatenated per-PE outputs are the exact
    global Delaunay edge set with no sort/unique dedup.

    Designation is vectorized (:func:`_designated_rows`) and the rows —
    self-contained: every row carries its full certificate — are dealt
    round-robin by global row index, not by owning chunk, so per-PE row
    counts differ by at most one and the table's fill_fraction stays
    near 1 even when chunk sizes are skewed.  The chunk-dealt scalar
    walk is retained as :func:`rdg_pair_plan_specs`, the row-content
    oracle.
    """
    from .. import obs
    from ..distrib.engine import GEOM_CERT, pair_plan_from_columns

    with obs.trace("plan/rdg", phase="plan", family="rdg", reseed=False, P=P):
        grid = rdg_grid(n, chunk_P or P, dim)
        counter = CellCounter(seed, grid, n)
        bank = _PointBank(seed, grid, counter, rng_impl)
        K = grid.cpd ** dim            # virtual chunks, one protocol run each
        cap = 4                        # d+1 <= 4 vertex slots per simplex row
        G = (dim + 1) * dim            # geom_a: the simplex vertices, flattened

        vg_l: List[np.ndarray] = []
        bits_l: List[np.ndarray] = []
        geom_l: List[np.ndarray] = []
        box_l: List[np.ndarray] = []
        for v in range(K):
            local_cells = set(local_cells_for_pe(grid, K, v))
            pts, gids, loc, simplices, box_lo, box_hi, _ = _certified_triangulation(
                bank, local_cells, dim, max_expand)
            rows, mask = _designated_rows(simplices, loc, gids, n, dim, cap)
            if not len(rows):
                continue
            sel = simplices[rows]
            vg = np.zeros((len(rows), cap), np.int64)
            vg[:, : dim + 1] = gids[sel]
            vg_l.append(vg)
            bits_l.append(mask)
            geom_l.append(pts[sel].reshape(len(rows), G))
            box_l.append(np.broadcast_to(
                np.concatenate([box_lo, box_hi]), (len(rows), 2 * dim)))
        k = sum(len(v) for v in vg_l)
        gid_a = np.concatenate(vg_l) if k else np.zeros((0, cap), np.int64)
        gid_b = np.zeros((k, cap), np.int64)
        gid_b[:, 0] = np.concatenate(bits_l) if k else 0
        geom_a = np.concatenate(geom_l) if k else np.zeros((0, G))
        geom_b = np.ones((k, G))       # right-padded with the table fill
        geom_b[:, : 2 * dim] = np.concatenate(box_l) if k else 0
        dpl = np.full(k, dim + 1, np.int64)
        out = pair_plan_from_columns(
            P, np.arange(k, dtype=np.int64) % P,
            np.full(k, GEOM_CERT, np.int32),
            np.zeros((k, 2), np.uint32), np.zeros((k, 2), np.uint32),
            dpl, dpl, gid_a, gid_b, geom_a, geom_b,
            np.zeros((k, 1)), np.ones(k, bool),
            capacity=cap, rng_impl=rng_impl, dim=dim)
        # the triangulation is a function of the points, hence of the seed:
        # reseed is a full re-emit (Qhull and all) against the new seed
        import dataclasses as _dc
        return _dc.replace(
            out, reseed_fn=lambda s: rdg_pair_plan(
                s, n, P, dim, rng_impl, chunk_P, max_expand))


def rdg_pair_plan_specs(seed: int, n: int, P: int, dim: int = 2,
                        rng_impl: str = "threefry2x32", chunk_P: int = 0,
                        max_expand: int = 8):
    """Retained oracle: the original scalar designation walk of
    :func:`rdg_pair_plan`, dealt by owning chunk (``v % P``).  Defines
    the row *content* and per-chunk row order the vectorized path must
    reproduce; the production path only re-deals the same rows for
    balance.  Not a production path."""
    from ..distrib.engine import GEOM_CERT, PairSpec, make_pair_plan, pair_slot_index

    grid = rdg_grid(n, chunk_P or P, dim)
    counter = CellCounter(seed, grid, n)
    bank = _PointBank(seed, grid, counter, rng_impl)
    K = grid.cpd ** dim            # virtual chunks, one protocol run each
    cap = 4                        # d+1 <= 4 vertex slots per simplex row
    zero_key = np.zeros(2, np.uint32)

    per_pe: List[List[PairSpec]] = [[] for _ in range(P)]
    for v in range(K):
        local_cells = set(local_cells_for_pe(grid, K, v))
        pts, gids, loc, simplices, box_lo, box_hi, _ = _certified_triangulation(
            bank, local_cells, dim, max_expand)
        local_gids = set(np.unique(gids[loc]).tolist())  # repro: allow(no-numpy-unique) O(cell) plan-time gid metadata, not edge dedup
        box = tuple(box_lo) + tuple(box_hi)

        seen: set = set()
        emit_mask: Dict[int, int] = {}
        for s_idx, simplex in enumerate(simplices):
            ls = loc[simplex]
            if not ls.any():
                continue
            for i in range(dim + 1):
                for j in range(i + 1, dim + 1):
                    if not (ls[i] or ls[j]):
                        continue
                    a, b = int(gids[simplex[i]]), int(gids[simplex[j]])
                    if a == b:
                        continue  # periodic self-image
                    edge = (max(a, b), min(a, b))
                    if edge[0] not in local_gids or edge in seen:
                        continue  # not ours / already designated
                    seen.add(edge)
                    emit_mask[s_idx] = emit_mask.get(s_idx, 0) | (
                        1 << pair_slot_index(i, j, cap))

        for s_idx, bits in sorted(emit_mask.items()):
            simplex = simplices[s_idx]
            vg = np.zeros(cap, np.int64)
            vg[: dim + 1] = gids[simplex]
            per_pe[v % P].append(PairSpec(  # repro: allow(no-per-chunk-host-loop) retained oracle
                GEOM_CERT, zero_key, zero_key, dim + 1, dim + 1,
                vg, bits, tuple(pts[simplex].ravel()), box,
                self_pair=True))
    return make_pair_plan(per_pe, capacity=cap, rng_impl=rng_impl, dim=dim)


def rdg_union(seed: int, n: int, P: int, dim: int = 2) -> np.ndarray:
    es = []
    for pe in range(P):
        e, _, _ = rdg_pe(seed, n, P, pe, dim)
        es.append(e)
    e = np.concatenate(es, axis=0)
    return np.unique(e, axis=0) if e.size else e.reshape(0, 2)  # repro: allow(no-numpy-unique) test-oracle union (engine dedups by simplex ownership)


def rdg_brute_edges(points: np.ndarray, dim: int) -> np.ndarray:
    """Global periodic DT oracle: triangulate the 3^d tiling, keep edges
    with an endpoint in the canonical copy, fold gids mod n."""
    n = len(points)
    shifts = list(itertools.product((-1.0, 0.0, 1.0), repeat=dim))
    tiles = np.concatenate([points + np.array(s) for s in shifts])
    base = np.tile(np.arange(n), len(shifts))
    canonical = np.zeros(len(tiles), dtype=bool)
    center = shifts.index(tuple([0.0] * dim))
    canonical[center * n: (center + 1) * n] = True

    tri = Delaunay(tiles)
    edges = set()
    for simplex in tri.simplices:
        for i, j in itertools.combinations(simplex, 2):
            if canonical[i] or canonical[j]:
                u, v = int(base[i]), int(base[j])
                if u == v:
                    continue
                edges.add((max(u, v), min(u, v)))
    return np.array(sorted(edges), dtype=np.int64)
