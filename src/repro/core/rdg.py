"""Random Delaunay graphs on the unit torus [0,1)^d, d in {2,3} (paper §6).

Point generation reuses the RGG grid/recursion with cell side
c ≈ ((d+1)/n)^(1/d) (mean (d+1)-th-nearest-neighbor distance).  Each PE
triangulates its chunk plus an expanding *halo* of recomputed neighbor
cells, and accepts the result only when

  (a) no convex-hull vertex of the local triangulation is chunk-local, and
  (b) every simplex containing a chunk-interior point has its
      circumsphere fully inside the chunk+halo region,

which guarantees those simplices belong to the global periodic Delaunay
triangulation (any point that could invalidate them would lie inside the
generated region and therefore has been generated).  Otherwise the halo
is expanded by one cell ring and the DT recomputed (paper: update).

Periodicity: halo cells are *unwrapped* — a cell may enter multiple
times under different ±1 translations, which also covers the P=1 case
(a chunk neighboring its own copies).  The local DT engine is Qhull
(scipy), the analog of the paper's CGAL backend; the paper's
contribution — the communication-free halo protocol — is implemented
here, and an independent Bowyer-Watson oracle lives in the tests.
"""
from __future__ import annotations

import itertools
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np
from scipy.spatial import Delaunay

from .rgg import CellCounter, CellGrid, local_cells_for_pe, make_grid, points_for_cells

Cell = Tuple[int, ...]


def rdg_grid(n: int, P: int, dim: int) -> CellGrid:
    c = ((dim + 1) / n) ** (1.0 / dim)
    return make_grid(n, c, P, dim)


def rdg_point_plan(seed: int, n: int, P: int, dim: int = 2,
                   rng_impl: str = "threefry2x32", chunk_P: int = 0):
    """PointPlan for the sharded engine over the RDG cell grid (the
    RGG grid with cell side ~ the (d+1)-th-nearest-neighbor distance);
    the triangulation phase consumes these cells via the halo protocol."""
    from .rgg import grid_point_plan

    grid = rdg_grid(n, chunk_P or P, dim)
    return grid_point_plan(seed, grid, CellCounter(seed, grid, n), P, rng_impl)


def _torus_canonical(cell: Cell, g: int) -> Tuple[Cell, Tuple[int, ...]]:
    canon = tuple(c % g for c in cell)
    shift = tuple((c - cc) // g for c, cc in zip(cell, canon))
    return canon, shift


def _ring(cells: set, dim: int) -> set:
    """All unwrapped cells adjacent to the given set (excluded)."""
    out = set()
    offs = [o for o in itertools.product((-1, 0, 1), repeat=dim) if any(o)]
    for c in cells:
        for o in offs:
            nb = tuple(a + b for a, b in zip(c, o))
            if nb not in cells:
                out.add(nb)
    return out


def _circumsphere(pts: np.ndarray) -> Tuple[np.ndarray, float]:
    """Circumcenter + radius of a d-simplex ((d+1) x d vertex array)."""
    a = pts[0]
    rows = pts[1:] - a
    rhs = 0.5 * (rows * rows).sum(axis=1)
    try:
        center = a + np.linalg.solve(rows, rhs)
    except np.linalg.LinAlgError:
        return a, math.inf  # degenerate sliver: force halo expansion
    return center, float(np.linalg.norm(center - a))


class _PointBank:
    """Deterministic point lookup per unwrapped cell (recompute-on-demand)."""

    def __init__(self, seed: int, grid: CellGrid, counter: CellCounter):
        self.seed, self.grid, self.counter = seed, grid, counter
        self._cache: Dict[Cell, Tuple[np.ndarray, np.ndarray]] = {}

    def get(self, cell: Cell) -> Tuple[np.ndarray, np.ndarray]:
        """(positions (k,d) unwrapped, gids (k,)) for one unwrapped cell."""
        if cell in self._cache:
            return self._cache[cell]
        canon, shift = _torus_canonical(cell, self.grid.g)
        pos, counts, offsets, _ = points_for_cells(
            self.seed, self.grid, self.counter, [canon]
        )
        k = counts[0]
        p = pos[0][:k] + np.asarray(shift, dtype=np.float64)
        g = offsets[0] + np.arange(k)
        self._cache[cell] = (p, g)
        return p, g


def rdg_pe(
    seed: int, n: int, P: int, pe: int, dim: int = 2, max_expand: int = 8,
    chunk_P: int = 0,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Delaunay edges incident to PE `pe`'s vertices on the torus.

    Returns (edges [k,2] gids u>v, local gids, #halo expansions used).
    ``chunk_P`` sizes the virtual chunk grid independently of P (the
    instance is a function of the grid; default: the legacy P-coupled
    grid).
    """
    grid = rdg_grid(n, chunk_P or P, dim)
    counter = CellCounter(seed, grid, n)
    bank = _PointBank(seed, grid, counter)

    local_cells = set(local_cells_for_pe(grid, P, pe))
    halo: set = set()
    region = set(local_cells)
    halo |= _ring(region, dim)
    region |= halo

    expansions = 0
    while True:
        pts_list, gid_list, is_local = [], [], []
        for cell in sorted(region):
            p, g = bank.get(cell)
            pts_list.append(p)
            gid_list.append(g)
            is_local.append(np.full(len(g), cell in local_cells))
        pts = np.concatenate(pts_list)
        gids = np.concatenate(gid_list)
        loc = np.concatenate(is_local)

        if len(pts) < dim + 2:
            raise ValueError("too few points for a Delaunay triangulation")

        tri = Delaunay(pts)

        # region bounding box (unwrapped cells are axis-aligned unit/g boxes)
        cells_arr = np.array(sorted(region))
        box_lo = cells_arr.min(axis=0) / grid.g
        box_hi = (cells_arr.max(axis=0) + 1) / grid.g

        ok = True
        for hv in tri.convex_hull.ravel():
            if loc[hv]:
                ok = False
                break
        if ok:
            for simplex in tri.simplices:
                if not loc[simplex].any():
                    continue
                center, rad = _circumsphere(pts[simplex])
                if np.any(center - rad < box_lo) or np.any(center + rad > box_hi):
                    ok = False
                    break
        if ok:
            break
        expansions += 1
        if expansions > max_expand:
            raise RuntimeError("halo did not converge")
        new_ring = _ring(region, dim)
        halo |= new_ring
        region |= new_ring

    # edges: simplex edges with >= 1 local endpoint
    edges = set()
    for simplex in tri.simplices:
        for i, j in itertools.combinations(simplex, 2):
            if loc[i] or loc[j]:
                u, v = int(gids[i]), int(gids[j])
                if u == v:
                    continue  # a point adjacent to its own periodic image
                edges.add((max(u, v), min(u, v)))

    local_gids = np.unique(gids[loc])
    e = np.array(sorted(edges), dtype=np.int64) if edges else np.zeros((0, 2), np.int64)
    return e, local_gids, expansions


def rdg_union(seed: int, n: int, P: int, dim: int = 2) -> np.ndarray:
    es = []
    for pe in range(P):
        e, _, _ = rdg_pe(seed, n, P, pe, dim)
        es.append(e)
    e = np.concatenate(es, axis=0)
    return np.unique(e, axis=0) if e.size else e.reshape(0, 2)


def rdg_brute_edges(points: np.ndarray, dim: int) -> np.ndarray:
    """Global periodic DT oracle: triangulate the 3^d tiling, keep edges
    with an endpoint in the canonical copy, fold gids mod n."""
    n = len(points)
    shifts = list(itertools.product((-1.0, 0.0, 1.0), repeat=dim))
    tiles = np.concatenate([points + np.array(s) for s in shifts])
    base = np.tile(np.arange(n), len(shifts))
    canonical = np.zeros(len(tiles), dtype=bool)
    center = shifts.index(tuple([0.0] * dim))
    canonical[center * n: (center + 1) * n] = True

    tri = Delaunay(tiles)
    edges = set()
    for simplex in tri.simplices:
        for i, j in itertools.combinations(simplex, 2):
            if canonical[i] or canonical[j]:
                u, v = int(base[i]), int(base[j])
                if u == v:
                    continue
                edges.add((max(u, v), min(u, v)))
    return np.array(sorted(edges), dtype=np.int64)
