"""Edge-list graph container + validation helpers (tests/benchmarks)."""
from __future__ import annotations

import numpy as np


def canonical_undirected(edges: np.ndarray) -> np.ndarray:
    """(u, v) with u > v, sorted, deduped."""
    e = np.asarray(edges, dtype=np.int64)
    if e.size == 0:
        return e.reshape(0, 2)
    u = np.maximum(e[:, 0], e[:, 1])
    v = np.minimum(e[:, 0], e[:, 1])
    return np.unique(np.stack([u, v], axis=1), axis=0)  # repro: allow(no-numpy-unique) oracle edge canonicalization, not the engine path


def has_self_loops(edges: np.ndarray) -> bool:
    e = np.asarray(edges)
    return bool((e[:, 0] == e[:, 1]).any()) if e.size else False


def has_duplicates(edges: np.ndarray) -> bool:
    e = np.asarray(edges)
    if e.size == 0:
        return False
    return len(np.unique(e, axis=0)) != len(e)  # repro: allow(no-numpy-unique) O(m) validation helper for tests, not the engine path


def degrees(edges: np.ndarray, n: int, directed: bool = False) -> np.ndarray:
    e = np.asarray(edges, dtype=np.int64)
    if e.size == 0:  # asarray of an empty list is shape (0,): no column axis
        return np.zeros(n, dtype=np.int64)
    d = np.bincount(e[:, 0], minlength=n)
    if not directed:
        d = d + np.bincount(e[:, 1], minlength=n)
    return d


def edges_to_set(edges: np.ndarray) -> set:
    return {tuple(x) for x in np.asarray(edges, dtype=np.int64)}
