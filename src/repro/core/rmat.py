"""R-MAT generator (paper §3.5.2) — the Graph 500 comparison baseline.

Each of the m edges descends log2(n) levels of the recursive adjacency-
matrix partition with probabilities (a, b, c, d); one hashed key per
edge makes it communication-free and embarrassingly parallel (this is
what the paper benchmarks *against*: R-MAT needs O(log n) variates per
edge, KaGen's generators O(1) — Fig. 17/18).

Graph500 semantics: self-loops and duplicate edges are kept.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .chunking import section_bounds
from .prng import device_key

_TAG_RMAT = 51


@partial(jax.jit, static_argnames=("log_n",))
def _rmat_edges(key, edge_ids, probs, log_n: int):
    a, b, c, _ = probs

    def one(eid):
        k = jax.random.fold_in(key, eid.astype(jnp.uint32))
        u = jax.random.uniform(k, (log_n,), dtype=jnp.float64)
        quad = (
            (u >= a).astype(jnp.int64)
            + (u >= a + b).astype(jnp.int64)
            + (u >= a + b + c).astype(jnp.int64)
        )
        bits = jnp.arange(log_n - 1, -1, -1, dtype=jnp.int64)
        src = jnp.sum((quad >= 2).astype(jnp.int64) << bits)
        dst = jnp.sum((quad % 2) << bits)
        return src, dst

    return jax.vmap(one)(edge_ids)


def rmat_pe(
    seed: int,
    log_n: int,
    m: int,
    P: int,
    pe: int,
    probs=(0.57, 0.19, 0.19, 0.05),
) -> np.ndarray:
    """PE `pe`'s share of the m edges; [k, 2] int64."""
    elo, ehi = section_bounds(m, P, pe)
    key = device_key(seed, _TAG_RMAT)
    ids = jnp.arange(elo, ehi, dtype=jnp.int64)
    src, dst = _rmat_edges(key, ids, jnp.array(probs, jnp.float64), log_n)
    return np.stack([np.asarray(src), np.asarray(dst)], axis=1)


def rmat_union(seed: int, log_n: int, m: int, P: int = 1, probs=(0.57, 0.19, 0.19, 0.05)):
    return np.concatenate([rmat_pe(seed, log_n, m, P, pe, probs) for pe in range(P)], axis=0)
