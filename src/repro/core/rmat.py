"""R-MAT generator (paper §3.5.2) — the Graph 500 comparison baseline.

Each of the m edges descends log2(n) levels of the recursive adjacency-
matrix partition with probabilities (a, b, c, d); one hashed key per
edge makes it communication-free and embarrassingly parallel (this is
what the paper benchmarks *against*: R-MAT needs O(log n) variates per
edge, KaGen's generators O(1) — Fig. 17/18).

Graph500 semantics: self-loops and duplicate edges are kept.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .chunking import section_bounds
from .prng import device_key, fold_in64

_TAG_RMAT = 51


@partial(jax.jit, static_argnames=("log_n",))
def _rmat_edges(key, edge_ids, probs, log_n: int):
    a, b, c, _ = probs

    def one(eid):
        k = fold_in64(key, eid)  # 64-bit safe: edge ids exceed 2^32 at scale
        u = jax.random.uniform(k, (log_n,), dtype=jnp.float64)
        quad = (
            (u >= a).astype(jnp.int64)
            + (u >= a + b).astype(jnp.int64)
            + (u >= a + b + c).astype(jnp.int64)
        )
        bits = jnp.arange(log_n - 1, -1, -1, dtype=jnp.int64)
        src = jnp.sum((quad >= 2).astype(jnp.int64) << bits)
        dst = jnp.sum((quad % 2) << bits)
        return src, dst

    return jax.vmap(one)(edge_ids)


def rmat_pe(
    seed: int,
    log_n: int,
    m: int,
    P: int,
    pe: int,
    probs=(0.57, 0.19, 0.19, 0.05),
) -> np.ndarray:
    """PE `pe`'s share of the m edges; [k, 2] int64."""
    elo, ehi = section_bounds(m, P, pe)
    key = device_key(seed, _TAG_RMAT)
    ids = jnp.arange(elo, ehi, dtype=jnp.int64)
    src, dst = _rmat_edges(key, ids, jnp.array(probs, jnp.float64), log_n)
    return np.stack([np.asarray(src), np.asarray(dst)], axis=1)


def rmat_plan(seed: int, log_n: int, m: int, P: int,
              probs=(0.57, 0.19, 0.19, 0.05), rng_impl: str = "threefry2x32"):
    """ChunkPlan for the unified engine: one KIND_RMAT chunk per PE
    covering its edge-id range; the hashed quadrant descent runs
    on-device with the same per-edge fold_in as :func:`rmat_pe`, so
    output is bit-identical."""
    from .. import obs
    from ..distrib.engine import (KIND_RMAT, chunk_plan_from_columns,
                                  reseedable_chunk_plan)

    def key_of(s: int) -> np.ndarray:
        one = np.asarray(jax.random.key_data(
            device_key(s, _TAG_RMAT, impl=rng_impl))).ravel()
        return np.broadcast_to(one, (P, one.size))

    with obs.trace("plan/rmat", phase="plan", family="rmat", reseed=False, P=P):
        a, b, c, _ = probs
        sec = m * np.arange(P + 1, dtype=np.int64) // P
        ids = np.arange(P, dtype=np.int64)
        z = np.zeros(P, np.int64)
        fparams = np.broadcast_to(
            np.array([float(a), float(b), float(c), 0.0]), (P, 4))
        plan = chunk_plan_from_columns(
            P, ids, np.full(P, KIND_RMAT, np.int32), key_of(seed), z,
            sec[1:] - sec[:-1],
            np.stack([np.full(P, log_n, np.int64), sec[:-1], z], axis=1),
            np.ones(P, bool), 1 << log_n, fparams=fparams, rng_impl=rng_impl)
        # edge-id sections are seed-independent: reseeding is a pure key swap
        return reseedable_chunk_plan(plan, key_fn=key_of)


def rmat_union(seed: int, log_n: int, m: int, P: int = 1, probs=(0.57, 0.19, 0.19, 0.05)):
    """Deprecated shim: delegates to :func:`repro.api.generate`."""
    from . import warn_deprecated_shim
    from ..api import RMAT, generate

    warn_deprecated_shim("rmat_union", "generate(RMAT(...))")
    return generate(RMAT(log_n=log_n, m=m, probs=tuple(probs), seed=seed), P).edges
