# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
import warnings


def warn_deprecated_shim(old: str, new: str) -> None:
    """Shared DeprecationWarning for the legacy per-family union entry
    points (one public helper, not a private cross-module import)."""
    warnings.warn(
        f"{old} is a deprecated shim; use {new} (repro.api)",
        DeprecationWarning, stacklevel=3)
