"""Chunk geometry + divide-and-conquer sample-count recursion (paper §4).

The adjacency matrix is partitioned into *chunks*; the number of edges in
each chunk is derived by recursively splitting the universe and drawing
hypergeometric variates from recursion-node-hashed generators
(:func:`repro.core.prng.host_rng`).  Every PE runs only its own
log-depth descent (``*_for_pe``); a vectorized full recursion
(``*_all``) exists for tests/benchmarks and must agree exactly.

Directed  G(n,m): chunks = row blocks (Fig. 1 left).
Undirected G(n,m): chunks = P x P lower-triangular block matrix; PE i owns
row i and column i so the shared chunk (i, j) is recomputed identically
by PE i and PE j (Fig. 1 right).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Tuple

import numpy as np

from .prng import PhiloxReplayer, hash_paths, host_rng
from .variates import hypergeometric

# region-type tags mixed into the recursion-node hash
_TRI, _RECT, _ROWS = 1, 2, 3


def section_bounds(n: int, P: int, i: int) -> Tuple[int, int]:
    """Vertex range of section i when [0, n) is split evenly into P."""
    return n * i // P, n * (i + 1) // P


def tri_size(w: int) -> int:
    """# of strictly-lower-triangular entries of a w x w block."""
    return w * (w - 1) // 2


# --------------------------------------------------------------------------
# directed G(n,m): 1-D recursion over row sections
# --------------------------------------------------------------------------

def _dir_universe(n: int, P: int, lo: int, hi: int) -> int:
    a, _ = section_bounds(n, P, lo)
    _, b = section_bounds(n, P, hi - 1)
    return (b - a) * (n - 1)


def directed_counts_for_pe(seed: int, n: int, m: int, P: int, pe: int) -> int:
    """Edge count of PE `pe`'s chunk — O(log P) variates, no communication."""
    lo, hi, mm = 0, P, m
    while hi - lo > 1:
        mid = (lo + hi) // 2
        u_left = _dir_universe(n, P, lo, mid)
        u_right = _dir_universe(n, P, mid, hi)
        rng = host_rng(seed, _ROWS, lo, hi)  # repro: allow(no-per-chunk-host-loop) O(log P) oracle descent
        m_left = hypergeometric(rng, u_left, u_right, mm)
        if pe < mid:
            hi, mm = mid, m_left
        else:
            lo, mm = mid, mm - m_left
    return mm


def directed_counts_all(seed: int, n: int, m: int, P: int) -> np.ndarray:
    """All chunk counts via the same recursion (test/benchmark oracle)."""
    out = np.zeros(P, dtype=np.int64)

    def rec(lo: int, hi: int, mm: int) -> None:
        if hi - lo == 1:
            out[lo] = mm
            return
        mid = (lo + hi) // 2
        u_left = _dir_universe(n, P, lo, mid)
        u_right = _dir_universe(n, P, mid, hi)
        rng = host_rng(seed, _ROWS, lo, hi)
        m_left = hypergeometric(rng, u_left, u_right, mm)
        rec(lo, mid, m_left)
        rec(mid, hi, mm - m_left)

    rec(0, P, m)
    return out


# --------------------------------------------------------------------------
# undirected G(n,m): 2-D recursion over the triangular chunk matrix
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Chunk:
    """One block of the lower-triangular adjacency matrix.

    ``kind == 'tri'``: strictly-lower triangle of vertex rows/cols
    [rlo, rhi);  ``kind == 'rect'``: full block rows [rlo, rhi) x cols
    [clo, chi) with chi <= rlo (strictly below the diagonal).
    """
    kind: str
    rlo: int
    rhi: int
    clo: int
    chi: int
    row_sec: int  # chunk-matrix coordinates (I, J)
    col_sec: int

    @property
    def universe(self) -> int:
        if self.kind == "tri":
            return tri_size(self.rhi - self.rlo)
        return (self.rhi - self.rlo) * (self.chi - self.clo)


def _tri_universe(n, P, lo, hi):
    a, _ = section_bounds(n, P, lo)
    _, b = section_bounds(n, P, hi - 1)
    return tri_size(b - a)


def _rect_universe(n, P, rlo, rhi, clo, chi):
    ra, _ = section_bounds(n, P, rlo)
    _, rb = section_bounds(n, P, rhi - 1)
    ca, _ = section_bounds(n, P, clo)
    _, cb = section_bounds(n, P, chi - 1)
    return (rb - ra) * (cb - ca)


def _make_chunk(n, P, I, J) -> Chunk:
    ra, rb = section_bounds(n, P, I)
    ca, cb = section_bounds(n, P, J)
    if I == J:
        return Chunk("tri", ra, rb, ra, rb, I, J)
    return Chunk("rect", ra, rb, ca, cb, I, J)


def undirected_chunks_for_pe(
    seed: int, n: int, m: int, P: int, pe: int
) -> List[Tuple[Chunk, int]]:
    """All (chunk, edge-count) pairs PE `pe` must generate.

    PE i owns chunk-matrix row i (chunks (i, j), j <= i) and column i
    (chunks (j, i), j >= i): exactly P chunks.  The descent prunes any
    region not intersecting that cross, so work is O(P) with O(log P)
    depth — the paper's Theorem 2 recursion.
    """
    out: List[Tuple[Chunk, int]] = []

    def want_tri(lo, hi):  # tri region intersects PE's cross iff it contains pe
        return lo <= pe < hi

    def want_rect(rlo, rhi, clo, chi):
        return (rlo <= pe < rhi) or (clo <= pe < chi)

    def rec_tri(lo, hi, mm):
        if mm < 0:
            raise AssertionError("negative edge count")
        if hi - lo == 1:
            out.append((_make_chunk(n, P, lo, lo), mm))
            return
        mid = (lo + hi) // 2
        uA = _tri_universe(n, P, lo, mid)
        uB = _rect_universe(n, P, mid, hi, lo, mid)
        uC = _tri_universe(n, P, mid, hi)
        rng = host_rng(seed, _TRI, lo, hi)
        mA = hypergeometric(rng, uA, uB + uC, mm)
        mB = hypergeometric(rng, uB, uC, mm - mA)
        mC = mm - mA - mB
        if want_tri(lo, mid):
            rec_tri(lo, mid, mA)
        if want_rect(mid, hi, lo, mid):
            rec_rect(mid, hi, lo, mid, mB)
        if want_tri(mid, hi):
            rec_tri(mid, hi, mC)

    def rec_rect(rlo, rhi, clo, chi, mm):
        if rhi - rlo == 1 and chi - clo == 1:
            out.append((_make_chunk(n, P, rlo, clo), mm))
            return
        # split the longer side (in sections) to keep depth logarithmic
        if rhi - rlo >= chi - clo:
            mid = (rlo + rhi) // 2
            uT = _rect_universe(n, P, rlo, mid, clo, chi)
            uB = _rect_universe(n, P, mid, rhi, clo, chi)
            rng = host_rng(seed, _RECT, rlo, rhi, clo, chi)
            mT = hypergeometric(rng, uT, uB, mm)
            if want_rect(rlo, mid, clo, chi):
                rec_rect(rlo, mid, clo, chi, mT)
            if want_rect(mid, rhi, clo, chi):
                rec_rect(mid, rhi, clo, chi, mm - mT)
        else:
            mid = (clo + chi) // 2
            uL = _rect_universe(n, P, rlo, rhi, clo, mid)
            uR = _rect_universe(n, P, rlo, rhi, mid, chi)
            rng = host_rng(seed, _RECT, rlo, rhi, clo, chi)
            mL = hypergeometric(rng, uL, uR, mm)
            if want_rect(rlo, rhi, clo, mid):
                rec_rect(rlo, rhi, clo, mid, mL)
            if want_rect(rlo, rhi, mid, chi):
                rec_rect(rlo, rhi, mid, chi, mm - mL)

    rec_tri(0, P, m)
    return out


def undirected_counts_all(seed: int, n: int, m: int, P: int) -> Dict[Tuple[int, int], int]:
    """Full chunk-count matrix (oracle; O(P^2) leaves)."""
    out: Dict[Tuple[int, int], int] = {}

    def rec_tri(lo, hi, mm):
        if hi - lo == 1:
            out[(lo, lo)] = mm
            return
        mid = (lo + hi) // 2
        uA = _tri_universe(n, P, lo, mid)
        uB = _rect_universe(n, P, mid, hi, lo, mid)
        uC = _tri_universe(n, P, mid, hi)
        rng = host_rng(seed, _TRI, lo, hi)
        mA = hypergeometric(rng, uA, uB + uC, mm)
        mB = hypergeometric(rng, uB, uC, mm - mA)
        rec_tri(lo, mid, mA)
        rec_rect(mid, hi, lo, mid, mB)
        rec_tri(mid, hi, mm - mA - mB)

    def rec_rect(rlo, rhi, clo, chi, mm):
        if rhi - rlo == 1 and chi - clo == 1:
            out[(rlo, clo)] = mm
            return
        if rhi - rlo >= chi - clo:
            mid = (rlo + rhi) // 2
            uT = _rect_universe(n, P, rlo, mid, clo, chi)
            uB = _rect_universe(n, P, mid, rhi, clo, chi)
            rng = host_rng(seed, _RECT, rlo, rhi, clo, chi)
            mT = hypergeometric(rng, uT, uB, mm)
            rec_rect(rlo, mid, clo, chi, mT)
            rec_rect(mid, rhi, clo, chi, mm - mT)
        else:
            mid = (clo + chi) // 2
            uL = _rect_universe(n, P, rlo, rhi, clo, mid)
            uR = _rect_universe(n, P, rlo, rhi, mid, chi)
            rng = host_rng(seed, _RECT, rlo, rhi, clo, chi)
            mL = hypergeometric(rng, uL, uR, mm)
            rec_rect(rlo, rhi, clo, mid, mL)
            rec_rect(rlo, rhi, mid, chi, mm - mL)

    rec_tri(0, P, m)
    return out


# --------------------------------------------------------------------------
# flattened split trees — vectorized replay of the D&C recursions
# --------------------------------------------------------------------------
#
# The recursion *structure* (which regions split, their universes, the
# hashed node positions) depends only on (n, P) — never the seed — so it
# is precomputed once into flat preorder arrays and cached.  A count
# pass for a seed is then one batched ``hash_paths`` call plus a tight
# loop drawing the identical hypergeometric variates through a reusable
# ``PhiloxReplayer``: bit-identical to the per-node ``host_rng``
# recursions above (same generators, same draw order) at a fraction of
# the cost, because the per-node ``Generator(Philox(key=...))``
# construction and scalar splitmix64 chains are gone.  The recursions
# above are retained as test oracles.

_NODE_LEAF, _NODE_TRI, _NODE_RECT = 0, 1, 2


class DirectedSplitTree:
    """Flattened 1-D row recursion for directed G(n, m).

    ``counts(seed, m)`` replays :func:`directed_counts_all`
    bit-identically: preorder node order is the oracle's visit order,
    and each internal node draws its one hypergeometric from the same
    ``(seed, _ROWS, lo, hi)``-hashed generator."""

    def __init__(self, n: int, P: int):
        self.n, self.P = n, P
        is_leaf: List[bool] = []
        leaf_pe: List[int] = []
        ul: List[int] = []
        ur: List[int] = []
        left: List[int] = []
        right: List[int] = []
        paths: List[Tuple[int, int, int]] = []

        def build(lo: int, hi: int) -> int:
            k = len(is_leaf)
            is_leaf.append(hi - lo == 1)
            leaf_pe.append(lo)
            ul.append(0)
            ur.append(0)
            left.append(-1)
            right.append(-1)
            paths.append((_ROWS, lo, hi))
            if hi - lo == 1:
                return k
            mid = (lo + hi) // 2
            ul[k] = _dir_universe(n, P, lo, mid)
            ur[k] = _dir_universe(n, P, mid, hi)
            left[k] = build(lo, mid)
            right[k] = build(mid, hi)
            return k

        build(0, P)
        self._is_leaf = np.asarray(is_leaf, bool)
        self._leaf_pe = np.asarray(leaf_pe, np.int64)
        self._ul = np.asarray(ul, np.int64)
        self._ur = np.asarray(ur, np.int64)
        self._left = np.asarray(left, np.int32)
        self._right = np.asarray(right, np.int32)
        self._paths = np.asarray(paths, np.int64).reshape(-1, 3)

    def counts(self, seed: int, m: int) -> np.ndarray:
        """Per-PE chunk edge counts; == ``directed_counts_all``."""
        num = len(self._is_leaf)
        hashes = hash_paths(seed, self._paths)
        mm = np.zeros(num, np.int64)
        mm[0] = m
        out = np.zeros(self.P, np.int64)
        rep = PhiloxReplayer()
        is_leaf, leaf_pe = self._is_leaf, self._leaf_pe
        ul, ur, lt, rt = self._ul, self._ur, self._left, self._right
        for k in range(num):
            cur = int(mm[k])
            if is_leaf[k]:
                out[leaf_pe[k]] = cur
            else:
                ml = (hypergeometric(rep.at(hashes[k]), ul[k], ur[k], cur)
                      if cur else 0)
                mm[lt[k]] = ml
                mm[rt[k]] = cur - ml
        return out


class UndirectedSplitTree:
    """Flattened 2-D triangular recursion for undirected G(n, m).

    Leaves are stored in full-DFS order — the visit order of
    ``undirected_counts_all``, and (filtered to ``leaf_I == pe or
    leaf_J == pe``) the exact emission order of
    ``undirected_chunks_for_pe``: the per-PE descent prunes subtrees
    but never reorders the survivors.  Tri nodes draw *two*
    hypergeometrics from one node generator (mA then mB), matching the
    oracle draw-for-draw."""

    def __init__(self, n: int, P: int):
        self.n, self.P = n, P
        typ: List[int] = []
        hidx: List[int] = []
        u1: List[int] = []
        u2: List[int] = []
        u3: List[int] = []
        c1: List[int] = []
        c2: List[int] = []
        c3: List[int] = []
        leaf_slot: List[int] = []
        leaf_I: List[int] = []
        leaf_J: List[int] = []
        tri_paths: List[Tuple[int, int, int]] = []
        rect_paths: List[Tuple[int, int, int, int, int]] = []

        def new_node(t: int) -> int:
            k = len(typ)
            typ.append(t)
            hidx.append(-1)
            u1.append(0)
            u2.append(0)
            u3.append(0)
            c1.append(-1)
            c2.append(-1)
            c3.append(-1)
            leaf_slot.append(-1)
            return k

        def leaf(I: int, J: int) -> int:
            k = new_node(_NODE_LEAF)
            leaf_slot[k] = len(leaf_I)
            leaf_I.append(I)
            leaf_J.append(J)
            return k

        def rec_tri(lo: int, hi: int) -> int:
            if hi - lo == 1:
                return leaf(lo, lo)
            k = new_node(_NODE_TRI)
            hidx[k] = len(tri_paths)
            tri_paths.append((_TRI, lo, hi))
            mid = (lo + hi) // 2
            u1[k] = _tri_universe(n, P, lo, mid)
            u2[k] = _rect_universe(n, P, mid, hi, lo, mid)
            u3[k] = _tri_universe(n, P, mid, hi)
            c1[k] = rec_tri(lo, mid)
            c2[k] = rec_rect(mid, hi, lo, mid)
            c3[k] = rec_tri(mid, hi)
            return k

        def rec_rect(rlo: int, rhi: int, clo: int, chi: int) -> int:
            if rhi - rlo == 1 and chi - clo == 1:
                return leaf(rlo, clo)
            k = new_node(_NODE_RECT)
            hidx[k] = len(rect_paths)
            rect_paths.append((_RECT, rlo, rhi, clo, chi))
            if rhi - rlo >= chi - clo:
                mid = (rlo + rhi) // 2
                u1[k] = _rect_universe(n, P, rlo, mid, clo, chi)
                u2[k] = _rect_universe(n, P, mid, rhi, clo, chi)
                c1[k] = rec_rect(rlo, mid, clo, chi)
                c2[k] = rec_rect(mid, rhi, clo, chi)
            else:
                mid = (clo + chi) // 2
                u1[k] = _rect_universe(n, P, rlo, rhi, clo, mid)
                u2[k] = _rect_universe(n, P, rlo, rhi, mid, chi)
                c1[k] = rec_rect(rlo, rhi, clo, mid)
                c2[k] = rec_rect(rlo, rhi, mid, chi)
            return k

        rec_tri(0, P)
        self._typ = np.asarray(typ, np.int8)
        self._hidx = np.asarray(hidx, np.int32)
        self._u1 = np.asarray(u1, np.int64)
        self._u2 = np.asarray(u2, np.int64)
        self._u3 = np.asarray(u3, np.int64)
        self._c1 = np.asarray(c1, np.int32)
        self._c2 = np.asarray(c2, np.int32)
        self._c3 = np.asarray(c3, np.int32)
        self._leaf_slot = np.asarray(leaf_slot, np.int32)
        self._tri_paths = np.asarray(tri_paths, np.int64).reshape(-1, 3)
        self._rect_paths = np.asarray(rect_paths, np.int64).reshape(-1, 5)
        #: chunk-matrix coordinates of leaf l, in full-DFS leaf order
        self.leaf_I = np.asarray(leaf_I, np.int64)
        self.leaf_J = np.asarray(leaf_J, np.int64)

    @property
    def num_leaves(self) -> int:
        return len(self.leaf_I)

    def counts(self, seed: int, m: int) -> np.ndarray:
        """Edge count per leaf (full-DFS leaf order); bit-identical to
        ``undirected_counts_all``'s ``(leaf_I[l], leaf_J[l])`` entry."""
        num = len(self._typ)
        h_tri = hash_paths(seed, self._tri_paths)
        h_rect = hash_paths(seed, self._rect_paths)
        mm = np.zeros(num, np.int64)
        mm[0] = m
        out = np.zeros(self.num_leaves, np.int64)
        rep = PhiloxReplayer()
        typ, hidx, leaf_slot = self._typ, self._hidx, self._leaf_slot
        u1, u2, u3 = self._u1, self._u2, self._u3
        c1, c2, c3 = self._c1, self._c2, self._c3
        for k in range(num):
            cur = int(mm[k])
            t = typ[k]
            if t == _NODE_LEAF:
                out[leaf_slot[k]] = cur
            elif t == _NODE_TRI:
                if cur:
                    rng = rep.at(h_tri[hidx[k]])
                    mA = hypergeometric(rng, u1[k], u2[k] + u3[k], cur)
                    mB = hypergeometric(rng, u2[k], u3[k], cur - mA)
                else:
                    mA = mB = 0
                mm[c1[k]] = mA
                mm[c2[k]] = mB
                mm[c3[k]] = cur - mA - mB
            else:
                mx = (hypergeometric(rep.at(h_rect[hidx[k]]), u1[k],
                                     u2[k], cur) if cur else 0)
                mm[c1[k]] = mx
                mm[c2[k]] = cur - mx
        return out


@lru_cache(maxsize=32)
def directed_split_tree(n: int, P: int) -> DirectedSplitTree:
    """Seed-independent flattened recursion structure (cached)."""
    return DirectedSplitTree(n, P)


@lru_cache(maxsize=32)
def undirected_split_tree(n: int, P: int) -> UndirectedSplitTree:
    """Seed-independent flattened recursion structure (cached)."""
    return UndirectedSplitTree(n, P)


# --------------------------------------------------------------------------
# d-dimensional cube chunks (RGG / RDG) with Z-order assignment (paper §5.1)
# --------------------------------------------------------------------------

def morton_decode(code: int, dim: int, bits: int) -> Tuple[int, ...]:
    """Z-order curve index -> grid coordinates."""
    coords = [0] * dim
    for b in range(bits):
        for d in range(dim):
            coords[d] |= ((code >> (b * dim + d)) & 1) << b
    return tuple(coords)


def morton_encode(coords: Tuple[int, ...], dim: int, bits: int) -> int:
    code = 0
    for b in range(bits):
        for d in range(dim):
            code |= ((coords[d] >> b) & 1) << (b * dim + d)
    return code


def cube_chunks_for_pe(P: int, dim: int, pe: int, cpd: int = 0) -> List[Tuple[int, ...]]:
    """Locality-aware chunk->PE assignment via the Z-order curve.

    Generates k = 2^(dim*b) >= P chunks and deals them round-robin in
    Morton order, so each PE's chunks are spatially clustered.  The grid
    has ``chunks_per_dim(P, dim)`` chunks along each axis by default;
    passing ``cpd`` explicitly decouples the chunk grid (and hence the
    generated instance) from the PE count.
    """
    cpd = cpd or chunks_per_dim(P, dim)
    b = cpd.bit_length() - 1
    return [morton_decode(c, dim, b) for c in range(cpd ** dim) if c % P == pe]


def chunks_per_dim(P: int, dim: int) -> int:
    """Chunk-grid side length: smallest power of two with 2^(dim*b) >= P."""
    b = 0
    while (1 << (dim * b)) < P:
        b += 1
    return 1 << b
