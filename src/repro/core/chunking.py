"""Chunk geometry + divide-and-conquer sample-count recursion (paper §4).

The adjacency matrix is partitioned into *chunks*; the number of edges in
each chunk is derived by recursively splitting the universe and drawing
hypergeometric variates from recursion-node-hashed generators
(:func:`repro.core.prng.host_rng`).  Every PE runs only its own
log-depth descent (``*_for_pe``); a vectorized full recursion
(``*_all``) exists for tests/benchmarks and must agree exactly.

Directed  G(n,m): chunks = row blocks (Fig. 1 left).
Undirected G(n,m): chunks = P x P lower-triangular block matrix; PE i owns
row i and column i so the shared chunk (i, j) is recomputed identically
by PE i and PE j (Fig. 1 right).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from .prng import host_rng
from .variates import hypergeometric

# region-type tags mixed into the recursion-node hash
_TRI, _RECT, _ROWS = 1, 2, 3


def section_bounds(n: int, P: int, i: int) -> Tuple[int, int]:
    """Vertex range of section i when [0, n) is split evenly into P."""
    return n * i // P, n * (i + 1) // P


def tri_size(w: int) -> int:
    """# of strictly-lower-triangular entries of a w x w block."""
    return w * (w - 1) // 2


# --------------------------------------------------------------------------
# directed G(n,m): 1-D recursion over row sections
# --------------------------------------------------------------------------

def _dir_universe(n: int, P: int, lo: int, hi: int) -> int:
    a, _ = section_bounds(n, P, lo)
    _, b = section_bounds(n, P, hi - 1)
    return (b - a) * (n - 1)


def directed_counts_for_pe(seed: int, n: int, m: int, P: int, pe: int) -> int:
    """Edge count of PE `pe`'s chunk — O(log P) variates, no communication."""
    lo, hi, mm = 0, P, m
    while hi - lo > 1:
        mid = (lo + hi) // 2
        u_left = _dir_universe(n, P, lo, mid)
        u_right = _dir_universe(n, P, mid, hi)
        rng = host_rng(seed, _ROWS, lo, hi)
        m_left = hypergeometric(rng, u_left, u_right, mm)
        if pe < mid:
            hi, mm = mid, m_left
        else:
            lo, mm = mid, mm - m_left
    return mm


def directed_counts_all(seed: int, n: int, m: int, P: int) -> np.ndarray:
    """All chunk counts via the same recursion (test/benchmark oracle)."""
    out = np.zeros(P, dtype=np.int64)

    def rec(lo: int, hi: int, mm: int) -> None:
        if hi - lo == 1:
            out[lo] = mm
            return
        mid = (lo + hi) // 2
        u_left = _dir_universe(n, P, lo, mid)
        u_right = _dir_universe(n, P, mid, hi)
        rng = host_rng(seed, _ROWS, lo, hi)
        m_left = hypergeometric(rng, u_left, u_right, mm)
        rec(lo, mid, m_left)
        rec(mid, hi, mm - m_left)

    rec(0, P, m)
    return out


# --------------------------------------------------------------------------
# undirected G(n,m): 2-D recursion over the triangular chunk matrix
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Chunk:
    """One block of the lower-triangular adjacency matrix.

    ``kind == 'tri'``: strictly-lower triangle of vertex rows/cols
    [rlo, rhi);  ``kind == 'rect'``: full block rows [rlo, rhi) x cols
    [clo, chi) with chi <= rlo (strictly below the diagonal).
    """
    kind: str
    rlo: int
    rhi: int
    clo: int
    chi: int
    row_sec: int  # chunk-matrix coordinates (I, J)
    col_sec: int

    @property
    def universe(self) -> int:
        if self.kind == "tri":
            return tri_size(self.rhi - self.rlo)
        return (self.rhi - self.rlo) * (self.chi - self.clo)


def _tri_universe(n, P, lo, hi):
    a, _ = section_bounds(n, P, lo)
    _, b = section_bounds(n, P, hi - 1)
    return tri_size(b - a)


def _rect_universe(n, P, rlo, rhi, clo, chi):
    ra, _ = section_bounds(n, P, rlo)
    _, rb = section_bounds(n, P, rhi - 1)
    ca, _ = section_bounds(n, P, clo)
    _, cb = section_bounds(n, P, chi - 1)
    return (rb - ra) * (cb - ca)


def _make_chunk(n, P, I, J) -> Chunk:
    ra, rb = section_bounds(n, P, I)
    ca, cb = section_bounds(n, P, J)
    if I == J:
        return Chunk("tri", ra, rb, ra, rb, I, J)
    return Chunk("rect", ra, rb, ca, cb, I, J)


def undirected_chunks_for_pe(
    seed: int, n: int, m: int, P: int, pe: int
) -> List[Tuple[Chunk, int]]:
    """All (chunk, edge-count) pairs PE `pe` must generate.

    PE i owns chunk-matrix row i (chunks (i, j), j <= i) and column i
    (chunks (j, i), j >= i): exactly P chunks.  The descent prunes any
    region not intersecting that cross, so work is O(P) with O(log P)
    depth — the paper's Theorem 2 recursion.
    """
    out: List[Tuple[Chunk, int]] = []

    def want_tri(lo, hi):  # tri region intersects PE's cross iff it contains pe
        return lo <= pe < hi

    def want_rect(rlo, rhi, clo, chi):
        return (rlo <= pe < rhi) or (clo <= pe < chi)

    def rec_tri(lo, hi, mm):
        if mm < 0:
            raise AssertionError("negative edge count")
        if hi - lo == 1:
            out.append((_make_chunk(n, P, lo, lo), mm))
            return
        mid = (lo + hi) // 2
        uA = _tri_universe(n, P, lo, mid)
        uB = _rect_universe(n, P, mid, hi, lo, mid)
        uC = _tri_universe(n, P, mid, hi)
        rng = host_rng(seed, _TRI, lo, hi)
        mA = hypergeometric(rng, uA, uB + uC, mm)
        mB = hypergeometric(rng, uB, uC, mm - mA)
        mC = mm - mA - mB
        if want_tri(lo, mid):
            rec_tri(lo, mid, mA)
        if want_rect(mid, hi, lo, mid):
            rec_rect(mid, hi, lo, mid, mB)
        if want_tri(mid, hi):
            rec_tri(mid, hi, mC)

    def rec_rect(rlo, rhi, clo, chi, mm):
        if rhi - rlo == 1 and chi - clo == 1:
            out.append((_make_chunk(n, P, rlo, clo), mm))
            return
        # split the longer side (in sections) to keep depth logarithmic
        if rhi - rlo >= chi - clo:
            mid = (rlo + rhi) // 2
            uT = _rect_universe(n, P, rlo, mid, clo, chi)
            uB = _rect_universe(n, P, mid, rhi, clo, chi)
            rng = host_rng(seed, _RECT, rlo, rhi, clo, chi)
            mT = hypergeometric(rng, uT, uB, mm)
            if want_rect(rlo, mid, clo, chi):
                rec_rect(rlo, mid, clo, chi, mT)
            if want_rect(mid, rhi, clo, chi):
                rec_rect(mid, rhi, clo, chi, mm - mT)
        else:
            mid = (clo + chi) // 2
            uL = _rect_universe(n, P, rlo, rhi, clo, mid)
            uR = _rect_universe(n, P, rlo, rhi, mid, chi)
            rng = host_rng(seed, _RECT, rlo, rhi, clo, chi)
            mL = hypergeometric(rng, uL, uR, mm)
            if want_rect(rlo, rhi, clo, mid):
                rec_rect(rlo, rhi, clo, mid, mL)
            if want_rect(rlo, rhi, mid, chi):
                rec_rect(rlo, rhi, mid, chi, mm - mL)

    rec_tri(0, P, m)
    return out


def undirected_counts_all(seed: int, n: int, m: int, P: int) -> Dict[Tuple[int, int], int]:
    """Full chunk-count matrix (oracle; O(P^2) leaves)."""
    out: Dict[Tuple[int, int], int] = {}

    def rec_tri(lo, hi, mm):
        if hi - lo == 1:
            out[(lo, lo)] = mm
            return
        mid = (lo + hi) // 2
        uA = _tri_universe(n, P, lo, mid)
        uB = _rect_universe(n, P, mid, hi, lo, mid)
        uC = _tri_universe(n, P, mid, hi)
        rng = host_rng(seed, _TRI, lo, hi)
        mA = hypergeometric(rng, uA, uB + uC, mm)
        mB = hypergeometric(rng, uB, uC, mm - mA)
        rec_tri(lo, mid, mA)
        rec_rect(mid, hi, lo, mid, mB)
        rec_tri(mid, hi, mm - mA - mB)

    def rec_rect(rlo, rhi, clo, chi, mm):
        if rhi - rlo == 1 and chi - clo == 1:
            out[(rlo, clo)] = mm
            return
        if rhi - rlo >= chi - clo:
            mid = (rlo + rhi) // 2
            uT = _rect_universe(n, P, rlo, mid, clo, chi)
            uB = _rect_universe(n, P, mid, rhi, clo, chi)
            rng = host_rng(seed, _RECT, rlo, rhi, clo, chi)
            mT = hypergeometric(rng, uT, uB, mm)
            rec_rect(rlo, mid, clo, chi, mT)
            rec_rect(mid, rhi, clo, chi, mm - mT)
        else:
            mid = (clo + chi) // 2
            uL = _rect_universe(n, P, rlo, rhi, clo, mid)
            uR = _rect_universe(n, P, rlo, rhi, mid, chi)
            rng = host_rng(seed, _RECT, rlo, rhi, clo, chi)
            mL = hypergeometric(rng, uL, uR, mm)
            rec_rect(rlo, rhi, clo, mid, mL)
            rec_rect(rlo, rhi, mid, chi, mm - mL)

    rec_tri(0, P, m)
    return out


# --------------------------------------------------------------------------
# d-dimensional cube chunks (RGG / RDG) with Z-order assignment (paper §5.1)
# --------------------------------------------------------------------------

def morton_decode(code: int, dim: int, bits: int) -> Tuple[int, ...]:
    """Z-order curve index -> grid coordinates."""
    coords = [0] * dim
    for b in range(bits):
        for d in range(dim):
            coords[d] |= ((code >> (b * dim + d)) & 1) << b
    return tuple(coords)


def morton_encode(coords: Tuple[int, ...], dim: int, bits: int) -> int:
    code = 0
    for b in range(bits):
        for d in range(dim):
            code |= ((coords[d] >> b) & 1) << (b * dim + d)
    return code


def cube_chunks_for_pe(P: int, dim: int, pe: int, cpd: int = 0) -> List[Tuple[int, ...]]:
    """Locality-aware chunk->PE assignment via the Z-order curve.

    Generates k = 2^(dim*b) >= P chunks and deals them round-robin in
    Morton order, so each PE's chunks are spatially clustered.  The grid
    has ``chunks_per_dim(P, dim)`` chunks along each axis by default;
    passing ``cpd`` explicitly decouples the chunk grid (and hence the
    generated instance) from the PE count.
    """
    cpd = cpd or chunks_per_dim(P, dim)
    b = cpd.bit_length() - 1
    return [morton_decode(c, dim, b) for c in range(cpd ** dim) if c % P == pe]


def chunks_per_dim(P: int, dim: int) -> int:
    """Chunk-grid side length: smallest power of two with 2^(dim*b) >= P."""
    b = 0
    while (1 << (dim * b)) < P:
        b += 1
    return 1 << b
