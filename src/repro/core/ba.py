"""Barabási-Albert preferential attachment, communication-free
(Sanders & Schulz [4], adapted in paper §3.5.1).

Batagelj-Brandes fill the edge array M sequentially:
    M[2k]   = k // d                 (source of edge k)
    M[2k+1] = M[r],  r ~ U[0, 2k]    (preferential target)

Sanders-Schulz observation: M[2k+1] can be resolved *independently* by
replaying the chain of positions with a hash-keyed uniform draw per
position — identical on every PE, no state, no communication:

    resolve(pos): while pos is odd: pos <- h(pos) in [0, pos);
                  return (pos // 2) // d

Chain length is O(log) w.h.p.; each edge is an independent
``lax.while_loop`` — embarrassingly parallel under ``vmap``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .chunking import section_bounds
from .prng import device_key, fold_in64 as _fold_in64

_TAG_BA = 41


@partial(jax.jit, static_argnames=("d",))
def _resolve_targets(key, edge_ids, d: int):
    """Vectorized chain resolution: target vertex of each edge id k."""

    def resolve(k):
        pos = 2 * k + 1

        def cond(p):
            return (p % 2) == 1

        def body(p):
            kk = _fold_in64(key, p)
            return jax.random.randint(kk, (), 0, p, dtype=jnp.int64)

        pos = jax.lax.while_loop(cond, body, pos)
        return (pos // 2) // d

    return jax.vmap(resolve)(edge_ids)


def ba_pe(seed: int, n: int, d: int, P: int, pe: int) -> np.ndarray:
    """Edges whose source vertex lies in PE `pe`'s range; [k, 2] int64."""
    key = device_key(seed, _TAG_BA)
    vlo, vhi = section_bounds(n, P, pe)
    edge_ids = jnp.arange(vlo * d, vhi * d, dtype=jnp.int64)
    tgt = _resolve_targets(key, edge_ids, d)
    src = edge_ids // d
    return np.stack([np.asarray(src), np.asarray(tgt)], axis=1)


def ba_sequential_reference(seed: int, n: int, d: int) -> np.ndarray:
    """Batagelj-Brandes with the *same* hash draws — must equal the
    parallel chain resolution bit-for-bit (test oracle)."""
    key = device_key(seed, _TAG_BA)
    M = np.zeros(2 * n * d, dtype=np.int64)
    # precompute the hashed uniform for every odd position in one batch
    odd = jnp.arange(1, 2 * n * d, 2, dtype=jnp.int64)

    def draw(p):
        return jax.random.randint(_fold_in64(key, p), (), 0, p, dtype=jnp.int64)

    draws = np.asarray(jax.jit(jax.vmap(draw))(odd))
    for k in range(n * d):
        M[2 * k] = k // d
        M[2 * k + 1] = M[draws[k]]
    return M.reshape(-1, 2)


def ba_plan(seed: int, n: int, d: int, P: int, rng_impl: str = "threefry2x32"):
    """ChunkPlan for the unified engine: one KIND_BA chunk per PE
    covering its edge-id range; the chain resolution runs on-device with
    the same hashed draws as :func:`ba_pe`, so output is bit-identical."""
    from .. import obs
    from ..distrib.engine import (KIND_BA, chunk_plan_from_columns,
                                  reseedable_chunk_plan)

    def key_of(s: int) -> np.ndarray:
        one = np.asarray(jax.random.key_data(
            device_key(s, _TAG_BA, impl=rng_impl))).ravel()
        return np.broadcast_to(one, (P, one.size))

    with obs.trace("plan/ba", phase="plan", family="ba", reseed=False, P=P):
        sec = n * np.arange(P + 1, dtype=np.int64) // P
        ids = np.arange(P, dtype=np.int64)
        z = np.zeros(P, np.int64)
        plan = chunk_plan_from_columns(
            P, ids, np.full(P, KIND_BA, np.int32), key_of(seed), z,
            (sec[1:] - sec[:-1]) * d,
            np.stack([np.full(P, d, np.int64), sec[:-1] * d, z], axis=1),
            np.ones(P, bool), n, rng_impl=rng_impl)
        # edge-id ranges (and hence counts/capacity) are seed-independent:
        # reseeding is a pure key swap
        return reseedable_chunk_plan(plan, key_fn=key_of)


def ba_union(seed: int, n: int, d: int, P: int = 1) -> np.ndarray:
    """Deprecated shim: delegates to :func:`repro.api.generate`."""
    from . import warn_deprecated_shim
    from ..api import BA, generate

    warn_deprecated_shim("ba_union", "generate(BA(...))")
    return generate(BA(n=n, d=d, seed=seed), P).edges
