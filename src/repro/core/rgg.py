"""Random geometric graphs in [0,1)^d, d in {2,3} (paper §5).

Communication-free parallelization: the unit cube is cut into a uniform
cell grid (cell side >= r when possible), cells are grouped into
2^(d*b) >= P Morton-ordered chunks, and per-cell vertex counts come from
a divide-and-conquer binomial recursion whose nodes are hashed — so any
PE can recompute any cell's vertices (its own *and* halo cells of
neighboring chunks) without communication.

Vertex ids are assigned in recursion order: the global id offset of a
cell is the sum of left-sibling counts along its root path, computable
in O(log #cells) by any PE — a consecutive, communication-free labeling.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache, partial
from typing import Dict, Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.pairdist.ops import pairdist, pad_points
from ..kernels.pairdist.ref import pairdist_mask_ref
from .chunking import chunks_per_dim, cube_chunks_for_pe, morton_decode
from .prng import (PhiloxReplayer, counter_uniform, device_key, fold_in_many,
                   hash_paths, host_rng)
from .variates import binomial

_TAG_SPLIT, _TAG_PTS = 21, 22

Box = Tuple[Tuple[int, int], ...]  # ((lo, hi), ...) in cell coordinates
Cell = Tuple[int, ...]


@dataclass(frozen=True)
class CellGrid:
    """Uniform cell grid aligned with the Morton chunk decomposition."""
    dim: int
    g: int          # cells per dimension
    cpd: int        # chunks per dimension (power of two)
    rho: int        # neighbor search range in cells (ceil(r * g))

    @property
    def cells_per_chunk_dim(self) -> int:
        return self.g // self.cpd

    @property
    def num_cells(self) -> int:
        return self.g ** self.dim

    def cell_id(self, cell: Cell) -> int:
        cid = 0
        for c in cell:
            cid = cid * self.g + int(c)
        return cid

    def chunk_cells(self, chunk: Cell) -> List[Cell]:
        cc = self.cells_per_chunk_dim
        ranges = [range(c * cc, (c + 1) * cc) for c in chunk]
        out: List[Cell] = []

        def rec(prefix, rest):
            if not rest:
                out.append(tuple(prefix))
                return
            for v in rest[0]:
                rec(prefix + [v], rest[1:])

        rec([], ranges)
        return out


def make_grid(n: int, radius: float, P: int, dim: int) -> CellGrid:
    """Cell side = max(r, n^-1/d) rounded to tile the chunk grid (§5)."""
    cpd = chunks_per_dim(P, dim)
    target = max(radius, n ** (-1.0 / dim))
    per_chunk = max(1, int(1.0 / (target * cpd)))
    g = cpd * per_chunk
    rho = max(1, math.ceil(radius * g - 1e-9))
    return CellGrid(dim=dim, g=g, cpd=cpd, rho=rho)


class CellCounter:
    """Divide-and-conquer per-cell vertex counts (hashed binomial splits).

    `count(box)` and `cell_offset(cell)` are pure functions of
    (seed, grid, n): every PE computing them agrees — the core
    communication-free invariant.  Memoized per instance.
    """

    def __init__(self, seed: int, grid: CellGrid, n: int):
        self.seed, self.grid, self.n = seed, grid, n
        root = tuple((0, grid.g) for _ in range(grid.dim))
        self._memo: Dict[Box, int] = {root: n}
        self._root = root

    @staticmethod
    def _volume(box: Box) -> int:
        v = 1
        for lo, hi in box:
            v *= hi - lo
        return v

    @staticmethod
    def _split(box: Box) -> Tuple[int, int, Box, Box]:
        """Halve the largest dim (ties -> lowest index); chunk-aligned."""
        widths = [hi - lo for lo, hi in box]
        d = int(np.argmax(widths))
        lo, hi = box[d]
        mid = (lo + hi) // 2
        left = box[:d] + ((lo, mid),) + box[d + 1:]
        right = box[:d] + ((mid, hi),) + box[d + 1:]
        return d, mid, left, right

    def count(self, box: Box) -> int:
        if box in self._memo:
            return self._memo[box]
        parent, path = self._parent_of(box)
        _, _, left, right = self._split(parent)
        cp = self.count(parent)
        rng = host_rng(self.seed, _TAG_SPLIT, *[x for lohi in parent for x in lohi])
        cl = binomial(rng, cp, self._volume(left) / self._volume(parent))
        self._memo[left] = cl
        self._memo[right] = cp - cl
        return self._memo[box]

    def _parent_of(self, box: Box) -> Tuple[Box, None]:
        """Walk down from the root until `box` is a child of the cursor."""
        cur = self._root
        while True:
            if cur == box:
                raise AssertionError("box is root")
            _, _, left, right = self._split(cur)
            if self._contains(left, box):
                if left == box:
                    return cur, None
                # force materialization of left count, then descend
                self._ensure_children(cur)
                cur = left
            elif self._contains(right, box):
                if right == box:
                    return cur, None
                self._ensure_children(cur)
                cur = right
            else:
                raise AssertionError(f"{box} not inside {cur}")

    def _ensure_children(self, parent: Box) -> None:
        _, _, left, right = self._split(parent)
        if left in self._memo:
            return
        cp = self.count(parent)
        rng = host_rng(self.seed, _TAG_SPLIT, *[x for lohi in parent for x in lohi])
        cl = binomial(rng, cp, self._volume(left) / self._volume(parent))
        self._memo[left] = cl
        self._memo[right] = cp - cl

    @staticmethod
    def _contains(outer: Box, inner: Box) -> bool:
        return all(ol <= il and ih <= oh for (ol, oh), (il, ih) in zip(outer, inner))

    def cell_count(self, cell: Cell) -> int:
        box = tuple((c, c + 1) for c in cell)
        cur = self._root
        while cur != box:
            self._ensure_children(cur)
            _, _, left, right = self._split(cur)
            cur = left if self._contains(left, box) else right
        return self._memo[box]

    def cell_offset(self, cell: Cell) -> int:
        """Global vertex-id offset: sum of left-sibling counts on the path."""
        box = tuple((c, c + 1) for c in cell)
        cur, off = self._root, 0
        while cur != box:
            self._ensure_children(cur)
            _, _, left, right = self._split(cur)
            if self._contains(left, box):
                cur = left
            else:
                off += self._memo[left]
                cur = right
        return off


class CellSplitTree:
    """The :class:`CellCounter` recursion, flattened for replay.

    The split *tree* — which boxes exist, their hash paths, their volume
    ratios, which leaf is which cell — is a pure function of the grid
    (``_split`` halves the largest dim, ties lowest), never of the seed.
    Building it once and replaying the binomial draws in preorder gives
    every cell's count and vertex-id offset for any seed in one flat
    pass, with the *identical* per-node ``host_rng`` draws as the
    memoized descent — this is the seed-independent structure half of
    the RGG plan emitters, and what makes their reseed path cheap.
    """

    def __init__(self, grid: CellGrid):
        self.grid = grid
        boxes: List[Box] = []
        left: List[int] = []
        right: List[int] = []

        def build(box: Box) -> int:
            i = len(boxes)
            boxes.append(box)
            left.append(-1)
            right.append(-1)
            if CellCounter._volume(box) > 1:
                _, _, lo, hi = CellCounter._split(box)
                left[i] = build(lo)
                right[i] = build(hi)
            return i

        build(tuple((0, grid.g) for _ in range(grid.dim)))
        self._num_nodes = len(boxes)
        # internal nodes in preorder (index order): parent before children
        self._internal = [i for i in range(len(boxes)) if left[i] >= 0]
        self._left = left
        self._right = right
        # fixed-width hash paths (_TAG_SPLIT, *flattened box) per internal
        # node, ready for the vectorized splitmix64 chain
        self._path = np.array(
            [(_TAG_SPLIT,) + tuple(x for lohi in boxes[i] for x in lohi)
             for i in self._internal], np.int64).reshape(len(self._internal),
                                                         1 + 2 * grid.dim)
        self._ratio = [CellCounter._volume(boxes[self._left[i]])
                       / CellCounter._volume(boxes[i]) for i in self._internal]
        # leaf node of each cell, indexed by row-major cell id
        leaf = np.zeros(grid.num_cells, np.int64)
        for i, box in enumerate(boxes):
            if left[i] < 0:
                leaf[grid.cell_id(tuple(lo for lo, _ in box))] = i
        self._leaf = leaf

    def counts_offsets(self, seed: int, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """(counts, vertex-id offsets) per cell (row-major cell id),
        bit-identical to :meth:`CellCounter.cell_count` /
        :meth:`CellCounter.cell_offset` for the same seed: the hash
        chains are batched (:func:`repro.core.prng.hash_paths`) and the
        Philox construction amortized (:class:`PhiloxReplayer`), but
        every node draws the identical variate the memoized descent
        would."""
        hashes = hash_paths(seed, self._path)
        replayer = PhiloxReplayer()
        cnt = np.zeros(self._num_nodes, np.int64)
        off = np.zeros(self._num_nodes, np.int64)
        cnt[0] = n
        left, right, ratio = self._left, self._right, self._ratio
        for k, i in enumerate(self._internal):
            c = int(cnt[i])
            if c:  # binomial(rng, 0, p) == 0 without consuming draws
                cl = binomial(replayer.at(hashes[k]), c, ratio[k])
            else:
                cl = 0
            l, r = left[i], right[i]
            cnt[l], cnt[r] = cl, c - cl
            off[l], off[r] = off[i], off[i] + cl
        return cnt[self._leaf], off[self._leaf]


# --------------------------------------------------------------------------
# device-side point generation
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cap", "dim", "g"))
def _points_for_cells(key, cell_ids, cell_coords, counts, cap: int, dim: int, g: int):
    """Uniform points inside each cell; (C, cap, dim) + mask (C, cap).

    Keyed by the *cell id* only, with capacity-independent per-slot
    draws — every PE regenerates identical points for the same cell no
    matter how its buffers are padded (the halo-recomputation
    invariant)."""
    def one(cid, coord, cnt):
        k = jax.random.fold_in(key, cid)
        u = counter_uniform(k, cap, dim)
        pos = (coord.astype(jnp.float64) + u) / g
        return pos, jnp.arange(cap) < cnt

    return jax.vmap(one)(cell_ids, cell_coords, counts)


def points_for_cells(
    seed: int, grid: CellGrid, counter: CellCounter, cells: Sequence[Cell],
    rng_impl: str | None = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """(positions (C,cap,dim) f64, counts (C,), gid offsets (C,), cap).

    ``rng_impl`` selects the key implementation so point consumers can
    follow the same hashed stream a non-default-impl plan regenerates
    on device (None = the default threefry stream)."""
    counts = np.array([counter.cell_count(c) for c in cells], dtype=np.int64)
    offsets = np.array([counter.cell_offset(c) for c in cells], dtype=np.int64)
    cap = max(1, int(counts.max()) if len(counts) else 1)
    cap = (cap + 127) // 128 * 128  # kernel block multiple
    ids = jnp.array([grid.cell_id(c) for c in cells], dtype=jnp.int64)
    coords = jnp.array(cells, dtype=jnp.int64)
    pos, mask = _points_for_cells(
        device_key(seed, _TAG_PTS, impl=rng_impl), ids, coords, jnp.array(counts),
        cap, grid.dim, grid.g
    )
    return np.asarray(pos), counts, offsets, cap


# --------------------------------------------------------------------------
# per-PE generation
# --------------------------------------------------------------------------

def _neighbor_offsets(dim: int, rho: int) -> List[Cell]:
    rng = range(-rho, rho + 1)
    if dim == 2:
        return [(a, b) for a in rng for b in rng]
    return [(a, b, c) for a in rng for b in rng for c in rng]


def _is_forward(delta: Cell) -> bool:
    for x in delta:
        if x != 0:
            return x > 0
    return False  # zero offset


def local_cells_for_pe(grid: CellGrid, P: int, pe: int) -> List[Cell]:
    """Cells of PE `pe`: the grid's Morton chunks dealt round-robin.

    The chunk grid comes from ``grid.cpd`` (not from P), so a grid built
    for a fixed virtual chunk count yields the identical instance on any
    number of PEs."""
    cells: List[Cell] = []
    for ch in cube_chunks_for_pe(P, grid.dim, pe, cpd=grid.cpd):
        cells.extend(grid.chunk_cells(ch))
    return cells


def rgg_pe(
    seed: int, n: int, radius: float, P: int, pe: int, dim: int = 2,
    interpret: bool = True, force_kernel: bool = False, chunk_P: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All edges incident to PE `pe`'s vertices — the per-PE *host loop*.

    Retired as the production edge phase (the engine executes
    :func:`rgg_pair_plan` on device instead); kept as the independent
    test oracle the PairPlan path is checked against, and as the paper's
    literal §5.1 protocol: halo cells of neighboring chunks are
    recomputed locally, never communicated.

    Returns (edges [k,2] global ids, local vertex gids, local positions).
    ``chunk_P`` sizes the virtual chunk grid independently of P (the
    instance is a function of the grid; default: the legacy P-coupled
    grid).
    """
    grid = make_grid(n, radius, chunk_P or P, dim)
    counter = CellCounter(seed, grid, n)
    local = local_cells_for_pe(grid, P, pe)
    local_set = set(local)

    # halo = cells within rho of any local cell, not local themselves
    halo: set = set()
    for cell in local:
        for d in _neighbor_offsets(dim, grid.rho):
            nb = tuple(c + o for c, o in zip(cell, d))
            if all(0 <= x < grid.g for x in nb) and nb not in local_set:
                halo.add(nb)
    all_cells = list(local) + sorted(halo)
    index_of = {c: i for i, c in enumerate(all_cells)}

    pos, counts, offsets, cap = points_for_cells(seed, grid, counter, all_cells)
    # (C, cap, 8) f32 blocks; padding rows are +inf so they never pass r^2
    blocks = np.full((len(all_cells), cap, 8), np.inf, dtype=np.float32)
    valid = np.arange(cap)[None, :] < counts[:, None]
    blocks[:, :, :dim] = np.where(valid[:, :, None], pos, np.inf).astype(np.float32)
    padded = jnp.asarray(blocks)
    r2 = radius * radius

    # kernel path: Pallas (TPU / interpret) or the jit'd jnp oracle.
    # On CPU the interpret-mode kernel is a correctness tool, not a
    # performance path — benchmarks and generators default to the oracle
    # there (identical results; kernel equivalence is asserted in tests).
    import jax as _jax
    use_ref = _jax.default_backend() == "cpu" and not force_kernel

    pairs_a, pairs_b = [], []
    for cell in local:
        ia = index_of[cell]
        for delta in _neighbor_offsets(dim, grid.rho):
            nb = tuple(c + o for c, o in zip(cell, delta))
            if not all(0 <= x < grid.g for x in nb):
                continue
            if all(o == 0 for o in delta):
                pairs_a.append(ia), pairs_b.append(ia)
                continue
            nb_local = nb in local_set
            if nb_local and not _is_forward(delta):
                continue  # local-local pair handled once, from the forward side
            pairs_a.append(ia), pairs_b.append(index_of[nb])

    edges_u, edges_v = [], []
    if pairs_a:
        A = padded[jnp.array(pairs_a)]
        B = padded[jnp.array(pairs_b)]
        if use_ref:
            fn = jax.jit(jax.vmap(lambda x, y: pairdist_mask_ref(x, y, r2, dim=dim)))
            masks = fn(A, B)
        else:
            masks = jax.vmap(lambda x, y: pairdist(x, y, r2, dim=dim, interpret=interpret))(A, B)
        masks = np.asarray(masks)
        for k, (ia, ib) in enumerate(zip(pairs_a, pairs_b)):
            mm = masks[k][: counts[ia], : counts[ib]]
            if ia == ib:
                mm = np.triu(mm, k=1)  # i < j within a cell
            ii, jj = np.nonzero(mm)
            if len(ii):
                edges_u.append(offsets[ia] + ii)
                edges_v.append(offsets[ib] + jj)

    if edges_u:
        edges = np.stack([np.concatenate(edges_u), np.concatenate(edges_v)], axis=1)
    else:
        edges = np.zeros((0, 2), dtype=np.int64)

    gids, positions = [], []
    for c in local:
        i = index_of[c]
        gids.append(np.arange(offsets[i], offsets[i] + counts[i]))
        positions.append(pos[i][: counts[i]])
    gids = np.concatenate(gids) if gids else np.zeros(0, np.int64)
    positions = np.concatenate(positions) if positions else np.zeros((0, dim))
    return edges, gids, positions


def grid_point_plan(seed: int, grid: CellGrid, counter: CellCounter, P: int,
                    rng_impl: str = "threefry2x32"):
    """PointPlan over a cube cell grid: every cell exactly once, dealt
    to PEs by Morton chunk (paper §5.1), keyed by cell id so the device
    stream is bit-identical to :func:`points_for_cells`.  Shared by RGG
    and RDG (which only differ in the grid's cell side); reseeding
    re-runs the counter recursion for the new seed against the same
    grid (RGG's :meth:`RggStructure.emit_points` is the fast path)."""
    import dataclasses as _dc

    from ..distrib.engine import POINTS_CUBE, make_point_plan

    base = device_key(seed, _TAG_PTS, impl=rng_impl)
    per_pe = []
    for pe in range(P):
        cells = local_cells_for_pe(grid, P, pe)
        ids = jnp.asarray([grid.cell_id(c) for c in cells], dtype=jnp.int64)
        kd = np.asarray(jax.vmap(jax.random.key_data)(fold_in_many(base, ids)))
        counts = np.array([counter.cell_count(c) for c in cells], np.int64)
        coords = np.asarray(cells, np.int64).reshape(len(cells), grid.dim)
        geom = np.ones((len(cells), 1), np.float64)
        per_pe.append((kd, counts, coords, geom))
    plan = make_point_plan(per_pe, POINTS_CUBE, scale=float(grid.g),
                           dim=grid.dim, rng_impl=rng_impl)
    n = counter.n

    def emit(s: int):
        return grid_point_plan(s, grid, CellCounter(s, grid, n), P, rng_impl)

    return _dc.replace(plan, reseed_fn=emit)


class RggStructure:
    """Seed-independent half of the RGG plan emitters.

    Everything except the binomial counts and the hashed cell keys — the
    split tree, the forward-canonical candidate-pair list, the Morton PE
    deal, the per-PE cell lists — is a pure function of
    (n, radius, chunk grid, P, dim).  :meth:`emit` / :meth:`emit_points`
    fill in the seed-dependent half fully vectorized: one split-tree
    replay plus one batched key dispatch plus numpy scatters, no
    per-pair host work.  The returned plans carry the emit methods as
    their ``reseed_fn``, so a plan-cache hit reseeds in a fraction of
    the cold emission cost (the serve plan cache's attack line (b)).
    """

    def __init__(self, n: int, radius: float, P: int, dim: int = 2,
                 rng_impl: str = "threefry2x32", chunk_P: int = 0):
        from ..distrib.engine import require_counter_rng

        require_counter_rng(rng_impl)
        self.n, self.radius, self.P, self.dim = int(n), float(radius), int(P), int(dim)
        self.rng_impl = rng_impl
        grid = make_grid(n, radius, chunk_P or P, dim)
        self.grid = grid
        self.tree = CellSplitTree(grid)
        g = grid.g
        # row-major cell coordinates (== np.ndindex order)
        coords = np.stack(np.meshgrid(*[np.arange(g, dtype=np.int64)] * dim,
                                      indexing="ij"), -1).reshape(g ** dim, dim)
        self._coords = coords
        self._coords_f = coords.astype(np.float64)
        cc = grid.cells_per_chunk_dim
        bits = grid.cpd.bit_length() - 1
        # batched morton_encode of each cell's chunk, bit-plane at a time
        chunk_of = coords // cc
        code = np.zeros(len(coords), np.int64)
        for b in range(bits):
            for d in range(dim):
                code |= ((chunk_of[:, d] >> b) & 1) << (b * dim + d)
        pe_of_cell = code % P
        # candidate pairs in the cold enumeration order: cells row-major,
        # self pair first, then forward deltas in _neighbor_offsets order
        forward = np.array(
            [d for d in _neighbor_offsets(dim, grid.rho) if _is_forward(d)],
            np.int64).reshape(-1, dim)
        deltas = np.concatenate([np.zeros((1, dim), np.int64), forward])
        nb = coords[:, None, :] + deltas[None, :, :]          # [N, D, dim]
        ok = ((nb >= 0) & (nb < g)).all(axis=-1)              # [N, D]
        strides = g ** np.arange(dim - 1, -1, -1, dtype=np.int64)
        nb_id = (nb * strides).sum(axis=-1)                   # row-major cell id
        N, D = ok.shape
        flat = ok.ravel()  # [N, D] row-major flatten = cell-major, delta-minor
        self._pa_i = np.repeat(np.arange(N, dtype=np.int64), D)[flat]
        self._pa_j = nb_id.ravel()[flat]
        self._pa_self = np.tile(np.arange(D) == 0, N)[flat]
        self._pa_pe = pe_of_cell[self._pa_i]
        self._fp = np.array([float(g), self.radius * self.radius], np.float64)
        # per-PE cell ids in local_cells_for_pe order (PointPlan layout):
        # chunks round-robin in Morton-code order, cells row-major within
        codes = np.arange(grid.cpd ** dim, dtype=np.int64)
        ch = np.zeros((len(codes), dim), np.int64)
        for b in range(bits):
            for d in range(dim):
                ch[:, d] |= ((codes >> (b * dim + d)) & 1) << b
        bc = np.stack(np.meshgrid(*[np.arange(cc, dtype=np.int64)] * dim,
                                  indexing="ij"), -1).reshape(cc ** dim, dim)
        strides = g ** np.arange(dim - 1, -1, -1, dtype=np.int64)
        cid = ((ch[:, None, :] * cc + bc[None, :, :]) * strides).sum(-1)
        self._local_ids = [cid[pe::P].reshape(-1) for pe in range(P)]

    def _keys(self, seed: int) -> np.ndarray:
        """Per-cell key data [num_cells, W], indexed by row-major cell id
        (== :meth:`CellGrid.cell_id`) — one batched fold_in dispatch."""
        base = device_key(seed, _TAG_PTS, impl=self.rng_impl)
        ids = jnp.arange(self.grid.num_cells, dtype=jnp.int64)
        return np.asarray(jax.vmap(jax.random.key_data)(fold_in_many(base, ids)))

    def emit(self, seed: int):
        """PairPlan for ``seed`` — bit-identical to the retired spec-list
        emission (same enumeration order, same table layout, same
        capacity rounding)."""
        import dataclasses as _dc

        from ..distrib.engine import GEOM_TORUS, PairPlan, make_pair_plan
        from .sampling import round_up_capacity

        counts, offsets = self.tree.counts_offsets(seed, self.n)
        ca = counts[self._pa_i]
        inc = (ca > 0) & np.where(self._pa_self, ca > 1,
                                  counts[self._pa_j] > 0)
        if not inc.any():
            plan = make_pair_plan([[] for _ in range(self.P)],
                                  rng_impl=self.rng_impl, dim=self.dim)
            return _dc.replace(plan, reseed_fn=self.emit)
        kd = self._keys(seed)
        ci, cj = self._pa_i[inc], self._pa_j[inc]
        selfp, pe = self._pa_self[inc], self._pa_pe[inc]
        k = ci.size
        # stable rank within each PE group = the per-PE append order
        order = np.argsort(pe, kind="stable")
        sorted_pe = pe[order]
        start = np.searchsorted(sorted_pe, np.arange(self.P))
        col = np.empty(k, np.int64)
        col[order] = np.arange(k, dtype=np.int64) - start[sorted_pe]
        P, dim = self.P, self.dim
        C = int(np.bincount(pe, minlength=P).max())
        W = kd.shape[-1]
        kind = np.zeros((P, C), np.int32)
        key_a = np.zeros((P, C, W), np.uint32)
        key_b = np.zeros((P, C, W), np.uint32)
        count_a = np.zeros((P, C), np.int64)
        count_b = np.zeros((P, C), np.int64)
        gid_a = np.zeros((P, C, 1), np.int64)
        gid_b = np.zeros((P, C, 1), np.int64)
        geom_a = np.ones((P, C, dim), np.float64)  # 1s: make_pair_plan padding
        geom_b = np.ones((P, C, dim), np.float64)
        fparams = np.zeros((P, C, 2), np.float64)
        self_pair = np.zeros((P, C), bool)
        active = np.zeros((P, C), bool)
        kind[pe, col] = GEOM_TORUS
        key_a[pe, col] = kd[ci]
        key_b[pe, col] = kd[cj]
        count_a[pe, col] = counts[ci]
        count_b[pe, col] = counts[cj]
        gid_a[pe, col, 0] = offsets[ci]
        gid_b[pe, col, 0] = offsets[cj]
        geom_a[pe, col] = self._coords_f[ci]
        geom_b[pe, col] = self._coords_f[cj]
        fparams[pe, col] = self._fp
        self_pair[pe, col] = selfp
        active[pe, col] = True
        cap = round_up_capacity(
            max(int(counts[ci].max()), int(counts[cj].max())), mult=8)
        return PairPlan(kind, key_a, key_b, count_a, count_b, gid_a, gid_b,
                        geom_a, geom_b, fparams, self_pair, active, cap,
                        dim, self.rng_impl, reseed_fn=self.emit)

    def emit_points(self, seed: int):
        """PointPlan for ``seed`` — bit-identical to
        :func:`grid_point_plan` over the same grid."""
        import dataclasses as _dc

        from ..distrib.engine import POINTS_CUBE, make_point_plan

        counts, _ = self.tree.counts_offsets(seed, self.n)
        kd = self._keys(seed)
        per_pe = [(kd[ids], counts[ids], self._coords[ids],
                   np.ones((len(ids), 1), np.float64))
                  for ids in self._local_ids]
        plan = make_point_plan(per_pe, POINTS_CUBE, scale=float(self.grid.g),
                               dim=self.dim, rng_impl=self.rng_impl)
        return _dc.replace(plan, reseed_fn=self.emit_points)


@lru_cache(maxsize=8)
def rgg_structure(n: int, radius: float, P: int, dim: int = 2,
                  rng_impl: str = "threefry2x32", chunk_P: int = 0) -> RggStructure:
    """Cached seed-independent :class:`RggStructure` — both cold and
    reseed emissions for a given shape share one instance, so the tree
    build is paid once per (n, radius, P, dim, impl, chunk grid)."""
    return RggStructure(n, radius, P, dim, rng_impl, chunk_P)


def rgg_point_plan(seed: int, n: int, radius: float, P: int, dim: int = 2,
                   rng_impl: str = "threefry2x32", chunk_P: int = 0):
    """PointPlan for the sharded engine over the RGG cell grid: the
    cached :class:`RggStructure` split-tree replay (bit-identical to the
    retained :func:`grid_point_plan` recursion over the same grid)."""
    from .. import obs

    with obs.trace("plan/rgg", phase="plan", family="rgg", reseed=False, P=P):
        return rgg_structure(n, radius, P, dim, rng_impl, chunk_P).emit_points(seed)


def rgg_pair_plan(seed: int, n: int, radius: float, P: int, dim: int = 2,
                  rng_impl: str = "threefry2x32", chunk_P: int = 0):
    """GEOM_TORUS PairPlan: every candidate cell pair exactly once.

    The forward-canonical enumeration of :func:`rgg_pe` made global:
    each cell pairs with itself and with its *forward* neighbors within
    ``rho`` rings, so every unordered cell pair within reach appears
    exactly once — the geometric analog of chunk ownership; per-PE
    outputs concatenate to the exact edge set with no dedup.  Rows are
    dealt to PEs by the Morton chunk that owns the pair's first cell
    (the same deal :func:`local_cells_for_pe` uses), so a PE streams the
    pairs of its own spatial region.

    The device regenerates both cells' points from hashed keys
    (bit-identical to the cube PointPlan / :func:`points_for_cells`
    stream) and runs the float32 r^2 test of the pairdist kernel, so
    the edge set matches the retired host loop exactly.  Empty cells
    emit no rows.  The pair list is a pure function of (seed, grid):
    identical for every P.

    Both cold emission and :meth:`~repro.distrib.engine.PairPlan.reseed`
    replay the cached :class:`RggStructure` — one split-tree pass, one
    batched key dispatch, numpy scatters.  The retired per-cell spec
    walk is retained as :func:`rgg_pair_plan_specs`, the table-layout
    oracle the vectorized path is tested against.
    """
    from .. import obs

    with obs.trace("plan/rgg", phase="plan", family="rgg", reseed=False, P=P):
        return rgg_structure(n, radius, P, dim, rng_impl, chunk_P).emit(seed)


def rgg_pair_plan_specs(seed: int, n: int, radius: float, P: int, dim: int = 2,
                        rng_impl: str = "threefry2x32", chunk_P: int = 0):
    """Retained oracle: the original per-cell spec-list emission of
    :func:`rgg_pair_plan`.  Defines the enumeration order and table
    layout the vectorized :meth:`RggStructure.emit` must reproduce
    bit-for-bit; not a production path."""
    import dataclasses as _dc

    from ..distrib.engine import GEOM_TORUS, PairSpec, make_pair_plan
    from .chunking import morton_encode

    grid = make_grid(n, radius, chunk_P or P, dim)
    counter = CellCounter(seed, grid, n)
    cells = [tuple(c) for c in np.ndindex(*([grid.g] * dim))]
    index_of = {c: i for i, c in enumerate(cells)}
    base = device_key(seed, _TAG_PTS, impl=rng_impl)
    ids = jnp.asarray([grid.cell_id(c) for c in cells], dtype=jnp.int64)
    kd = np.asarray(jax.vmap(jax.random.key_data)(fold_in_many(base, ids)))
    counts = np.array([counter.cell_count(c) for c in cells], np.int64)
    offsets = np.array([counter.cell_offset(c) for c in cells], np.int64)

    cc = grid.cells_per_chunk_dim
    bits = grid.cpd.bit_length() - 1
    fp = (float(grid.g), float(radius) * float(radius))
    forward = [d for d in _neighbor_offsets(dim, grid.rho) if _is_forward(d)]

    per_pe: List[List[PairSpec]] = [[] for _ in range(P)]
    for ci, cell in enumerate(cells):
        if counts[ci] == 0:
            continue
        pe = morton_encode(tuple(x // cc for x in cell), dim, bits) % P

        def pair(cj: int, self_pair: bool) -> PairSpec:
            return PairSpec(  # repro: allow(no-per-chunk-host-loop) retained oracle
                GEOM_TORUS, kd[ci], kd[cj], int(counts[ci]), int(counts[cj]),
                int(offsets[ci]), int(offsets[cj]),
                tuple(float(x) for x in cell),
                tuple(float(x) for x in cells[cj]),
                fparams=fp, self_pair=self_pair)

        if counts[ci] > 1:
            per_pe[pe].append(pair(ci, True))
        for delta in forward:
            nb = tuple(c + o for c, o in zip(cell, delta))
            if not all(0 <= x < grid.g for x in nb):
                continue
            cj = index_of[nb]
            if counts[cj]:
                per_pe[pe].append(pair(cj, False))
    plan = make_pair_plan(per_pe, rng_impl=rng_impl, dim=dim)
    structure = rgg_structure(n, radius, P, dim, rng_impl, chunk_P)
    return _dc.replace(plan, reseed_fn=structure.emit)


def rgg_union(seed: int, n: int, radius: float, P: int, dim: int = 2) -> np.ndarray:
    """Distinct undirected edge union over all PEs (canonical u>v)."""
    es = []
    for pe in range(P):
        e, _, _ = rgg_pe(seed, n, radius, P, pe, dim)
        es.append(e)
    e = np.concatenate(es, axis=0)
    if e.size == 0:
        return e.reshape(0, 2)
    u = np.maximum(e[:, 0], e[:, 1])
    v = np.minimum(e[:, 0], e[:, 1])
    return np.unique(np.stack([u, v], axis=1), axis=0)  # repro: allow(no-numpy-unique) test-oracle union (engine dedups by pair ownership)


def rgg_all_points(seed: int, n: int, radius: float, P: int, dim: int = 2):
    """Every vertex (gid-ordered) — oracle input for brute-force tests."""
    grid = make_grid(n, radius, P, dim)
    counter = CellCounter(seed, grid, n)
    cells = [tuple(c) for c in np.ndindex(*([grid.g] * dim))]
    pos, counts, offsets, cap = points_for_cells(seed, grid, counter, cells)
    out = np.zeros((n, dim))
    for i, c in enumerate(cells):
        out[offsets[i]: offsets[i] + counts[i]] = pos[i][: counts[i]]
    return out


def rgg_brute_edges(points: np.ndarray, radius: float) -> np.ndarray:
    d2 = ((points[:, None, :] - points[None, :, :]) ** 2).sum(-1)
    u, v = np.nonzero(np.tril(d2 <= radius * radius, k=-1))
    return np.stack([u, v], axis=1)
