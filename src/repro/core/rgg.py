"""Random geometric graphs in [0,1)^d, d in {2,3} (paper §5).

Communication-free parallelization: the unit cube is cut into a uniform
cell grid (cell side >= r when possible), cells are grouped into
2^(d*b) >= P Morton-ordered chunks, and per-cell vertex counts come from
a divide-and-conquer binomial recursion whose nodes are hashed — so any
PE can recompute any cell's vertices (its own *and* halo cells of
neighboring chunks) without communication.

Vertex ids are assigned in recursion order: the global id offset of a
cell is the sum of left-sibling counts along its root path, computable
in O(log #cells) by any PE — a consecutive, communication-free labeling.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Dict, Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.pairdist.ops import pairdist, pad_points
from ..kernels.pairdist.ref import pairdist_mask_ref
from .chunking import chunks_per_dim, cube_chunks_for_pe, morton_decode
from .prng import counter_uniform, device_key, fold_in_many, host_rng
from .variates import binomial

_TAG_SPLIT, _TAG_PTS = 21, 22

Box = Tuple[Tuple[int, int], ...]  # ((lo, hi), ...) in cell coordinates
Cell = Tuple[int, ...]


@dataclass(frozen=True)
class CellGrid:
    """Uniform cell grid aligned with the Morton chunk decomposition."""
    dim: int
    g: int          # cells per dimension
    cpd: int        # chunks per dimension (power of two)
    rho: int        # neighbor search range in cells (ceil(r * g))

    @property
    def cells_per_chunk_dim(self) -> int:
        return self.g // self.cpd

    @property
    def num_cells(self) -> int:
        return self.g ** self.dim

    def cell_id(self, cell: Cell) -> int:
        cid = 0
        for c in cell:
            cid = cid * self.g + int(c)
        return cid

    def chunk_cells(self, chunk: Cell) -> List[Cell]:
        cc = self.cells_per_chunk_dim
        ranges = [range(c * cc, (c + 1) * cc) for c in chunk]
        out: List[Cell] = []

        def rec(prefix, rest):
            if not rest:
                out.append(tuple(prefix))
                return
            for v in rest[0]:
                rec(prefix + [v], rest[1:])

        rec([], ranges)
        return out


def make_grid(n: int, radius: float, P: int, dim: int) -> CellGrid:
    """Cell side = max(r, n^-1/d) rounded to tile the chunk grid (§5)."""
    cpd = chunks_per_dim(P, dim)
    target = max(radius, n ** (-1.0 / dim))
    per_chunk = max(1, int(1.0 / (target * cpd)))
    g = cpd * per_chunk
    rho = max(1, math.ceil(radius * g - 1e-9))
    return CellGrid(dim=dim, g=g, cpd=cpd, rho=rho)


class CellCounter:
    """Divide-and-conquer per-cell vertex counts (hashed binomial splits).

    `count(box)` and `cell_offset(cell)` are pure functions of
    (seed, grid, n): every PE computing them agrees — the core
    communication-free invariant.  Memoized per instance.
    """

    def __init__(self, seed: int, grid: CellGrid, n: int):
        self.seed, self.grid, self.n = seed, grid, n
        root = tuple((0, grid.g) for _ in range(grid.dim))
        self._memo: Dict[Box, int] = {root: n}
        self._root = root

    @staticmethod
    def _volume(box: Box) -> int:
        v = 1
        for lo, hi in box:
            v *= hi - lo
        return v

    @staticmethod
    def _split(box: Box) -> Tuple[int, int, Box, Box]:
        """Halve the largest dim (ties -> lowest index); chunk-aligned."""
        widths = [hi - lo for lo, hi in box]
        d = int(np.argmax(widths))
        lo, hi = box[d]
        mid = (lo + hi) // 2
        left = box[:d] + ((lo, mid),) + box[d + 1:]
        right = box[:d] + ((mid, hi),) + box[d + 1:]
        return d, mid, left, right

    def count(self, box: Box) -> int:
        if box in self._memo:
            return self._memo[box]
        parent, path = self._parent_of(box)
        _, _, left, right = self._split(parent)
        cp = self.count(parent)
        rng = host_rng(self.seed, _TAG_SPLIT, *[x for lohi in parent for x in lohi])
        cl = binomial(rng, cp, self._volume(left) / self._volume(parent))
        self._memo[left] = cl
        self._memo[right] = cp - cl
        return self._memo[box]

    def _parent_of(self, box: Box) -> Tuple[Box, None]:
        """Walk down from the root until `box` is a child of the cursor."""
        cur = self._root
        while True:
            if cur == box:
                raise AssertionError("box is root")
            _, _, left, right = self._split(cur)
            if self._contains(left, box):
                if left == box:
                    return cur, None
                # force materialization of left count, then descend
                self._ensure_children(cur)
                cur = left
            elif self._contains(right, box):
                if right == box:
                    return cur, None
                self._ensure_children(cur)
                cur = right
            else:
                raise AssertionError(f"{box} not inside {cur}")

    def _ensure_children(self, parent: Box) -> None:
        _, _, left, right = self._split(parent)
        if left in self._memo:
            return
        cp = self.count(parent)
        rng = host_rng(self.seed, _TAG_SPLIT, *[x for lohi in parent for x in lohi])
        cl = binomial(rng, cp, self._volume(left) / self._volume(parent))
        self._memo[left] = cl
        self._memo[right] = cp - cl

    @staticmethod
    def _contains(outer: Box, inner: Box) -> bool:
        return all(ol <= il and ih <= oh for (ol, oh), (il, ih) in zip(outer, inner))

    def cell_count(self, cell: Cell) -> int:
        box = tuple((c, c + 1) for c in cell)
        cur = self._root
        while cur != box:
            self._ensure_children(cur)
            _, _, left, right = self._split(cur)
            cur = left if self._contains(left, box) else right
        return self._memo[box]

    def cell_offset(self, cell: Cell) -> int:
        """Global vertex-id offset: sum of left-sibling counts on the path."""
        box = tuple((c, c + 1) for c in cell)
        cur, off = self._root, 0
        while cur != box:
            self._ensure_children(cur)
            _, _, left, right = self._split(cur)
            if self._contains(left, box):
                cur = left
            else:
                off += self._memo[left]
                cur = right
        return off


# --------------------------------------------------------------------------
# device-side point generation
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cap", "dim", "g"))
def _points_for_cells(key, cell_ids, cell_coords, counts, cap: int, dim: int, g: int):
    """Uniform points inside each cell; (C, cap, dim) + mask (C, cap).

    Keyed by the *cell id* only, with capacity-independent per-slot
    draws — every PE regenerates identical points for the same cell no
    matter how its buffers are padded (the halo-recomputation
    invariant)."""
    def one(cid, coord, cnt):
        k = jax.random.fold_in(key, cid)
        u = counter_uniform(k, cap, dim)
        pos = (coord.astype(jnp.float64) + u) / g
        return pos, jnp.arange(cap) < cnt

    return jax.vmap(one)(cell_ids, cell_coords, counts)


def points_for_cells(
    seed: int, grid: CellGrid, counter: CellCounter, cells: Sequence[Cell],
    rng_impl: str | None = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """(positions (C,cap,dim) f64, counts (C,), gid offsets (C,), cap).

    ``rng_impl`` selects the key implementation so point consumers can
    follow the same hashed stream a non-default-impl plan regenerates
    on device (None = the default threefry stream)."""
    counts = np.array([counter.cell_count(c) for c in cells], dtype=np.int64)
    offsets = np.array([counter.cell_offset(c) for c in cells], dtype=np.int64)
    cap = max(1, int(counts.max()) if len(counts) else 1)
    cap = (cap + 127) // 128 * 128  # kernel block multiple
    ids = jnp.array([grid.cell_id(c) for c in cells], dtype=jnp.int64)
    coords = jnp.array(cells, dtype=jnp.int64)
    pos, mask = _points_for_cells(
        device_key(seed, _TAG_PTS, impl=rng_impl), ids, coords, jnp.array(counts),
        cap, grid.dim, grid.g
    )
    return np.asarray(pos), counts, offsets, cap


# --------------------------------------------------------------------------
# per-PE generation
# --------------------------------------------------------------------------

def _neighbor_offsets(dim: int, rho: int) -> List[Cell]:
    rng = range(-rho, rho + 1)
    if dim == 2:
        return [(a, b) for a in rng for b in rng]
    return [(a, b, c) for a in rng for b in rng for c in rng]


def _is_forward(delta: Cell) -> bool:
    for x in delta:
        if x != 0:
            return x > 0
    return False  # zero offset


def local_cells_for_pe(grid: CellGrid, P: int, pe: int) -> List[Cell]:
    """Cells of PE `pe`: the grid's Morton chunks dealt round-robin.

    The chunk grid comes from ``grid.cpd`` (not from P), so a grid built
    for a fixed virtual chunk count yields the identical instance on any
    number of PEs."""
    cells: List[Cell] = []
    for ch in cube_chunks_for_pe(P, grid.dim, pe, cpd=grid.cpd):
        cells.extend(grid.chunk_cells(ch))
    return cells


def rgg_pe(
    seed: int, n: int, radius: float, P: int, pe: int, dim: int = 2,
    interpret: bool = True, force_kernel: bool = False, chunk_P: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All edges incident to PE `pe`'s vertices — the per-PE *host loop*.

    Retired as the production edge phase (the engine executes
    :func:`rgg_pair_plan` on device instead); kept as the independent
    test oracle the PairPlan path is checked against, and as the paper's
    literal §5.1 protocol: halo cells of neighboring chunks are
    recomputed locally, never communicated.

    Returns (edges [k,2] global ids, local vertex gids, local positions).
    ``chunk_P`` sizes the virtual chunk grid independently of P (the
    instance is a function of the grid; default: the legacy P-coupled
    grid).
    """
    grid = make_grid(n, radius, chunk_P or P, dim)
    counter = CellCounter(seed, grid, n)
    local = local_cells_for_pe(grid, P, pe)
    local_set = set(local)

    # halo = cells within rho of any local cell, not local themselves
    halo: set = set()
    for cell in local:
        for d in _neighbor_offsets(dim, grid.rho):
            nb = tuple(c + o for c, o in zip(cell, d))
            if all(0 <= x < grid.g for x in nb) and nb not in local_set:
                halo.add(nb)
    all_cells = list(local) + sorted(halo)
    index_of = {c: i for i, c in enumerate(all_cells)}

    pos, counts, offsets, cap = points_for_cells(seed, grid, counter, all_cells)
    # (C, cap, 8) f32 blocks; padding rows are +inf so they never pass r^2
    blocks = np.full((len(all_cells), cap, 8), np.inf, dtype=np.float32)
    valid = np.arange(cap)[None, :] < counts[:, None]
    blocks[:, :, :dim] = np.where(valid[:, :, None], pos, np.inf).astype(np.float32)
    padded = jnp.asarray(blocks)
    r2 = radius * radius

    # kernel path: Pallas (TPU / interpret) or the jit'd jnp oracle.
    # On CPU the interpret-mode kernel is a correctness tool, not a
    # performance path — benchmarks and generators default to the oracle
    # there (identical results; kernel equivalence is asserted in tests).
    import jax as _jax
    use_ref = _jax.default_backend() == "cpu" and not force_kernel

    pairs_a, pairs_b = [], []
    for cell in local:
        ia = index_of[cell]
        for delta in _neighbor_offsets(dim, grid.rho):
            nb = tuple(c + o for c, o in zip(cell, delta))
            if not all(0 <= x < grid.g for x in nb):
                continue
            if all(o == 0 for o in delta):
                pairs_a.append(ia), pairs_b.append(ia)
                continue
            nb_local = nb in local_set
            if nb_local and not _is_forward(delta):
                continue  # local-local pair handled once, from the forward side
            pairs_a.append(ia), pairs_b.append(index_of[nb])

    edges_u, edges_v = [], []
    if pairs_a:
        A = padded[jnp.array(pairs_a)]
        B = padded[jnp.array(pairs_b)]
        if use_ref:
            fn = jax.jit(jax.vmap(lambda x, y: pairdist_mask_ref(x, y, r2, dim=dim)))
            masks = fn(A, B)
        else:
            masks = jax.vmap(lambda x, y: pairdist(x, y, r2, dim=dim, interpret=interpret))(A, B)
        masks = np.asarray(masks)
        for k, (ia, ib) in enumerate(zip(pairs_a, pairs_b)):
            mm = masks[k][: counts[ia], : counts[ib]]
            if ia == ib:
                mm = np.triu(mm, k=1)  # i < j within a cell
            ii, jj = np.nonzero(mm)
            if len(ii):
                edges_u.append(offsets[ia] + ii)
                edges_v.append(offsets[ib] + jj)

    if edges_u:
        edges = np.stack([np.concatenate(edges_u), np.concatenate(edges_v)], axis=1)
    else:
        edges = np.zeros((0, 2), dtype=np.int64)

    gids, positions = [], []
    for c in local:
        i = index_of[c]
        gids.append(np.arange(offsets[i], offsets[i] + counts[i]))
        positions.append(pos[i][: counts[i]])
    gids = np.concatenate(gids) if gids else np.zeros(0, np.int64)
    positions = np.concatenate(positions) if positions else np.zeros((0, dim))
    return edges, gids, positions


def grid_point_plan(seed: int, grid: CellGrid, counter: CellCounter, P: int,
                    rng_impl: str = "threefry2x32"):
    """PointPlan over a cube cell grid: every cell exactly once, dealt
    to PEs by Morton chunk (paper §5.1), keyed by cell id so the device
    stream is bit-identical to :func:`points_for_cells`.  Shared by RGG
    and RDG (which only differ in the grid's cell side)."""
    from ..distrib.engine import POINTS_CUBE, make_point_plan

    base = device_key(seed, _TAG_PTS, impl=rng_impl)
    per_pe = []
    for pe in range(P):
        cells = local_cells_for_pe(grid, P, pe)
        ids = jnp.asarray([grid.cell_id(c) for c in cells], dtype=jnp.int64)
        kd = np.asarray(jax.vmap(jax.random.key_data)(fold_in_many(base, ids)))
        counts = np.array([counter.cell_count(c) for c in cells], np.int64)
        coords = np.asarray(cells, np.int64).reshape(len(cells), grid.dim)
        geom = np.ones((len(cells), 1), np.float64)
        per_pe.append((kd, counts, coords, geom))
    return make_point_plan(per_pe, POINTS_CUBE, scale=float(grid.g), dim=grid.dim,
                           rng_impl=rng_impl)


def rgg_point_plan(seed: int, n: int, radius: float, P: int, dim: int = 2,
                   rng_impl: str = "threefry2x32", chunk_P: int = 0):
    """PointPlan for the sharded engine over the RGG cell grid."""
    grid = make_grid(n, radius, chunk_P or P, dim)
    return grid_point_plan(seed, grid, CellCounter(seed, grid, n), P, rng_impl)


def rgg_pair_plan(seed: int, n: int, radius: float, P: int, dim: int = 2,
                  rng_impl: str = "threefry2x32", chunk_P: int = 0):
    """GEOM_TORUS PairPlan: every candidate cell pair exactly once.

    The forward-canonical enumeration of :func:`rgg_pe` made global:
    each cell pairs with itself and with its *forward* neighbors within
    ``rho`` rings, so every unordered cell pair within reach appears
    exactly once — the geometric analog of chunk ownership; per-PE
    outputs concatenate to the exact edge set with no dedup.  Rows are
    dealt to PEs by the Morton chunk that owns the pair's first cell
    (the same deal :func:`local_cells_for_pe` uses), so a PE streams the
    pairs of its own spatial region.

    The device regenerates both cells' points from hashed keys
    (bit-identical to the cube PointPlan / :func:`points_for_cells`
    stream) and runs the float32 r^2 test of the pairdist kernel, so
    the edge set matches the retired host loop exactly.  Empty cells
    emit no rows.  The pair list is a pure function of (seed, grid):
    identical for every P.
    """
    from ..distrib.engine import GEOM_TORUS, PairSpec, make_pair_plan
    from .chunking import morton_encode

    grid = make_grid(n, radius, chunk_P or P, dim)
    counter = CellCounter(seed, grid, n)
    cells = [tuple(c) for c in np.ndindex(*([grid.g] * dim))]
    index_of = {c: i for i, c in enumerate(cells)}
    base = device_key(seed, _TAG_PTS, impl=rng_impl)
    ids = jnp.asarray([grid.cell_id(c) for c in cells], dtype=jnp.int64)
    kd = np.asarray(jax.vmap(jax.random.key_data)(fold_in_many(base, ids)))
    counts = np.array([counter.cell_count(c) for c in cells], np.int64)
    offsets = np.array([counter.cell_offset(c) for c in cells], np.int64)

    cc = grid.cells_per_chunk_dim
    bits = grid.cpd.bit_length() - 1
    fp = (float(grid.g), float(radius) * float(radius))
    forward = [d for d in _neighbor_offsets(dim, grid.rho) if _is_forward(d)]

    per_pe: List[List[PairSpec]] = [[] for _ in range(P)]
    for ci, cell in enumerate(cells):
        if counts[ci] == 0:
            continue
        pe = morton_encode(tuple(x // cc for x in cell), dim, bits) % P

        def pair(cj: int, self_pair: bool) -> PairSpec:
            return PairSpec(
                GEOM_TORUS, kd[ci], kd[cj], int(counts[ci]), int(counts[cj]),
                int(offsets[ci]), int(offsets[cj]),
                tuple(float(x) for x in cell),
                tuple(float(x) for x in cells[cj]),
                fparams=fp, self_pair=self_pair)

        if counts[ci] > 1:
            per_pe[pe].append(pair(ci, True))
        for delta in forward:
            nb = tuple(c + o for c, o in zip(cell, delta))
            if not all(0 <= x < grid.g for x in nb):
                continue
            cj = index_of[nb]
            if counts[cj]:
                per_pe[pe].append(pair(cj, False))
    return make_pair_plan(per_pe, rng_impl=rng_impl, dim=dim)


def rgg_union(seed: int, n: int, radius: float, P: int, dim: int = 2) -> np.ndarray:
    """Distinct undirected edge union over all PEs (canonical u>v)."""
    es = []
    for pe in range(P):
        e, _, _ = rgg_pe(seed, n, radius, P, pe, dim)
        es.append(e)
    e = np.concatenate(es, axis=0)
    if e.size == 0:
        return e.reshape(0, 2)
    u = np.maximum(e[:, 0], e[:, 1])
    v = np.minimum(e[:, 0], e[:, 1])
    return np.unique(np.stack([u, v], axis=1), axis=0)  # repro: allow(no-numpy-unique) test-oracle union (engine dedups by pair ownership)


def rgg_all_points(seed: int, n: int, radius: float, P: int, dim: int = 2):
    """Every vertex (gid-ordered) — oracle input for brute-force tests."""
    grid = make_grid(n, radius, P, dim)
    counter = CellCounter(seed, grid, n)
    cells = [tuple(c) for c in np.ndindex(*([grid.g] * dim))]
    pos, counts, offsets, cap = points_for_cells(seed, grid, counter, cells)
    out = np.zeros((n, dim))
    for i, c in enumerate(cells):
        out[offsets[i]: offsets[i] + counts[i]] = pos[i][: counts[i]]
    return out


def rgg_brute_edges(points: np.ndarray, radius: float) -> np.ndarray:
    d2 = ((points[:, None, :] - points[None, :, :]) ** 2).sum(-1)
    u, v = np.nonzero(np.tril(d2 <= radius * radius, k=-1))
    return np.stack([u, v], axis=1)
