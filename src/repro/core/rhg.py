"""Threshold random hyperbolic graphs (paper §7).

Partition (Fig. 3): a central *core* disk [0, R/2] (the paper's merged
clique annuli — any two points with r <= R/2 are adjacent), plus
equal-height concentric annuli over [R/2, R].  Each annulus is split
angularly into P chunks and further into equal-width cells holding an
expected constant number of vertices.

Communication-free plan: per-annulus counts are a multinomial drawn via
dependent binomials (§7.1); within an annulus, per-cell counts come from
a hashed 1-D binomial recursion (`RangeCounter`).  Any PE can regenerate
any cell bit-identically, so neighborhood queries recompute remote cells
instead of communicating (inward/outward queries).

Adjacency tests use the trig-free precompute (§7.2.1, Eq. 9) evaluated
by the `hypdist` Pallas kernel; candidate windows per (vertex, annulus)
use the Δθ bound (Eq. 8) whose overestimation is bounded by OE(·) ≤ √e
(Cor. 11) — so candidate work stays O(m).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..kernels.hypdist.ops import (
    FEAT,
    cosh_threshold,
    hypdist,
    pad_features,
    precompute_features,
)
from ..kernels.hypdist.ref import hypdist_mask_ref

import jax as _jax
import jax.numpy as _jnp

_ref_jit = None


def _hyp_ref(q, c, cosh_r):
    global _ref_jit
    if _ref_jit is None:
        import jax
        _ref_jit = jax.jit(hypdist_mask_ref)
    return _ref_jit(_jnp.asarray(q), _jnp.asarray(c), cosh_r)
from .prng import (PhiloxReplayer, device_key, fold_in_many, hash_paths,
                   host_rng)
from .variates import binomial, multinomial_split

_TAG_ANN, _TAG_CELLS, _TAG_V = 31, 32, 33
_TAG_V_DEV = 34  # device-side vertex stream (sharded engine)
_TAG_V_ENG = 35  # device vertex stream, P-independent engine cell layout
_TAG_CELLS_ENG = 36  # RangeCounter streams for the engine cell layout
_CELL_OCC = 8  # expected vertices per cell (paper's tuning constant)


@dataclass(frozen=True)
class RHGParams:
    n: int
    avg_deg: float
    gamma: float
    seed: int

    @property
    def alpha(self) -> float:
        return (self.gamma - 1.0) / 2.0

    @property
    def C(self) -> float:
        xi = self.alpha / (self.alpha - 0.5)
        return -2.0 * math.log(self.avg_deg * math.pi / (2.0 * xi * xi))

    @property
    def R(self) -> float:
        return 2.0 * math.log(self.n) + self.C


def expected_tail_exponent(params: RHGParams) -> float:
    """Degree-distribution power-law exponent: 2*alpha + 1 == gamma.

    Gugelmann et al.: the threshold RHG degree sequence follows a power
    law with exponent 2*alpha + 1, which the alpha = (gamma-1)/2
    parametrization pins to the requested gamma — the closed-form law
    repro.stats validates fitted tail exponents against (paper §7).
    """
    return 2.0 * params.alpha + 1.0


def expected_avg_degree(params: RHGParams) -> float:
    """Expected average degree: the constant C (Eq. 4) is calibrated as
    C = -2 ln(avg_deg * pi / (2 xi^2)), the inverse of the asymptotic
    mean-degree formula — so the model's expectation *is* the requested
    ``avg_deg`` (up to o(1) finite-size terms)."""
    return float(params.avg_deg)


def _cdf(params: RHGParams, r: float) -> float:
    """mu(B_r(0)) = (cosh(alpha r) - 1)/(cosh(alpha R) - 1)  (Eq. A.2)."""
    a = params.alpha
    return (math.cosh(a * r) - 1.0) / (math.cosh(a * params.R) - 1.0)


def _inv_cdf_interval(params: RHGParams, lo: float, hi: float, u: np.ndarray) -> np.ndarray:
    """Inverse radial CDF restricted to [lo, hi)."""
    a = params.alpha
    clo, chi = np.cosh(a * lo), np.cosh(a * hi)
    return np.arccosh(clo + u * (chi - clo)) / a


def annuli_boundaries(params: RHGParams) -> np.ndarray:
    """[R/2 = l_0 < l_1 < ... < l_k = R], constant height ~ ln2/alpha."""
    half = params.R / 2.0
    k = max(1, int(params.alpha * half / math.log(2.0)))
    return half + np.arange(k + 1) * (half / k)


def region_counts(params: RHGParams) -> Tuple[int, np.ndarray, np.ndarray]:
    """(core count, per-annulus counts, boundaries) — identical on all PEs."""
    bounds = annuli_boundaries(params)
    probs = [_cdf(params, bounds[0])]
    for i in range(len(bounds) - 1):
        probs.append(_cdf(params, bounds[i + 1]) - _cdf(params, bounds[i]))
    probs = np.asarray(probs)
    counts = multinomial_split(host_rng(params.seed, _TAG_ANN), params.n, probs)
    return int(counts[0]), counts[1:], bounds


class RangeCounter:
    """1-D hashed binomial recursion over [0, units): per-cell counts and
    recursion-order (== angular-order) vertex-id offsets."""

    def __init__(self, seed: int, tag: int, annulus: int, units: int, total: int):
        self.seed, self.tag, self.annulus, self.units = seed, tag, annulus, units
        self._memo: Dict[Tuple[int, int], int] = {(0, units): total}

    def _children(self, lo: int, hi: int) -> Tuple[int, int]:
        mid = (lo + hi) // 2
        key_l = (lo, mid)
        if key_l not in self._memo:
            cp = self.count(lo, hi)
            rng = host_rng(self.seed, self.tag, self.annulus, lo, hi)
            cl = binomial(rng, cp, (mid - lo) / (hi - lo))
            self._memo[key_l] = cl
            self._memo[(mid, hi)] = cp - cl
        return self._memo[key_l], self._memo[(mid, hi)]

    def count(self, lo: int, hi: int) -> int:
        if (lo, hi) in self._memo:
            return self._memo[(lo, hi)]
        # descend from the smallest memoized ancestor
        clo, chi = 0, self.units
        while (clo, chi) != (lo, hi):
            mid = (clo + chi) // 2
            self._children(clo, chi)
            if hi <= mid:
                chi = mid
            elif lo >= mid:
                clo = mid
            else:
                raise AssertionError("query range must align with recursion")
        return self._memo[(lo, hi)]

    def cell_count(self, i: int) -> int:
        return self.count(i, i + 1)

    def cell_offset(self, i: int) -> int:
        clo, chi, off = 0, self.units, 0
        while chi - clo > 1:
            mid = (clo + chi) // 2
            left, _ = self._children(clo, chi)
            if i < mid:
                chi = mid
            else:
                off += left
                clo = mid
        return off


def _range_table(seed: int, tag: int, annulus: int, units: int,
                 total: int) -> Tuple[np.ndarray, np.ndarray]:
    """Level-synchronous replay of the :class:`RangeCounter` recursion:
    (per-cell counts, per-cell vertex-id offsets) over [0, units).

    Every interval's split draw comes from its own hashed generator
    (``host_rng(seed, tag, annulus, lo, hi)``), so the draws can be
    replayed level by level — one batched :func:`hash_paths` per level
    plus the identical scalar Binomials — and remain bit-identical to
    the memoized descent for every cell."""
    cnt_cells = np.zeros(units, np.int64)
    off_cells = np.zeros(units, np.int64)
    lo = np.array([0], np.int64)
    hi = np.array([units], np.int64)
    cnt = np.array([total], np.int64)
    off = np.array([0], np.int64)
    rep = PhiloxReplayer()
    while True:
        leaf = (hi - lo) == 1
        if leaf.any():
            cnt_cells[lo[leaf]] = cnt[leaf]
            off_cells[lo[leaf]] = off[leaf]
        keep = ~leaf
        if not keep.any():
            return cnt_cells, off_cells
        plo, phi = lo[keep], hi[keep]
        pc, po = cnt[keep], off[keep]
        mid = (plo + phi) // 2
        m = len(plo)
        paths = np.stack([np.full(m, tag, np.int64),
                          np.full(m, annulus, np.int64), plo, phi], axis=1)
        hashes = hash_paths(seed, paths)
        cl = np.empty(m, np.int64)
        for i in range(m):
            c = int(pc[i])
            if c:  # binomial(rng, 0, p) == 0 without consuming draws
                cl[i] = binomial(rep.at(hashes[i]), c,
                                 (int(mid[i]) - int(plo[i]))
                                 / (int(phi[i]) - int(plo[i])))
            else:
                cl[i] = 0
        lo = np.empty(2 * m, np.int64)
        hi = np.empty(2 * m, np.int64)
        cnt = np.empty(2 * m, np.int64)
        off = np.empty(2 * m, np.int64)
        lo[0::2], hi[0::2], cnt[0::2], off[0::2] = plo, mid, cl, po
        lo[1::2], hi[1::2], cnt[1::2], off[1::2] = mid, phi, pc - cl, po + cl


@dataclass
class _Annulus:
    idx: int
    lo: float
    hi: float
    count: int
    cells: int          # U_b, a multiple of P
    counter: RangeCounter
    gid0: int           # global id offset of this annulus

    @property
    def cell_width(self) -> float:
        return 2.0 * math.pi / self.cells


class RHGPlan:
    """Shared deterministic plan — every PE derives the identical one."""

    def __init__(self, params: RHGParams, P: int):
        self.params, self.P = params, P
        self.n_core, ann_counts, self.bounds = region_counts(params)
        self.annuli: List[_Annulus] = []
        gid = self.n_core
        for b, cnt in enumerate(ann_counts):
            cells = P * max(1, int(cnt) // (_CELL_OCC * P))
            ctr = RangeCounter(params.seed, _TAG_CELLS, b, cells, int(cnt))
            self.annuli.append(
                _Annulus(b, float(self.bounds[b]), float(self.bounds[b + 1]),
                         int(cnt), cells, ctr, gid)
            )
            gid += int(cnt)

    # ---------------- vertex generation (hash-keyed, recomputable) --------

    def core_vertices(self) -> Tuple[np.ndarray, np.ndarray]:
        rng = host_rng(self.params.seed, _TAG_V, -1, 0)
        u = rng.random(self.n_core)
        theta = rng.random(self.n_core) * 2.0 * math.pi
        r = _inv_cdf_interval(self.params, 0.0, self.params.R / 2.0, u)
        return r, theta

    def cell_vertices(self, b: int, cell: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """(radii, angles, gid0) of one cell — identical from any PE."""
        ann = self.annuli[b]
        cnt = ann.counter.cell_count(cell)
        rng = host_rng(self.params.seed, _TAG_V, b, cell)
        u = rng.random(cnt)
        theta = (cell + rng.random(cnt)) * ann.cell_width
        r = _inv_cdf_interval(self.params, ann.lo, ann.hi, u)
        return r, theta, ann.gid0 + ann.counter.cell_offset(cell)


def delta_theta(r: np.ndarray, ell: float, R: float) -> np.ndarray:
    """Max angular deviation for a neighbor at radius >= ell (Eq. A.3)."""
    r = np.asarray(r, np.float64)
    with np.errstate(invalid="ignore", divide="ignore"):
        arg = (np.cosh(r) * math.cosh(ell) - math.cosh(R)) / (np.sinh(r) * math.sinh(ell))
    out = np.where(r + ell < R, math.pi, np.arccos(np.clip(arg, -1.0, 1.0)))
    return out


def _adjacency(q_feat: np.ndarray, c_feat: np.ndarray, cosh_r: float,
               interpret: bool = True) -> np.ndarray:
    """Edge mask via the hypdist kernel (padded to 128 blocks).

    On CPU the jit'd jnp oracle is used (bit-identical to the kernel,
    asserted in tests); the Pallas path runs on TPU / interpret mode."""
    qp = pad_features(q_feat)
    cp = pad_features(c_feat)
    if _jax.default_backend() == "cpu":
        mask = np.asarray(_hyp_ref(qp, cp, cosh_r))
    else:
        mask = np.asarray(hypdist(qp, cp, cosh_r, interpret=interpret))
    return mask[: len(q_feat), : len(c_feat)].astype(bool)


def rhg_pe(
    params: RHGParams, P: int, pe: int, interpret: bool = True,
    batch: int = 512,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """All edges incident to PE `pe`'s vertices, communication-free.

    Returns (edges [k,2], local gids, local radii, local angles).
    """
    plan = RHGPlan(params, P)
    R, coshR = params.R, cosh_threshold(params.R)
    chunk_lo, chunk_hi = pe * 2 * math.pi / P, (pe + 1) * 2 * math.pi / P

    # ---- core (recomputed redundantly on every PE, paper §7.1) ----------
    core_r, core_theta = plan.core_vertices()
    core_feat = precompute_features(core_r, core_theta)
    core_gids = np.arange(plan.n_core)
    core_local = (core_theta >= chunk_lo) & (core_theta < chunk_hi)

    # ---- local vertices per annulus -------------------------------------
    local: Dict[int, Tuple[np.ndarray, ...]] = {}
    for ann in plan.annuli:
        cpc = ann.cells // P
        rs, ts, gs = [], [], []
        for cell in range(pe * cpc, (pe + 1) * cpc):
            r, t, g0 = plan.cell_vertices(ann.idx, cell)
            rs.append(r), ts.append(t), gs.append(g0 + np.arange(len(r)))
        r = np.concatenate(rs) if rs else np.zeros(0)
        t = np.concatenate(ts) if ts else np.zeros(0)
        g = np.concatenate(gs) if gs else np.zeros(0, np.int64)
        local[ann.idx] = (r, t, g)

    edges_u: List[np.ndarray] = []
    edges_v: List[np.ndarray] = []

    def emit(mask: np.ndarray, qg: np.ndarray, cg: np.ndarray):
        ii, jj = np.nonzero(mask)
        if len(ii):
            u, v = qg[ii], cg[jj]
            keep = u != v
            edges_u.append(u[keep])
            edges_v.append(v[keep])

    # ---- core-core: a clique by the triangle inequality (r_u + r_v < R),
    # but checked through the same Eq. 9 path so borderline float rounding
    # can never disagree with the oracle/other PEs.
    if plan.n_core > 1 and core_local.any():
        m = _adjacency(core_feat[core_local], core_feat, coshR, interpret)
        emit(m, core_gids[core_local], core_gids)

    # ---- queries: local vertices (incl. owned core) vs every region ----
    query_sets = [(core_r[core_local], core_theta[core_local], core_gids[core_local])]
    query_sets += [local[a] for a in local]

    # cache of regenerated remote cells per annulus
    cell_cache: Dict[Tuple[int, int], Tuple[np.ndarray, np.ndarray, int]] = {}

    def get_cell(b: int, cell: int):
        key = (b, cell)
        if key not in cell_cache:
            cell_cache[key] = plan.cell_vertices(b, cell)
        return cell_cache[key]

    for (qr, qt, qg) in query_sets:
        if len(qr) == 0:
            continue
        q_feat_all = precompute_features(qr, qt)

        # vs core candidates (inward query; no window needed — core is tiny)
        if plan.n_core > 0:
            for s in range(0, len(qr), batch):
                sl = slice(s, s + batch)
                emit(_adjacency(q_feat_all[sl], core_feat, coshR, interpret), qg[sl], core_gids)

        # vs each annulus (inward + outward unified)
        for ann in plan.annuli:
            if ann.count == 0:
                continue
            dth = delta_theta(qr, ann.lo, R)
            w = ann.cell_width
            lo_cell = np.floor((qt - dth) / w).astype(np.int64)
            hi_cell = np.floor((qt + dth) / w).astype(np.int64)
            span = np.minimum(hi_cell - lo_cell + 1, ann.cells)
            L = int(span.max())
            for s in range(0, len(qr), batch):
                sl = slice(s, s + batch)
                q_feat = q_feat_all[sl]
                cand_feats, cand_gids = [], []
                # gather candidate cells for this batch (dedup per batch)
                needed = {}
                for qi in range(*sl.indices(len(qr))):
                    for j in range(int(span[qi])):
                        c = (lo_cell[qi] + j) % ann.cells
                        needed[c] = True
                for c in needed:
                    r, t, g0 = get_cell(ann.idx, int(c))
                    if len(r):
                        cand_feats.append(precompute_features(r, t))
                        cand_gids.append(g0 + np.arange(len(r)))
                if not cand_feats:
                    continue
                c_feat = np.concatenate(cand_feats)
                c_gid = np.concatenate(cand_gids)
                emit(_adjacency(q_feat, c_feat, coshR, interpret), qg[sl], c_gid)

    if edges_u:
        e = np.stack([np.concatenate(edges_u), np.concatenate(edges_v)], axis=1)
        u = np.maximum(e[:, 0], e[:, 1])
        v = np.minimum(e[:, 0], e[:, 1])
        e = np.unique(np.stack([u, v], axis=1), axis=0)  # repro: allow(no-numpy-unique) test-oracle union (engine dedups by pair ownership)
    else:
        e = np.zeros((0, 2), dtype=np.int64)

    lg = [core_gids[core_local]] + [local[a][2] for a in local]
    lr = [core_r[core_local]] + [local[a][0] for a in local]
    lt = [core_theta[core_local]] + [local[a][1] for a in local]
    return e, np.concatenate(lg), np.concatenate(lr), np.concatenate(lt)


def rhg_point_plan(params: RHGParams, P: int):
    """PointPlan for the sharded engine: every annulus cell exactly once.

    Cell geometry, per-cell counts and gid offsets are the host
    ``RHGPlan`` tables (so counts match the reference bit-for-bit); the
    (r, theta) draws come from the device-side fold_in stream keyed on
    (annulus, cell) — distribution-identical to the host Philox path and
    recomputable by any PE, which is the communication-free invariant.
    """
    from ..distrib.engine import POINTS_POLAR, make_point_plan

    plan = RHGPlan(params, P)
    a = params.alpha
    base = device_key(params.seed, _TAG_V_DEV)
    per_pe = []
    for pe in range(P):
        kds, counts, cells, geoms = [], [], [], []
        for ann in plan.annuli:
            cpc = ann.cells // P
            lo_cell, hi_cell = pe * cpc, (pe + 1) * cpc
            if hi_cell == lo_cell:
                continue
            ann_key = _jax.random.fold_in(base, ann.idx)
            ids = _jnp.arange(lo_cell, hi_cell, dtype=_jnp.int64)
            kds.append(np.asarray(_jax.vmap(_jax.random.key_data)(fold_in_many(ann_key, ids))))
            counts.extend(ann.counter.cell_count(c) for c in range(lo_cell, hi_cell))
            cells.extend((ann.idx, c) for c in range(lo_cell, hi_cell))
            geoms.extend(
                (math.cosh(a * ann.lo), math.cosh(a * ann.hi), ann.cell_width)
                for _ in range(lo_cell, hi_cell)
            )
        kd = np.concatenate(kds, axis=0) if kds else np.zeros((0, 2), np.uint32)
        per_pe.append((
            kd,
            np.asarray(counts, np.int64),
            np.asarray(cells, np.int64).reshape(len(counts), 2),
            np.asarray(geoms, np.float64).reshape(len(counts), 3),
        ))
    out = make_point_plan(per_pe, POINTS_POLAR, scale=a, dim=2)
    # RHG structure (annuli, cells-per-ring) is itself seed-dependent
    # (multinomial region counts size the cell grids): reseed re-emits
    return dataclasses.replace(
        out, reseed_fn=lambda s: rhg_point_plan(
            dataclasses.replace(params, seed=s), P))


# --------------------------------------------------------------------------
# engine cell layout + edge (candidate-pair) plan for distrib.engine
# --------------------------------------------------------------------------
#
# The per-PE reference generator above couples its cell grid to P
# (`cells = P * max(1, cnt // (_CELL_OCC * P))`), so its output is only
# comparable at a fixed P.  The engine layout below is *P-independent*:
# the same annuli, region counts and cells for every P, with P only
# deciding which PE executes which cell/pair — so `api.generate` yields
# the identical edge set on 1, 2 or 4096 PEs.  The core disk is one
# more "cell" (index 0, angular width 2*pi), putting the whole vertex
# set on the device-side hashed stream.

@dataclass(frozen=True)
class EngineCell:
    """One cell of the P-independent device layout."""
    ring: int       # 0 = core disk, 1 + b for annulus b
    cell: int       # angular index within the ring
    clo: float      # cosh(alpha * r_lo)
    chi: float      # cosh(alpha * r_hi)
    width: float    # angular cell width
    count: int
    gid0: int
    key_data: np.ndarray  # uint32 [W]


@dataclass(frozen=True)
class RhgEngineTable:
    """The P-independent cell layout as flat columns (one row per cell,
    ring-major, == the :func:`rhg_engine_cells` list order)."""
    ring: np.ndarray        # int64 [N]
    cell: np.ndarray        # int64 [N] angular index within the ring
    clo: np.ndarray         # f64 [N] cosh(alpha * r_lo)
    chi: np.ndarray         # f64 [N] cosh(alpha * r_hi)
    width: np.ndarray       # f64 [N] angular cell width
    count: np.ndarray       # int64 [N]
    gid0: np.ndarray        # int64 [N]
    key_data: np.ndarray    # uint32 [N, W]
    ring_lo: np.ndarray     # f64 [rings] inner radius (0.0 for the core)
    ring_start: np.ndarray  # int64 [rings] first row of each ring
    ring_k: np.ndarray      # int64 [rings] cells per ring
    ring_width: np.ndarray  # f64 [rings]


def rhg_engine_table(params: RHGParams,
                     rng_impl: str = "threefry2x32") -> RhgEngineTable:
    """Vectorized :func:`rhg_engine_cells`: one level-synchronous
    :func:`_range_table` replay per ring, one batched key dispatch over
    every cell, numpy column assembly — bit-identical rows in the same
    ring-major order."""
    n_core, ann_counts, bounds = region_counts(params)
    a = params.alpha
    B = len(ann_counts)
    ks = np.maximum(1, ann_counts.astype(np.int64) // _CELL_OCC)
    cnts, offs = [], []
    for b in range(B):
        c, o = _range_table(params.seed, _TAG_CELLS_ENG, b, int(ks[b]),
                            int(ann_counts[b]))
        cnts.append(c)
        offs.append(o)
    one = np.ones(1, np.int64)
    ring = np.concatenate([0 * one, np.repeat(np.arange(1, B + 1), ks)])
    cell = np.concatenate([0 * one] + [np.arange(k, dtype=np.int64) for k in ks])
    count = np.concatenate([n_core * one] + cnts)
    gid_ring = n_core + np.concatenate(
        [np.zeros(1, np.int64), np.cumsum(ann_counts.astype(np.int64))[:-1]])
    gid0 = np.concatenate([0 * one] +
                          [gid_ring[b] + offs[b] for b in range(B)])
    # math.cosh, not np.cosh: the SIMD variant can differ by 1 ulp from
    # the libm scalar the oracle rows were built with
    ring_clo = np.array([1.0] + [math.cosh(a * float(x))
                                 for x in bounds[:-1]])
    ring_chi = np.array([math.cosh(a * params.R / 2.0)]
                        + [math.cosh(a * float(x)) for x in bounds[1:]])
    ring_width = np.concatenate([[2.0 * math.pi], 2.0 * math.pi / ks])
    base = device_key(params.seed, _TAG_V_ENG, impl=rng_impl)
    keys = _jax.vmap(_jax.random.fold_in)(
        fold_in_many(base, _jnp.asarray(ring)), _jnp.asarray(cell))
    key_data = np.asarray(_jax.vmap(_jax.random.key_data)(keys))
    return RhgEngineTable(
        ring=ring, cell=cell,
        clo=ring_clo[ring], chi=ring_chi[ring], width=ring_width[ring],
        count=count, gid0=gid0, key_data=key_data,
        ring_lo=np.concatenate([[0.0], bounds[:-1]]),
        ring_start=np.concatenate([0 * one,
                                   1 + np.concatenate([np.zeros(1, np.int64),
                                                       np.cumsum(ks)[:-1]])]),
        ring_k=np.concatenate([one, ks]),
        ring_width=ring_width)


def rhg_engine_cells(params: RHGParams, rng_impl: str = "threefry2x32"):
    """(cells, ring_lo) — the P-independent cell table.

    ``ring_lo[r]`` is ring r's inner radius (0.0 for the core), the
    quantity the cell-level Delta-theta candidate bound needs.

    Retained oracle: defines the row order and values the vectorized
    :func:`rhg_engine_table` must reproduce bit-for-bit; the production
    emitters consume the table."""
    n_core, ann_counts, bounds = region_counts(params)
    a = params.alpha
    base = device_key(params.seed, _TAG_V_ENG, impl=rng_impl)

    def kd(ring, cell):
        k = _jax.random.fold_in(_jax.random.fold_in(base, ring), cell)
        return np.asarray(_jax.random.key_data(k)).ravel()

    cells = [EngineCell(0, 0, 1.0, math.cosh(a * params.R / 2.0),
                        2.0 * math.pi, n_core, 0, kd(0, 0))]
    ring_lo = [0.0]
    gid = n_core
    for b, cnt in enumerate(ann_counts):
        k = max(1, int(cnt) // _CELL_OCC)
        ctr = RangeCounter(params.seed, _TAG_CELLS_ENG, b, k, int(cnt))
        lo, hi = float(bounds[b]), float(bounds[b + 1])
        width = 2.0 * math.pi / k
        clo, chi = math.cosh(a * lo), math.cosh(a * hi)
        ring_keys = _jax.vmap(_jax.random.key_data)(
            fold_in_many(_jax.random.fold_in(base, b + 1),
                         _jnp.arange(k, dtype=_jnp.int64)))
        ring_keys = np.asarray(ring_keys)
        for c in range(k):
            cells.append(EngineCell(b + 1, c, clo, chi, width,
                                    ctr.cell_count(c),
                                    gid + ctr.cell_offset(c), ring_keys[c]))
        ring_lo.append(lo)
        gid += int(cnt)
    return cells, ring_lo


def rhg_engine_point_plan(params: RHGParams, P: int, rng_impl: str = "threefry2x32"):
    """PointPlan over the engine cell layout (core included), cells
    dealt round-robin by global index."""
    from .. import obs
    from ..distrib.engine import POINTS_POLAR, make_point_plan

    with obs.trace("plan/rhg", phase="plan", family="rhg", reseed=False, P=P):
        t = rhg_engine_table(params, rng_impl)
        per_pe = []
        for pe in range(P):
            sl = slice(pe, None, P)
            per_pe.append((
                t.key_data[sl],
                t.count[sl],
                np.stack([t.ring[sl], t.cell[sl]], axis=1),
                np.stack([t.clo[sl], t.chi[sl], t.width[sl]], axis=1),
            ))
        out = make_point_plan(per_pe, POINTS_POLAR, scale=params.alpha, dim=2,
                              rng_impl=rng_impl)
        return dataclasses.replace(
            out, reseed_fn=lambda s: rhg_engine_point_plan(
                dataclasses.replace(params, seed=s), P, rng_impl))


def rhg_engine_all_points(params: RHGParams, rng_impl: str = "threefry2x32") -> np.ndarray:
    """Every engine-layout vertex as (r, theta) in gid order."""
    from ..distrib.engine import run_points

    cells, _ = rhg_engine_cells(params, rng_impl)
    pts, mask, _ = run_points(rhg_engine_point_plan(params, 1, rng_impl), check=False)
    out = np.zeros((params.n, 2))
    for i, c in enumerate(cells):
        out[c.gid0: c.gid0 + c.count] = pts[0, i][: c.count]
    return out


def rhg_pair_plan(params: RHGParams, P: int, rng_impl: str = "threefry2x32"):
    """PairPlan: every candidate cell pair exactly once, dealt to PEs.

    Candidates come from the cell-level Delta-theta bound (Eq. 8
    evaluated at both cells' inner radii, the maximal angular reach
    over their contents), so every adjacent vertex pair is covered and
    candidate work stays near-linear (Cor. 11).  The enumeration is a
    pure function of the spec — every PE derives the identical global
    pair list and executes its slice, which makes the union exact for
    any P with zero communication.

    Emission is fully vectorized: ring-pair candidate windows become
    2-D index grids, deduped by sorting pair codes (the retired
    set-based walk is retained as :func:`rhg_pair_plan_specs`, the
    table-layout oracle).  The enumeration itself depends on the seed
    (region counts size the rings), so reseed re-emits — at the same
    vectorized cost."""
    from .. import obs
    from ..distrib.engine import GEOM_HYP, pair_plan_from_columns

    with obs.trace("plan/rhg", phase="plan", family="rhg", reseed=False, P=P):
        t = rhg_engine_table(params, rng_impl)
        code = _pair_codes(t, params.R)
        N = len(t.ring)
        ia, ib = code // N, code % N
        k = ia.size
        fp = np.broadcast_to(
            np.array([params.alpha, cosh_threshold(params.R)]), (k, 2))
        geom_a = np.stack([t.clo[ia], t.chi[ia],
                           t.cell[ia].astype(np.float64), t.width[ia]], axis=1)
        geom_b = np.stack([t.clo[ib], t.chi[ib],
                           t.cell[ib].astype(np.float64), t.width[ib]], axis=1)
        out = pair_plan_from_columns(
            P, ia % P, np.full(k, GEOM_HYP, np.int32),
            t.key_data[ia], t.key_data[ib], t.count[ia], t.count[ib],
            t.gid0[ia][:, None], t.gid0[ib][:, None], geom_a, geom_b,
            fp, ia == ib, rng_impl=rng_impl)
        return dataclasses.replace(
            out, reseed_fn=lambda s: rhg_pair_plan(
                dataclasses.replace(params, seed=s), P, rng_impl))


def _pair_codes(t: RhgEngineTable, R: float) -> np.ndarray:
    """Candidate cell-pair codes ``max(i1,i2) * N + min(i1,i2)``,
    deduped and ascending (== ``sorted(pairs)`` of the set-based walk).

    One 2-D index grid per ring pair: within a ring the window is a
    fixed span around each cell; across rings it is the Delta-theta
    window of each cell's angular extent, with full-ring fallback when
    the window wraps."""
    N = len(t.ring)
    rings = len(t.ring_k)
    codes: List[np.ndarray] = []
    for r1 in range(rings):
        k1, w1 = int(t.ring_k[r1]), float(t.ring_width[r1])
        s1, lo1 = int(t.ring_start[r1]), float(t.ring_lo[r1])
        c1 = np.arange(k1, dtype=np.int64)
        for r2 in range(r1 + 1):
            k2, w2 = int(t.ring_k[r2]), float(t.ring_width[r2])
            s2, lo2 = int(t.ring_start[r2]), float(t.ring_lo[r2])
            if lo1 + lo2 < R:
                dth = math.pi
            else:
                dth = float(delta_theta(np.array([lo1]), lo2, R)[0])
            if r1 == r2:
                span = min(int(dth / w1) + 1, k1)
                j = np.arange(span + 1, dtype=np.int64)
                i1 = (s1 + c1)[:, None]
                i2 = s1 + (c1[:, None] + j[None, :]) % k1
                codes.append((np.maximum(i1, i2) * N
                              + np.minimum(i1, i2)).ravel())
                continue
            lo_c = np.floor((c1 * w1 - dth) / w2).astype(np.int64)
            hi_c = np.floor(((c1 + 1) * w1 + dth) / w2).astype(np.int64)
            span = hi_c - lo_c + 1
            full = span >= k2
            # s1 > s2 + k2 here, so i1 > i2 always: i1 is the code's major
            if full.any():
                i1 = (s1 + c1[full])[:, None]
                i2 = (s2 + np.arange(k2, dtype=np.int64))[None, :]
                codes.append((i1 * N + i2).ravel())
            part = ~full
            if part.any():
                S = int(span[part].max())
                j = np.arange(S, dtype=np.int64)
                i2 = s2 + (lo_c[part][:, None] + j[None, :]) % k2
                i1 = np.broadcast_to((s1 + c1[part])[:, None], i2.shape)
                ok = j[None, :] < span[part][:, None]
                codes.append((i1 * N + i2)[ok].ravel())
    allc = np.sort(np.concatenate(codes))
    keep = np.ones(len(allc), bool)
    keep[1:] = allc[1:] != allc[:-1]
    return allc[keep]


def rhg_pair_plan_specs(params: RHGParams, P: int,
                        rng_impl: str = "threefry2x32"):
    """Retained oracle: the original set-based candidate walk of
    :func:`rhg_pair_plan`.  Defines the pair order and table layout the
    vectorized path must reproduce bit-for-bit; not a production path."""
    from ..distrib.engine import GEOM_HYP, PairSpec, make_pair_plan

    cells, ring_lo = rhg_engine_cells(params, rng_impl)
    R = params.R
    rings: List[List[EngineCell]] = [[] for _ in ring_lo]
    for c in cells:
        rings[c.ring].append(c)

    pairs = set()
    for r1 in range(len(rings)):
        k1 = len(rings[r1])
        w1 = rings[r1][0].width
        for r2 in range(r1 + 1):
            k2 = len(rings[r2])
            w2 = rings[r2][0].width
            lo1, lo2 = ring_lo[r1], ring_lo[r2]
            if lo1 + lo2 < R:
                dth = math.pi
            else:
                dth = float(delta_theta(np.array([lo1]), lo2, R)[0])
            for c1 in range(k1):
                if r1 == r2:
                    span = min(int(dth / w1) + 1, k1)
                    cands = range(c1, c1 + span + 1)
                else:
                    lo_c = math.floor((c1 * w1 - dth) / w2)
                    hi_c = math.floor(((c1 + 1) * w1 + dth) / w2)
                    if hi_c - lo_c + 1 >= k2:
                        cands = range(k2)
                    else:
                        cands = range(lo_c, hi_c + 1)
                i1 = _cell_index(rings, r1, c1)
                for c2 in cands:
                    i2 = _cell_index(rings, r2, c2 % k2)
                    pairs.add((max(i1, i2), min(i1, i2)))

    fp = (params.alpha, cosh_threshold(R))
    per_pe: List[List[PairSpec]] = [[] for _ in range(P)]
    for ia, ib in sorted(pairs):
        A, B = cells[ia], cells[ib]
        per_pe[ia % P].append(PairSpec(  # repro: allow(no-per-chunk-host-loop) retained oracle
            GEOM_HYP, A.key_data, B.key_data, A.count, B.count, A.gid0, B.gid0,
            (A.clo, A.chi, A.cell, A.width), (B.clo, B.chi, B.cell, B.width),
            fparams=fp, self_pair=ia == ib,
        ))
    return make_pair_plan(per_pe, rng_impl=rng_impl)


def _cell_index(rings: List[List[EngineCell]], ring: int, cell: int) -> int:
    """Global index of (ring, cell) in the flat cells list (ring-major)."""
    off = 0
    for r in range(ring):
        off += len(rings[r])
    return off + cell


def rhg_union(params: RHGParams, P: int, interpret: bool = True) -> np.ndarray:
    es = [rhg_pe(params, P, pe, interpret)[0] for pe in range(P)]
    e = np.concatenate(es, axis=0)
    return np.unique(e, axis=0) if e.size else e.reshape(0, 2)  # repro: allow(no-numpy-unique) test-oracle union (engine dedups by pair ownership)


def rhg_all_vertices(params: RHGParams, P: int = 1):
    """Every vertex in gid order (oracle input)."""
    plan = RHGPlan(params, P)
    r_all = np.zeros(params.n)
    t_all = np.zeros(params.n)
    cr, ct = plan.core_vertices()
    r_all[: plan.n_core], t_all[: plan.n_core] = cr, ct
    for ann in plan.annuli:
        for cell in range(ann.cells):
            r, t, g0 = plan.cell_vertices(ann.idx, cell)
            r_all[g0: g0 + len(r)] = r
            t_all[g0: g0 + len(t)] = t
    return r_all, t_all


def rhg_brute_edges(r: np.ndarray, theta: np.ndarray, R: float) -> np.ndarray:
    """O(n^2) oracle using the identical Eq. 9 float64 expression."""
    f = precompute_features(r, theta)
    acc = f[:, 0][:, None] * f[:, 0][None, :]
    acc += f[:, 1][:, None] * f[:, 1][None, :]
    acc -= f[:, 2][:, None] * f[:, 2][None, :]
    acc += cosh_threshold(R) * (f[:, 3][:, None] * f[:, 3][None, :])
    mask = np.tril(acc > 0, k=-1)
    u, v = np.nonzero(mask)
    return np.stack([u, v], axis=1)
