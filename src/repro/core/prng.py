"""Pseudorandomization substrate (paper §2.2).

The paper seeds a PRNG from a *hash of the recursion-tree position* so
that every PE recomputes identical variates without communication.  We
realize this twice:

* **Host side** (the O(P)-sized divide-and-conquer *plan*): splitmix64
  hashing of ``(seed, *path)`` tuples -> ``numpy`` Philox generators.
  Used for hypergeometric/binomial splits whose results must become
  concrete Python ints (array capacities).

* **Device side** (bulk vertex/edge generation inside ``jit``):
  ``jax.random.fold_in`` chains.  Threefry is counter-based, so
  ``fold_in(key, cell_id)`` *is* the paper's "hash of the subtree seed"
  — stateless, identical on every device, independent across ids.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
_U64 = np.uint64


def splitmix64(x: np.uint64) -> np.uint64:
    """One splitmix64 round; high-quality 64-bit mixer (vectorized-safe)."""
    with np.errstate(over="ignore"):
        x = _U64(x) + _GOLDEN
        x = (x ^ (x >> _U64(30))) * _MIX1
        x = (x ^ (x >> _U64(27))) * _MIX2
        return x ^ (x >> _U64(31))


def hash_path(seed: int, *path: int) -> int:
    """Stable 64-bit hash of a recursion-tree position.

    Rank-independent: two PEs hashing the same (seed, path) always agree,
    different paths give independent streams (splitmix64 avalanche).
    """
    with np.errstate(over="ignore"):
        h = splitmix64(_U64(seed & 0xFFFFFFFFFFFFFFFF))
        for p in path:
            h = splitmix64(h ^ (_U64(int(p) & 0xFFFFFFFFFFFFFFFF) + _GOLDEN))
    return int(h)


def hash_paths(seed: int, paths: np.ndarray) -> np.ndarray:
    """Vectorized :func:`hash_path` over the rows of ``paths`` [N, L]:
    one splitmix64 chain per row, bit-identical to the scalar loop.
    Replay loops (plan reseeding) hash every recursion-tree node in a
    few array passes instead of N python chains."""
    with np.errstate(over="ignore"):
        h = np.full(len(paths), splitmix64(_U64(seed & 0xFFFFFFFFFFFFFFFF)),
                    np.uint64)
        for c in range(paths.shape[1]):
            col = paths[:, c].astype(np.int64).astype(np.uint64)
            h = splitmix64(h ^ (col + _GOLDEN))
    return h


def host_rng(seed: int, *path: int) -> np.random.Generator:
    """Numpy generator for one recursion-tree node (host-side plan)."""
    return np.random.Generator(np.random.Philox(key=hash_path(seed, *path)))


class PhiloxReplayer:
    """Reusable Philox generator for hot replay loops.

    ``at(h)`` resets one shared bit generator to the freshly-keyed
    Philox state, so its draws are bit-identical to
    ``np.random.Generator(np.random.Philox(key=h))`` at a fraction of
    the construction cost — the per-node half of what makes plan
    reseeding cheap (:func:`hash_paths` is the other half)."""

    def __init__(self):
        self._bg = np.random.Philox(key=0)
        self._gen = np.random.Generator(self._bg)

    def at(self, h: int) -> np.random.Generator:
        st = self._bg.state
        st["state"]["key"][:] = (int(h) & 0xFFFFFFFFFFFFFFFF, 0)
        st["state"]["counter"][:] = 0
        st["buffer_pos"] = 4
        st["has_uint32"] = 0
        st["uinteger"] = 0
        self._bg.state = st
        return self._gen


def device_key(seed: int, *path: int, impl: str | None = None) -> jax.Array:
    """JAX PRNG key for a recursion-tree node (device-side bulk gen).

    ``impl`` selects the key implementation ('threefry2x32' default,
    'rbg' for the TPU-native RngBitGenerator perf path)."""
    key = jax.random.key(seed & 0x7FFFFFFF, impl=impl)
    for p in path:
        key = jax.random.fold_in(key, int(p) & 0x7FFFFFFF)
    return key


def fold_in_many(key: jax.Array, ids: jax.Array) -> jax.Array:
    """Vectorized fold_in: one independent key per id (traced-safe)."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)


def fold_in64(key: jax.Array, x: jax.Array) -> jax.Array:
    """fold_in for 64-bit values (split into two 31-bit limbs)."""
    k = jax.random.fold_in(key, (x >> 31).astype(jnp.uint32))
    return jax.random.fold_in(k, (x & 0x7FFFFFFF).astype(jnp.uint32))


# --------------------------------------------------------------------------
# capacity-independent bulk draws (the fixed-capacity invariant)
# --------------------------------------------------------------------------
#
# ``jax.random.bits``/``randint``/``uniform`` encrypt the whole output
# array as one counter block (threefry even pairs word i with word
# i + N/2), so the value at slot i depends on the array *length*.
# Generators here pad every chunk/cell to a static capacity, and two
# PEs recomputing the same chunk may pad it differently — the draws
# below fold the slot index into the key instead (the paper's
# hash-per-element scheme), so slot i's value depends only on (key, i)
# and buffers can grow without changing the stream.

def counter_bits64(key: jax.Array, capacity: int, width: int) -> jax.Array:
    """uint64 [capacity, width]; word (i, j) is a pure function of
    (key, i, j) — never of ``capacity``."""
    def slot(i):
        b = jax.random.bits(jax.random.fold_in(key, i), (width, 2), dtype=jnp.uint32)
        return (b[:, 0].astype(jnp.uint64) << 32) | b[:, 1].astype(jnp.uint64)

    return jax.vmap(slot)(jnp.arange(capacity, dtype=jnp.uint32))


def counter_uniform(key: jax.Array, capacity: int, width: int) -> jax.Array:
    """float64 [capacity, width] uniforms in [0, 1), 53-bit mantissa,
    capacity-independent per slot."""
    w = counter_bits64(key, capacity, width)
    return (w >> jnp.uint64(11)).astype(jnp.float64) * (1.0 / (1 << 53))
