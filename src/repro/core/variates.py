"""Random variates for the divide-and-conquer splits (paper §2.2).

Hypergeometric (G(n,m) splits), binomial (G(n,p) / spatial cell counts)
and multinomial (RHG annuli) variates, each drawn from a generator seeded
by the recursion-tree hash — see :mod:`repro.core.prng`.

Exact sampling is used whenever parameters fit the int64-exact regime
(universe <= 2^60, i.e. graphs up to ~2^30 vertices); beyond that we
switch to a clamped normal approximation.  The paper's C++ code makes the
same trade (stocc's exact samplers below 64 bit, GMP + asymptotics above);
at universes > 2^60 the approximation error is far below statistical
resolution.
"""
from __future__ import annotations

import numpy as np

_EXACT_LIMIT = 10**9 - 1   # numpy's Generator.hypergeometric hard limit
_BINOM_LIMIT = 1 << 62


def hypergeometric(rng: np.random.Generator, ngood: int, nbad: int, nsample: int) -> int:
    """# of 'good' elements in a uniform nsample-subset of ngood+nbad.

    Three regimes (paper: stocc exact below 64 bit, GMP+asymptotics above):
      exact     max(ngood, nbad) < 1e9       (numpy's limit)
      binomial  nsample^2 << total           (without ~= with replacement;
                TV error O(nsample^2/total))
      normal    everything huge              (CLT; relative error -> 0)
    """
    ngood, nbad, nsample = int(ngood), int(nbad), int(nsample)
    total = ngood + nbad
    if not 0 <= nsample <= total:
        raise ValueError(f"nsample {nsample} out of range for total {total}")
    lo, hi = max(0, nsample - nbad), min(nsample, ngood)
    if lo == hi:
        return lo
    if max(ngood, nbad) <= _EXACT_LIMIT:
        return int(rng.hypergeometric(ngood, nbad, nsample))
    if nsample * nsample <= total // 100 and nsample <= _BINOM_LIMIT:
        return int(np.clip(rng.binomial(nsample, ngood / total), lo, hi))
    p = ngood / total
    mean = nsample * p
    var = nsample * p * (1.0 - p) * (total - nsample) / (total - 1.0)
    return int(np.clip(round(rng.normal(mean, np.sqrt(max(var, 0.0)))), lo, hi))


def binomial(rng: np.random.Generator, n: int, p: float) -> int:
    """Binomial(n, p) with large-n normal fallback."""
    n = int(n)
    if p <= 0.0 or n == 0:
        return 0
    if p >= 1.0:
        return n
    if n <= _EXACT_LIMIT:
        return int(rng.binomial(n, p))
    mean, var = n * p, n * p * (1.0 - p)
    return int(np.clip(round(rng.normal(mean, np.sqrt(var))), 0, n))


def multinomial_split(rng: np.random.Generator, n: int, probs: np.ndarray) -> np.ndarray:
    """Multinomial(n, probs) via dependent binomials (paper §7.1).

    Drawn as the paper does for annuli: iteratively condition on the
    remaining mass, so prefix counts agree between PEs that only need a
    prefix of the outcome vector.
    """
    probs = np.asarray(probs, dtype=np.float64)
    out = np.zeros(len(probs), dtype=np.int64)
    remaining, mass = int(n), 1.0
    for i, pi in enumerate(probs[:-1]):
        if remaining == 0:
            break
        q = 0.0 if mass <= 0 else min(1.0, pi / mass)
        out[i] = binomial(rng, remaining, q)
        remaining -= out[i]
        mass -= pi
    out[len(probs) - 1] += remaining
    return out
