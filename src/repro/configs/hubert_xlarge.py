"""hubert-xlarge [audio]: encoder-only (bidirectional), frame-level
targets (504 clusters) [arXiv:2106.07447; unverified].  Audio frontend is
a stub: input_specs supplies precomputed frame embeddings."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab=504,
        causal=False, frontend="audio",
    )


def get_smoke_config() -> ArchConfig:
    return get_config().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=64, dtype="float32",
    )
