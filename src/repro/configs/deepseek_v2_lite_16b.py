"""deepseek-v2-lite-16b [moe]: MLA kv_lora=512, 64 routed top-6 + 2 shared,
first layer dense [arXiv:2405.04434; hf].  (The assignment line's "160
routed" tail describes full V2; the leading "MoE 64e top-6" is V2-Lite.)"""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab=102400,
        mla=True, kv_lora_rank=512, q_lora_rank=0,
        qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
        moe=True, n_experts=64, n_shared_experts=2, top_k=6,
        moe_d_ff=1408, first_dense_layers=1,
    )


def get_smoke_config() -> ArchConfig:
    return get_config().replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, kv_lora_rank=32, qk_rope_dim=16, qk_nope_dim=32,
        v_head_dim=32, n_experts=8, top_k=2, moe_d_ff=64, dtype="float32",
    )
