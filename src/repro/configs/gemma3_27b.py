"""gemma3-27b [dense]: 5 local (sliding 1024) : 1 global, 128k context
[hf:google/gemma-3; unverified]."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
        d_ff=21504, vocab=262144, head_dim=128,
        attn_kind="local_global", local_per_global=5, window=1024,
        qk_norm=True, rope_theta=1e6,
    )


def get_smoke_config() -> ArchConfig:
    return get_config().replace(
        n_layers=12, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window=16, dtype="float32",
    )
