"""qwen3-0.6b [dense]: qk_norm, GQA [hf:Qwen/Qwen3; hf]."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=3072, vocab=151936, head_dim=128,
        qk_norm=True, rope_theta=1e6,
    )


def get_smoke_config() -> ArchConfig:
    return get_config().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, dtype="float32",
    )
