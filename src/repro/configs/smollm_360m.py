"""smollm-360m [dense]: llama-arch small [hf:HuggingFaceTB/SmolLM; hf]."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab=49152, head_dim=64,
    )


def get_smoke_config() -> ArchConfig:
    return get_config().replace(
        n_layers=4, d_model=96, n_heads=3, n_kv_heads=1, head_dim=32,
        d_ff=192, vocab=256, dtype="float32",
    )
