"""granite-20b [dense]: llama-arch, code, MQA (kv=1) [arXiv:2405.04324; hf]."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="granite-20b", family="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab=49152, head_dim=128,
    )


def get_smoke_config() -> ArchConfig:
    return get_config().replace(
        n_layers=4, d_model=96, n_heads=4, n_kv_heads=1, head_dim=24,
        d_ff=192, vocab=256, dtype="float32",
    )
