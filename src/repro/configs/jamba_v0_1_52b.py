"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave (attention at
offset 4 of each 8-layer block), MoE 16e top-2 on every 2nd layer
[arXiv:2403.19887; hf].  The SSM mixer uses our Mamba2/SSD block (TPU
hardware adaptation of Jamba's Mamba-1 — see DESIGN.md)."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=65536,
        moe=True, n_experts=16, top_k=2, moe_d_ff=14336, moe_every=2,
        ssm=True, attn_every=8, d_state=16, ssm_head_dim=64, expand=2,
    )


def get_smoke_config() -> ArchConfig:
    return get_config().replace(
        n_layers=16, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, n_experts=4, top_k=2, moe_d_ff=128,
        d_state=8, ssm_head_dim=16, dtype="float32",
    )
