"""qwen2-vl-72b [vlm]: M-RoPE, dynamic resolution [arXiv:2409.12191; hf].
Vision frontend is a stub: input_specs supplies precomputed patch
embeddings; M-RoPE runs with coinciding (t,h,w) text positions."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, head_dim=128,
        mrope=True, rope_theta=1e6, frontend="vision",
    )


def get_smoke_config() -> ArchConfig:
    return get_config().replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=256, dtype="float32",
    )
