"""Architecture registry + assigned input shapes.

Shapes (LM family):
  train_4k    : train_step,  seq 4096,   global batch 256
  prefill_32k : prefill,     seq 32768,  global batch 32
  decode_32k  : serve_step,  1 new token against a 32768 KV cache, batch 128
  long_500k   : serve_step,  1 new token against a 524288 cache,  batch 1
                (sub-quadratic archs only — see `applicable`)
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCHS = [
    "deepseek_v2_lite_16b",
    "mixtral_8x7b",
    "qwen2_vl_72b",
    "smollm_360m",
    "granite_20b",
    "gemma3_27b",
    "qwen3_0p6b",
    "jamba_v0_1_52b",
    "hubert_xlarge",
    "mamba2_2p7b",
]


def get_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.get_config()


def get_smoke_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.get_smoke_config()


def applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """Which (arch x shape) cells run; skips mirror DESIGN.md rules."""
    s = SHAPES[shape]
    if not cfg.causal and s.kind == "decode":
        return False, "encoder-only: no decode step"
    if shape == "long_500k":
        subq = cfg.ssm or cfg.attn_kind in ("swa", "local_global")
        if not subq:
            return False, "pure full attention: 500k decode cache skipped per shape rules"
    return True, ""


def input_specs(cfg: ArchConfig, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    [vlm]/[audio] archs receive precomputed patch/frame embeddings from
    the stub frontend instead of token ids (per assignment rules)."""
    s = SHAPES[shape]
    i32 = jnp.int32
    if s.kind == "train":
        out = {
            "labels": jax.ShapeDtypeStruct((s.batch, s.seq), i32),
            "positions": jax.ShapeDtypeStruct((s.batch, s.seq), i32),
        }
        if cfg.frontend != "none":
            out["embeds"] = jax.ShapeDtypeStruct((s.batch, s.seq, cfg.d_model), jnp.bfloat16)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((s.batch, s.seq), i32)
        return out
    if s.kind == "prefill":
        out = {"positions": jax.ShapeDtypeStruct((s.batch, s.seq), i32)}
        if cfg.frontend != "none":
            out["embeds"] = jax.ShapeDtypeStruct((s.batch, s.seq, cfg.d_model), jnp.bfloat16)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((s.batch, s.seq), i32)
        return out
    # decode: one new token; the KV/SSM cache itself is an argument whose
    # specs come from transformer.caches_init via eval_shape
    return {
        "tokens": jax.ShapeDtypeStruct((s.batch, 1), i32),
        "positions": jax.ShapeDtypeStruct((s.batch, 1), i32),
    }
