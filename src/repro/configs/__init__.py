from .base import ARCHS, SHAPES, get_config, get_smoke_config, input_specs, applicable  # noqa
