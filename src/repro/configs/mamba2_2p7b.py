"""mamba2-2.7b [ssm]: attention-free SSD (state-space duality), no FFN,
d_state=128 [arXiv:2405.21060; unverified]."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=50280,
        ssm=True, attn_every=0, d_state=128, ssm_head_dim=64, expand=2,
    )


def get_smoke_config() -> ArchConfig:
    return get_config().replace(
        n_layers=4, d_model=64, vocab=256, d_state=16, ssm_head_dim=16,
        dtype="float32",
    )
