"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000,
        attn_kind="swa", window=4096,
        moe=True, n_experts=8, top_k=2, moe_d_ff=14336,
    )


def get_smoke_config() -> ArchConfig:
    return get_config().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, n_experts=4, top_k=2, moe_d_ff=128, window=32,
        dtype="float32",
    )
