"""Dry-run sweep driver: every (arch x shape x mesh) cell in its own
subprocess (fresh XLA state, bounded memory), results cached as JSON.

    PYTHONPATH=src python -m repro.launch.sweep [--multi-pod-only-train]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "smollm_360m", "qwen3_0p6b", "mamba2_2p7b", "hubert_xlarge",
    "deepseek_v2_lite_16b", "granite_20b", "gemma3_27b",
    "mixtral_8x7b", "jamba_v0_1_52b", "qwen2_vl_72b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def run_one(arch: str, shape: str, multi_pod: bool, outdir: str, timeout=3600) -> dict:
    tag = f"{arch}.{shape}.{'mp' if multi_pod else 'sp'}"
    out = os.path.join(outdir, tag + ".json")
    if os.path.exists(out):
        with open(out) as f:
            return json.load(f)
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    t0 = time.time()
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0 or not os.path.exists(out):
        res = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
               "status": "error", "stderr": r.stderr[-2000:]}
        with open(out, "w") as f:
            json.dump(res, f)
        return res
    with open(out) as f:
        res = json.load(f)
    print(f"[{time.strftime('%H:%M:%S')}] {tag}: {res['status']} "
          f"({time.time()-t0:.0f}s)", flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default=os.path.abspath(RESULTS))
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    cells = []
    # single-pod: full roofline table; multi-pod: train_4k per arch proves
    # the 'pod' axis shards (plus the generator cell on both meshes)
    for arch in args.archs.split(","):
        for shape in args.shapes.split(","):
            cells.append((arch, shape, False))
    for arch in args.archs.split(","):
        cells.append((arch, "train_4k", True))

    summary = []
    for arch, shape, mp in cells:
        try:
            res = run_one(arch, shape, mp, args.outdir)
        except subprocess.TimeoutExpired:
            res = {"arch": arch, "shape": shape, "status": "timeout"}
        summary.append(res)

    # generator cells (the paper's technique itself) on both meshes
    for mp in (False, True):
        tag = f"kagen_er_gnm.gen.{'mp' if mp else 'sp'}"
        out = os.path.join(args.outdir, tag + ".json")
        if not os.path.exists(out):
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", "kagen_er_gnm", "--out", out]
            if mp:
                cmd.append("--multi-pod")
            subprocess.run(cmd, capture_output=True, text=True, timeout=1800)

    ok = sum(1 for s in summary if s.get("status") == "ok")
    skip = sum(1 for s in summary if s.get("status") == "skipped")
    err = [f"{s['arch']}.{s['shape']}" for s in summary if s.get("status") not in ("ok", "skipped")]
    print(f"\nDONE: {ok} ok, {skip} skipped, errors: {err}")


if __name__ == "__main__":
    main()
