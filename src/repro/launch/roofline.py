"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "deepseek_v2_lite_16b", "mixtral_8x7b", "qwen2_vl_72b", "smollm_360m",
    "granite_20b", "gemma3_27b", "qwen3_0p6b", "jamba_v0_1_52b",
    "hubert_xlarge", "mamba2_2p7b", "kagen_er_gnm",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "gen"]

HBM_PER_CHIP = 16 * 2**30  # v5e


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}u"


def load(dirname):
    rows = {}
    for f in glob.glob(os.path.join(dirname, "*.json")):
        with open(f) as fh:
            d = json.load(fh)
        key = (d.get("arch"), d.get("shape"), bool(d.get("multi_pod")))
        rows[key] = d
    return rows


def make_table(rows, multi_pod=False):
    out = []
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "peak GB/chip | fits | useful-flops ratio | bottleneck note |")
    sep = "|" + "---|" * 10
    out.append(hdr)
    out.append(sep)
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = rows.get((arch, shape, multi_pod))
            if d is None:
                d = rows.get((arch, f"n2^30_m2^34", multi_pod)) if shape == "gen" and arch == "kagen_er_gnm" else None
            if d is None:
                continue
            if d["status"] == "skipped":
                out.append(f"| {arch} | {shape} | - | - | - | skipped | - | - | - | {d['reason']} |")
                continue
            if d["status"] != "ok":
                out.append(f"| {arch} | {shape} | - | - | - | ERROR | - | - | - | {d.get('stderr','')[:40]} |")
                continue
            r = d["roofline"]
            peak = d.get("memory", {}).get("peak_per_device")
            peak_gb = f"{peak/2**30:.1f}" if peak else "-"
            fits = "yes" if (peak or 0) <= HBM_PER_CHIP else "NO"
            ratio = d.get("useful_flops_ratio")
            ratio_s = f"{ratio:.2f}" if ratio else "-"
            note = _note(d)
            out.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | {d['dominant'].replace('_s','')} "
                f"| {peak_gb} | {fits} | {ratio_s} | {note} |"
            )
    return "\n".join(out)


def _note(d):
    dom = d["dominant"]
    r = d["roofline"]
    colls = d.get("collectives", {})
    if d.get("zero_collectives"):
        return "communication-free by construction (asserted)"
    if dom == "collective_s":
        big = max(colls.items(), key=lambda kv: kv[1]["bytes"])[0] if colls else "?"
        return f"dominated by {big}; cut via RS/AG + bf16 gathers"
    if dom == "memory_s":
        return "bytes-proxy bound; fuse/avoid materialized intermediates"
    return "compute-bound: near roofline if overlap hides comm"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    print(make_table(rows, args.multi_pod))


if __name__ == "__main__":
    main()
