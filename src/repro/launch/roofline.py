"""Roofline model: achieved-vs-peak fractions for traced programs.

Two layers:

* the analytic model — :class:`Peaks`, :func:`roofline_seconds` and
  :func:`achieved_fraction` turn the static FLOP/byte estimates of
  :class:`repro.launch.hlocost.HloCost` into a time floor
  ``max(flops/peak_flops, bytes/peak_bw)`` and compare it against
  measured span time.  :func:`program_summary` does this for one
  lowered program; :func:`trace_summary` joins a captured
  :class:`repro.obs.Tracer` with a ``{name: lowered}`` program map, so
  benchmark records carry "this run achieved X% of its roofline" next
  to the phase breakdown.

* the legacy table CLI — aggregate dry-run JSONs into the
  EXPERIMENTS.md roofline table:

    PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, Optional

from .hlocost import HloCost

# --------------------------------------------------------------------------
# the analytic model
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Peaks:
    """Peak rates of the executing device(s)."""
    flops_per_s: float
    bytes_per_s: float


# rough single-device peaks per backend; calibration knobs, not specs —
# the achieved fraction is for *relative* comparison across programs
_BACKEND_PEAKS = {
    "cpu": (5.0e10, 2.0e10),
    "gpu": (1.0e14, 1.0e12),
    "tpu": (2.0e14, 8.0e11),
}


def default_peaks() -> Peaks:
    """Backend-matched peaks; override with ``REPRO_PEAK_FLOPS`` /
    ``REPRO_PEAK_BW`` (floats, per-second) for calibrated hardware."""
    f = float(os.environ.get("REPRO_PEAK_FLOPS", 0) or 0)
    b = float(os.environ.get("REPRO_PEAK_BW", 0) or 0)
    if f > 0 and b > 0:
        return Peaks(f, b)
    try:
        import jax
        backend = jax.default_backend()
    except Exception:
        backend = "cpu"
    df, db = _BACKEND_PEAKS.get(backend, _BACKEND_PEAKS["cpu"])
    return Peaks(f if f > 0 else df, b if b > 0 else db)


def roofline_seconds(flops: float, nbytes: float,
                     peaks: Optional[Peaks] = None) -> float:
    """The roofline time floor: max of compute and memory terms."""
    peaks = peaks if peaks is not None else default_peaks()
    return max(flops / peaks.flops_per_s, nbytes / peaks.bytes_per_s)


def achieved_fraction(flops: float, nbytes: float, measured_s: float,
                      peaks: Optional[Peaks] = None) -> Optional[float]:
    """roofline_floor / measured — 1.0 means running at the roofline;
    None when the measurement is missing or degenerate."""
    if not measured_s or measured_s <= 0:
        return None
    return roofline_seconds(flops, nbytes, peaks) / measured_s


def program_summary(lowered, measured_s: Optional[float] = None,
                    peaks: Optional[Peaks] = None) -> dict:
    """FLOP/byte estimate + roofline verdict for one lowered program.

    ``lowered`` is a ``jax.stages.Lowered``/``Compiled`` (or an
    :class:`HloCost` already built from one).  ``measured_s`` is the
    span-measured execution time to compare against the floor."""
    cost = lowered if isinstance(lowered, HloCost) else HloCost.from_lowered(lowered)
    peaks = peaks if peaks is not None else default_peaks()
    floor = roofline_seconds(cost.flops, cost.bytes, peaks)
    compute_s = cost.flops / peaks.flops_per_s
    memory_s = cost.bytes / peaks.bytes_per_s
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "roofline_s": floor,
        "bound": "compute" if compute_s >= memory_s else "memory",
        "measured_s": measured_s,
        "achieved_fraction": achieved_fraction(
            cost.flops, cost.bytes, measured_s or 0.0, peaks),
    }


def trace_summary(tr, programs: Optional[Dict[str, object]] = None,
                  peaks: Optional[Peaks] = None) -> dict:
    """Join a captured :class:`repro.obs.Tracer` with lowered programs.

    ``programs`` maps a span-name prefix (``"run"``, ``"wave"``,
    ``"slab"``) to the lowered program whose executions those spans
    timed; each entry gets a :func:`program_summary` with
    ``measured_s`` summed from the matching exec-phase spans (falling
    back to the trace's total exec time when no span matches)."""
    totals = tr.phase_totals()
    out = {"phases": totals, "programs": {}}
    spans = [s for s in tr.spans() if not s.instant and s.phase == "exec"]
    for name, lowered in (programs or {}).items():
        measured = sum(s.seconds for s in spans
                       if s.name == name or s.name.startswith(name + "/"))
        if not measured:
            measured = totals.get("exec_s", 0.0)
        out["programs"][name] = program_summary(lowered, measured, peaks)
    return out


# --------------------------------------------------------------------------
# the legacy dry-run table CLI
# --------------------------------------------------------------------------

ARCH_ORDER = [
    "deepseek_v2_lite_16b", "mixtral_8x7b", "qwen2_vl_72b", "smollm_360m",
    "granite_20b", "gemma3_27b", "qwen3_0p6b", "jamba_v0_1_52b",
    "hubert_xlarge", "mamba2_2p7b", "kagen_er_gnm",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "gen"]

HBM_PER_CHIP = 16 * 2**30  # v5e


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}u"


def load(dirname):
    rows = {}
    for f in glob.glob(os.path.join(dirname, "*.json")):
        with open(f) as fh:
            d = json.load(fh)
        key = (d.get("arch"), d.get("shape"), bool(d.get("multi_pod")))
        rows[key] = d
    return rows


def make_table(rows, multi_pod=False):
    out = []
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "peak GB/chip | fits | useful-flops ratio | bottleneck note |")
    sep = "|" + "---|" * 10
    out.append(hdr)
    out.append(sep)
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = rows.get((arch, shape, multi_pod))
            if d is None:
                d = rows.get((arch, f"n2^30_m2^34", multi_pod)) if shape == "gen" and arch == "kagen_er_gnm" else None
            if d is None:
                continue
            if d["status"] == "skipped":
                out.append(f"| {arch} | {shape} | - | - | - | skipped | - | - | - | {d['reason']} |")
                continue
            if d["status"] != "ok":
                out.append(f"| {arch} | {shape} | - | - | - | ERROR | - | - | - | {d.get('stderr','')[:40]} |")
                continue
            r = d["roofline"]
            peak = d.get("memory", {}).get("peak_per_device")
            peak_gb = f"{peak/2**30:.1f}" if peak else "-"
            fits = "yes" if (peak or 0) <= HBM_PER_CHIP else "NO"
            ratio = d.get("useful_flops_ratio")
            ratio_s = f"{ratio:.2f}" if ratio else "-"
            note = _note(d)
            out.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | {d['dominant'].replace('_s','')} "
                f"| {peak_gb} | {fits} | {ratio_s} | {note} |"
            )
    return "\n".join(out)


def _note(d):
    dom = d["dominant"]
    r = d["roofline"]
    colls = d.get("collectives", {})
    if d.get("zero_collectives"):
        return "communication-free by construction (asserted)"
    if dom == "collective_s":
        big = max(colls.items(), key=lambda kv: kv[1]["bytes"])[0] if colls else "?"
        return f"dominated by {big}; cut via RS/AG + bf16 gathers"
    if dom == "memory_s":
        return "bytes-proxy bound; fuse/avoid materialized intermediates"
    return "compute-bound: near roofline if overlap hides comm"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    print(make_table(rows, args.multi_pod))


if __name__ == "__main__":
    main()
