import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "--xla_backend_optimization_level=0")
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes (16x16 single-pod, 2x16x16 multi-pod), prove it
fits (memory_analysis), and extract the roofline terms (cost_analysis +
collective-bytes HLO parse).

Run one cell:   python -m repro.launch.dryrun --arch smollm_360m --shape train_4k
Sweep:          python -m repro.launch.sweep
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import SHAPES, applicable, get_config, input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import pmesh  # noqa: E402
from repro.models import shardings as SH  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.train import optimizer as O  # noqa: E402
from repro.train.train_loop import make_train_step  # noqa: E402

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\((?:[a-z0-9]+\[[0-9,]*\][^)]*)\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(r"\bwhile\(.*?body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CALL_RE = re.compile(r"\bcall\(.*?to_apply=%?([\w.\-]+)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict:
    """name -> list of instruction lines (flat, depth-1)."""
    comps, cur, name, entry = {}, None, None, None
    depth = 0
    for line in hlo.splitlines():
        s = line.strip()
        if depth == 0:
            m = _COMP_HDR.match(s)
            if m and s.endswith("{"):
                name = m.group(1)
                cur = []
                comps[name] = cur
                if s.startswith("ENTRY"):
                    entry = name
                depth = 1
                continue
        if depth >= 1:
            depth += s.count("{") - s.count("}")
            if depth == 0:
                cur, name = None, None
            elif cur is not None:
                cur.append(s)
    comps["__entry__"] = entry
    return comps


def collective_stats(hlo: str) -> dict:
    """Per-device collective bytes by kind, *weighted by loop trip counts*
    (scan-over-layers executes its body collectives reps times)."""
    comps = _split_computations(hlo)
    entry = comps.pop("__entry__")

    def walk(name, seen=()) -> dict:
        if name not in comps or name in seen:
            return {}
        out: dict = {}
        for line in comps[name]:
            m = _COLL_RE.search(line)
            if m:
                rec = out.setdefault(m.group(2), {"count": 0, "bytes": 0})
                rec["count"] += 1
                rec["bytes"] += _shape_bytes(m.group(1))
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                sub = walk(wm.group(1), seen + (name,))
                for k, v in sub.items():
                    rec = out.setdefault(k, {"count": 0, "bytes": 0})
                    rec["count"] += v["count"] * trips
                    rec["bytes"] += v["bytes"] * trips
            cm = _CALL_RE.search(line)
            if cm:
                sub = walk(cm.group(1), seen + (name,))
                for k, v in sub.items():
                    rec = out.setdefault(k, {"count": 0, "bytes": 0})
                    rec["count"] += v["count"]
                    rec["bytes"] += v["bytes"]
        return out

    return walk(entry) if entry else {}


def build_cell(arch: str, shape: str, mesh):
    """(fn, args, in_shardings) for one (arch x shape) cell."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    pshapes = T.param_shapes(cfg)
    pspecs = SH.param_specs(pshapes, mesh, cfg)
    batch_sds = input_specs(cfg, shape)
    bspecs = SH.batch_specs(cfg, mesh, batch_sds)

    if spec.kind == "train":
        opt_shapes = jax.eval_shape(O.opt_init, pshapes)
        ospecs = {"m": pspecs, "v": pspecs, "step": jax.sharding.PartitionSpec()}
        accum = int(os.environ.get("DRYRUN_ACCUM", "1"))
        step = make_train_step(cfg, O.OptConfig(), accum=accum)
        fn = jax.jit(
            step,
            in_shardings=SH.to_named((pspecs, ospecs, bspecs), mesh),
            out_shardings=SH.to_named((pspecs, ospecs, None), mesh),
            donate_argnums=(0, 1),
        )
        args = (pshapes, opt_shapes, batch_sds)
    elif spec.kind == "prefill":
        cache_shapes = jax.eval_shape(
            lambda: T.caches_init(cfg, spec.batch, spec.seq, jnp.dtype(cfg.dtype))
        )
        cspecs = SH.cache_specs(cfg, mesh, cache_shapes)

        def prefill_step(params, batch, caches):
            h, _, caches = T.forward(params, cfg, batch, caches=caches)
            logits = h[:, -1] @ params["embed"]["head"].astype(h.dtype)
            return logits, caches

        fn = jax.jit(
            prefill_step,
            in_shardings=SH.to_named((pspecs, bspecs, cspecs), mesh),
            out_shardings=SH.to_named((None, cspecs), mesh),
            donate_argnums=(2,),
        )
        args = (pshapes, batch_sds, cache_shapes)
    else:  # decode
        cache_shapes = jax.eval_shape(
            lambda: T.caches_init(cfg, spec.batch, spec.seq, jnp.dtype(cfg.dtype))
        )
        cspecs = SH.cache_specs(cfg, mesh, cache_shapes)

        def serve_step(params, tokens, positions, caches):
            return T.decode_step(params, cfg, tokens, positions, caches)

        fn = jax.jit(
            serve_step,
            in_shardings=SH.to_named(
                (pspecs, bspecs["tokens"], bspecs["positions"], cspecs), mesh
            ),
            out_shardings=SH.to_named((None, cspecs), mesh),
            donate_argnums=(3,),
        )
        args = (pshapes, batch_sds["tokens"], batch_sds["positions"], cache_shapes)
    return cfg, fn, args


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    cfg = get_config(arch)
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}
    t0 = time.time()
    with mesh, pmesh.use_hints(mesh):
        cfg, fn, args = build_cell(arch, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = dict(compiled.cost_analysis() or {})
    cost = {k: float(v) for k, v in cost.items() if np.isscalar(v)}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        mem["peak_per_device"] = (
            mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
            - mem["alias_bytes"]
        )
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    hlo = compiled.as_text()
    from repro.launch.hlocost import HloCost

    hc = HloCost(hlo)
    colls = hc.collectives
    coll_bytes = sum(v["bytes"] for v in colls.values())

    spec = SHAPES[shape]
    tokens = spec.batch * (spec.seq if spec.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    mult = 3 if spec.kind == "train" else 1  # fwd+bwd
    model_flops = 2 * n_active * tokens * mult

    # trip-count-weighted per-device costs (XLA's cost_analysis counts
    # while bodies once; ours multiplies by known_trip_count)
    flops_dev = float(hc.flops)
    bytes_dev = float(hc.bytes)
    res = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod, "chips": chips,
        "accum": int(os.environ.get("DRYRUN_ACCUM", "1")),
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost": cost,
        "memory": mem,
        "collectives": colls,
        "per_device": {
            "flops": flops_dev,
            "bytes": bytes_dev,
            "collective_bytes": coll_bytes,
        },
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_bytes / LINK_BW,
        },
        "model_flops_global": model_flops,
        "useful_flops_ratio": (
            model_flops / (flops_dev * chips) if flops_dev else None
        ),
    }
    r = res["roofline"]
    res["dominant"] = max(r, key=r.get)
    return res


def run_generator_cell(multi_pod: bool) -> dict:
    """The paper's own technique on the production mesh: a GraphSpec
    planned and lowered through the unified engine, zero collectives
    asserted."""
    from repro.api import GNM
    from repro.distrib.engine import collective_ops_in, edge_executor

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    n, m = 1 << 30, 1 << 34
    t0 = time.time()
    with mesh:
        plan = GNM(n=n, m=m, directed=True, seed=7).plan(chips)
        fn, inputs = edge_executor(plan, mesh)
        lowered = fn.lower(*inputs)
        compiled = lowered.compile()
    hlo = lowered.as_text()
    assert not collective_ops_in(hlo), "generator must be communication-free"
    cost = {k: float(v) for k, v in (compiled.cost_analysis() or {}).items()
            if np.isscalar(v)}
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    return {
        "arch": "kagen_er_gnm", "shape": f"n2^30_m2^34", "multi_pod": multi_pod,
        "chips": chips, "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "cost": cost,
        "collectives": {},
        "per_device": {"flops": flops_dev, "bytes": bytes_dev, "collective_bytes": 0},
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": 0.0,
        },
        "dominant": "memory_s" if bytes_dev / HBM_BW > flops_dev / PEAK_FLOPS else "compute_s",
        "zero_collectives": True,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.arch == "kagen_er_gnm":
        res = run_generator_cell(args.multi_pod)
    else:
        res = run_cell(args.arch, args.shape, args.multi_pod)
    js = json.dumps(res, indent=1, default=str)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)
    print(js)


if __name__ == "__main__":
    main()
