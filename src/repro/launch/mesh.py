"""Production meshes.  A FUNCTION, not a module-level constant, so
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for tests (requires forced device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
