"""Production training driver.

Single-host (CPU/debug):
    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --steps 100

Multi-host TPU pod (one invocation per host; jax.distributed picks up
the TPU runtime): see launch/run_pod.sh.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--data-mesh", type=int, default=0,
                    help="data-parallel ways (0 = all devices)")
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--multihost", action="store_true")
    args = ap.parse_args()

    if args.multihost:
        jax.distributed.initialize()

    from repro.configs import get_config, get_smoke_config
    from repro.data import pipeline as D
    from repro.models import pmesh
    from repro.models import shardings as SH
    from repro.models import transformer as T
    from repro.train import checkpoint as CK
    from repro.train import optimizer as O
    from repro.train.train_loop import make_train_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    nd = jax.device_count()
    dm = args.data_mesh or (nd // args.model_mesh)
    mesh = jax.make_mesh((dm, args.model_mesh), ("data", "model"))

    dc = D.DataConfig(kind="rhg_walk", vocab=cfg.vocab, seq_len=256,
                      batch_per_shard=4, num_shards=dm, seed=11)
    opt_cfg = O.OptConfig(total_steps=args.steps)
    step_fn = make_train_step(cfg, opt_cfg, accum=args.accum)

    with mesh, pmesh.use_hints(mesh):
        params = T.model_init(jax.random.key(0), cfg)
        pspecs = SH.param_specs(jax.tree.map(lambda x: x, params), mesh, cfg)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, jax.sharding.NamedSharding(mesh, s)),
            params, pspecs, is_leaf=lambda x: hasattr(x, "dtype"))
        opt = O.opt_init(params)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        start = CK.latest_step(args.ckpt_dir) or 0
        if start:
            restored, _ = CK.restore(args.ckpt_dir, {"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            print(f"resumed from step {start}")

        t0 = time.time()
        for s in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in D.make_global_batch(dc, s).items()}
            params, opt, metrics = jit_step(params, opt, batch)
            if s % 10 == 0:
                print(f"step {s} loss {float(metrics['loss']):.4f} "
                      f"({(s - start + 1) / (time.time() - t0):.2f} it/s)", flush=True)
            if (s + 1) % args.ckpt_every == 0:
                CK.save(args.ckpt_dir, s + 1, {"params": params, "opt": opt},
                        meta={"arch": cfg.name}, background=True)
        CK.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt},
                meta={"arch": cfg.name})
        print("done")


if __name__ == "__main__":
    main()
