"""Trip-count-aware cost model over optimized HLO text.

XLA's HloCostAnalysis counts a `while` body once, but our models scan
over layers (reps x superblock), so flops/bytes/collective bytes must be
weighted by `known_trip_count`.  This walker parses the optimized module
into per-computation instruction tables (HLO is SSA per computation, so
operand shapes resolve locally) and accounts:

  dot           2 * numel(result) * prod(contracted lhs dims)   [flops]
  elementwise   numel(result)                                   [flops]
  reduce        numel(input)                                    [flops]
  fusion        result+operand bytes; body recursed flops-only
                (fused interiors generate no HBM traffic)
  while         body recursed x known_trip_count
  call/cond     body recursed x1
  collective    separate ledger (result-shape bytes proxy)

Bytes = result + operand bytes on materializing instructions — a
first-order HBM-traffic proxy (no inter-instruction cache reuse, free
bitcasts), used for roofline *terms* where cross-cell consistency
matters more than absolute accuracy.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_WHILE_BODY = re.compile(r"body=%?([\w.\-]+)")
_TRIP = re.compile(r"known_trip_count[^0-9]*?(\d+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND = re.compile(r"%([\w.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "logistic", "rsqrt", "sqrt", "negate",
    "abs", "and", "or", "xor", "not", "compare", "select", "convert",
    "cosine", "sine", "expm1", "log1p", "floor", "ceil", "round-nearest-afz",
    "clamp", "atan2",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}
_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    "get-dimension-size", "all-gather-done", "all-reduce-done",
    "collective-permute-done", "copy-start", "copy-done",
    "optimization-barrier",
}


def _shape_list(text: str):
    return [
        (dt, [int(d) for d in dims.split(",") if d])
        for dt, dims in _SHAPE_RE.findall(text)
        if dt in _DTYPE_BYTES
    ]


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(shapes) -> int:
    if not shapes:
        return 0
    n = 1
    for d in shapes[0][1]:
        n *= d
    return n


class _Instr:
    __slots__ = ("name", "op", "shape_text", "args_text", "tail_text")

    def __init__(self, name, op, shape_text, args_text, tail_text):
        self.name, self.op = name, op
        self.shape_text, self.args_text, self.tail_text = shape_text, args_text, tail_text


def _parse_instr(line: str) -> Optional[_Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    om = _OP_RE.search(rhs)
    if not om:
        return None
    op = om.group(1)
    shape_text = rhs[: om.start()]
    # balanced-paren scan for the operand list
    i = om.end() - 1
    depth = 0
    j = i
    for j in range(i, len(rhs)):
        if rhs[j] == "(":
            depth += 1
        elif rhs[j] == ")":
            depth -= 1
            if depth == 0:
                break
    args_text = rhs[i + 1: j]
    tail_text = rhs[j + 1:]
    return _Instr(name, op, shape_text, args_text, tail_text)


def split_computations(hlo: str) -> Tuple[Dict[str, List[_Instr]], Optional[str]]:
    comps: Dict[str, List[_Instr]] = {}
    entry = None
    cur: Optional[List[_Instr]] = None
    depth = 0
    for line in hlo.splitlines():
        s = line.strip()
        if depth == 0:
            m = _COMP_HDR.match(s)
            if m and s.endswith("{") and "->" in s:
                cur = []
                comps[m.group(1)] = cur
                if s.startswith("ENTRY"):
                    entry = m.group(1)
                depth = 1
                continue
        if depth >= 1:
            depth += s.count("{") - s.count("}")
            if depth <= 0:
                cur, depth = None, 0
            elif cur is not None:
                ins = _parse_instr(s)
                if ins:
                    cur.append(ins)
    return comps, entry


class HloCost:
    """Aggregate per-device flops / bytes / collectives for a module.

    Construct from optimized HLO text directly, or from a JAX staging
    object via :meth:`from_lowered` (the current lowering API:
    ``jit(f).lower(...)`` -> ``Lowered``, ``.compile()`` ->
    ``Compiled``, whose ``as_text()`` is the optimized HLO this walker
    parses — ``Lowered.as_text()`` alone is StableHLO MLIR, a different
    grammar).  :mod:`repro.analyze.programs` uses this to attach static
    FLOP/byte estimates to every registered program signature."""

    @classmethod
    def from_lowered(cls, lowered) -> "HloCost":
        """Cost model from a ``jax.stages.Lowered`` or ``Compiled``."""
        compiled = lowered.compile() if hasattr(lowered, "compile") else lowered
        return cls(compiled.as_text())

    def summary(self) -> dict:
        """JSON-ready ledger: flops, HBM-byte proxy, collective bytes."""
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collectives": {k: dict(v) for k, v in self.collectives.items()},
        }

    def __init__(self, hlo: str):
        self.comps, self.entry = split_computations(hlo)
        self.symtab: Dict[str, Dict[str, str]] = {
            cname: {i.name: i.shape_text for i in instrs}
            for cname, instrs in self.comps.items()
        }
        self._memo: Dict[Tuple[str, bool], Tuple[int, int, dict]] = {}
        self.flops, self.bytes, self.collectives = self._walk(self.entry, False)

    def _operand_shapes(self, cname: str, ins: _Instr):
        tab = self.symtab.get(cname, {})
        shapes = []
        for name in _OPERAND.findall(ins.args_text):
            if name in tab:
                shapes.extend(_shape_list(tab[name]))
        return shapes

    def _walk(self, name: Optional[str], flops_only: bool):
        if name is None:
            return 0, 0, {}
        key = (name, flops_only)
        if key in self._memo:
            return self._memo[key]
        if name not in self.comps:
            return 0, 0, {}
        self._memo[key] = (0, 0, {})  # cycle guard
        flops = nbytes = 0
        colls: dict = {}

        def merge(c, mult):
            for k, v in c.items():
                rec = colls.setdefault(k, {"count": 0, "bytes": 0})
                rec["count"] += v["count"] * mult
                rec["bytes"] += v["bytes"] * mult

        for ins in self.comps[name]:
            op = ins.op
            if op in _SKIP:
                continue
            if op == "while":
                bm = _WHILE_BODY.search(ins.tail_text)
                tm = _TRIP.search(ins.tail_text)
                trips = int(tm.group(1)) if tm else 1
                if bm:
                    f, b, c = self._walk(bm.group(1), flops_only)
                    flops += f * trips
                    nbytes += b * trips
                    merge(c, trips)
                continue
            if op in ("call", "conditional", "custom-call"):
                cm = _CALLS.search(ins.tail_text)
                if cm:
                    f, b, c = self._walk(cm.group(1), flops_only)
                    flops += f
                    nbytes += b
                    merge(c, 1)
                continue
            if op == "fusion":
                cm = _CALLS.search(ins.tail_text)
                if cm:
                    f, _, c = self._walk(cm.group(1), True)
                    flops += f
                    merge(c, 1)
                if not flops_only:
                    nbytes += _nbytes(_shape_list(ins.shape_text))
                    nbytes += _nbytes(self._operand_shapes(name, ins))
                continue
            if op in _COLLECTIVES:
                kind = op.replace("-start", "")
                rec = colls.setdefault(kind, {"count": 0, "bytes": 0})
                rec["count"] += 1
                b = _nbytes(_shape_list(ins.shape_text))
                # XLA-CPU legalizes bf16 dots by upcasting to f32 BEFORE
                # the partitioner, so collectives fed by converts move f32
                # on the host backend where a TPU would move bf16.  Model
                # the TPU bytes (this is a dry-run for TPU hardware).
                if "f32[" in ins.shape_text and "convert" in ins.args_text:
                    b //= 2
                rec["bytes"] += b
                continue

            result = _shape_list(ins.shape_text)
            if op == "dot":
                operands = self._operand_shapes(name, ins)
                contracted = 1
                mm = _LHS_CONTRACT.search(ins.tail_text)
                if mm and operands:
                    lhs_dims = operands[0][1]
                    for idx in mm.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contracted *= lhs_dims[int(idx)]
                flops += 2 * _numel(result) * contracted
            elif op in _ELEMENTWISE:
                flops += _numel(result)
            elif op == "reduce":
                flops += _numel(self._operand_shapes(name, ins))
            if not flops_only:
                nbytes += _nbytes(result)
                nbytes += _nbytes(self._operand_shapes(name, ins))

        out = (flops, nbytes, colls)
        self._memo[key] = out
        return out
