"""Prometheus-style metrics: counters, gauges, histograms, exposition.

The serving tier's operational surface: queue depth, slab fill
fraction, packing-group slab counts, plan-cache hit/miss/evictions,
fault reissues and per-ticket latency percentiles all live in a
:class:`Registry` that renders the standard text exposition format
(``# TYPE`` headers + ``name{label="v"} value`` samples), so
``Service.metrics()`` can be scraped, diffed in CI, or parsed back
with :func:`parse_exposition`.

This is deliberately dependency-free and host-side — metrics are
updated from ordinary Python control flow (scheduler ticks, sink
deliveries), never from inside jitted programs.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "parse_exposition",
           "DEFAULT_BUCKETS"]

# latency-ish default bucket bounds in seconds (upper-inclusive, +Inf last)
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0)


def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.help = help
        self.labels = tuple(sorted((labels or {}).items()))

    def samples(self) -> Iterable[Tuple[str, Tuple[Tuple[str, str], ...], float]]:
        raise NotImplementedError


class Counter(_Metric):
    """Monotonically increasing count."""
    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        super().__init__(name, help, labels)
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {v})")
        self.value += v

    def samples(self):
        yield self.name, self.labels, self.value


class Gauge(_Metric):
    """Point-in-time value; ``fn`` makes it a callback gauge whose
    value is read at render time (live queue depths, cache sizes)."""
    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None,
                 fn: Optional[Callable[[], float]] = None):
        super().__init__(name, help, labels)
        self._value = 0.0
        self.fn = fn

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self._value += v

    def dec(self, v: float = 1.0) -> None:
        self._value -= v

    @property
    def value(self) -> float:
        return float(self.fn()) if self.fn is not None else self._value

    def samples(self):
        yield self.name, self.labels, self.value


class Histogram(_Metric):
    """Cumulative-bucket histogram plus a bounded reservoir for
    percentiles (the exposition carries the buckets; ``percentile`` is
    a host-side convenience over the most recent observations)."""
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                 reservoir: int = 8192):
        super().__init__(name, help, labels)
        self.bounds = tuple(sorted(buckets))
        self.counts = [0] * (len(self.bounds) + 1)   # +Inf last
        self.sum = 0.0
        self.count = 0
        self._recent: deque = deque(maxlen=reservoir)

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        self._recent.append(v)
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def percentile(self, q: float) -> Optional[float]:
        """q in [0, 1] over the retained reservoir (None when empty)."""
        if not self._recent:
            return None
        vals = sorted(self._recent)
        idx = min(len(vals) - 1, max(0, int(round(q * (len(vals) - 1)))))
        return vals[idx]

    def samples(self):
        cum = 0
        for b, c in zip(self.bounds + (math.inf,), self.counts):
            cum += c
            yield (f"{self.name}_bucket",
                   self.labels + (("le", _fmt_value(b)),), float(cum))
        yield f"{self.name}_sum", self.labels, self.sum
        yield f"{self.name}_count", self.labels, float(self.count)


class Registry:
    """A named set of metrics rendering one text exposition.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent
    per (name, labels)), so instrumentation sites don't need wiring
    order guarantees.
    """

    def __init__(self, prefix: str = ""):
        self.prefix = prefix
        self._metrics: "Dict[Tuple[str, tuple], _Metric]" = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, labels, **kw) -> _Metric:
        name = self.prefix + name
        key = (name, tuple(sorted((labels or {}).items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(name, help, labels, **kw)
            return m

    def counter(self, name: str, help: str = "", labels=None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels=None,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get(Gauge, name, help, labels, fn=fn)
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, help: str = "", labels=None,
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def collect(self) -> List[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def render(self) -> str:
        """The Prometheus text exposition (format version 0.0.4)."""
        lines: List[str] = []
        seen_headers = set()
        for m in sorted(self.collect(), key=lambda m: (m.name, m.labels)):
            if m.name not in seen_headers:
                seen_headers.add(m.name)
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                lines.append(f"# TYPE {m.name} {m.kind}")
            for sample_name, labels, value in m.samples():
                lines.append(
                    f"{sample_name}{_fmt_labels(labels)} {_fmt_value(value)}")
        return "\n".join(lines) + "\n"

    def to_dict(self) -> Dict[str, float]:
        """Flat ``{sample_name{labels}: value}`` view (tests, JSON)."""
        out: Dict[str, float] = {}
        for m in self.collect():
            for sample_name, labels, value in m.samples():
                out[f"{sample_name}{_fmt_labels(labels)}"] = value
        return out


def parse_exposition(text: str) -> Dict[str, float]:
    """Parse a text exposition back into ``{name{labels}: value}``.

    Strict enough to be the CI assertion that ``Service.metrics()``
    stays well-formed: every non-comment line must be
    ``name[{labels}] value`` with a float-parseable value, and every
    sample must be preceded by a ``# TYPE`` header for its family.
    """
    out: Dict[str, float] = {}
    typed: set = set()
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 3 and parts[1] == "TYPE":
                typed.add(parts[2])
            continue
        name_part, _, value_part = line.rpartition(" ")
        if not name_part:
            raise ValueError(f"line {lineno}: no value in {line!r}")
        value = math.inf if value_part == "+Inf" else float(value_part)
        family = name_part.split("{")[0]
        base = family
        for suffix in ("_bucket", "_sum", "_count"):
            if family.endswith(suffix):
                base = family[: -len(suffix)]
        if family not in typed and base not in typed:
            raise ValueError(f"line {lineno}: sample {family!r} has no "
                             f"# TYPE header")
        out[name_part] = value
    return out
