"""repro.obs — spans, metrics and phase-attributed tracing.

Zero-overhead-when-disabled, host-side-only observability for the
whole stack: plan emitters open ``plan/*`` spans, the runtime opens
``wave``/``run``/``slab`` spans (with device time split out at
``block_until_ready`` boundaries) and emits compile-cache events, and
the serving tier keeps queue/slab/cache/latency metrics in a
Prometheus-style registry.

    from repro import obs

    with obs.capture() as tr:
        generate(spec, P=8)
    print(tr.phase_totals())          # {'plan_s': .., 'exec_s': .., 'sink_s': ..}
    tr.export_chrome("trace.json")    # load in ui.perfetto.dev

See ``src/repro/obs/README.md`` for the span/metric inventory and the
profiling recipes.
"""
from .metrics import (Counter, Gauge, Histogram, Registry, parse_exposition,
                      DEFAULT_BUCKETS)
from .tracer import (NULL_SPAN, PHASES, Span, SpanRecord, Tracer, capture,
                     disable, enable, event, export_chrome, is_enabled,
                     jax_profiler_trace, phase_totals, trace, tracer)

__all__ = [
    # tracer
    "NULL_SPAN", "PHASES", "Span", "SpanRecord", "Tracer", "capture",
    "disable", "enable", "event", "export_chrome", "is_enabled",
    "jax_profiler_trace", "phase_totals", "trace", "tracer",
    # metrics
    "Counter", "Gauge", "Histogram", "Registry", "parse_exposition",
    "DEFAULT_BUCKETS",
]
