"""Host-side span tracer: phase-attributed timing for plan → wave → serve.

The paper's headline numbers rest on *per-phase* timing discipline —
its tables separate chunk planning from generation from I/O — and the
repo's own ``BENCH_pairs.json`` shows why: device exec beats the
retired host loops by 30–1378x while end-to-end gains stall at 1–2x
because host plan emission dominates.  This tracer is how a slow run
gets attributed: every layer opens named spans (``plan/gnm``,
``wave/dispatch``, ``sink/deliver``, …) tagged with a coarse *phase*
(``plan`` / ``exec`` / ``sink``), and :meth:`Tracer.phase_totals`
folds them into the ``plan_s``/``exec_s``/``sink_s`` breakdown the
benchmark records carry.

Design constraints, in order:

* **Zero overhead when disabled.**  Tracing is off by default;
  :func:`trace` then returns one shared no-op context manager — no
  span object, no event record, no clock read.  Instrumented hot paths
  stay within noise (< 2% on the streaming benchmarks).
* **Host-side only.**  Spans never cross into jitted programs — no
  host callbacks in lowered IR, so ``repro.analyze``'s contract scan
  is unaffected by instrumentation.  Device time is attributed by
  closing a span after ``jax.block_until_ready`` at the call site
  (the runtime does this only while tracing is enabled).
* **Monotonic clocks, thread-safe, nestable.**  Spans use
  ``time.perf_counter_ns`` (never wall-clock-of-day), keep a
  per-thread stack for parent attribution, and append finished records
  under a lock.

Export targets the Chrome trace-event JSON schema (``chrome://tracing``
/ `Perfetto <https://ui.perfetto.dev>`_ both load it); an optional
bridge mirrors spans into ``jax.profiler`` annotations so they appear
inside TensorBoard device traces.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Span", "SpanRecord", "Tracer", "trace", "event", "enable", "disable",
    "is_enabled", "tracer", "capture", "phase_totals", "export_chrome",
    "jax_profiler_trace", "PHASES",
]

# the canonical phase names benchmark records report
PHASES = ("plan", "exec", "sink")


@dataclass(frozen=True)
class SpanRecord:
    """One finished span (or instant event, when ``dur_ns`` is 0 and
    ``instant`` is set)."""
    name: str
    t0_ns: int                  # perf_counter_ns at entry
    dur_ns: int
    tid: int                    # python thread ident
    span_id: int
    parent_id: int              # 0 = top level
    attrs: Dict[str, Any] = field(default_factory=dict)
    instant: bool = False

    @property
    def seconds(self) -> float:
        return self.dur_ns / 1e9

    @property
    def phase(self) -> Optional[str]:
        p = self.attrs.get("phase")
        return p if isinstance(p, str) else None


class _NullSpan:
    """The shared disabled-path context manager: no state, no clock."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    """One live span; created by :meth:`Tracer.span` only while the
    tracer is enabled.  Context-manager protocol: the clock starts at
    ``__enter__`` and the record is appended at ``__exit__``."""
    __slots__ = ("_tracer", "name", "attrs", "_t0", "_id", "_parent", "_jax")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._t0 = 0
        self._id = 0
        self._parent = 0
        self._jax = None

    def set(self, **attrs) -> None:
        """Attach attributes after entry (e.g. counts known at exit)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        tr = self._tracer
        stack = tr._stack()
        self._parent = stack[-1] if stack else 0
        self._id = tr._next_id()
        stack.append(self._id)
        if tr.jax_annotations:
            self._jax = _jax_annotation(self.name)
            if self._jax is not None:
                self._jax.__enter__()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        dur = time.perf_counter_ns() - self._t0
        if self._jax is not None:
            self._jax.__exit__(*exc)
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] == self._id:
            stack.pop()
        tr._record(SpanRecord(self.name, self._t0, dur,
                              threading.get_ident(), self._id, self._parent,
                              self.attrs))
        return False


def _jax_annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` for ``name``, or None when
    the bridge is unavailable (jax absent / API moved)."""
    try:
        from jax.profiler import TraceAnnotation
    except Exception:
        return None
    return TraceAnnotation(name)


class Tracer:
    """Collects spans + instant events; one module-global instance is
    the default target of :func:`trace` / :func:`event`.

    ``enabled`` is the single hot-path check: every instrumentation
    point reads it (via :func:`trace`) and gets :data:`NULL_SPAN` back
    when tracing is off.
    """

    def __init__(self, enabled: bool = False, jax_annotations: bool = False):
        self.enabled = bool(enabled)
        self.jax_annotations = bool(jax_annotations)
        self._records: List[SpanRecord] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._counter = 0

    # ------------------------------------------------------------ plumbing

    def _stack(self) -> List[int]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _next_id(self) -> int:
        with self._lock:
            self._counter += 1
            return self._counter

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            self._records.append(rec)

    # ------------------------------------------------------------ recording

    def span(self, name: str, **attrs) -> Span:
        """An *unconditional* span (records even when ``enabled`` is
        False is NOT the contract — callers go through :func:`trace`;
        this constructor assumes the enabled check already happened)."""
        return Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """Record an instant event (compile-cache hit, fault reissue)."""
        stack = self._stack()
        self._record(SpanRecord(name, time.perf_counter_ns(), 0,
                                threading.get_ident(), self._next_id(),
                                stack[-1] if stack else 0, attrs,
                                instant=True))

    def clear(self) -> None:
        with self._lock:
            self._records = []
            self._counter = 0

    # ------------------------------------------------------------ reading

    def spans(self) -> List[SpanRecord]:
        """Finished records, in completion order (leaf-before-parent)."""
        with self._lock:
            return list(self._records)

    def phase_totals(self) -> Dict[str, float]:
        """Seconds per phase: ``{"plan_s": .., "exec_s": .., "sink_s": ..}``.

        Nesting-aware: a span whose *ancestor* already carries the same
        phase contributes nothing (its time is inside the ancestor), so
        e.g. a reseed emitter that re-enters a cold ``plan/...`` span
        never double-counts.
        """
        recs = self.spans()
        by_id = {r.span_id: r for r in recs}
        totals = {p: 0.0 for p in PHASES}
        for r in recs:
            p = r.phase
            if p not in totals or r.instant:
                continue
            anc = by_id.get(r.parent_id)
            shadowed = False
            while anc is not None:
                if anc.phase == p:
                    shadowed = True
                    break
                anc = by_id.get(anc.parent_id)
            if not shadowed:
                totals[p] += r.seconds
        return {f"{p}_s": t for p, t in totals.items()}

    def summary(self) -> Dict[str, Any]:
        """Aggregate view: per-name counts/totals plus the phase fold."""
        agg: Dict[str, Dict[str, float]] = {}
        for r in self.spans():
            a = agg.setdefault(r.name, {"count": 0, "total_s": 0.0})
            a["count"] += 1
            a["total_s"] += r.seconds
        return {"phases": self.phase_totals(), "spans": agg}

    # ------------------------------------------------------------ export

    def export_chrome(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event JSON (Perfetto-loadable).

        Complete ``X`` (duration) events for spans, ``i`` (instant)
        events for counters; timestamps in microseconds per the schema.
        Writes to ``path`` when given; always returns the dict.
        """
        tids = {}
        events = []
        for r in self.spans():
            tid = tids.setdefault(r.tid, len(tids) + 1)
            ev = {
                "name": r.name,
                "cat": r.phase or "span",
                "ph": "i" if r.instant else "X",
                "ts": r.t0_ns / 1e3,
                "pid": 1,
                "tid": tid,
                "args": {k: _jsonable(v) for k, v in r.attrs.items()},
            }
            if r.instant:
                ev["s"] = "t"
            else:
                ev["dur"] = r.dur_ns / 1e3
            events.append(ev)
        out = {
            "traceEvents": sorted(events, key=lambda e: e["ts"]),
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs", "phases": self.phase_totals()},
        }
        if path is not None:
            with open(path, "w") as f:
                json.dump(out, f, indent=1)
                f.write("\n")
        return out


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


# --------------------------------------------------------------------------
# module-global tracer + the hot-path entry points
# --------------------------------------------------------------------------

_TRACER = Tracer()


def tracer() -> Tracer:
    """The current global tracer."""
    return _TRACER


def is_enabled() -> bool:
    return _TRACER.enabled


def enable(jax_annotations: bool = False, clear: bool = False) -> Tracer:
    """Turn tracing on (optionally mirroring spans into
    ``jax.profiler`` annotations); returns the tracer."""
    if clear:
        _TRACER.clear()
    _TRACER.jax_annotations = bool(jax_annotations)
    _TRACER.enabled = True
    return _TRACER


def disable() -> Tracer:
    _TRACER.enabled = False
    return _TRACER


def trace(name: str, **attrs):
    """Open a span (context manager) — THE instrumentation entry point.

    Disabled path: returns the shared :data:`NULL_SPAN` singleton —
    nothing is allocated by this module and no clock is read."""
    if not _TRACER.enabled:
        return NULL_SPAN
    return _TRACER.span(name, **attrs)


def event(name: str, **attrs) -> None:
    """Record an instant event (no-op while disabled)."""
    if _TRACER.enabled:
        _TRACER.instant(name, **attrs)


def phase_totals() -> Dict[str, float]:
    return _TRACER.phase_totals()


def export_chrome(path: Optional[str] = None) -> dict:
    return _TRACER.export_chrome(path)


@contextlib.contextmanager
def capture(jax_annotations: bool = False) -> Iterator[Tracer]:
    """Scoped tracing: install a *fresh* enabled tracer for the block,
    restore the previous one after.

        with obs.capture() as tr:
            generate(spec, P)
        print(tr.phase_totals())
    """
    global _TRACER
    prev = _TRACER
    _TRACER = Tracer(enabled=True, jax_annotations=jax_annotations)
    try:
        yield _TRACER
    finally:
        _TRACER = prev


@contextlib.contextmanager
def jax_profiler_trace(logdir: str) -> Iterator[None]:
    """Bridge to the JAX device profiler: wraps ``jax.profiler.trace``
    so a traced region also produces a TensorBoard-loadable device
    profile next to the host-side span trace.  No-op if jax's profiler
    is unavailable (e.g. headless minimal builds)."""
    try:
        from jax.profiler import trace as _jtrace
    except Exception:
        yield
        return
    with _jtrace(logdir):
        yield
