"""One ``GraphSpec -> plan -> run`` front door for all seven families.

The paper's pitch is a *single* communication-free paradigm behind many
network models; this module is that paradigm as one library interface
(the KaGen shape):

1. **Spec**: a frozen dataclass (:class:`GNM`, :class:`GNP`,
   :class:`RGG`, :class:`RHG`, :class:`RDG`, :class:`BA`, :class:`RMAT`,
   :class:`SBM`) carrying seed + model parameters.
2. **Plan**: ``spec.plan(P, rng_impl=...)`` runs the host-side O(P)-ish
   divide-and-conquer recursion and emits the per-PE table
   (``ChunkPlan`` for sampled families, a geometry-kind-tagged
   ``PairPlan`` for RGG/RHG/RDG edges) that :mod:`repro.distrib.engine`
   executes as one zero-collective SPMD program.  ``PointPlan`` vertex
   tables remain available from the geometric emitters for callers that
   want positions only.
3. **Run / stream**: :func:`generate` executes the plan and returns a
   :class:`Graph`; :func:`iter_edge_chunks` yields fixed-capacity edge
   buffers chunk-by-chunk and :func:`iter_points` streams the
   geometric families' vertex positions — per-chunk counts are host
   data, so a 2^30-edge instance is consumed in O(capacity) memory
   instead of one [P, C, cap, 2] materialization.  Both execution
   paths live in :mod:`repro.distrib.runtime`: the streams ride its
   mesh-wide *wave* dispatch (``mesh=``, ``batch=``, ``prefetch=``),
   so streaming throughput scales with device count too.

Every spec produces the identical edge set for any P: the instance is
a function of the *virtual chunk grid* (the spec's ``chunks`` field,
default ``max(P, 16)`` — KaGen's chunks >= PEs decoupling), and P only
decides which PE executes which chunk/cell/pair.  (This is also why
:class:`RHG` runs on the P-independent engine cell layout rather than
the per-PE reference generator, whose cell grid is coupled to P.)

    >>> from repro.api import GNM, generate
    >>> g = generate(GNM(n=1000, m=8000, seed=1), P=4)
    >>> g.m, g.n
    (8000, 1000)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Protocol, Tuple, Union, runtime_checkable

import numpy as np

from .core import ba as _ba
from .core import er as _er
from .core import graph as _graph
from .core import rdg as _rdg
from .core import rgg as _rgg
from .core import rhg as _rhg
from .core import rmat as _rmat
from .core import sbm as _sbm
from .distrib import engine, runtime
from . import obs

DEFAULT_RNG = "threefry2x32"

# default virtual chunk-grid size: any P <= 16 generates the identical
# instance; larger machines grow the grid (chunks >= PEs) unless the
# spec pins `chunks` explicitly.
DEFAULT_CHUNKS = 16

Plan = Union["engine.ChunkPlan", "engine.PointPlan", "engine.PairPlan"]


def _virtual_chunks(chunks: Optional[int], P: int) -> int:
    return chunks if chunks else max(P, DEFAULT_CHUNKS)


# --------------------------------------------------------------------------
# results
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Graph:
    """Generated edge list plus the metadata needed to interpret it."""
    edges: np.ndarray               # int64 [m, 2]
    n: int                          # number of vertices
    directed: bool = False
    points: Optional[np.ndarray] = None  # geometric families, [n, dim]

    @property
    def m(self) -> int:
        return len(self.edges)

    def degrees(self) -> np.ndarray:
        return _graph.degrees(self.edges, self.n, self.directed)


@dataclass(frozen=True)
class EdgeChunk:
    """One streamed chunk: a fixed-capacity device buffer + validity.

    ``mask`` is the authoritative validity (:meth:`edges` uses it when
    present); ``count`` is the host-known number of valid edges in the
    chunk — for an *unbatched* ChunkPlan buffer that is also a
    contiguous prefix length (``buffer[:count]`` is valid), but for
    batched buffers (``[b, cap, 2]``) validity is per row, so slice by
    ``mask``, never by ``count``.  Candidate-pair buffers have
    scattered validity and carry ``mask`` only.  The buffer never
    exceeds the plan's static capacity (times the stream ``batch``),
    which is how the streaming path keeps peak memory independent of
    total edge count.

    ``pe`` is the virtual PE that owns (emitted) this chunk — the
    plan's ownership stream index surfaced in-band as stream metadata
    (placement debugging, per-PE load accounting; a chunk never mixes
    PEs).  Note that :mod:`repro.stats` routes by *vertex* ownership,
    not chunk ownership: the stream being an exact once-per-chunk
    union is what its accumulators rely on, and that holds regardless
    of ``pe``.
    """
    buffer: object                  # [cap, 2] / [b, cap, 2] (device or host)
    count: Optional[int] = None     # valid edges in this chunk (ChunkPlan)
    mask: Optional[object] = None   # bool validity, same leading shape
    pe: Optional[int] = None        # owning virtual PE

    def edges(self) -> np.ndarray:
        """Materialize this chunk's valid edges on the host."""
        if self.mask is not None:
            return np.asarray(self.buffer)[np.asarray(self.mask)]
        return np.asarray(self.buffer)[: self.count]


@dataclass(frozen=True)
class PointChunk:
    """One streamed vertex-cell buffer: positions + validity + owner.

    The point analog of :class:`EdgeChunk` — :func:`iter_points` yields
    these so vertex positions of huge geometric instances stream in
    O(capacity) buffers instead of the [P, C, cap, dim]
    materialization of ``engine.run_points``."""
    buffer: object                  # [cap, dim] or [b, cap, dim] positions
    mask: object                    # bool [cap] / [b, cap] validity
    pe: Optional[int] = None        # owning virtual PE

    def points(self) -> np.ndarray:
        """Materialize this chunk's valid positions on the host."""
        return np.asarray(self.buffer)[np.asarray(self.mask)]


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------

@runtime_checkable
class GraphSpec(Protocol):
    """What every family spec provides: parameters + a plan emitter."""
    seed: int

    @property
    def num_vertices(self) -> int: ...

    @property
    def directed(self) -> bool: ...

    def plan(self, P: int, *, rng_impl: str = DEFAULT_RNG) -> Plan: ...


@dataclass(frozen=True)
class GNM:
    """Erdős-Rényi G(n, m): exactly m distinct edges (paper §4).

    ``chunks`` sizes the virtual chunk grid (the instance); the legacy
    per-PE generators correspond to ``chunks == P``."""
    n: int
    m: int
    directed: bool = False
    seed: int = 0
    chunks: Optional[int] = None

    @property
    def num_vertices(self) -> int:
        return self.n

    def plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        k = _virtual_chunks(self.chunks, P)
        f = _er.gnm_directed_plan if self.directed else _er.gnm_undirected_plan
        return engine.deal_plan(f(self.seed, self.n, self.m, k, rng_impl), P)


@dataclass(frozen=True)
class GNP:
    """Erdős-Rényi G(n, p): Bernoulli(p) per vertex pair (paper §4.3)."""
    n: int
    p: float
    directed: bool = False
    seed: int = 0
    chunks: Optional[int] = None

    @property
    def num_vertices(self) -> int:
        return self.n

    def plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        k = _virtual_chunks(self.chunks, P)
        f = _er.gnp_directed_plan if self.directed else _er.gnp_undirected_plan
        return engine.deal_plan(f(self.seed, self.n, self.p, k, rng_impl), P)


@dataclass(frozen=True)
class RGG:
    """Random geometric graph in [0,1)^dim: edge iff dist <= radius (§5)."""
    n: int
    radius: float
    dim: int = 2
    seed: int = 0
    chunks: Optional[int] = None
    directed: bool = False

    @property
    def num_vertices(self) -> int:
        return self.n

    def plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        return _rgg.rgg_pair_plan(self.seed, self.n, self.radius, P, self.dim,
                                  rng_impl, chunk_P=_virtual_chunks(self.chunks, P))

    def point_plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        """PointPlan over the same virtual cell grid the edge plan
        regenerates, so streamed positions match ``Graph.points``."""
        return _rgg.rgg_point_plan(self.seed, self.n, self.radius, P, self.dim,
                                   rng_impl, chunk_P=_virtual_chunks(self.chunks, P))


@dataclass(frozen=True)
class RHG:
    """Threshold random hyperbolic graph (paper §7), power-law exponent
    ``gamma``, target average degree ``avg_deg``."""
    n: int
    avg_deg: float
    gamma: float
    seed: int = 0
    directed: bool = False

    @property
    def num_vertices(self) -> int:
        return self.n

    @property
    def params(self) -> _rhg.RHGParams:
        return _rhg.RHGParams(n=self.n, avg_deg=self.avg_deg,
                              gamma=self.gamma, seed=self.seed)

    def plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        return _rhg.rhg_pair_plan(self.params, P, rng_impl)

    def point_plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        """Polar PointPlan over the engine cell layout — the same
        hashed streams the pair plan recomputes for its edge tests."""
        return _rhg.rhg_engine_point_plan(self.params, P, rng_impl)


@dataclass(frozen=True)
class RDG:
    """Random Delaunay graph on the unit torus [0,1)^dim (paper §6)."""
    n: int
    dim: int = 2
    seed: int = 0
    chunks: Optional[int] = None
    directed: bool = False

    @property
    def num_vertices(self) -> int:
        return self.n

    def plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        return _rdg.rdg_pair_plan(self.seed, self.n, P, self.dim, rng_impl,
                                  chunk_P=self.chunks or 0)

    def plan_segment(self, P: int, lo: int, hi: int, *,
                     rng_impl: str = DEFAULT_RNG):
        """Lazily emit the plan rows of PEs [lo, hi) only.  The device
        triangulation passes run once per seed (cached on the RDG
        planning structure); each segment just deals its PE slice."""
        return _rdg.rdg_plan_segment(self.seed, self.n, P, lo, hi, self.dim,
                                     rng_impl, chunk_P=self.chunks or 0)

    def point_plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        """PointPlan over the RDG cell grid (same virtual chunk grid as
        the simplex-certificate edge plan)."""
        return _rdg.rdg_point_plan(self.seed, self.n, P, self.dim, rng_impl,
                                   chunk_P=self.chunks or 0)


@dataclass(frozen=True)
class BA:
    """Barabási-Albert preferential attachment, d edges per vertex
    (Sanders-Schulz chain resolution, paper §3.5.1)."""
    n: int
    d: int
    seed: int = 0
    directed: bool = True

    @property
    def num_vertices(self) -> int:
        return self.n

    def plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        return _ba.ba_plan(self.seed, self.n, self.d, P, rng_impl)


@dataclass(frozen=True)
class RMAT:
    """R-MAT with 2^log_n vertices and m edges (Graph 500 semantics:
    self-loops and duplicates kept; paper §3.5.2)."""
    log_n: int
    m: int
    probs: Tuple[float, float, float, float] = (0.57, 0.19, 0.19, 0.05)
    seed: int = 0
    directed: bool = True

    @property
    def num_vertices(self) -> int:
        return 1 << self.log_n

    def plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        return _rmat.rmat_plan(self.seed, self.log_n, self.m, P, self.probs, rng_impl)


@dataclass(frozen=True)
class SBM:
    """Stochastic block model: ``blocks`` equal groups, within-block
    probability p_in, cross-block p_out (paper §Future-Work)."""
    n: int
    blocks: int
    p_in: float
    p_out: float
    seed: int = 0
    directed: bool = False

    @property
    def num_vertices(self) -> int:
        return self.n

    def plan(self, P: int, *, rng_impl: str = DEFAULT_RNG):
        return _sbm.sbm_plan(self.seed, self.n, self.blocks,
                             self.p_in, self.p_out, P, rng_impl)

    def plan_segment(self, P: int, lo: int, hi: int, *,
                     rng_impl: str = DEFAULT_RNG):
        """Lazily emit the plan rows of PEs [lo, hi) only — the
        PE-range build :func:`plan_emitter` hands to the runtime's
        plan/execute overlap (cost scales with ``(hi - lo) / P``)."""
        return _sbm.sbm_plan_segment(self.seed, self.n, self.blocks,
                                     self.p_in, self.p_out, P, lo, hi,
                                     rng_impl)


# --------------------------------------------------------------------------
# execution
# --------------------------------------------------------------------------
#
# All execution — jit + shard_map, compile caching keyed on the plan's
# static signature, the once-per-program zero-collective assertion, and
# both the materializing and the wave-streaming paths — lives in
# repro.distrib.runtime.  Every plan type implements the runtime's
# PlanProgram protocol, so this module only extracts edges from the
# (payload, valid) outputs.


def _run_plan_edges(plan, mesh, check) -> np.ndarray:
    edges, keep, _ = runtime.run(plan, mesh, check=check)
    with obs.trace("extract", phase="sink"):
        return np.asarray(edges)[np.asarray(keep)]


def _geometric_points(spec, P: int, rng_impl: str) -> np.ndarray:
    """All vertex positions of a geometric spec in gid order (the
    ``return_points`` payload; oracle input for brute-force parity)."""
    if isinstance(spec, RHG):
        return _rhg.rhg_engine_all_points(spec.params, rng_impl)
    if isinstance(spec, RGG):
        grid = _rgg.make_grid(spec.n, spec.radius,
                              _virtual_chunks(spec.chunks, P), spec.dim)
    else:
        grid = _rdg.rdg_grid(
            spec.n, spec.chunks or _rdg.default_chunk_P(P, spec.dim), spec.dim)
    return _rgg_grid_points(spec.seed, grid, spec.n, rng_impl)


# --------------------------------------------------------------------------
# the public entry points
# --------------------------------------------------------------------------

def generate(
    spec: GraphSpec,
    P: int = 1,
    *,
    mesh=None,
    rng_impl: str = DEFAULT_RNG,
    check: bool = True,
    return_points: bool = False,
) -> Graph:
    """Generate ``spec`` across P virtual PEs; returns a :class:`Graph`.

    The edge set is identical for every P.  ``check=True`` asserts the
    zero-collective invariant on the lowered engine HLO (once per
    distinct program).  ``return_points`` additionally fills
    ``Graph.points`` for the geometric families (RGG/RDG/RHG).
    """
    plan = spec.plan(P, rng_impl=rng_impl)
    points = None
    if isinstance(plan, (engine.ChunkPlan, engine.PairPlan)):
        edges = _run_plan_edges(plan, mesh, check)
        if return_points and isinstance(plan, engine.PairPlan):
            points = _geometric_points(spec, P, rng_impl)
    else:
        raise TypeError(f"unknown plan type {type(plan).__name__}")
    return Graph(edges=edges, n=spec.num_vertices,
                 directed=spec.directed, points=points)


def collect(spec: GraphSpec, P: int = 1, **kwargs):
    """Streaming analytics over ``spec``: :func:`repro.stats.collect`.

    Convenience re-export so the generate/measure pair lives behind one
    front door; see :mod:`repro.stats` for the metric definitions."""
    from . import stats as _stats

    return _stats.collect(spec, P, **kwargs)


def validate(spec: GraphSpec, P: int = 1, **kwargs):
    """Goodness-of-fit of ``spec``'s output against its closed-form
    model law: :func:`repro.stats.validate` (re-export)."""
    from . import stats as _stats

    return _stats.validate(spec, P, **kwargs)


def verify_contracts(spec: GraphSpec, P: int = 1, *, mesh=None,
                     batch: int = 4, raise_on_violation: bool = True):
    """Statically verify ``spec``'s communication-free contracts.

    Lowers every program the spec emits (its edge plan and, for
    geometric families, its point plan — through both the runtime's
    materializing run step and the shard_map'd wave step) and walks the
    modules with :mod:`repro.analyze` Pass 1: zero collectives, no host
    callbacks, deterministic counter PRNG on recompute paths, static
    shapes.  Nothing executes — this is the paper's §2 invariant
    checked on the lowered IR, the same scanner ``generate(...,
    check=True)`` asserts with at runtime.  Returns the per-program
    reports; raises ``AssertionError`` on any violation unless
    ``raise_on_violation=False``.
    """
    from .analyze import programs as _programs

    reports = _programs.scan_spec(spec, P, mesh=mesh, batch=batch,
                                  name=type(spec).__name__.lower())
    bad = [r for r in reports if not r.ok]
    if bad and raise_on_violation:
        lines = [f"{r.name}: " + (r.error or "; ".join(
            f.detail for f in r.scan.findings)) for r in bad]
        raise AssertionError(
            "static contract violations:\n  " + "\n  ".join(lines))
    return reports


def _rgg_grid_points(seed: int, grid, n: int,
                     rng_impl: str = DEFAULT_RNG) -> np.ndarray:
    """All points of a cube cell grid in gid order (RGG/RDG helper);
    follows the same hashed stream the pair plans regenerate on device."""
    counter = _rgg.CellCounter(seed, grid, n)
    cells = [tuple(c) for c in np.ndindex(*([grid.g] * grid.dim))]
    pos, counts, offsets, _ = _rgg.points_for_cells(seed, grid, counter, cells,
                                                    rng_impl)
    out = np.zeros((n, grid.dim))
    for i in range(len(cells)):
        out[offsets[i]: offsets[i] + counts[i]] = pos[i][: counts[i]]
    return out


def plan_emitter(
    spec: GraphSpec,
    P: int = 1,
    *,
    segments: int = 0,
    rng_impl: str = DEFAULT_RNG,
) -> "runtime.PlanEmitter":
    """A lazily segmented plan for ``spec``: the input of the runtime's
    plan/execute overlap path (:class:`repro.distrib.runtime.PlanEmitter`).

    Families that implement ``plan_segment(P, lo, hi)`` (e.g.
    :class:`SBM`) emit each PE-range natively at ``(hi - lo) / P`` of
    the full plan cost, so the first segment's waves execute while the
    background planner emits the rest and time-to-first-chunk drops to
    ~``max(segment_plan_s, exec_s)``.  Other families fall back to one
    full emission *on the planner thread* (first ``build`` call) plus
    ``slice_plan`` segmentation — same ordering/bit-identity contract,
    planning merely moved off the consumer thread.  ``segments=0``
    picks the runtime default.
    """
    seg_fn = getattr(spec, "plan_segment", None)
    if seg_fn is not None:
        build = lambda lo, hi: seg_fn(P, lo, hi, rng_impl=rng_impl)
    else:
        state = {}

        def build(lo: int, hi: int):
            if "plan" not in state:
                state["plan"] = spec.plan(P, rng_impl=rng_impl)
            return engine.slice_plan(state["plan"], lo, hi)

    return runtime.PlanEmitter(P, build, segments)


def iter_edge_chunks(
    spec: GraphSpec,
    P: int = 1,
    *,
    mesh=None,
    rng_impl: str = DEFAULT_RNG,
    check: bool = False,
    batch: int = 1,
    prefetch: int = 2,
    overlap: int = 0,
) -> Iterator[EdgeChunk]:
    """Stream ``spec``'s edges as :class:`EdgeChunk` wave rows.

    Every family streams through the runtime's **wave** path: each
    dispatch executes the next ``batch`` chunks / candidate pairs of
    *every* mesh row simultaneously under ``shard_map`` (streaming
    scales with device count, not just :func:`generate`), with
    ``prefetch`` waves kept in flight so wave k+1 is dispatched before
    chunk k is consumed.  Peak memory is O(devices · batch · capacity),
    never O(total edges), and per-chunk capacities are host-known plan
    data: the consumer can size downstream buffers before any device
    work happens.

    Each chunk carries the id of its owning PE (``chunk.pe``, from the
    plan's ownership stream index; a chunk never mixes PEs).  Per-PE
    order is exact: grouping chunks by ``pe`` and concatenating
    ``chunk.edges()`` reproduces ``generate(spec, P).edges`` — and on a
    single-device mesh the stream order itself is generate order, so
    plain concatenation reproduces it too.  ``batch > 1`` yields
    batched buffers ([b, cap, 2] with a [b, cap] mask); ``mesh``
    accepts any mesh whose size divides P, including a multi-process
    ``jax.make_mesh``; ``check`` asserts the zero-collective invariant
    on the lowered wave step itself (once per program signature).

    ``overlap > 0`` streams through a lazily segmented plan
    (:func:`plan_emitter` with that many segments): plan emission runs
    on a background thread while earlier segments' waves execute, so
    cold time-to-first-chunk is ~``max(segment_plan_s, exec_s)``
    instead of ``plan_s + exec_s``.  Chunk edges, PE ids and per-PE
    order are identical to the non-overlapped stream; ``count``
    metadata is omitted (``mask`` stays authoritative).
    """
    if overlap:
        em = plan_emitter(spec, P, segments=int(overlap), rng_impl=rng_impl)
        for pe, slots, payload, valid in runtime.stream_slots(
                em, mesh=mesh, batch=batch, prefetch=prefetch, check=check):
            if batch <= 1:
                yield EdgeChunk(buffer=payload[0], mask=valid[0], pe=int(pe))
            else:
                yield EdgeChunk(buffer=payload, mask=valid, pe=int(pe))
        return
    plan = spec.plan(P, rng_impl=rng_impl)
    if not isinstance(plan, (engine.ChunkPlan, engine.PairPlan)):
        raise TypeError(f"unknown plan type {type(plan).__name__}")
    chunk_counts = plan.count if isinstance(plan, engine.ChunkPlan) else None
    for pe, slots, payload, valid in runtime.stream_slots(
            plan, mesh=mesh, batch=batch, prefetch=prefetch, check=check):
        count = (int(chunk_counts[pe, slots].sum())
                 if chunk_counts is not None else None)
        if batch <= 1:
            yield EdgeChunk(buffer=payload[0], mask=valid[0],
                            count=count, pe=int(pe))
        else:
            yield EdgeChunk(buffer=payload, mask=valid, count=count, pe=int(pe))


def iter_points(
    spec: GraphSpec,
    P: int = 1,
    *,
    mesh=None,
    rng_impl: str = DEFAULT_RNG,
    check: bool = False,
    batch: int = 1,
    prefetch: int = 2,
) -> Iterator[PointChunk]:
    """Stream a geometric spec's vertex positions as :class:`PointChunk`.

    The streaming route to ``Graph.points``: ``generate(...,
    return_points=True)`` materializes all n positions, this yields
    O(batch · capacity) cell buffers through the same runtime wave path
    as :func:`iter_edge_chunks` (whole-mesh dispatch, prefetch
    double-buffering, zero-collective-checked wave step).  Positions
    follow the exact hashed per-cell streams the family's edge plan
    recomputes, in gid order within each PE: grouping by ``pe`` and
    concatenating ``chunk.points()`` reproduces the masked
    ``engine.run_points`` output of ``spec.point_plan(P)``.
    """
    point_plan = getattr(spec, "point_plan", None)
    if point_plan is None:
        raise TypeError(
            f"{type(spec).__name__} has no vertex positions to stream "
            f"(only the geometric families RGG/RDG/RHG carry points)")
    plan = point_plan(P, rng_impl=rng_impl)
    for pe, slots, payload, valid in runtime.stream_slots(
            plan, mesh=mesh, batch=batch, prefetch=prefetch, check=check):
        if batch <= 1:
            yield PointChunk(buffer=payload[0], mask=valid[0], pe=int(pe))
        else:
            yield PointChunk(buffer=payload, mask=valid, pe=int(pe))


def serve(specs, P: int = 1, **kwargs):
    """Serve many concurrent specs off one mesh: :func:`repro.serve.serve`.

    Bit-identical to ``[generate(s, P) for s in specs]``, but requests
    resolve plans through a re-seedable cache and their ready slots
    pack into shared mixed-request slabs (see :mod:`repro.serve`).
    Keyword arguments forward to :class:`repro.serve.Service`; use the
    ``Service`` class directly for streaming consumption, continuous
    admission and per-request latency metrics."""
    from .serve import serve as _serve

    return _serve(specs, P, **kwargs)


def make_service(P: int = 1, **kwargs):
    """Construct a :class:`repro.serve.Service` (lazy front door)."""
    from .serve import Service

    return Service(P, **kwargs)
