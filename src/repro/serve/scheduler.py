"""Mixed-request slab scheduler: many plans, one device program.

The runtime's wave streamer executes one plan's next slots per
dispatch.  Serving wants the transpose: at any moment there are many
in-flight requests — different families, seeds and sizes — each a few
slots from done, and dispatching them one plan at a time would leave
the mesh mostly idle.  The scheduler packs *ready slots from different
requests* into shared ``[D, B]`` slabs (D mesh rows x B batch) and
executes them through :func:`repro.distrib.runtime.run_slab`.

This is sound because a slot is a pure function of its row — the
paper's communication-free invariant, one level down: chunk/pair rows
carry everything their device program reads, so rows from different
plans can sit in one slab without observing each other.  Two
plan-level facts make the packing *bit-exact*:

* **Capacity independence** — every per-slot draw is counter-indexed
  (:mod:`repro.core.prng`), so a chunk row executed at any capacity
  >= its own count yields the identical valid prefix, and a pair row's
  valid (i, j) hits are the same set in the same lexicographic order
  at any capacity >= its cell counts.  Slabs therefore run at a
  power-of-two *capacity class* and plans bucket into it.  The one
  exception is GEOM_CERT, whose per-edge emit bitmask is indexed by
  ``pair_slot_index(i, j, capacity)`` — those rows pack only with
  exact-capacity peers.

* **Kind dispatch is per row** — the engine's ``KIND_*`` / ``GEOM_*``
  branches select via ``jnp.where(kind == ...)`` per slot, so a slab
  may mix G(n,m), SBM and BA chunk rows (or RGG and RHG pair rows) and
  each row still takes exactly its plan's decode path.

Fault tolerance rides on the same purity: slab rows are placed by a
deterministic :class:`repro.distrib.fault.ChunkAssignment`; when mesh
rows "die" mid-slab the lost slots are retired and reissued onto the
surviving rows given by :func:`~repro.distrib.fault.reassign_after_failure`
— recomputation, never state transfer, and the delivered stream is
bit-identical because sinks reassemble by per-request sequence number.
"""
from __future__ import annotations

import queue as _queue
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import obs
from ..distrib import engine, fault, runtime

__all__ = ["SlabProgram", "Scheduler", "program_of"]


def _capacity_class(cap: int, floor: int) -> int:
    """Smallest power-of-two >= cap (>= floor): the shared slab capacity."""
    c = floor
    while c < cap:
        c <<= 1
    return c


@dataclass(frozen=True)
class SlabProgram:
    """The static device program one packing group shares.

    Any plan whose rows this program can execute bit-identically maps
    to the same ``SlabProgram`` (see :func:`program_of`), and every
    slab of the group reuses one compiled executable keyed by
    :meth:`signature`.
    """
    plan_kind: str            # "chunk" | "pair"
    capacity: int             # shared slab capacity (class bound, or exact)
    W: int                    # PRNG key words
    rng_impl: str
    kinds: Tuple[int, ...]    # KIND_* / GEOM_* branches the program lowers
    dim: int = 2              # pair: spatial dimension (static decode)
    log_n: int = 0            # chunk: RMAT descent depth (0 = no RMAT branch)
    K: int = 1                # pair: gid words
    G: int = 1                # pair: geometry features
    F: int = 1                # pair: float params

    def signature(self) -> tuple:
        return ("serve", self.plan_kind, self.capacity, self.W, self.rng_impl,
                self.kinds, self.dim, self.log_n, self.K, self.G, self.F)

    def slot_fn(self):
        if self.plan_kind == "chunk":
            return engine._edge_chunk_fn(self.capacity, self.rng_impl,
                                         self.kinds, self.log_n)
        return engine._pair_fn(self.capacity, self.rng_impl, self.kinds,
                               self.dim)

    def slab_arrays(self, D: int, B: int) -> List[np.ndarray]:
        """Fresh row tables for one ``[D, B]`` slab, padding-initialized
        exactly like the plan emitters pad their tables (geom = 1s)."""
        if self.plan_kind == "chunk":
            return [np.zeros((D, B), np.int32),            # kind (EMPTY)
                    np.zeros((D, B, self.W), np.uint32),   # key_data
                    np.zeros((D, B), np.int64),            # universe
                    np.zeros((D, B), np.int64),            # count
                    np.zeros((D, B, 3), np.int64),         # params
                    np.zeros((D, B, 4), np.float64),       # fparams
                    np.zeros((D, B), bool)]                # owned
        return [np.zeros((D, B), np.int32),                # kind (EMPTY)
                np.zeros((D, B, self.W), np.uint32),       # key_a
                np.zeros((D, B, self.W), np.uint32),       # key_b
                np.zeros((D, B), np.int64),                # count_a
                np.zeros((D, B), np.int64),                # count_b
                np.zeros((D, B, self.K), np.int64),        # gid_a
                np.zeros((D, B, self.K), np.int64),        # gid_b
                np.ones((D, B, self.G), np.float64),       # geom_a
                np.ones((D, B, self.G), np.float64),       # geom_b
                np.zeros((D, B, self.F), np.float64),      # fparams
                np.zeros((D, B), bool),                    # self_pair
                np.zeros((D, B), bool)]                    # active

    def gather_rows(self, plan) -> List[np.ndarray]:
        """Plan rows in stream order, padded to this program's widths:
        ``[S, ...]`` per input table (S = number of streamed slots)."""
        index = np.asarray(plan.stream_index(), np.int64).reshape(-1, 2)
        i, j = index[:, 0], index[:, 1]
        vals = [np.asarray(a[i, j]) for a in plan.input_arrays()]
        if self.plan_kind == "pair":
            for p, fill in ((5, 0), (6, 0), (7, 1.0), (8, 1.0), (9, 0.0)):
                width = (self.K, self.K, self.G, self.G, self.F)[p - 5]
                v = vals[p]
                if v.shape[-1] > width:
                    raise ValueError(
                        f"plan width {v.shape[-1]} exceeds program width "
                        f"{width} for input {p}")
                if v.shape[-1] < width:
                    out = np.full(v.shape[:-1] + (width,), fill, v.dtype)
                    out[..., : v.shape[-1]] = v
                    vals[p] = out
        return vals


def program_of(plan) -> SlabProgram:
    """The packing group a plan's slots execute under.

    Chunk plans of one capacity class share a program lowering all
    sampled kinds + BA (RMAT plans additionally key on their static
    descent depth), so G(n,m)/G(n,p)/SBM/BA rows pack together.  Pair
    plans without CERT rows share the HYP+TORUS program per (capacity
    class, dim), so RGG and RHG rows pack together; CERT plans key on
    their exact capacity (the emit bitmask is capacity-indexed).
    """
    if isinstance(plan, engine.ChunkPlan):
        log_n = plan.rmat_log_n
        kinds = sorted(set(engine.SAMPLED_KINDS) | {engine.KIND_BA}
                       | ({engine.KIND_RMAT} if log_n else set()))
        return SlabProgram("chunk", _capacity_class(plan.capacity, 64),
                           plan.key_data.shape[-1], plan.rng_impl,
                           tuple(kinds), log_n=log_n)
    if isinstance(plan, engine.PairPlan):
        W = plan.key_a.shape[-1]
        if engine.GEOM_CERT in plan.kinds_present:
            return SlabProgram("pair", plan.capacity, W, plan.rng_impl,
                               plan.kinds_present, dim=plan.dim,
                               K=plan.gid_a.shape[-1],
                               G=plan.geom_a.shape[-1],
                               F=plan.fparams.shape[-1])
        return SlabProgram("pair", _capacity_class(plan.capacity, 8), W,
                           plan.rng_impl,
                           (engine.GEOM_HYP, engine.GEOM_TORUS),
                           dim=plan.dim, K=1, G=max(4, plan.dim), F=2)
    raise TypeError(f"no slab program for plan type {type(plan).__name__}")


class _Group:
    """One packing group: a program plus its FIFO of pending slots."""
    __slots__ = ("program", "queue")

    def __init__(self, program: SlabProgram):
        self.program = program
        self.queue: deque = deque()   # (sink, seq, row-tuple)


class _Admission:
    """One in-flight lazily segmented request: the background planner's
    segment feed plus the request's running sequence base."""
    __slots__ = ("feed", "sink", "base")

    def __init__(self, feed: _queue.Queue, sink):
        self.feed = feed
        self.sink = sink
        self.base = 0


class Scheduler:
    """Packs pending slots from all in-flight requests into slabs.

    ``enqueue`` appends a plan's slots (in its stream order) to the
    FIFO of their packing group; each ``tick`` drains up to ``D * B``
    slots from one group into a slab and demuxes the results to the
    per-request sinks.  Requests admitted between ticks join partially
    drained queues, so their slots ride in the very next slab alongside
    older requests' remainders — continuous batching.
    """

    def __init__(self, mesh, slab_batch: int = 8, check: bool = True,
                 registry: Optional[obs.Registry] = None):
        self.mesh = mesh
        self.D = runtime.mesh_size(mesh)
        self.B = int(slab_batch)
        self.check = check
        self._groups: Dict[tuple, _Group] = {}
        self._admissions: List[_Admission] = []
        self._rr = 0
        self._fault: Optional[Tuple[int, Tuple[int, ...]]] = None
        self.slabs = 0
        self.slots = 0
        self.reissued = 0
        self.registry = registry if registry is not None \
            else obs.Registry("repro_serve_")
        r = self.registry
        self._m_slabs = r.counter("slabs_total", "slabs executed")
        self._m_slots = r.counter("slots_total", "slots executed")
        self._m_reissued = r.counter(
            "reissued_total", "slots recomputed after mesh-row faults")
        self._m_fill = r.histogram(
            "slab_fill_fraction", "occupied fraction of each [D, B] slab",
            buckets=(0.1, 0.25, 0.5, 0.75, 0.9, 1.0))
        r.gauge("queue_depth", "pending slots across packing groups",
                fn=lambda: float(self.pending))
        r.gauge("packing_groups", "live packing groups",
                fn=lambda: float(len(self._groups)))

    def enqueue(self, plan, sink) -> Optional[int]:
        """Admit one request's plan; returns its slot count.

        Accepts a :class:`repro.distrib.runtime.PlanEmitter` too: plan
        segments are then emitted on a background planner thread and
        admitted incrementally as they arrive — early segments' slots
        ride slabs while later PE ranges are still being planned, so a
        request's first results land before its plan is fully emitted.
        The sink's global sequence numbering (segment base + in-segment
        stream order) equals the full plan's stream order, so delivery
        stays bit-identical; returns ``None`` (the total slot count is
        unknown until the last segment lands)."""
        if isinstance(plan, runtime.PlanEmitter):
            self._admissions.append(
                _Admission(runtime._plan_feed(plan, 1), sink))
            self._admit_ready()
            return None
        S = self._admit(plan, sink, 0)
        sink.expect(S)
        return S

    def _admit(self, plan, sink, base: int) -> int:
        """Append one plan's slots (stream order, seqs from ``base``)
        to their packing group's FIFO; returns the slot count."""
        prog = program_of(plan)
        key = prog.signature()
        group = self._groups.get(key)
        if group is None:
            group = self._groups[key] = _Group(prog)
        vals = group.program.gather_rows(plan)
        S = len(vals[0])
        for seq in range(S):
            group.queue.append((sink, base + seq, tuple(v[seq] for v in vals)))
        return S

    def _admit_ready(self, block: bool = False) -> None:
        """Drain finished plan segments from background planners into
        packing groups.  All scheduler mutation happens here, on the
        consumer thread — planner threads only build tables.  With
        ``block=True`` (drain, nothing else runnable) wait for one
        segment if no planner has produced anything yet."""
        progressed = False
        for adm in list(self._admissions):
            while adm in self._admissions:
                try:
                    item = adm.feed.get_nowait()
                except _queue.Empty:
                    break
                progressed = True
                self._apply_segment(adm, item)
        if block and not progressed and self._admissions:
            adm = self._admissions[0]
            self._apply_segment(adm, adm.feed.get())

    def _apply_segment(self, adm: _Admission, item) -> None:
        if item is None:          # planner exhausted: total now known
            self._admissions.remove(adm)
            adm.sink.expect(adm.base)
            return
        if isinstance(item, BaseException):
            self._admissions.remove(adm)
            raise item
        _i, _lo, _hi, seg = item
        adm.base += self._admit(seg, adm.sink, adm.base)

    @property
    def pending(self) -> int:
        return sum(len(g.queue) for g in self._groups.values())

    @property
    def emitting(self) -> bool:
        """True while any admitted request's background planner is
        still emitting segments (more slots will arrive)."""
        return bool(self._admissions)

    def wait_segment(self) -> None:
        """Block until at least one pending segment has been admitted
        (no-op when nothing is emitting): the idle-but-emitting path of
        :meth:`drain` and the service loop."""
        self._admit_ready(block=True)

    def inject_fault(self, dead_rows, at_slab: Optional[int] = None) -> None:
        """Arm a one-shot failure: the given mesh rows die during slab
        ``at_slab`` (default: the next one).  Their results are
        discarded and the lost slots reissued onto survivors."""
        when = self.slabs if at_slab is None else int(at_slab)
        self._fault = (when, tuple(int(d) for d in dead_rows))

    def tick(self) -> bool:
        """Execute one slab from the next non-empty group (round-robin
        across groups so no family starves).  False when idle."""
        self._admit_ready()
        groups = [g for g in self._groups.values() if g.queue]
        if not groups:
            return False
        group = groups[self._rr % len(groups)]
        self._rr += 1
        take = min(len(group.queue), self.D * self.B)
        entries = [group.queue.popleft() for _ in range(take)]
        assignment = fault.ChunkAssignment(take, tuple(range(self.D)))
        placement = self._place(range(take), assignment.worker_of)
        self._execute(group, entries, placement, assignment)
        return True

    def _place(self, ids, worker_of) -> Dict[int, Tuple[int, int]]:
        """Deterministic slot -> (mesh row, column) placement; callers
        hand in the assignment's worker map (or the survivor remap)."""
        cols: Dict[int, int] = {}
        out: Dict[int, Tuple[int, int]] = {}
        for k in ids:
            d = worker_of(k)
            b = cols.get(d, 0)
            if b < self.B:
                out[k] = (d, b)
                cols[d] = b + 1
        return out

    def _assemble(self, prog: SlabProgram, entries, placement):
        """Fill one ``[D, B]`` slab's valid mask + row tables."""
        valid = np.zeros((self.D, self.B), bool)
        rows = prog.slab_arrays(self.D, self.B)
        for k, (d, b) in placement.items():
            valid[d, b] = True
            for arr, val in zip(rows, entries[k][2]):
                arr[d, b] = val
        return valid, rows

    def peek_slab(self):
        """Assemble (but neither dequeue nor execute) the next slab:
        ``(program, valid, rows)``.  The :mod:`repro.analyze.programs`
        registration hook — what it lowers is exactly what
        :meth:`tick` would run."""
        groups = [g for g in self._groups.values() if g.queue]
        if not groups:
            raise RuntimeError("no pending slots to assemble")
        group = groups[self._rr % len(groups)]
        take = min(len(group.queue), self.D * self.B)
        entries = [group.queue[k] for k in range(take)]
        assignment = fault.ChunkAssignment(take, tuple(range(self.D)))
        placement = self._place(range(take), assignment.worker_of)
        valid, rows = self._assemble(group.program, entries, placement)
        return group.program, valid, rows

    def _execute(self, group: _Group, entries, placement, assignment) -> None:
        prog = group.program
        valid, rows = self._assemble(prog, entries, placement)
        payload, ok = runtime.run_slab(prog.slot_fn, prog.signature(), valid,
                                       rows, self.mesh, check=self.check)
        payload, ok = np.asarray(payload), np.asarray(ok)
        self.slabs += 1
        self.slots += len(placement)
        self._m_slabs.inc()
        self._m_slots.inc(len(placement))
        self._m_fill.observe(len(placement) / float(self.D * self.B))
        self.registry.counter(
            "group_slabs_total", "slabs per packing group",
            labels={"group": prog.plan_kind}).inc()

        dead: set = set()
        if self._fault is not None and self.slabs > self._fault[0]:
            dead = set(self._fault[1])
            self._fault = None

        lost = []
        with obs.trace("serve/deliver", phase="sink", slab=self.slabs):
            for k, (d, b) in placement.items():
                sink, seq, _ = entries[k]
                if d in dead:
                    lost.append(k)
                else:
                    sink.deliver(seq, payload[d, b], ok[d, b])

        if lost:
            # retire-and-reissue: the deterministic survivor map decides
            # where every lost slot recomputes (zero state transfer).
            remap = fault.reassign_after_failure(assignment, sorted(dead))
            self.reissued += len(lost)
            self._m_reissued.inc(len(lost))
            obs.event("fault_reissue", lost=len(lost), dead=sorted(dead))
            remaining = lost
            while remaining:
                placed = self._place(remaining, remap.worker_of)
                self._execute(group, entries, placed, remap)
                remaining = [k for k in remaining if k not in placed]

    def drain(self) -> None:
        while True:
            if self.tick():
                continue
            if not self.emitting:
                return
            # idle but a background planner is still emitting: wait for
            # its next segment instead of spinning
            self.wait_segment()
