"""Generation-as-a-service: concurrent GraphSpec requests, one mesh.

:class:`Service` is the front door tying the serving tier together:

* ``submit(spec)`` resolves the request's plan through the re-seedable
  :class:`~repro.serve.plancache.PlanCache` (a warm shape costs a
  reseed, not a host D&C recursion), hands its slots to the slab
  :class:`~repro.serve.scheduler.Scheduler`, and returns a
  :class:`Ticket`.
* Requests may be submitted at any time — between ticks, mid-drain,
  from a streaming consumer's pull loop.  Their slots join partially
  drained packing queues and ride the next slab alongside older
  requests' remainders (continuous batching).
* ``Ticket.result()`` / ``Ticket.chunks()`` drive the scheduler just
  far enough to satisfy the caller, so a streaming consumer and the
  batch drain share one code path.

Every delivered request is bit-identical to ``generate(spec, P)`` —
same edges, same order — because slab packing never changes what a
slot computes (see :mod:`repro.serve.scheduler`), and the packed slab
program itself passes the zero-collective contract (asserted once per
program by the runtime's ``check`` path).
"""
from __future__ import annotations

import time
from typing import Iterable, List, Optional, Sequence

from .. import obs
from ..api import DEFAULT_RNG, GraphSpec, plan_emitter
from ..distrib import runtime
from .plancache import PlanCache
from .scheduler import Scheduler
from .sinks import ChunkSink, GraphSink, Sink

__all__ = ["Service", "Ticket", "serve"]


class Ticket:
    """Handle for one submitted request."""

    def __init__(self, service: "Service", sink: Sink, submitted: float):
        self._service = service
        self.sink = sink
        self.submitted = submitted
        self.completed: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.sink.done

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-completion wall seconds (None while in flight)."""
        if self.completed is None:
            return None
        return self.completed - self.submitted

    def result(self):
        """Block (tick the scheduler) until this request completes,
        then return the sink's result."""
        self._service.drain_until(self)
        return self.sink.result()

    def chunks(self):
        """Stream this request's edge chunks in plan order, ticking the
        scheduler between yields (requires a :class:`ChunkSink`)."""
        if not isinstance(self.sink, ChunkSink):
            raise TypeError("chunks() requires a ChunkSink request; "
                            "submit with sink='chunks'")
        while True:
            while self.sink.ready:
                yield self.sink.ready.popleft()
            if self.sink.done:
                return
            if not self._service.tick():
                raise RuntimeError("scheduler idle but request incomplete")


class Service:
    """Multi-tenant batched graph-generation service.

    ``P`` is the virtual PE count every request's plan is emitted for
    (the generated instance is a function of the spec and P, exactly
    as in ``generate``); the mesh — default the largest device set
    dividing P — is what slabs are sharded over.
    """

    def __init__(self, P: int = 1, *, mesh=None, rng_impl: str = DEFAULT_RNG,
                 slab_batch: int = 8, cache_capacity: int = 64,
                 check: bool = True):
        self.P = int(P)
        self.rng_impl = rng_impl
        self.mesh = mesh if mesh is not None else runtime.mesh_for(self.P)
        self.cache = PlanCache(cache_capacity)
        self.registry = obs.Registry("repro_serve_")
        self.scheduler = Scheduler(self.mesh, slab_batch=slab_batch,
                                   check=check, registry=self.registry)
        self._inflight: List[Ticket] = []
        self.submitted = 0
        self.completed = 0
        r = self.registry
        self._m_submitted = r.counter(
            "requests_submitted_total", "requests admitted")
        self._m_completed = r.counter(
            "requests_completed_total", "requests fully delivered")
        self._m_latency = r.histogram(
            "ticket_latency_seconds", "submit-to-completion wall seconds")
        r.gauge("inflight_requests", "admitted but incomplete requests",
                fn=lambda: float(len(self._inflight)))
        for key in ("hits", "misses", "evictions", "entries"):
            r.gauge(f"plan_cache_{key}", f"plan cache {key}",
                    fn=(lambda k=key: float(self.cache.stats[k])))

    # ------------------------------------------------------------ requests

    def submit(self, spec: GraphSpec, sink: object = "graph", *,
               overlap: int = 0) -> Ticket:
        """Admit one request; returns its :class:`Ticket` immediately.

        ``sink`` selects the consumer: ``"graph"`` (materialize),
        ``"chunks"`` (streaming), ``"stats"`` (accumulate-only), or any
        :class:`~repro.serve.sinks.Sink` instance.

        ``overlap > 0`` admits the request as a lazily segmented plan
        (:func:`repro.api.plan_emitter` with that many segments): its
        PE-range segments are emitted on a background planner thread
        and join the packing queues as they land, so early slots ride
        slabs while later ranges are still being planned — cold-start
        admission returns without paying the full ``plan_s``.  Results
        are bit-identical to the cached-plan path; the plan cache is
        bypassed (segments are not reseedable whole plans).
        """
        t0 = time.perf_counter()
        with obs.trace("serve/admit", phase="plan",
                       family=type(spec).__name__):
            if overlap:
                plan = plan_emitter(spec, self.P, segments=int(overlap),
                                    rng_impl=self.rng_impl)
            else:
                plan = self.cache.plan(spec, self.P, self.rng_impl)
        self.submitted += 1
        self._m_submitted.inc()
        if sink == "graph":
            sink = GraphSink(spec.num_vertices, spec.directed)
        elif sink == "chunks":
            sink = ChunkSink()
        elif sink == "stats":
            from .sinks import StatsSink

            sink = StatsSink(spec.num_vertices, spec.directed)
        elif not isinstance(sink, Sink):
            raise TypeError(f"unknown sink {sink!r}")
        ticket = Ticket(self, sink, t0)
        self.scheduler.enqueue(plan, sink)
        self._inflight.append(ticket)
        if ticket.done:  # zero-slot request (e.g. m == 0)
            ticket.completed = time.perf_counter()
            self._inflight.remove(ticket)
            self.completed += 1
            self._m_completed.inc()
            self._m_latency.observe(ticket.latency)
        return ticket

    # ------------------------------------------------------------ progress

    def _settle(self) -> None:
        now = time.perf_counter()
        still = []
        for t in self._inflight:
            if t.done:
                t.completed = now
                self.completed += 1
                self._m_completed.inc()
                self._m_latency.observe(t.latency)
            else:
                still.append(t)
        self._inflight = still

    def tick(self) -> bool:
        """Make progress: execute one slab, or — when slab queues are
        empty but a background planner is still emitting segments —
        wait for the next segment.  False when nothing is pending."""
        ran = self.scheduler.tick()
        if not ran and self.scheduler.emitting:
            self.scheduler.wait_segment()
            self._settle()   # a zero-trailing-slot request may finish here
            return True
        if ran:
            self._settle()
        return ran

    def drain(self) -> None:
        """Run until every admitted request has completed."""
        while self.tick():
            pass

    def drain_until(self, ticket: Ticket) -> None:
        while not ticket.done:
            if not self.tick():
                raise RuntimeError("scheduler idle but request incomplete")

    def serve(self, specs: Iterable[GraphSpec]) -> List[object]:
        """Submit every spec, drain, return per-request results in
        submission order (Graphs, for the default sink)."""
        tickets = [self.submit(s) for s in specs]
        self.drain()
        return [t.result() for t in tickets]

    # ------------------------------------------------------------ metrics

    def inject_fault(self, dead_rows: Sequence[int],
                     at_slab: Optional[int] = None) -> None:
        """Test hook: kill the given mesh rows during one upcoming slab
        (see :meth:`repro.serve.scheduler.Scheduler.inject_fault`)."""
        self.scheduler.inject_fault(dead_rows, at_slab)

    @property
    def stats(self) -> dict:
        return {
            "cache": self.cache.stats,
            "slabs": self.scheduler.slabs,
            "slots": self.scheduler.slots,
            "reissued": self.scheduler.reissued,
            "pending_slots": self.scheduler.pending,
            "submitted": self.submitted,
            "completed": self.completed,
            "inflight": len(self._inflight),
            "queue_depth": self.scheduler.pending,
        }

    def metrics(self) -> str:
        """The service's Prometheus text exposition: request counters,
        in-flight/queue gauges, latency histogram, slab fill fraction,
        packing-group slab counts, plan-cache and fault-reissue
        counters (see :func:`repro.obs.parse_exposition`)."""
        return self.registry.render()

    def latency_percentile(self, q: float) -> Optional[float]:
        """q-th ticket-latency percentile over recent completions."""
        return self._m_latency.percentile(q)


def serve(specs: Iterable[GraphSpec], P: int = 1, **kwargs) -> List[object]:
    """One-shot convenience: serve ``specs`` on a fresh :class:`Service`.

    Equivalent to ``[generate(s, P) for s in specs]`` — bit-for-bit —
    but with plan-cache reseeds and packed mixed-request slabs doing
    the work.  Keyword arguments forward to :class:`Service`.
    """
    return Service(P, **kwargs).serve(list(specs))
