"""Re-seedable plan cache: the serving tier's host-side fast path.

A plan is mostly *structure* — chunk grids, candidate-pair
enumerations, decode parameters — and structure depends only on the
spec's shape (family + every field except ``seed``), the virtual PE
count and the key impl.  Every emitter therefore attaches a
``reseed_fn`` that recomputes just the seed-dependent columns (keys,
counts) against the cached structure, so serving many seeds of one
shape costs one cold emission plus microsecond-scale reseeds instead
of a full host D&C recursion per request.  Reseeded plans are
bit-identical to cold emissions for the same seed (asserted by
tests/test_serve.py for every family).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Tuple

from .. import obs


def spec_shape(spec) -> Tuple:
    """Hashable identity of everything about ``spec`` except its seed.

    Two specs with equal shape emit plans sharing all structure tables;
    only key/count columns differ — exactly what ``reseed`` recomputes.
    """
    if not dataclasses.is_dataclass(spec):
        raise TypeError(f"spec {type(spec).__name__} is not a dataclass")
    return (type(spec).__name__,) + tuple(
        (f.name, getattr(spec, f.name))
        for f in dataclasses.fields(spec) if f.name != "seed")


class PlanCache:
    """LRU plan cache keyed by ``(spec_shape, P, rng_impl)``.

    A hit returns ``cached_plan.reseed(spec.seed)``; a miss emits cold
    via ``spec.plan`` and stores the result (which carries the reseed
    emitter and, for the geometric families, the lazily-built
    vectorized replay structure).  Counters expose hit/miss/eviction
    totals for the service stats endpoint.
    """

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: "OrderedDict[tuple, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def plan(self, spec, P: int, rng_impl: str):
        """The plan ``spec.plan(P, rng_impl=...)`` would emit, via the
        cache's reseed fast path when the shape is warm."""
        key = (spec_shape(spec), int(P), rng_impl)
        cached = self._entries.get(key)
        if cached is not None:
            try:
                out = cached.reseed(spec.seed)
            except ValueError:
                # plan carries no reseed emitter: refresh the entry cold
                cached = None
            else:
                self._entries.move_to_end(key)
                self.hits += 1
                obs.event("plan_cache", hit=True, shape=key[0][0])
                return out
        self.misses += 1
        obs.event("plan_cache", hit=False, shape=key[0][0])
        plan = spec.plan(P, rng_impl=rng_impl)
        self._entries[key] = plan
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return plan

    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self._entries)}
