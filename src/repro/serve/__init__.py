"""repro.serve — multi-tenant batched generation-as-a-service.

Many concurrent :class:`~repro.api.GraphSpec` requests (mixed
families, seeds, sizes) are served off one device mesh: plans resolve
through a re-seedable :class:`PlanCache` (structure cached by spec
shape, seeds swapped in microseconds), ready slots from different
requests pack into shared ``[devices, batch]`` slabs executed by the
communication-free engine, and per-request sinks reassemble streams
that are bit-identical to ``generate(spec, P)``.  See
``src/repro/serve/README.md`` for the architecture tour.
"""
from .plancache import PlanCache, spec_shape
from .scheduler import Scheduler, SlabProgram, program_of
from .service import Service, Ticket, serve
from .sinks import ChunkSink, GraphSink, Sink, StatsSink

__all__ = [
    "PlanCache", "spec_shape",
    "Scheduler", "SlabProgram", "program_of",
    "Service", "Ticket", "serve",
    "Sink", "GraphSink", "ChunkSink", "StatsSink",
]
