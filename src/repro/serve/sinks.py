"""Per-request result sinks: demux targets for the slab scheduler.

The scheduler delivers ``(seq, payload, mask)`` per executed slot —
possibly out of order when a fault reissues retired slots.  Every sink
reassembles by sequence number, so the consumed stream is always the
plan's stream order regardless of slab packing, admission timing or
failures: concatenating the masked rows reproduces
``generate(spec, P)`` bit-for-bit.

Three concrete sinks cover the serving surface:

* :class:`GraphSink` — materialize the request into an
  :class:`repro.api.Graph` (the ``serve()`` default),
* :class:`ChunkSink` — buffer :class:`repro.api.EdgeChunk` objects for
  streaming consumption (``Ticket.chunks()`` drives the scheduler
  between yields, so peak memory stays O(capacity)),
* :class:`StatsSink` — fold each chunk into edge-count / degree
  accumulators and drop the buffers (generation-as-measurement).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["Sink", "GraphSink", "ChunkSink", "StatsSink"]


class Sink:
    """Base sink: in-order reassembly of per-slot deliveries.

    Subclasses override ``_consume(seq, payload, mask)`` (called in
    strict sequence order) and ``_finish()`` (called once, after the
    last slot).  ``expect(total)`` arrives at admission time; a request
    with zero slots finishes immediately.
    """

    def __init__(self):
        self._pending: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._next = 0
        self._total: Optional[int] = None
        self.done = False

    def expect(self, total: int) -> None:
        self._total = int(total)
        self._maybe_finish()

    def deliver(self, seq: int, payload, mask) -> None:
        if self.done:
            raise RuntimeError(f"delivery after completion (seq {seq})")
        self._pending[seq] = (payload, mask)
        while self._next in self._pending:
            p, m = self._pending.pop(self._next)
            self._consume(self._next, p, m)
            self._next += 1
        self._maybe_finish()

    def _maybe_finish(self) -> None:
        if not self.done and self._total is not None and self._next == self._total:
            self.done = True
            self._finish()

    def _consume(self, seq: int, payload, mask) -> None:
        raise NotImplementedError

    def _finish(self) -> None:
        pass


class GraphSink(Sink):
    """Materialize the request into a :class:`repro.api.Graph` —
    the exact edges ``generate(spec, P)`` returns."""

    def __init__(self, n: int, directed: bool):
        super().__init__()
        self.n = int(n)
        self.directed = bool(directed)
        self._parts = []
        self.graph = None

    def _consume(self, seq: int, payload, mask) -> None:
        self._parts.append(np.asarray(payload)[np.asarray(mask)])

    def _finish(self) -> None:
        from ..api import Graph

        edges = (np.concatenate(self._parts) if self._parts
                 else np.zeros((0, 2), np.int64))
        self._parts = []
        self.graph = Graph(edges=edges, n=self.n, directed=self.directed)

    def result(self):
        if not self.done:
            raise RuntimeError("request not complete; drain the service")
        return self.graph


class ChunkSink(Sink):
    """Buffer per-slot edge chunks for streaming consumption.

    ``ready`` holds :class:`repro.api.EdgeChunk` objects in stream
    order; :meth:`repro.serve.service.Ticket.chunks` pops them while
    ticking the scheduler, so consumption and generation interleave.
    """

    def __init__(self):
        super().__init__()
        self.ready: deque = deque()

    def _consume(self, seq: int, payload, mask) -> None:
        from ..api import EdgeChunk

        mask = np.asarray(mask)
        self.ready.append(EdgeChunk(buffer=np.asarray(payload),
                                    count=int(mask.sum()), mask=mask))

    def result(self):
        if not self.done:
            raise RuntimeError("request not complete; drain the service")
        return list(self.ready)


class StatsSink(Sink):
    """Accumulate edge count + degree histogram without materializing.

    Uses the same per-chunk degree fold as :meth:`repro.api.Graph.degrees`
    (degrees are additive over any partition of the exact edge union the
    scheduler delivers), so ``degrees`` matches the materialized graph's
    bit-for-bit.
    """

    def __init__(self, n: int, directed: bool):
        super().__init__()
        self.n = int(n)
        self.directed = bool(directed)
        self.num_edges = 0
        self.degrees = np.zeros(self.n, np.int64)

    def _consume(self, seq: int, payload, mask) -> None:
        from ..core import graph as _graph

        edges = np.asarray(payload)[np.asarray(mask)]
        self.num_edges += len(edges)
        self.degrees += _graph.degrees(edges, self.n, self.directed)

    def result(self):
        if not self.done:
            raise RuntimeError("request not complete; drain the service")
        return {"num_edges": self.num_edges, "degrees": self.degrees}
