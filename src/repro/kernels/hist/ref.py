"""Pure-jnp oracle for the hist kernel (identical bin semantics)."""
import jax.numpy as jnp


def log2_bin_ref(v):
    """bin 0 <- value 0; bin 1 + floor(log2 v) <- value v >= 1."""
    v = jnp.asarray(v)
    b = jnp.zeros_like(v)
    for k in range(31):
        b = b + (v >= (1 << k)).astype(v.dtype)
    return b


def hist_counts_ref(values, *, num_bins: int, log2: bool = False):
    """int32 counts[num_bins]; negatives ignored, overflow clamped."""
    v = jnp.asarray(values).reshape(-1)
    b = log2_bin_ref(v) if log2 else v
    b = jnp.minimum(b, num_bins - 1)
    w = (v >= 0).astype(jnp.int32)
    return jnp.zeros(num_bins, jnp.int32).at[jnp.where(v < 0, 0, b)].add(w)
