"""Jit'd public wrapper around the hist kernel (pads, dispatches).

Two entry points, both device-side:

* :func:`degree_histogram` — histogram of *values* (e.g. per-vertex
  degrees), linear or log2-binned, any input length (padded with -1 to
  the value-block multiple).
* :func:`bincount_ids` — scatter-add of occurrence counts over ids
  (degree accumulation from edge endpoints).  The one-hot segment-sum
  kernel is O(N * num_bins) work, the right trade on TPU up to a few
  thousand bins; above ``SCATTER_BINS_LIMIT`` it falls back to XLA's
  native scatter-add (still on device — never a host bincount loop).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .hist import LOG2_BINS, hist_counts

_BLOCK_V = 1024
SCATTER_BINS_LIMIT = 4096
_ONEHOT_WORK_LIMIT = 1 << 27  # max N * bins the one-hot formulation pays


def pad_values(v, block: int = _BLOCK_V) -> jax.Array:
    """int array [N] -> int32 [ceil(N/block)*block, 1], -1 padding.

    -1 rows fall in no bin, so padded entries never count — masks stay
    implicit, like the pairdist kernel's +inf rows."""
    v = jnp.asarray(v, jnp.int32).reshape(-1)
    npad = max(block, (v.shape[0] + block - 1) // block * block)
    out = jnp.full((npad, 1), -1, jnp.int32)
    return out.at[: v.shape[0], 0].set(v)


def degree_histogram(values, num_bins: int, *, log2: bool = False,
                     interpret: bool = True) -> jax.Array:
    """int64 counts[num_bins] of ``values`` via the Pallas kernel."""
    counts = hist_counts(pad_values(values), num_bins=num_bins, log2=log2,
                         interpret=interpret)
    return counts[:num_bins].astype(jnp.int64)


def log2_histogram(values, *, interpret: bool = True) -> jax.Array:
    """int64 counts[LOG2_BINS]: bin 0 = zeros, bin 1+k = [2^k, 2^(k+1))."""
    return degree_histogram(values, LOG2_BINS, log2=True, interpret=interpret)


@partial(jax.jit, static_argnames=("length",))
def _scatter_add(ids, length: int):
    return jnp.zeros(length, jnp.int64).at[ids].add(1, mode="drop")


def bincount_ids(ids, length: int, *, interpret: bool = True) -> jax.Array:
    """int64 counts[length]: occurrences of each id in [0, length).

    Device scatter-add: the Pallas one-hot kernel when its O(N*length)
    work is worth it (length <= SCATTER_BINS_LIMIT and N*length within
    the work budget), XLA scatter otherwise.  Out-of-range ids are
    *dropped* on both paths (the kernel's overflow clamp is masked off
    here — identical semantics whichever path dispatches, so
    sentinel-padded batches count correctly at any length)."""
    ids = jnp.asarray(ids, jnp.int64)
    if (length <= SCATTER_BINS_LIMIT
            and ids.size * max(length, 1) <= _ONEHOT_WORK_LIMIT):
        ids = jnp.where(ids >= length, -1, ids)  # drop, don't clamp
        return degree_histogram(ids, length, interpret=interpret)
    return _scatter_add(ids, length)


def log2_bin_edges(num_bins: int = LOG2_BINS) -> np.ndarray:
    """Lower edge of each log2 bin: [0, 1, 2, 4, 8, ...]."""
    return np.concatenate([[0], 2 ** np.arange(num_bins - 1, dtype=np.int64)])
