"""Pallas TPU kernel: scatter-add histogram (binning + segment-sum).

Degree accumulation is the stats subsystem's hot loop, and TPUs have no
fast per-element scatter: the idiomatic formulation is *one-hot
segment-sum* — each grid step loads a (bv,) tile of values, computes
their bin ids on the VPU (log2 binning is 31 integer compares, exact,
no float log), expands to a (bv, bb) one-hot tile against the step's
bin window, and column-sums into the (1, bb) output block.  The grid is
(bin blocks, value blocks) with the *value* dim innermost, so each
output block's revisits are consecutive (the standard Pallas accumulate
pattern: zero on the first value step, ``+=`` after) and the counts
tile stays resident in VMEM for its whole reduction.

Negative values are padding and fall in no bin; values past the last
bin are clamped into it (an explicit overflow bin keeps totals exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# log2 binning: bin 0 holds value 0, bin 1 + k holds [2^k, 2^(k+1)).
# 32 bins cover every non-negative int32 (max value 2^31 - 1 -> bin 31).
LOG2_BINS = 32


def _hist_kernel(v_ref, out_ref, *, num_bins: int, block_b: int, log2: bool):
    j, i = pl.program_id(0), pl.program_id(1)  # bin block outer, value block inner

    @pl.when(i == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = v_ref[:, 0]  # (bv,) int32; negatives = padding
    if log2:
        b = jnp.zeros_like(v)
        for k in range(31):  # static: bin id = 1 + floor(log2 v), exact in int
            b += (v >= (1 << k)).astype(jnp.int32)
    else:
        b = v
    b = jnp.where(v < 0, -1, jnp.minimum(b, num_bins - 1))  # clamp = overflow bin
    local = b - j * block_b  # this step's bin window
    onehot = local[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (v.shape[0], block_b), 1)
    out_ref[0, :] += jnp.sum(onehot, axis=0, dtype=jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("num_bins", "log2", "block_v", "block_b", "interpret")
)
def hist_counts(
    values: jax.Array,
    *,
    num_bins: int,
    log2: bool = False,
    block_v: int = 1024,
    block_b: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """int32 counts[ceil(num_bins/block_b) * block_b] of ``values``.

    values: (N, 1) int32, N a multiple of ``block_v``; negative entries
    are padding and counted nowhere.  With ``log2=False`` bin = value;
    with ``log2=True`` bin = 0 for value 0, else 1 + floor(log2 value).
    Values >= num_bins land in the last (overflow) bin either way, so
    the counts always sum to the number of non-negative values.  Only
    the first ``num_bins`` output entries are meaningful.
    """
    n, one = values.shape
    assert one == 1 and n % block_v == 0, (values.shape, block_v)
    bpad = (num_bins + block_b - 1) // block_b * block_b
    grid = (bpad // block_b, n // block_v)  # value dim innermost: consecutive
    return pl.pallas_call(                  # revisits of each counts block
        functools.partial(_hist_kernel, num_bins=num_bins, block_b=block_b,
                          log2=log2),
        grid=grid,
        in_specs=[pl.BlockSpec((block_v, 1), lambda j, i: (i, 0))],
        out_specs=pl.BlockSpec((1, block_b), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, bpad), jnp.int32),
        interpret=interpret,
    )(values)[0]
