"""Pure-jnp oracle for the pairdist facade (the shared euclid tile ref)."""
from ..pairmask.ref import euclid_mask_ref as pairdist_mask_ref  # noqa: F401
