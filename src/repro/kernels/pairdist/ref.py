"""Pure-jnp oracle for the pairdist kernel."""
import jax.numpy as jnp


def pairdist_mask_ref(a, b, r2, *, dim: int):
    da = a[:, None, :dim] - b[None, :, :dim]
    return (jnp.sum(da * da, axis=-1) <= jnp.asarray(r2, jnp.float32)).astype(jnp.int8)
