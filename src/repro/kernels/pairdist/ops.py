"""Jit'd public wrapper around the pairdist kernel (pads, dispatches)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .pairdist import pairdist_mask

_DPAD = 8  # sublane-friendly coordinate padding


def pad_points(pts: jax.Array) -> jax.Array:
    """(N, d) f32 -> (ceil128(N), _DPAD) with +inf padding rows.

    +inf rows give +inf distances, so padded entries can never pass the
    r^2 threshold — masks stay implicit.
    """
    n, d = pts.shape
    npad = (n + 127) // 128 * 128
    out = jnp.full((npad, _DPAD), jnp.inf, jnp.float32)
    return out.at[:n, :d].set(pts.astype(jnp.float32))


def pairdist(a_padded, b_padded, r2, *, dim: int, interpret: bool = True):
    """Adjacency mask between padded point blocks."""
    return pairdist_mask(a_padded, b_padded, r2, dim=dim, interpret=interpret)
