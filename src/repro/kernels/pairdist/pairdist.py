"""Blocked all-pairs distance threshold (RGG edges) — the ``euclid``
tile of the unified pair-mask kernel.

TPU adaptation of the paper's GPGPU edge kernel (§5.3): one thread block
per cell-pair on the GPU becomes one VMEM-resident (bm x bn) tile per
grid step.  The tile math (and why it runs on the VPU, not the MXU)
lives in :mod:`repro.kernels.pairmask.pairmask`; this module is the
RGG-facing facade kept for its established import path and signature.
"""
from __future__ import annotations

import jax

from ..pairmask.pairmask import pair_mask


def pairdist_mask(
    a: jax.Array,
    b: jax.Array,
    r2: jax.Array,
    *,
    dim: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """int8 mask[M, N], 1 where ||a_i - b_j||^2 <= r2.

    a: (M, dpad) f32, b: (N, dpad) f32 — caller pads M, N to block
    multiples and dpad to the sublane-friendly width; only the first
    `dim` coordinates are used.
    """
    return pair_mask(a, b, r2, tile="euclid", dim=dim,
                     block_m=block_m, block_n=block_n, interpret=interpret)
