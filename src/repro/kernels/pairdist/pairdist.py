"""Pallas TPU kernel: blocked all-pairs distance threshold (RGG edges).

TPU adaptation of the paper's GPGPU edge kernel (§5.3): one thread block
per cell-pair on the GPU becomes one VMEM-resident (bm x bn) tile per
grid step here.  Points are laid out points-major with the coordinate
axis padded to the lane width so loads are contiguous; the (bm, bn)
distance tile is accumulated one coordinate at a time on the VPU (d is
2 or 3 — an MXU matmul would waste 125/128 of the systolic array, so the
VPU broadcast-subtract-square formulation is the roofline-correct choice
on TPU; this is a deliberate deviation from the GPU version's
shared-memory dot-product trick, see DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairdist_kernel(a_ref, b_ref, r2_ref, out_ref, *, dim: int):
    # a_ref: (bm, dpad) f32, b_ref: (bn, dpad) f32, out: (bm, bn) int8
    acc = jnp.zeros((a_ref.shape[0], b_ref.shape[0]), jnp.float32)
    for d in range(dim):  # static tiny loop: d in {2, 3}
        diff = a_ref[:, d][:, None] - b_ref[:, d][None, :]
        acc = acc + diff * diff
    out_ref[...] = (acc <= r2_ref[0, 0]).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("dim", "block_m", "block_n", "interpret")
)
def pairdist_mask(
    a: jax.Array,
    b: jax.Array,
    r2: jax.Array,
    *,
    dim: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """int8 mask[M, N], 1 where ||a_i - b_j||^2 <= r2.

    a: (M, dpad) f32, b: (N, dpad) f32 — caller pads M, N to block
    multiples and dpad to the sublane-friendly width; only the first
    `dim` coordinates are used.
    """
    m, dpad = a.shape
    n = b.shape[0]
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (m // block_m, n // block_n)
    r2_arr = jnp.asarray(r2, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_pairdist_kernel, dim=dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, dpad), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, dpad), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        interpret=interpret,
    )(a, b, r2_arr)
