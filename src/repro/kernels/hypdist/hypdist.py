"""Pallas TPU kernel: trig-free hyperbolic adjacency (paper §7.2.1, Eq. 9).

After the per-vertex precompute [cos θ, sin θ, coth r, 1/sinh r] the
adjacency test  dist_H(p, q) < R  becomes the sign of a 4-term fused
inner product:

    cosθp·cosθq + sinθp·sinθq − cothp·cothq + coshR·(1/sinhp)(1/sinhq) > 0

which is exactly the paper's Vc-vectorized check, mapped onto the TPU
VPU: one (bm x bn) tile of query x candidate pairs per grid step, four
broadcast FMAs per tile.  The structure-of-arrays layout the paper uses
for SIMD is the natural Pallas layout here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hypdist_kernel(q_ref, c_ref, coshr_ref, out_ref):
    # q_ref: (bm, 8), c_ref: (bn, 8) — features in cols 0..3
    coshR = coshr_ref[0, 0]
    acc = q_ref[:, 0][:, None] * c_ref[:, 0][None, :]
    acc += q_ref[:, 1][:, None] * c_ref[:, 1][None, :]
    acc -= q_ref[:, 2][:, None] * c_ref[:, 2][None, :]
    acc += coshR * (q_ref[:, 3][:, None] * c_ref[:, 3][None, :])
    out_ref[...] = (acc > 0).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def hypdist_mask(
    q: jax.Array,
    c: jax.Array,
    cosh_r: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """int8 mask[M, N]: 1 where dist_H(q_i, c_j) < R (Eq. 9 form).

    q: (M, 8), c: (N, 8) feature blocks (padded); cosh_r: scalar cosh(R).
    Self-pairs are NOT excluded here (gid comparison happens outside).
    """
    m, f = q.shape
    n = c.shape[0]
    assert m % block_m == 0 and n % block_n == 0, (m, n)
    grid = (m // block_m, n // block_n)
    coshR = jnp.asarray(cosh_r, q.dtype).reshape(1, 1)
    return pl.pallas_call(
        _hypdist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, f), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, f), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        interpret=interpret,
    )(q, c, coshR)
