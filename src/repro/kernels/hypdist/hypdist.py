"""Trig-free hyperbolic adjacency (paper §7.2.1, Eq. 9) — the ``hyp``
tile of the unified pair-mask kernel.

After the per-vertex precompute [cos θ, sin θ, coth r, 1/sinh r] the
adjacency test  dist_H(p, q) < R  becomes the sign of a 4-term fused
inner product — exactly the paper's Vc-vectorized check.  The tile math
lives in :mod:`repro.kernels.pairmask.pairmask`; this module is the
RHG-facing facade kept for its established import path and signature.
"""
from __future__ import annotations

import jax

from ..pairmask.pairmask import pair_mask


def hypdist_mask(
    q: jax.Array,
    c: jax.Array,
    cosh_r: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """int8 mask[M, N]: 1 where dist_H(q_i, c_j) < R (Eq. 9 form).

    q: (M, 8), c: (N, 8) feature blocks (padded); cosh_r: scalar cosh(R).
    Self-pairs are NOT excluded here (gid comparison happens outside).
    """
    return pair_mask(q, c, cosh_r, tile="hyp",
                     block_m=block_m, block_n=block_n, interpret=interpret)
