"""Jit'd wrapper: per-vertex precompute + padding + kernel dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .hypdist import hypdist_mask

FEAT = 8  # 4 features padded to sublane width

# padding rows: coth = +huge makes the Eq. 9 expression strongly negative
_PAD_ROW = np.array([0.0, 0.0, 1e30, 0.0, 0, 0, 0, 0])


def precompute_features(r: np.ndarray, theta: np.ndarray, dtype=np.float64) -> np.ndarray:
    """(N, 8): [cos θ, sin θ, coth r, 1/sinh r, 0...] (paper §7.2.1)."""
    r = np.maximum(np.asarray(r, np.float64), 1e-12)
    sh = np.sinh(r)
    out = np.zeros((len(r), FEAT), np.float64)
    out[:, 0] = np.cos(theta)
    out[:, 1] = np.sin(theta)
    out[:, 2] = np.cosh(r) / sh
    out[:, 3] = 1.0 / sh
    return out.astype(dtype)


def pad_features(feat: np.ndarray, rows: int | None = None, dtype=np.float64) -> np.ndarray:
    n = len(feat)
    rows = rows if rows is not None else (n + 127) // 128 * 128
    rows = max(128, (rows + 127) // 128 * 128)
    out = np.tile(_PAD_ROW, (rows, 1))
    out[:n] = feat
    return out.astype(dtype)


def hypdist(q_feat, c_feat, cosh_r, *, interpret: bool = True):
    return hypdist_mask(jnp.asarray(q_feat), jnp.asarray(c_feat), cosh_r, interpret=interpret)
