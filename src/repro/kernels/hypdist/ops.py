"""Jit'd wrapper: per-vertex precompute + padding + kernel dispatch."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from .hypdist import hypdist_mask

FEAT = 8  # 4 features padded to sublane width

# cosh overflows float64 just past this point (cosh(x) ~ e^x / 2)
_COSH_OVERFLOW_R = 700.0


def cosh_threshold(R: float) -> float:
    """cosh(R) for the Eq. 9 threshold, overflow-free.

    Above the float64 overflow point the comparison is evaluated in the
    log domain (log cosh R = R - log 2 + log1p(e^-2R)) and clamped to
    the largest finite float64 — every real feature product still
    compares on the correct side, and no RuntimeWarning is emitted.
    """
    R = abs(float(R))
    if R < _COSH_OVERFLOW_R:
        return math.cosh(R)
    log_cosh = R - math.log(2.0) + math.log1p(math.exp(-2.0 * R))
    if log_cosh >= math.log(np.finfo(np.float64).max):
        return float(np.finfo(np.float64).max)
    return math.exp(log_cosh)

# padding rows: coth = +huge makes the Eq. 9 expression strongly negative
_PAD_ROW = np.array([0.0, 0.0, 1e30, 0.0, 0, 0, 0, 0])


def precompute_features(r: np.ndarray, theta: np.ndarray, dtype=np.float64) -> np.ndarray:
    """(N, 8): [cos θ, sin θ, coth r, 1/sinh r, 0...] (paper §7.2.1)."""
    r = np.maximum(np.asarray(r, np.float64), 1e-12)
    sh = np.sinh(r)
    out = np.zeros((len(r), FEAT), np.float64)
    out[:, 0] = np.cos(theta)
    out[:, 1] = np.sin(theta)
    out[:, 2] = np.cosh(r) / sh
    out[:, 3] = 1.0 / sh
    return out.astype(dtype)


def pad_features(feat: np.ndarray, rows: int | None = None, dtype=np.float64) -> np.ndarray:
    n = len(feat)
    rows = rows if rows is not None else (n + 127) // 128 * 128
    rows = max(128, (rows + 127) // 128 * 128)
    out = np.tile(_PAD_ROW, (rows, 1))
    out[:n] = feat
    return out.astype(dtype)


def hypdist(q_feat, c_feat, cosh_r, *, interpret: bool = True):
    return hypdist_mask(jnp.asarray(q_feat), jnp.asarray(c_feat), cosh_r, interpret=interpret)
