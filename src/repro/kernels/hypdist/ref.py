"""Pure-jnp oracle for the hypdist facade (the shared hyp tile ref)."""
from ..pairmask.ref import hyp_mask_ref as hypdist_mask_ref  # noqa: F401
