"""Pure-jnp oracle for the hypdist kernel (same Eq. 9 formulation)."""
import jax.numpy as jnp


def hypdist_mask_ref(q, c, cosh_r):
    acc = q[:, 0][:, None] * c[:, 0][None, :]
    acc += q[:, 1][:, None] * c[:, 1][None, :]
    acc -= q[:, 2][:, None] * c[:, 2][None, :]
    acc += jnp.asarray(cosh_r, q.dtype) * (q[:, 3][:, None] * c[:, 3][None, :])
    return (acc > 0).astype(jnp.int8)
