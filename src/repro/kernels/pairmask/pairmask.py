"""Pallas TPU kernel: one blocked pair-mask primitive for both geometric
adjacency tests.

The RGG Euclidean threshold (``pairdist``) and the RHG hyperbolic Eq. 9
test (``hypdist``) were two structurally identical kernels: one
VMEM-resident (bm x bn) tile of A-side x B-side pairs per grid step, a
per-kind tile test on the VPU, an int8 mask out.  They now share this
single ``pallas_call`` harness with a kind-specific *tile function* —
the kernel-level mirror of the engine's kind-tagged ``PairPlan``
(GEOM_TORUS / GEOM_HYP are just tiles of the same sweep).

Tile kinds:

``euclid`` — accumulate squared coordinate differences one axis at a
  time (d in {2, 3}; an MXU matmul would waste 125/128 of the systolic
  array, so the VPU broadcast-subtract-square is the roofline-correct
  form on TPU) and compare ``acc <= r^2`` inclusively in float32.

``hyp`` — the paper's §7.2.1 trig-free precompute: four broadcast FMAs
  ``cosθ·cosθ' + sinθ·sinθ' − coth·coth' + coshR·(1/sinh)(1/sinh')``
  and the sign test ``acc > 0``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILES = ("euclid", "hyp")


def _euclid_tile(a_ref, b_ref, s_ref, out_ref, *, dim: int):
    # a_ref: (bm, dpad) f32, b_ref: (bn, dpad) f32, out: (bm, bn) int8
    acc = jnp.zeros((a_ref.shape[0], b_ref.shape[0]), jnp.float32)
    for d in range(dim):  # static tiny loop: d in {2, 3}
        diff = a_ref[:, d][:, None] - b_ref[:, d][None, :]
        acc = acc + diff * diff
    out_ref[...] = (acc <= s_ref[0, 0]).astype(jnp.int8)


def _hyp_tile(q_ref, c_ref, coshr_ref, out_ref):
    # q_ref: (bm, 8), c_ref: (bn, 8) — features in cols 0..3
    coshR = coshr_ref[0, 0]
    acc = q_ref[:, 0][:, None] * c_ref[:, 0][None, :]
    acc += q_ref[:, 1][:, None] * c_ref[:, 1][None, :]
    acc -= q_ref[:, 2][:, None] * c_ref[:, 2][None, :]
    acc += coshR * (q_ref[:, 3][:, None] * c_ref[:, 3][None, :])
    out_ref[...] = (acc > 0).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("tile", "dim", "block_m", "block_n", "interpret")
)
def pair_mask(
    a: jax.Array,
    b: jax.Array,
    scalar: jax.Array,
    *,
    tile: str,
    dim: int = 2,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """int8 mask[M, N] of the tile test over all (a_i, b_j) pairs.

    a: (M, F), b: (N, F) — caller pads M, N to block multiples and F to
    the sublane-friendly width per kind.  ``scalar`` is the tile's
    threshold (r^2 for ``euclid``, cosh R for ``hyp``); ``dim`` is only
    read by ``euclid``.  Self-pairs are NOT excluded here (gid
    comparison happens outside).
    """
    if tile not in TILES:
        raise ValueError(f"unknown tile {tile!r}; know {TILES}")
    m, f = a.shape
    n = b.shape[0]
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (m // block_m, n // block_n)
    if tile == "euclid":
        kernel = functools.partial(_euclid_tile, dim=dim)
        s = jnp.asarray(scalar, jnp.float32).reshape(1, 1)
    else:
        kernel = _hyp_tile
        s = jnp.asarray(scalar, a.dtype).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, f), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, f), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        interpret=interpret,
    )(a, b, s)
