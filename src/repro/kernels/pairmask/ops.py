"""Public entry for the unified pair-mask kernel.

``pairdist`` (RGG) and ``hypdist`` (RHG) are thin per-kind facades over
this module; padding helpers stay with them because the two kinds pad
differently (+inf coordinate rows vs. the huge-coth feature row).
"""
from __future__ import annotations

from .pairmask import TILES, pair_mask  # noqa: F401
