"""Pure-jnp oracle for the unified pair-mask kernel (both tile kinds)."""
import jax.numpy as jnp


def euclid_mask_ref(a, b, r2, *, dim: int):
    da = a[:, None, :dim] - b[None, :, :dim]
    return (jnp.sum(da * da, axis=-1) <= jnp.asarray(r2, jnp.float32)).astype(jnp.int8)


def hyp_mask_ref(q, c, cosh_r):
    acc = q[:, 0][:, None] * c[:, 0][None, :]
    acc += q[:, 1][:, None] * c[:, 1][None, :]
    acc -= q[:, 2][:, None] * c[:, 2][None, :]
    acc += jnp.asarray(cosh_r, q.dtype) * (q[:, 3][:, None] * c[:, 3][None, :])
    return (acc > 0).astype(jnp.int8)


def pair_mask_ref(a, b, scalar, *, tile: str, dim: int = 2):
    """Reference twin of :func:`repro.kernels.pairmask.pairmask.pair_mask`."""
    if tile == "euclid":
        return euclid_mask_ref(a, b, scalar, dim=dim)
    if tile == "hyp":
        return hyp_mask_ref(a, b, scalar)
    raise ValueError(f"unknown tile {tile!r}")
