"""The Cramer circumsphere predicate, shared by every consumer.

One formula, three call sites that must agree bit-for-bit:

* the host planning pass (:func:`repro.core.rdg.circumspheres`, the
  numpy twin with the identical operation order),
* the engine's GEOM_CERT re-certification
  (:func:`repro.distrib.engine._circumsphere_in_box` delegates here),
* the Bowyer-Watson insertion kernel in this package, whose in-sphere
  test consumes the squared radius directly.

The solve is Cramer's rule on the (d x d) system ``rows @ off = rhs``
with ``rows = V[1:] - V[0]`` and ``rhs = |rows|^2 / 2``; a zero
determinant marks a degenerate (collinear / coplanar) simplex, which
every consumer treats as failing containment — the signal that forces
a halo expansion.
"""
from __future__ import annotations

import jax.numpy as jnp


def circumsphere(simp):
    """Circumsphere of ``[..., d+1, d]`` simplices, d in {2, 3}.

    Returns ``(center [..., d], r2 [...], nondeg [...])`` where ``r2``
    is the *squared* circumradius (``sqrt(r2)`` is bit-identical to the
    historical radius: the sum of squares is formed once, in the same
    order).  Degenerate simplices (``det == 0``) report ``nondeg ==
    False`` with a junk finite center/r2 — callers decide whether that
    means radius infinity (host certification) or an abort flag (the
    insertion kernel).
    """
    d = simp.shape[-1]
    if d not in (2, 3):
        raise ValueError(f"circumsphere supports d in {{2, 3}}, got {d}")
    a0 = simp[..., 0, :]
    rows = simp[..., 1:, :] - a0[..., None, :]
    rhs = 0.5 * jnp.sum(rows * rows, axis=-1)
    if d == 2:
        det = (rows[..., 0, 0] * rows[..., 1, 1]
               - rows[..., 0, 1] * rows[..., 1, 0])
        num = jnp.stack(
            [rhs[..., 0] * rows[..., 1, 1] - rows[..., 0, 1] * rhs[..., 1],
             rows[..., 0, 0] * rhs[..., 1] - rhs[..., 0] * rows[..., 1, 0]],
            axis=-1)
    else:
        c0, c1, c2 = rows[..., 0], rows[..., 1], rows[..., 2]

        def det3(x, y, z):
            return (x[..., 0] * (y[..., 1] * z[..., 2] - y[..., 2] * z[..., 1])
                    - y[..., 0] * (x[..., 1] * z[..., 2] - x[..., 2] * z[..., 1])
                    + z[..., 0] * (x[..., 1] * y[..., 2] - x[..., 2] * y[..., 1]))

        det = det3(c0, c1, c2)
        num = jnp.stack([det3(rhs, c1, c2), det3(c0, rhs, c2),
                         det3(c0, c1, rhs)], axis=-1)
    nondeg = det != 0
    off = num / jnp.where(nondeg, det, 1.0)[..., None]
    center = a0 + off
    r2 = jnp.sum(off * off, axis=-1)
    return center, r2, nondeg


def circumsphere_in_box(simp, lo, hi):
    """GEOM_CERT containment: circumsphere of ``[..., d+1, d]`` simplices
    fully inside the axis-aligned box ``[lo, hi]`` (each ``[..., d]``).
    Degenerate simplices fail.  This is the certificate the engine
    re-derives on device for every shipped simplex row."""
    center, r2, nondeg = circumsphere(simp)
    rad = jnp.sqrt(r2)[..., None]
    inside = (jnp.all(center - rad >= lo, axis=-1)
              & jnp.all(center + rad <= hi, axis=-1))
    return nondeg & inside
