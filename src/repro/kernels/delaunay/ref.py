"""Pure-jnp Bowyer-Watson insertion core + the jitted/vmapped reference.

One chunk+halo point set per row, fixed shapes throughout so a whole
halo round vmaps into a single device dispatch:

* the ``d+1`` super-simplex vertices live at indices ``N..N+d`` (``N``
  the padded point capacity); padding slots ``cnt..N`` are never
  inserted, so a vertex id is either a real point (``< cnt``) or super
  (``>= N``),
* simplex slots carry their vertex ids and circumcenter in one packed
  float64 row (vertex ids are tiny integers, exact in f64) and the
  *squared* circumradius from the shared Cramer predicate
  (:mod:`.predicates`) in a separate ``rr`` array — the in-sphere test
  is a gather-free ``d2 < rr`` scan, and a dead or never-used slot is
  simply ``rr == -inf`` (killing a cavity is an elementwise ``where``,
  not a scatter),
* each loop trip inserts a *group* of up to ``G`` points at once: the
  candidates are the first ``G`` uninserted points, their cavities are
  scanned against the slot table in one pass, and a candidate is
  accepted when it is independent of every earlier-accepted candidate
  (cavities disjoint and not inside any of their new circumspheres) —
  independent insertions commute, so the grouped result equals the
  sequential one and the Delaunay triangulation is unique regardless;
  rejected candidates simply retry next trip.  Any *exact* incidence
  between a candidate and another candidate's new circumsphere
  (cosphericity across the group) clears ``ok`` instead of guessing,
* cavities and the accepted group's boundary facets are compacted by
  binary-searching their ``cumsum`` (XLA's CPU scatter and sort are
  serial; a few binary searches are not) into ``CAV`` slots and a
  group-wide budget of ``W = (d-1)*CAV + 2`` slots (the exact worst
  case for one ``CAV``-simplex cavity), so gather, circumsphere, and
  scatter cost track the real work of the round, not slot capacity,
* the cavity boundary is found by sort-and-count over packed facet
  keys (a facet shared by two cavity simplices is interior; seen once,
  boundary); new simplices reuse killed slots first, then append at
  ``top``.

Anything the fixed shapes cannot express — no containing simplex, a
cavity larger than ``CAV``, slot overflow, an exact in-sphere tie
(cocircular / cospherical points), a degenerate new simplex — clears
the per-row ``ok`` flag instead of producing a wrong triangulation;
the emitter treats ``not ok`` exactly like a failed certificate and
expands the halo.

The insertion loop is a ``lax.while_loop`` whose trip count is bounded
by the emitter-derived point count (at least one candidate — the
earliest — is accepted per trip); under ``vmap`` all rows advance in
lockstep until the longest row finishes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .predicates import circumsphere

# super-simplex vertex directions (scaled by the row's extent): an
# equilateral triangle / regular tetrahedron whose insphere covers the
# point bounding box with orders of magnitude to spare
_SUPER_UNIT = {
    2: ((0.0, 2.0), (-1.7320508075688772, -1.0), (1.7320508075688772, -1.0)),
    3: ((1.0, 1.0, 1.0), (1.0, -1.0, -1.0), (-1.0, 1.0, -1.0),
        (-1.0, -1.0, 1.0)),
}
_SUPER_SCALE = 512.0

# facet k of a simplex = all vertices but k
_FACET_IDX = {
    2: ((1, 2), (0, 2), (0, 1)),
    3: ((1, 2, 3), (0, 2, 3), (0, 1, 3), (0, 1, 2)),
}

# candidates considered per loop trip (see module docstring)
GROUP = 4


def _iota(dtype, n):
    """``arange(n)`` as a traced primitive.  ``jnp.arange`` materialises
    an eager constant at trace time, which ``pallas_call`` rejects as a
    captured const; ``broadcasted_iota`` binds inside the kernel (the
    same idiom as :mod:`repro.kernels.hist`)."""
    return jax.lax.broadcasted_iota(dtype, (n,), 0)


def _facet_idx(dim):
    """Traced [d+1, d] facet table: row ``k`` lists all vertices but
    ``k`` in ascending order, i.e. ``j + (j >= k)``."""
    kk = jax.lax.broadcasted_iota(jnp.int32, (dim + 1, dim), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (dim + 1, dim), 1)
    return jj + (jj >= kk).astype(jnp.int32)


def _super_unit(dim, dtype):
    """Traced [d+1, d] super-simplex directions, value-identical to the
    ``_SUPER_UNIT`` table (``sqrt(3.)`` is correctly rounded, so the 2d
    entries match the literals bit for bit)."""
    vv = jax.lax.broadcasted_iota(jnp.int32, (dim + 1, dim), 0)
    cc = jax.lax.broadcasted_iota(jnp.int32, (dim + 1, dim), 1)
    if dim == 2:
        r3 = jnp.sqrt(jnp.asarray(3.0, dtype))
        x = jnp.where(vv == 0, jnp.asarray(0.0, dtype),
                      jnp.where(vv == 1, -r3, r3))
        y = jnp.where(vv == 0, jnp.asarray(2.0, dtype),
                      jnp.asarray(-1.0, dtype))
        return jnp.where(cc == 0, x, y)
    return jnp.where((vv == 0) | (vv == cc + 1),
                     jnp.asarray(1.0, dtype), jnp.asarray(-1.0, dtype))


def boundary_capacity(cavity: int, dim: int) -> int:
    """Max boundary facets of a connected cavity of ``cavity`` simplices:
    ``(d+1)*cavity`` facet slots minus the ``2*(cavity-1)`` interior
    pairings."""
    return (dim - 1) * cavity + 2


def triangulate(pts, cnt, *, dim: int, num_simplices: int, cavity: int,
                group: int = GROUP):
    """Incremental Delaunay triangulation of one padded point row.

    pts: [N, d] float64 (slots >= cnt ignored), cnt: scalar int.
    Returns ``(simp [S, d+1] int32, alive [S] bool, ok bool)``: the
    alive slots triangulate points+super; rows with any vertex >= N are
    super-incident (the hull certificate reads them, the edge phase
    drops them).  ``ok`` is False when the fixed capacities or general
    position were violated — the caller must expand and retry.
    """
    N = pts.shape[0]
    S, CAV, G = num_simplices, cavity, group
    F = CAV * (dim + 1)
    W = boundary_capacity(CAV, dim)   # group-wide new-simplex budget
    UC = 3 * CAV                  # union-cavity window for a whole group
    fidx = _facet_idx(dim)
    V = N + dim + 1

    valid = _iota(jnp.int32, N) < cnt
    lo = jnp.min(jnp.where(valid[:, None], pts, jnp.inf), axis=0)
    hi = jnp.max(jnp.where(valid[:, None], pts, -jnp.inf), axis=0)
    lo = jnp.where(jnp.isfinite(lo), lo, 0.0)
    hi = jnp.where(jnp.isfinite(hi), hi, 0.0)
    center = 0.5 * (lo + hi)
    extent = 0.5 * jnp.max(hi - lo) + 1.0
    sup = center[None, :] + _SUPER_SCALE * extent * _super_unit(
        dim, pts.dtype)
    work = jnp.concatenate([pts, sup], axis=0)          # [V, d]

    # packed slot row = d+1 vertex ids (exact small ints in f64) + the
    # d circumcenter coordinates; rr = squared radius, -inf == dead
    c0, r20, nd0 = circumsphere(sup)
    packed = jnp.zeros((S, 2 * dim + 1), pts.dtype)
    packed = packed.at[0].set(jnp.concatenate(
        [_iota(pts.dtype, dim + 1) + N, c0]))
    rr = jnp.full(S, -jnp.inf, pts.dtype)
    rr = rr.at[0].set(jnp.where(nd0, r20, jnp.inf))

    # facet keys fit int32 for every realistic bucket size; int64 is the
    # safety net for enormous rows
    ktype = jnp.int32 if V ** dim + F < 2 ** 31 else jnp.int64
    # narrow counters keep the per-trip cumsums cheap; widen when the
    # slot table could overflow int16
    cdt = jnp.int16 if max(S, N) < 2 ** 15 else jnp.int32

    def body(state):
        nins, ins, packed, rr, top, ok = state
        # candidates: G uninserted points spread evenly across the
        # remaining ranks — points arrive cell-ordered, so consecutive
        # ranks are spatial neighbours with colliding cavities, while
        # strided ranks are mostly independent and the whole group lands
        icum = jnp.cumsum((valid & ~ins).astype(cdt))
        rem = (cnt - nins).astype(jnp.int32)
        stride = jnp.maximum(rem // G, 1)
        ranks = _iota(jnp.int32, G) * stride
        cand = jnp.searchsorted(
            icum, (ranks + 1).astype(icum.dtype)).astype(jnp.int32)
        cm = ranks < rem
        p = work[jnp.clip(cand, 0, V - 1)]               # [G, d]

        # one in-sphere scan of the slot table for the whole group; d2
        # expands to |cc|^2 - 2 cc.p + |p|^2 so the G columns come from
        # a single [S,d]x[d,G] matmul instead of G elementwise passes
        cc = packed[:, dim + 1:]
        d2 = (jnp.sum(cc * cc, axis=1)[:, None]
              - 2.0 * (cc @ p.T)
              + jnp.sum(p * p, axis=1)[None, :])
        bad = (d2 < rr[:, None]) & cm[None, :]           # [S, G]
        tie = (d2 == rr[:, None]) & cm[None, :]
        # compact the slots bad for ANY candidate (the union cavity)
        # into UC entries in one pass — cumsum is nondecreasing, so the
        # j-th set slot sits at the first index where the running count
        # reaches j+1 — then build each candidate's cavity inside that
        # small window
        bany = jnp.any(bad, axis=1)
        ucum = jnp.cumsum(bany.astype(cdt))
        nu = ucum[-1].astype(jnp.int32)
        uni = jnp.searchsorted(
            ucum, _iota(cdt, UC) + 1).astype(jnp.int32)
        badu = bad[jnp.clip(uni, 0, S - 1)] \
            & (_iota(jnp.int32, UC) < nu)[:, None]       # [UC, G]
        cumu = jnp.cumsum(badu.astype(cdt), axis=0)
        nb = cumu[-1].astype(jnp.int32)                  # [G]
        cav1 = _iota(cdt, CAV) + 1
        locidx = jax.vmap(
            lambda c: jnp.searchsorted(c, cav1),
            in_axes=1)(cumu).astype(jnp.int32)           # [G, CAV]
        badidx = jnp.where(locidx < UC,
                           uni[jnp.clip(locidx, 0, UC - 1)], S)
        cmask = _iota(jnp.int32, CAV)[None, :] < nb[:, None]
        cav = packed[jnp.clip(badidx, 0, S - 1), :dim + 1].astype(jnp.int32)
        facets = jnp.sort(cav[:, :, fidx], axis=-1)      # [G, CAV, d+1, d]
        ffl = facets.reshape(G, F, dim)
        ff = ffl.astype(ktype)
        fm = jnp.repeat(cmask, dim + 1, axis=1)          # [G, F]
        key = ff[:, :, 0]
        for k in range(1, dim):
            key = key * V + ff[:, :, k]
        # masked rows get unique sentinel keys so they never pair with
        # (or shadow) a real facet in the occurrence count
        key = jnp.where(fm, key,
                        ktype(V) ** dim + _iota(ktype, F)[None, :])
        sk = jnp.sort(key, axis=1)
        # a key is a boundary facet iff it occurs exactly once: the
        # entry after its first sorted occurrence differs
        left = jax.vmap(functools.partial(jnp.searchsorted, side="left"))(
            sk, key)
        nxt = jnp.take_along_axis(sk, jnp.clip(left + 1, 0, F - 1), axis=1)
        bnd = fm & jnp.where(left + 1 < F, nxt != key, True)
        bcum = jnp.cumsum(bnd.astype(cdt), axis=1)
        nnew = bcum[:, -1].astype(jnp.int32)             # [G]

        # stage-1 acceptance: candidate j survives if no earlier
        # survivor's cavity overlaps its cavity (independent insertions
        # commute) and the group's new-simplex budget W holds
        ov = jnp.einsum("uj,ul->jl", badu.astype(jnp.int32),
                        badu.astype(jnp.int32)) > 0      # [G, G]
        accs = [cm[0]]
        newsum = jnp.where(cm[0], nnew[0], 0)
        for j in range(1, G):
            prev = jnp.stack(accs)
            take = (cm[j] & ~jnp.any(prev & ov[:j, j])
                    & (newsum + nnew[j] <= W))
            accs.append(take)
            newsum = newsum + jnp.where(take, nnew[j], 0)
        acc = jnp.stack(accs)                            # [G]

        # compact the survivors' boundary facets to exact width W, THEN
        # gather vertices and run the circumsphere — per-row scatter and
        # gather overhead tracks the real work, not G*F slot capacity
        wflat = (acc[:, None] & bnd).reshape(G * F)
        wcum = jnp.cumsum(wflat.astype(cdt))
        nw = wcum[-1].astype(jnp.int32)
        wsel = jnp.searchsorted(
            wcum, _iota(cdt, W) + 1).astype(jnp.int32)
        wm = _iota(jnp.int32, W) < nw
        wsafe = jnp.clip(wsel, 0, G * F - 1)
        wowner = wsafe // F                              # candidate index
        lpos = (jnp.take(bcum.reshape(G * F), wsafe) - 1).astype(jnp.int32)
        wf = ffl.reshape(G * F, dim)[wsafe]              # [W, d]
        wnew = jnp.concatenate(
            [wf, cand[wowner][:, None]], axis=1)         # [W, d+1]
        wctr, wr2, wnok = circumsphere(work[wnew])       # [W, ...]

        # stage-2 acceptance: demote candidate j if it lies inside (or
        # exactly on — cosphericity clears ok) a new circumsphere of an
        # earlier survivor; removals only weaken stage-1 constraints,
        # so the greedy chain stays valid
        pw = jnp.sum((wctr[:, None, :] - p[None, :, :]) ** 2, axis=2)
        oh = ((wowner[:, None] == _iota(jnp.int32, G)[None, :])
              & wm[:, None]).astype(jnp.int32)           # [W, G] owner 1-hot
        hg = (oh.T @ (pw < wr2[:, None]).astype(jnp.int32)) > 0
        tg = (oh.T @ (pw == wr2[:, None]).astype(jnp.int32)) > 0
        faccs = [acc[0]]
        for j in range(1, G):
            prev = jnp.stack(faccs)
            faccs.append(acc[j] & ~jnp.any(prev & hg[:j, j]))
        facc = jnp.stack(faccs)                          # [G]

        # slot allocation: each survivor's cavity reuses its own killed
        # slots first, then appends to a per-candidate range past top
        a = jnp.where(facc, jnp.maximum(nnew - nb, 0), 0)
        aoff = (jnp.cumsum(a) - a).astype(jnp.int32)
        fmask = wm & facc[wowner]
        nb_o = nb[wowner]
        slots = jnp.where(
            fmask,
            jnp.where(lpos < nb_o,
                      badidx[wowner, jnp.clip(lpos, 0, CAV - 1)],
                      top + aoff[wowner] + lpos - nb_o),
            S + _iota(jnp.int32, W))                     # OOB == dropped
        killed = jnp.any(bad & facc[None, :], axis=1)
        rr = jnp.where(killed, -jnp.inf, rr)  # kill cavities, elementwise
        packed = packed.at[slots].set(
            jnp.concatenate([wnew.astype(pts.dtype), wctr], axis=1),
            mode="drop", unique_indices=True)
        rr = rr.at[slots].set(jnp.where(wnok, wr2, jnp.inf), mode="drop",
                              unique_indices=True)
        top = top + jnp.sum(a).astype(top.dtype)
        ins = ins.at[cand].set(facc, mode="drop", unique_indices=True)
        nins = nins + jnp.sum(facc).astype(nins.dtype)
        gi = _iota(jnp.int32, G)
        offdiag = gi[:, None] != gi[None, :]
        ok = (ok
              & (nu <= UC)
              & jnp.all(jnp.where(facc,
                                  (nb > 0) & (nb <= CAV) & (nnew <= W),
                                  True))
              & ~jnp.any(tie)
              & ~jnp.any(fmask & ~wnok)
              & ~jnp.any(tg & facc[:, None] & facc[None, :] & offdiag)
              & (top <= S))
        return nins, ins, packed, rr, top, ok

    state = jax.lax.while_loop(
        lambda s: s[0] < cnt, body,
        (jnp.int32(0), jnp.zeros(N, bool), packed, rr, jnp.int32(1),
         jnp.bool_(True)))
    _, _, packed, rr, top, ok = state
    simp = packed[:, :dim + 1].astype(jnp.int32)
    alive = rr > -jnp.inf
    return simp, alive, ok


@functools.partial(jax.jit,
                   static_argnames=("dim", "num_simplices", "cavity",
                                    "group"))
def delaunay_ref(pts, cnt, *, dim: int, num_simplices: int, cavity: int,
                 group: int = GROUP):
    """Jitted reference: vmap of :func:`triangulate` over batch rows.
    pts: [B, N, d] float64, cnt: [B] int32."""
    core = functools.partial(triangulate, dim=dim,
                             num_simplices=num_simplices, cavity=cavity,
                             group=group)
    return jax.vmap(core)(pts, cnt)
