"""Dispatch facade for the batched device-side Delaunay triangulation.

:func:`batched_delaunay` is what the RDG plan emitter calls once per
halo round: every pending chunk's padded point row triangulates in one
device batch.  On CPU the jitted/vmapped reference is the production
path (the Pallas interpreter re-traces per call); pass
``force_kernel=True`` (or run on an accelerator backend) to dispatch
the ``pallas_call`` harness.

Capacities are emitter-derived and static per (padded size, dim)
bucket, so recompiles stay bounded across halo rounds:

* ``simplex_capacity(N, dim)`` — slot budget.  2d retriangulation is
  Euler-exact (+2 simplices per insertion, killed slots reused), so
  ``2N + O(1)`` suffices; 3d cavity retriangulation can leak slots
  (fewer new simplices than killed), so the budget carries the
  expected ~6.8N complexity (measured high-water ~6.5N on uniform
  rows) with slack.
* ``cavity_capacity(dim)`` — max simplices deleted by one insertion;
  overflow clears the row's ``ok`` flag, and the emitter expands the
  halo and retries (a different point set reshuffles insertion order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .delaunay import delaunay_call
from .ref import delaunay_ref


def simplex_capacity(n: int, dim: int) -> int:
    return 2 * n + 16 if dim == 2 else 8 * n + 64


def cavity_capacity(dim: int) -> int:
    """Max simplices one insertion may delete.  Sized from measured
    high-water marks on uniform rows (2d ~10-15, 3d ~40-60) with slack;
    the cavity-derived compaction widths (union cavity = 3*CAV, boundary
    budget ~ (d-1)*CAV) dominate the per-trip sort/einsum cost, so the
    budget stays as tight as safety allows — at the production 2d shape
    CAV 64 -> 32 alone is a ~30% kernel cut.  Overflow is never wrong:
    it clears the row's ``ok`` and the emitter expands the halo."""
    return 32 if dim == 2 else 96


def group_size(dim: int) -> int:
    """Insertion-group width per loop trip.  Measured at the production
    row shapes ([16, 1024] 2d, [8, 1280] 3d): the group-quadratic
    acceptance scans grow faster than the per-trip fixed costs shrink,
    so the narrow group wins in both dims (G=8/16/24 cost 1.4x/2.4x/5x
    the G=4 wall time in 2d)."""
    return 4


def batched_delaunay(points, counts, *, dim: int, interpret: bool = True,
                     force_kernel: bool = False):
    """Triangulate ``B`` padded point rows in one dispatch.

    points: [B, N, d] float64, counts: [B] int.  Returns
    ``(simp [B, S, d+1] int32, alive [B, S] bool, ok [B] bool)``:
    alive slots triangulate each row's points plus its super-simplex
    (vertex ids >= N); ``ok=False`` rows must be rebuilt with a larger
    halo.  Padding rows (count 0) are inert and cost no loop trips.
    """
    pts = jnp.asarray(points, jnp.float64)
    cnt = jnp.asarray(counts, jnp.int32)
    B, N, d = pts.shape
    if d != dim:
        raise ValueError(f"points are {d}-dimensional, expected {dim}")
    S = simplex_capacity(N, dim)
    CAV = cavity_capacity(dim)
    G = group_size(dim)
    use_ref = jax.default_backend() == "cpu" and not force_kernel
    if use_ref:
        simp, alive, ok = delaunay_ref(pts, cnt, dim=dim, num_simplices=S,
                                       cavity=CAV, group=G)
        return simp, alive, ok
    simp, alive, ok = delaunay_call(pts, cnt, dim=dim, num_simplices=S,
                                    cavity=CAV, group=G, interpret=interpret)
    return simp, alive.astype(bool), ok.astype(bool)
