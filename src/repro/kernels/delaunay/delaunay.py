"""Pallas harness for the batched Bowyer-Watson triangulation.

One chunk+halo row per grid step: the kernel body runs the same
insertion core as :mod:`.ref` (shared arithmetic => shared Cramer
predicate => certificates bit-identical to the engine's GEOM_CERT
re-check), reading one padded point row from VMEM and writing that
row's simplex slots, alive mask, and ok flag.  ``interpret=True`` by
default, like the other kernels in this package tree: the CPU
production path dispatches the jitted reference (see :mod:`.ops`), and
the Pallas path is exercised in interpret mode for parity until real
TPU time is available.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import GROUP, triangulate


def _dt_kernel(pts_ref, cnt_ref, simp_ref, alive_ref, ok_ref, *,
               dim: int, num_simplices: int, cavity: int, group: int):
    pts = pts_ref[0]                      # (N, d) f64
    cnt = cnt_ref[0]
    simp, alive, ok = triangulate(pts, cnt, dim=dim,
                                  num_simplices=num_simplices, cavity=cavity,
                                  group=group)
    simp_ref[0] = simp
    alive_ref[0] = alive.astype(jnp.int8)
    ok_ref[0] = ok.astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("dim", "num_simplices", "cavity", "group",
                              "interpret"))
def delaunay_call(pts, cnt, *, dim: int, num_simplices: int, cavity: int,
                  group: int = GROUP, interpret: bool = True):
    """Batched triangulation via ``pallas_call``; one row per grid step.

    pts: [B, N, d] float64, cnt: [B] int32.  Returns
    ``(simp [B, S, d+1] int32, alive [B, S] int8, ok [B] int8)`` with
    the same row semantics as :func:`repro.kernels.delaunay.ref.triangulate`.
    """
    B, N, d = pts.shape
    assert d == dim, (d, dim)
    S = num_simplices
    kernel = functools.partial(_dt_kernel, dim=dim, num_simplices=S,
                               cavity=cavity, group=group)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, N, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, dim + 1), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, dim + 1), jnp.int32),
            jax.ShapeDtypeStruct((B, S), jnp.int8),
            jax.ShapeDtypeStruct((B,), jnp.int8),
        ],
        interpret=interpret,
    )(pts, cnt)
