"""Batched device-side Delaunay triangulation (Bowyer-Watson)."""
from .ops import (batched_delaunay, cavity_capacity, group_size,
                  simplex_capacity)
from .predicates import circumsphere, circumsphere_in_box

__all__ = [
    "batched_delaunay", "cavity_capacity", "group_size",
    "simplex_capacity",
    "circumsphere", "circumsphere_in_box",
]
