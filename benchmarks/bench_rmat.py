"""Paper Fig. 17/18: R-MAT comparison.

The paper's point: R-MAT needs O(log n) variates/edge, KaGen's ER needs
O(1) — ER and sRHG are ~10-15x faster per edge.  We reproduce the ratio
measurement on identical budgets.
"""
from __future__ import annotations

from repro.core import er, rmat
from .common import row, timeit


def main():
    log_n, m = 20, 1 << 20
    n = 1 << log_n
    t_rmat = timeit(lambda: rmat.rmat_union(5, log_n, m, P=1), warmup=1, iters=2)  # repro: allow(no-deprecated-shim) legacy-path A/B baseline
    t_er = timeit(lambda: er.gnm_directed(5, n, m, P=1), warmup=1, iters=2)  # repro: allow(no-deprecated-shim) legacy-path A/B baseline
    row("rmat_m2^20", t_rmat / m * 1e6,
        f"edges_per_s={m/t_rmat:.0f}")
    row("er_vs_rmat_m2^20", t_er / m * 1e6,
        f"er_edges_per_s={m/t_er:.0f};rmat_slowdown={t_rmat/t_er:.2f}x")
    # weak scaling of rmat (Fig 17)
    m_per_pe = 1 << 18
    for P in (1, 4, 8):
        mm = m_per_pe * P
        per_pe = [timeit(lambda pe=pe: rmat.rmat_pe(6, log_n, mm, P, pe),
                         warmup=0, iters=1) for pe in range(P)]
        row(f"rmat_weak_P{P}", max(per_pe) / m_per_pe * 1e6,
            f"max_pe_s={max(per_pe):.3f}")


if __name__ == "__main__":
    main()
