"""LM-side microbench: smoke-scale train-step and decode throughput on
CPU (the TPU numbers come from the dry-run roofline, EXPERIMENTS.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import pipeline as D
from repro.models import transformer as T
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step
from .common import row, timeit


def main():
    for arch in ("smollm_360m", "mamba2_2p7b"):
        cfg = get_smoke_config(arch)
        params = T.model_init(jax.random.key(0), cfg)
        dc = D.DataConfig(vocab=cfg.vocab, seq_len=128, batch_per_shard=8, seed=1)
        batch = {k: jnp.asarray(v) for k, v in D.make_batch(dc, 0, 0).items()}
        step = jax.jit(make_train_step(cfg, O.OptConfig()))
        opt = O.opt_init(params)

        def run():
            p2, o2, m = step(params, opt, batch)
            jax.block_until_ready(m["loss"])

        t = timeit(run, warmup=1, iters=3)
        toks = dc.seq_len * dc.batch_per_shard
        row(f"lm_train_smoke_{arch}", t / toks * 1e6,
            f"tokens_per_s={toks/t:.0f}")

    from repro.train.serve import generate
    cfg = get_smoke_config("qwen3_0p6b")
    params = T.model_init(jax.random.key(1), cfg)
    prompts = np.ones((4, 8), np.int32)
    t = timeit(lambda: generate(params, cfg, prompts, steps=16), warmup=1, iters=2)
    row("lm_decode_smoke_qwen3", t / (4 * 16) * 1e6,
        f"tokens_per_s={4*16/t:.0f}")


if __name__ == "__main__":
    main()
