"""Paper Fig. 6 (sequential ER comparison) + Fig. 7/8 (ER scaling).

Fig. 6 analog: our G(n,m) per-edge cost vs a Boost-style sequential
baseline (Vitter Algorithm-D-like skip sampling in numpy).
Fig. 7/8 analog: simulated weak scaling — max per-PE generation time as
P grows with fixed m/P (single machine executes PEs sequentially; the
communication-free property means per-PE times ARE the parallel time).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import er
from .common import row, timeit, traced_phases, update_bench_json


def boost_style_baseline(seed: int, n: int, m: int) -> np.ndarray:
    """Sequential sorted-sample baseline (Algorithm D analog)."""
    rng = np.random.default_rng(seed)
    U = n * (n - 1)
    # sorted sample via exponential spacings + dedup-retry (expected O(m))
    k = m
    out = np.unique(rng.integers(0, U, size=int(k * 1.05) + 16))
    while len(out) < m:
        out = np.unique(np.concatenate([out, rng.integers(0, U, size=m)]))
    idx = out[:m]
    u = idx // (n - 1)
    c = idx % (n - 1)
    v = c + (c >= u)
    return np.stack([u, v], axis=1)


def bench_fig6():
    n = 1 << 20
    for m in (1 << 18, 1 << 20):
        t_ours = timeit(lambda: er.gnm_directed(0, n, m, P=1))  # repro: allow(no-deprecated-shim) legacy-path A/B baseline
        t_base = timeit(lambda: boost_style_baseline(0, n, m))
        row(f"er_seq_directed_n2^20_m2^{m.bit_length()-1}",
            t_ours / m * 1e6,
            f"ours_s={t_ours:.3f};baseline_s={t_base:.3f};speedup={t_base/t_ours:.2f}x")
        t_u = timeit(lambda: er.gnm_undirected(0, n, m // 2, P=1))  # repro: allow(no-deprecated-shim) legacy-path A/B baseline
        row(f"er_seq_undirected_n2^20_m2^{m.bit_length()-2}",
            t_u / (m // 2) * 1e6, f"ours_s={t_u:.3f}")


def bench_fig7_weak_scaling():
    m_per_pe = 1 << 18
    for P in (1, 2, 4, 8):
        m = m_per_pe * P
        n = m // 16
        per_pe = [
            timeit(lambda pe=pe: er.gnm_directed_pe(1, n, m, P, pe), warmup=1, iters=1)
            for pe in range(P)
        ]
        row(f"er_weak_directed_P{P}", max(per_pe) / m_per_pe * 1e6,
            f"max_pe_s={max(per_pe):.3f};imbalance={max(per_pe)/ (sum(per_pe)/P):.2f}")
        per_pe_u = [
            timeit(lambda pe=pe: er.gnm_undirected_pe(1, n, m // 2, P, pe), warmup=1, iters=1)
            for pe in range(P)
        ]
        row(f"er_weak_undirected_P{P}", max(per_pe_u) / m_per_pe * 1e6,
            f"max_pe_s={max(per_pe_u):.3f};recompute_bound=2x")


def bench_fig8_strong_scaling():
    m, n = 1 << 21, 1 << 17
    base = None
    for P in (1, 2, 4, 8):
        per_pe = [
            timeit(lambda pe=pe: er.gnm_directed_pe(2, n, m, P, pe), warmup=0, iters=1)
            for pe in range(P)
        ]
        t = max(per_pe)
        base = base or t
        row(f"er_strong_directed_P{P}", t / (m / P) * 1e6,
            f"speedup={base/t:.2f}x_of_{P}x")


def bench_engine_phases():
    """The engine path end-to-end (plan emit -> SPMD run -> extract),
    with the plan/exec/sink phase breakdown when tracing is on."""
    from repro.api import GNM, generate

    n, m, P = 1 << 16, 1 << 18, 8
    spec = GNM(n=n, m=m, seed=7, chunks=P)
    generate(spec, P)  # compile warmup
    t0 = time.perf_counter()
    g, phases = traced_phases(lambda: generate(spec, P))
    wall = time.perf_counter() - t0
    rec = {"n": n, "m": m, "P": P, "edges": int(g.edges.shape[0]),
           "wall_s": round(wall, 4)}
    if phases is not None:
        rec["phases"] = phases
    update_bench_json(f"er_engine_n2^16_P{P}", rec, name="er")
    row(f"er_engine_n2^16_P{P}", wall / m * 1e6,
        f"wall_s={wall:.3f}" + (
            f";plan_s={phases['plan_s']:.3f};exec_s={phases['exec_s']:.3f};"
            f"sink_s={phases['sink_s']:.3f}" if phases else ""))


def main():
    bench_fig6()
    bench_fig7_weak_scaling()
    bench_fig8_strong_scaling()
    bench_engine_phases()


if __name__ == "__main__":
    main()
