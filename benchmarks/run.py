"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only er,rgg,...]
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="er,rgg,rhg,rdg,rmat,kernels,lm,sharded,serve")
    args = ap.parse_args()
    which = set(args.only.split(","))
    print("name,us_per_call,derived")
    if "er" in which:
        from . import bench_er
        bench_er.main()
    if "rgg" in which:
        from . import bench_rgg
        bench_rgg.main()
    if "rhg" in which:
        from . import bench_rhg
        bench_rhg.main()
    if "rdg" in which:
        from . import bench_rdg
        bench_rdg.main()
    if "rmat" in which:
        from . import bench_rmat
        bench_rmat.main()
    if "kernels" in which:
        from . import bench_kernels
        bench_kernels.main()
    if "lm" in which:
        from . import bench_lm
        bench_lm.main()
    if "sharded" in which:
        from . import bench_sharded
        bench_sharded.main()
    if "serve" in which:
        from . import bench_serve
        bench_serve.main()


if __name__ == "__main__":
    main()
