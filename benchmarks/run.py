"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only er,rgg,...] [--trace]

``--trace`` turns on :mod:`repro.obs` span tracing for the whole run:
benches that support it add a ``phases`` (plan/exec/sink seconds)
breakdown to their BENCH_*.json records, and any spans recorded outside
the benches' own captures are exported to ``--trace-out`` as a
Chrome-trace JSON loadable in ui.perfetto.dev.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="er,rgg,rhg,rdg,rmat,kernels,lm,sharded,serve")
    ap.add_argument("--trace", action="store_true",
                    help="enable repro.obs tracing (phases in BENCH json)")
    ap.add_argument("--trace-out", default="trace.json",
                    help="Chrome-trace export path (with --trace)")
    args = ap.parse_args()
    if args.trace:
        from repro import obs
        obs.enable(clear=True)
    which = set(args.only.split(","))
    print("name,us_per_call,derived")
    if "er" in which:
        from . import bench_er
        bench_er.main()
    if "rgg" in which:
        from . import bench_rgg
        bench_rgg.main()
    if "rhg" in which:
        from . import bench_rhg
        bench_rhg.main()
    if "rdg" in which:
        from . import bench_rdg
        bench_rdg.main()
    if "rmat" in which:
        from . import bench_rmat
        bench_rmat.main()
    if "kernels" in which:
        from . import bench_kernels
        bench_kernels.main()
    if "lm" in which:
        from . import bench_lm
        bench_lm.main()
    if "sharded" in which:
        from . import bench_sharded
        bench_sharded.main()
    if "serve" in which:
        from . import bench_serve
        bench_serve.main()
    if args.trace:
        from repro import obs
        obs.export_chrome(args.trace_out)
        print(f"# trace written to {args.trace_out}")


if __name__ == "__main__":
    main()
