"""Paper Fig. 9/10/11: RGG comparison + weak/strong scaling.

Comparison analog (Fig. 9): Holtgrewe et al. need to exchange ALL
vertices (O(n/P) comm volume per PE); we recompute halo cells instead.
We report our per-PE time plus the byte volume Holtgrewe-style sorting
would have shipped (its local compute is similar, so comm is the delta).
"""
from __future__ import annotations

import numpy as np

from repro.core import rgg
from .common import row, timeit


def bench_comparison():
    for n_per_pe in (1 << 14, 1 << 15):
        P = 4
        n = n_per_pe * P
        r = 0.55 * np.sqrt(np.log(n) / n)
        per_pe = [
            timeit(lambda pe=pe: rgg.rgg_pe(3, n, r, P, pe, 2), warmup=0, iters=1)
            for pe in range(P)
        ]
        holtgrewe_bytes = n * (2 * 8 + 8)  # coords + id exchanged once
        row(f"rgg2d_P4_npe2^{n_per_pe.bit_length()-1}",
            max(per_pe) / n_per_pe * 1e6,
            f"max_pe_s={max(per_pe):.3f};our_comm_bytes=0;"
            f"holtgrewe_comm_bytes={holtgrewe_bytes}")


def bench_weak_scaling():
    for dim in (2, 3):
        n_per_pe = 1 << 13
        for P in (1, 4, 8):
            n = n_per_pe * P
            r = 0.55 * (np.log(n) / n) ** (1.0 / dim)
            per_pe = [
                timeit(lambda pe=pe: rgg.rgg_pe(5, n, r, P, pe, dim), warmup=0, iters=1)
                for pe in range(P)
            ]
            row(f"rgg{dim}d_weak_P{P}", max(per_pe) / n_per_pe * 1e6,
                f"max_pe_s={max(per_pe):.3f}")


def bench_strong_scaling():
    n, dim = 1 << 16, 2
    r = 0.55 * np.sqrt(np.log(n) / n)
    base = None
    for P in (1, 4, 8):
        per_pe = [
            timeit(lambda pe=pe: rgg.rgg_pe(7, n, r, P, pe, dim), warmup=0, iters=1)
            for pe in range(P)
        ]
        t = max(per_pe)
        base = base or t
        row(f"rgg2d_strong_P{P}", t / (n / P) * 1e6, f"speedup={base/t:.2f}x")


def main():
    bench_comparison()
    bench_weak_scaling()
    bench_strong_scaling()


if __name__ == "__main__":
    main()
