"""RGG edge phase: retired per-PE host loop vs the GEOM_TORUS PairPlan
executor, in edges/sec.

The host loop (``rgg.rgg_pe``, now a test oracle) enumerates cell pairs
in Python and dispatches one masked kernel batch per PE; the engine
path emits the same forward-canonical pair list once as a PairPlan and
executes it as a single zero-collective SPMD step.  Results (and the
plan's ``fill_fraction`` padding-waste figure) land in the
machine-readable ``BENCH_pairs.json`` at the repo root — the perf
trajectory the ROADMAP's geometric items are tracked against.

    PYTHONPATH=src python -m benchmarks.bench_rgg [--log-n 14 --pes 8]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import rgg
from repro.distrib import engine

from .common import row, timeit, traced_phases, update_bench_json


def bench_pairplan_vs_host(n: int, P: int, seed: int = 3, dim: int = 2,
                           host_iters: int = 1) -> dict:
    r = 0.55 * float((np.log(n) / n) ** (1.0 / dim))
    chunk_P = max(P, 16)

    t0 = time.perf_counter()
    plan = rgg.rgg_pair_plan(seed, n, r, P, dim, chunk_P=chunk_P)
    t_plan = time.perf_counter() - t0

    fn, inputs = engine.pair_executor(plan, engine.default_mesh(plan.num_pes))
    out = jax.block_until_ready(fn(*inputs))  # compile once
    m = int(np.asarray(out[1]).sum())
    t_exec = timeit(lambda: jax.block_until_ready(fn(*inputs)), warmup=0)

    def host_loop():
        for pe in range(P):
            rgg.rgg_pe(seed, n, r, P, pe, dim, chunk_P=chunk_P)

    t_host = timeit(host_loop, warmup=0, iters=host_iters)

    rec = {
        "n": n, "P": P, "dim": dim, "radius": r, "edges": m,
        "host_loop_s": t_host, "plan_s": t_plan, "engine_exec_s": t_exec,
        "host_eps": m / t_host, "engine_eps": m / t_exec,
        "engine_eps_with_plan": m / (t_plan + t_exec),
        "speedup_exec": t_host / t_exec,
        "speedup_with_plan": t_host / (t_plan + t_exec),
        "pairs": plan.total_pairs, "capacity": plan.capacity,
        "fill_fraction": plan.fill_fraction,
    }
    # phase-attributed end-to-end view of the same instance (plan emit
    # -> SPMD run -> extract) when the harness enabled tracing
    from repro.api import RGG, generate

    spec = RGG(n=n, radius=r, seed=seed, dim=dim, chunks=chunk_P)
    generate(spec, P, check=False)  # compile warmup
    _, phases = traced_phases(lambda: generate(spec, P, check=False))
    if phases is not None:
        rec["phases"] = phases
    row(f"rgg{dim}d_pairplan_n2^{n.bit_length()-1}_P{P}", t_exec / m * 1e6,
        f"engine_eps={rec['engine_eps']:.0f};host_eps={rec['host_eps']:.0f};"
        f"speedup_exec={rec['speedup_exec']:.1f}x;"
        f"speedup_with_plan={rec['speedup_with_plan']:.1f}x;"
        f"fill={plan.fill_fraction:.3f}")
    update_bench_json(f"rgg{dim}d", rec)
    return rec


def bench_engine_scaling(n: int, seed: int = 5) -> None:
    """Engine edge-phase weak view: same instance, growing P — the table
    deal changes, the executed pair set (and edge set) does not."""
    r = 0.55 * float(np.sqrt(np.log(n) / n))
    for P in (1, 4, 8):
        plan = rgg.rgg_pair_plan(seed, n, r, P, chunk_P=16)
        fn, inputs = engine.pair_executor(plan, engine.default_mesh(plan.num_pes))
        out = jax.block_until_ready(fn(*inputs))
        m = int(np.asarray(out[1]).sum())
        t = timeit(lambda: jax.block_until_ready(fn(*inputs)), warmup=0)
        row(f"rgg2d_engine_P{P}", t / m * 1e6,
            f"eps={m / t:.0f};fill={plan.fill_fraction:.3f}")


def main(log_n: int = 14, P: int = 8) -> None:
    rec = bench_pairplan_vs_host(1 << log_n, P)
    if rec["speedup_exec"] < 2.0:  # the PairPlan acceptance bar (record, don't abort)
        print(f"# WARNING: PairPlan speedup {rec['speedup_exec']:.2f}x < 2x "
              f"acceptance bar at n=2^{log_n}, P={P}")
    bench_engine_scaling(1 << (log_n - 1))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--log-n", type=int, default=14)
    ap.add_argument("--pes", type=int, default=8)
    args = ap.parse_args()
    main(args.log_n, args.pes)
