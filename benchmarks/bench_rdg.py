"""RDG edge phase: retired per-PE host loop vs the GEOM_CERT PairPlan
path (per-chunk Qhull on the host, batched circumsphere certificates +
edge emission on device), in edges/sec.

End-to-end the triangulation dominates — Qhull is the one piece that
stays host-side (ROADMAP: device-side DT) — so the record splits the
plan phase (Qhull + batched certification) from the executor step and
reports both rates.  Results land in ``BENCH_pairs.json`` next to the
RGG record.

    PYTHONPATH=src python -m benchmarks.bench_rdg [--log-n 13 --pes 8]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import rdg
from repro.distrib import engine

from .common import row, timeit, update_bench_json


def bench_pairplan_vs_host(n: int, P: int, seed: int = 11, dim: int = 2) -> dict:
    chunk_P = max(P, 16)

    t0 = time.perf_counter()
    plan = rdg.rdg_pair_plan(seed, n, P, dim, chunk_P=chunk_P)
    t_plan = time.perf_counter() - t0

    fn, inputs = engine.pair_executor(plan, engine.default_mesh(plan.num_pes))
    out = jax.block_until_ready(fn(*inputs))  # compile once
    m = int(np.asarray(out[1]).sum())
    t_exec = timeit(lambda: jax.block_until_ready(fn(*inputs)), warmup=0)

    def host_loop():
        for pe in range(P):
            rdg.rdg_pe(seed, n, P, pe, dim, chunk_P=chunk_P)

    t_host = timeit(host_loop, warmup=0, iters=1)

    rec = {
        "n": n, "P": P, "dim": dim, "edges": m,
        "host_loop_s": t_host, "plan_s": t_plan, "engine_exec_s": t_exec,
        "host_eps": m / t_host, "engine_eps": m / t_exec,
        "engine_eps_with_plan": m / (t_plan + t_exec),
        "speedup_exec": t_host / t_exec,
        "speedup_with_plan": t_host / (t_plan + t_exec),
        "simplex_rows": plan.total_pairs, "capacity": plan.capacity,
        "fill_fraction": plan.fill_fraction,
        "host_side": "qhull triangulation only (certificates ride the executor)",
    }
    # balanced round-robin certificate deal: padding waste stays bounded
    assert plan.fill_fraction >= 0.85, (
        f"RDG PairPlan fill {plan.fill_fraction:.3f} < 0.85 — "
        f"the balanced deal regressed")
    row(f"rdg{dim}d_pairplan_n2^{n.bit_length()-1}_P{P}", t_exec / m * 1e6,
        f"engine_eps={rec['engine_eps']:.0f};host_eps={rec['host_eps']:.0f};"
        f"speedup_exec={rec['speedup_exec']:.1f}x;"
        f"speedup_with_plan={rec['speedup_with_plan']:.1f}x;"
        f"fill={plan.fill_fraction:.3f}")
    update_bench_json(f"rdg{dim}d", rec)
    return rec


def main(log_n: int = 13, P: int = 8) -> None:
    bench_pairplan_vs_host(1 << log_n, P)
    bench_pairplan_vs_host(1 << (log_n - 2), P, dim=3)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--log-n", type=int, default=13)
    ap.add_argument("--pes", type=int, default=8)
    args = ap.parse_args()
    main(args.log_n, args.pes)
