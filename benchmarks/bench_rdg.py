"""Paper Fig. 12/13: RDG weak/strong scaling (2d + 3d), halo expansions."""
from __future__ import annotations

from repro.core import rdg
from .common import row, timeit


def bench_weak():
    for dim in (2, 3):
        n_per_pe = 1 << 11 if dim == 3 else 1 << 12
        for P in (1, 4):
            n = n_per_pe * P
            per_pe, expansions = [], []
            for pe in range(P):
                per_pe.append(timeit(lambda pe=pe: rdg.rdg_pe(11, n, P, pe, dim),
                                     warmup=0, iters=1))
                expansions.append(rdg.rdg_pe(11, n, P, pe, dim)[2])
            row(f"rdg{dim}d_weak_P{P}", max(per_pe) / n_per_pe * 1e6,
                f"max_pe_s={max(per_pe):.3f};halo_expansions={max(expansions)}")


def bench_strong():
    n, dim = 1 << 14, 2
    base = None
    for P in (1, 4, 9):
        per_pe = [timeit(lambda pe=pe: rdg.rdg_pe(13, n, P, pe, dim),
                         warmup=0, iters=1) for pe in range(P)]
        t = max(per_pe)
        base = base or t
        row(f"rdg2d_strong_P{P}", t / (n / P) * 1e6, f"speedup={base/t:.2f}x")


def main():
    bench_weak()
    bench_strong()


if __name__ == "__main__":
    main()
