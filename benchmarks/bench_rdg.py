"""RDG edge phase: retired per-PE host loop vs the GEOM_CERT PairPlan
path (batched device Delaunay triangulation + circumsphere certificates
+ edge emission), in edges/sec.

Since PR 10 the triangulation itself runs on device
(:func:`repro.kernels.delaunay.batched_delaunay`, one dispatch per halo
round); Qhull survives only as the test oracle and the tiny-grid wrap
fallback.  Plan emission is therefore a per-*seed* cost the serve plan
cache amortises, so the record splits it three ways:

* ``plan_cold_s`` — first plan in the process: jit compiles for the
  (rows x points) buckets the halo protocol visits;
* ``plan_s`` — steady state: a *fresh seed* through ``plan.reseed_fn``
  with warm buckets (the serve seed-rotation path), which is what
  ``speedup_with_plan`` uses;
* ``engine_exec_s`` — the SPMD executor step alone.

Results land in ``BENCH_pairs.json`` next to the RGG record, with the
PR-8 ``phases`` dict (plan/exec/sink attribution) when tracing is on.

    PYTHONPATH=src python -m benchmarks.bench_rdg [--log-n 13 --pes 8]
"""
from __future__ import annotations

import argparse
import itertools
import time

import jax
import numpy as np

from repro.core import rdg
from repro.distrib import engine

from .common import row, timeit, traced_phases, update_bench_json


def bench_pairplan_vs_host(n: int, P: int, seed: int = 11, dim: int = 2) -> dict:
    t0 = time.perf_counter()
    plan = rdg.rdg_pair_plan(seed, n, P, dim)
    t_plan_cold = time.perf_counter() - t0

    # steady state: new seed, warm jit buckets — the serve plan cache's
    # reseed path (structure cached, device triangulation re-runs)
    seeds = itertools.count(seed + 1)
    t_plan = timeit(lambda: plan.reseed_fn(next(seeds)), warmup=1, iters=3)

    fn, inputs = engine.pair_executor(plan, engine.default_mesh(plan.num_pes))
    out = jax.block_until_ready(fn(*inputs))  # compile once
    m = int(np.asarray(out[1]).sum())
    t_exec = timeit(lambda: jax.block_until_ready(fn(*inputs)), warmup=0)

    def host_loop():
        for pe in range(P):
            rdg.rdg_pe(seed, n, P, pe, dim)

    t_host = timeit(host_loop, warmup=0, iters=1)

    rec = {
        "n": n, "P": P, "dim": dim, "edges": m,
        "host_loop_s": t_host, "plan_cold_s": t_plan_cold, "plan_s": t_plan,
        "engine_exec_s": t_exec,
        "host_eps": m / t_host, "engine_eps": m / t_exec,
        "engine_eps_with_plan": m / (t_plan + t_exec),
        "speedup_exec": t_host / t_exec,
        "speedup_with_plan": t_host / (t_plan + t_exec),
        "simplex_rows": plan.total_pairs, "capacity": plan.capacity,
        "fill_fraction": plan.fill_fraction,
        "host_side": "none — device DT (Qhull retired to test oracle)",
    }
    # phase-attributed end-to-end view of the same instance (plan emit
    # -> SPMD run -> extract) when the harness enabled tracing
    from repro.api import RDG, generate

    spec = RDG(n=n, dim=dim, seed=seed)
    generate(spec, P, check=False)  # compile warmup
    _, phases = traced_phases(lambda: generate(spec, P, check=False))
    if phases is not None:
        rec["phases"] = phases
    # balanced round-robin certificate deal: padding waste stays bounded
    assert plan.fill_fraction >= 0.85, (
        f"RDG PairPlan fill {plan.fill_fraction:.3f} < 0.85 — "
        f"the balanced deal regressed")
    row(f"rdg{dim}d_pairplan_n2^{n.bit_length()-1}_P{P}", t_exec / m * 1e6,
        f"engine_eps={rec['engine_eps']:.0f};host_eps={rec['host_eps']:.0f};"
        f"speedup_exec={rec['speedup_exec']:.1f}x;"
        f"speedup_with_plan={rec['speedup_with_plan']:.1f}x;"
        f"fill={plan.fill_fraction:.3f}")
    update_bench_json(f"rdg{dim}d", rec)
    return rec


def main(log_n: int = 13, P: int = 8) -> None:
    bench_pairplan_vs_host(1 << log_n, P)
    bench_pairplan_vs_host(1 << (log_n - 2), P, dim=3)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--log-n", type=int, default=13)
    ap.add_argument("--pes", type=int, default=8)
    args = ap.parse_args()
    main(args.log_n, args.pes)
