"""Kernel microbenchmarks (paper §5.3 GPGPU kernels / §7.2.1 SIMD checks).

CPU wall times are from interpret-mode / XLA-CPU paths — the derived
column reports the TPU roofline model instead: bytes and flops per
candidate check, and the implied v5e-bound throughput.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hypdist.ops import pad_features, precompute_features
from repro.kernels.hypdist.ref import hypdist_mask_ref
from repro.kernels.pairdist.ref import pairdist_mask_ref
from .common import row, timeit


def main():
    m = n = 1024
    a = jax.random.uniform(jax.random.key(0), (m, 8), dtype=jnp.float32)
    b = jax.random.uniform(jax.random.key(1), (n, 8), dtype=jnp.float32)
    ref = jax.jit(lambda x, y: pairdist_mask_ref(x, y, 0.01, dim=3))
    t = timeit(lambda: ref(a, b).block_until_ready())
    checks = m * n
    flops_per = 3 * 3  # d subs, d mults, d-1 adds + cmp ~ 9
    bytes_per = (2 * 8 * 4) / n + 1  # amortized loads + mask store
    v5e_bound = 197e12 / flops_per
    row("pairdist_1024x1024_xla", t / checks * 1e6,
        f"flops_per_check={flops_per};bytes_per_check~{bytes_per:.1f};"
        f"v5e_checks_per_s={v5e_bound:.2e}")

    rr = np.random.default_rng(0)
    feats = precompute_features(rr.uniform(5, 12, m), rr.uniform(0, 6.28, m))
    f = jnp.asarray(pad_features(feats, dtype=np.float32))
    refh = jax.jit(lambda x, y: hypdist_mask_ref(x, y, np.cosh(12.0)))
    t = timeit(lambda: refh(f, f).block_until_ready())
    row("hypdist_1024x1024_xla", t / checks * 1e6,
        "flops_per_check=8;eq9_fma_form=4dots;"
        f"v5e_checks_per_s={197e12/8:.2e}")

    # pallas interpret-mode correctness cost (not a perf number)
    from repro.kernels.pairdist.pairdist import pairdist_mask
    t = timeit(lambda: np.asarray(pairdist_mask(a[:128], b[:128], 0.01, dim=3)),
               warmup=1, iters=1)
    row("pairdist_128x128_pallas_interpret", t / (128 * 128) * 1e6,
        "correctness_path=interpret")


if __name__ == "__main__":
    main()
