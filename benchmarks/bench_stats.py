"""Streaming analytics throughput: edges/sec through the full
generate -> accumulate pipeline (repro.stats.collect), per family.

The interesting number is the *pipeline* rate — chunk generation, host
routing by vertex ownership, and device scatter-adds overlap in one
stream — plus the pure-generation rate for reference, so the analytics
overhead is visible as the ratio.

    python -m benchmarks.bench_stats [--scale 16] [--pes 8]
"""
from __future__ import annotations

import argparse

from repro.api import BA, GNM, GNP, RHG, RMAT, generate
from repro.stats import collect

from .common import row, timeit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=16, help="log2 vertices")
    ap.add_argument("--pes", type=int, default=8)
    args = ap.parse_args()
    n, P = 1 << args.scale, args.pes

    specs = [
        ("gnp", GNP(n=n, p=16.0 / n, seed=1)),
        ("gnm", GNM(n=n, m=8 * n, seed=1)),
        ("ba", BA(n=n, d=8, seed=1)),
        ("rmat", RMAT(log_n=args.scale, m=8 * n, seed=1)),
        ("rhg", RHG(n=max(1 << 14, n >> 2), avg_deg=8, gamma=2.7, seed=1)),
    ]
    print(f"# n=2^{args.scale} P={P}; columns: name, us, edges/sec")
    for name, spec in specs:
        m = generate(spec, P).m
        t_gen = timeit(lambda: generate(spec, P), warmup=1, iters=3)
        t_col = timeit(lambda: collect(spec, P, batch=512), warmup=1, iters=3)
        row(f"{name}-generate", t_gen * 1e6, f"{m / t_gen:.3g} edges/s")
        row(f"{name}-collect", t_col * 1e6,
            f"{m / t_col:.3g} edges/s ({t_col / t_gen:.2f}x generate)")


if __name__ == "__main__":
    main()
