"""Unified engine vs per-PE Python loop (the refactor's perf claim),
plus the rng_impl A/B (threefry2x32 vs TPU-native rbg) through the
engine.

The per-PE reference path dispatches one jit per chunk batch per PE
from Python; the engine lowers the whole plan into a single SPMD
program.  Both produce bit-identical edge sets, so the delta is pure
dispatch/fusion overhead.  Run with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to also measure
true multi-device execution.

    python -m benchmarks.bench_sharded [--rng-impl both|threefry2x32|rbg]

Recorded numbers live in benchmarks/README.md.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import er, rgg
from repro.core.chunking import undirected_chunks_for_pe
from repro.distrib.engine import edge_executor, default_mesh, point_executor, run_edges

from .common import row, timeit


def _pe_loop_gnm_undirected(seed, n, m, P):
    """The host per-PE reference path (ownership union), one jit batch
    per chunk kind per PE — the dispatch-bound baseline.  (The public
    er.gnm_undirected now delegates to the engine, so the loop must be
    spelled out here.)"""
    return np.concatenate([
        er._gen_chunks(seed, n, er._owned(undirected_chunks_for_pe(seed, n, m, P, pe), pe))
        for pe in range(P)
    ])


def bench_er_engine_vs_loop():
    seed, n = 0, 1 << 17
    for P in (4, 8, 16):
        m = P << 16
        plan = er.gnm_undirected_plan(seed, n, m, P)
        mesh = default_mesh(P)
        fn, inputs = edge_executor(plan, mesh)

        def engine_run():
            edges, keep = fn(*inputs)
            return np.asarray(edges)[np.asarray(keep)]

        t_engine = timeit(engine_run)
        t_loop = timeit(lambda: _pe_loop_gnm_undirected(seed, n, m, P))
        row(
            f"sharded_gnm_undirected_P{P}",
            t_engine / m * 1e6,
            f"engine_s={t_engine:.3f};pe_loop_s={t_loop:.3f};"
            f"speedup={t_loop / t_engine:.2f}x;devices={len(mesh.devices.ravel())}",
        )


def bench_rgg_points_engine_vs_loop():
    seed, n, r = 0, 1 << 15, 0.004
    for P in (4, 8):
        plan = rgg.rgg_point_plan(seed, n, r, P, 2)
        mesh = default_mesh(P)
        fn, inputs = point_executor(plan, mesh)

        def engine_run():
            pts, mask = fn(*inputs)
            return np.asarray(pts), np.asarray(mask)

        t_engine = timeit(engine_run)
        t_loop = timeit(lambda: rgg.rgg_all_points(seed, n, r, P, 2))
        row(
            f"sharded_rgg_points_P{P}",
            t_engine / n * 1e6,
            f"engine_s={t_engine:.3f};host_loop_s={t_loop:.3f};"
            f"speedup={t_loop / t_engine:.2f}x",
        )


def bench_ownership_vs_unique():
    """The dedup replacement: owned-chunk union vs np.unique union."""
    seed, n = 1, 1 << 17
    for P in (8, 16):
        m = P << 16
        t_owned = timeit(lambda: _pe_loop_gnm_undirected(seed, n, m, P))

        def unique_union():
            all_e = np.concatenate(
                [er.gnm_undirected_pe(seed, n, m, P, pe) for pe in range(P)]
            )
            return np.unique(all_e, axis=0)

        t_unique = timeit(unique_union)
        row(
            f"gnm_undirected_dedup_P{P}",
            t_owned / m * 1e6,
            f"owned_s={t_owned:.3f};unique_s={t_unique:.3f};"
            f"speedup={t_unique / t_owned:.2f}x",
        )


def bench_rng_impl(impls=("threefry2x32", "rbg")):
    """rng_impl A/B through the engine: counter-based threefry (the
    paper-faithful hash-per-element stream) vs the backend-native
    RngBitGenerator ('rbg': one fused op per draw, weaker fold_in
    independence — the beyond-paper perf option).  Closes the ROADMAP
    'plumbed but unbenchmarked' item; numbers in benchmarks/README.md."""
    seed, n = 0, 1 << 18
    for impl in impls:
        for P in (4, 8):
            m = P << 17
            plan = er.gnm_directed_plan(seed, n, m, P, rng_impl=impl)
            mesh = default_mesh(P)
            fn, inputs = edge_executor(plan, mesh)

            def engine_run():
                return jax.block_until_ready(fn(*inputs))

            t = timeit(engine_run)
            row(
                f"engine_gnm_directed_{impl}_P{P}",
                t / m * 1e6,
                f"engine_s={t:.3f};medges_per_s={m / t / 1e6:.1f};"
                f"backend={jax.default_backend()};devices={len(mesh.devices.ravel())}",
            )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rng-impl", choices=("both", "threefry2x32", "rbg"),
                    default="both", help="which PRNG impls to A/B through the engine")
    args, _ = ap.parse_known_args()
    bench_er_engine_vs_loop()
    bench_rgg_points_engine_vs_loop()
    bench_ownership_vs_unique()
    impls = ("threefry2x32", "rbg") if args.rng_impl == "both" else (args.rng_impl,)
    bench_rng_impl(impls)


if __name__ == "__main__":
    main()
