"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, List, Tuple

# machine-readable perf trajectory for the geometric PairPlan engine
BENCH_PAIRS_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_pairs.json")


def update_bench_json(key: str, record: dict, path: str = BENCH_PAIRS_PATH) -> None:
    """Merge one benchmark record into the repo-root JSON file."""
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[key] = record
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


def timeit(fn: Callable, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def row(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.2f},{derived}"
    print(line, flush=True)
    return line
