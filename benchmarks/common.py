"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, List, Optional, Tuple

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_json_path(name: str) -> str:
    """Repo-root path of the machine-readable ``BENCH_<name>.json``."""
    return os.path.join(_REPO_ROOT, f"BENCH_{name}.json")


# legacy alias: the geometric PairPlan perf trajectory
BENCH_PAIRS_PATH = bench_json_path("pairs")


def update_bench_json(key: str, record: dict, path: Optional[str] = None,
                      name: str = "pairs") -> None:
    """Merge one benchmark record into a repo-root ``BENCH_*.json``
    (``path`` overrides; otherwise ``name`` picks the file)."""
    path = path if path is not None else bench_json_path(name)
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[key] = record
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


def timeit(fn: Callable, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def traced_phases(fn: Callable):
    """``(result, phases)``: when tracing is enabled, run ``fn`` under a
    fresh capture and return its plan/exec/sink phase breakdown (the
    ``phases`` field of BENCH_*.json records); otherwise run plain and
    return ``(result, None)`` — the disabled path adds nothing."""
    from repro import obs

    if not obs.is_enabled():
        return fn(), None
    with obs.capture() as tr:
        out = fn()
    return out, {k: round(v, 6) for k, v in tr.phase_totals().items()}


def row(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.2f},{derived}"
    print(line, flush=True)
    return line
