"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple


def timeit(fn: Callable, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def row(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.2f},{derived}"
    print(line, flush=True)
    return line
