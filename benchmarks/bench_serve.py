"""Packed multi-tenant serving vs naive per-request generation.

Two claims the serving tier (repro.serve) makes, measured:

1. **Packing**: 256 concurrent mixed-family requests (4 families x 64
   distinct seeds) served through one :class:`repro.serve.Service` —
   plan-cache reseeds, shared [D, batch] slabs, per-request sinks —
   vs the naive loop ``[generate(s, P) for s in specs]`` that plans
   cold and dispatches each request alone.  Same bit-identical output
   (spot-checked), so the delta is pure amortization: host planning,
   compile reuse, slab occupancy.
2. **Reseed**: per family, ``plan.reseed(seed)`` against a warm cached
   structure vs a cold ``spec.plan(P)`` host emission — the plan
   cache's hit fast path.

Runs on 8 virtual devices (flag set before jax imports) and writes
``BENCH_serve.json`` at the repo root.

    python -m benchmarks.bench_serve [--requests 256] [--pes 8]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import jax
import numpy as np

from repro.api import BA, GNM, GNP, RDG, RGG, RHG, generate
from repro.serve import PlanCache, Service

from .common import row, timeit, traced_phases

P = 8


def mixed_specs(count: int):
    """count requests cycling four families, distinct seeds."""
    shapes = [
        lambda s: GNM(n=512, m=1024, seed=s, chunks=4),
        lambda s: GNP(n=512, p=0.004, seed=s, chunks=4),
        lambda s: BA(n=256, d=2, seed=s),
        lambda s: RGG(n=512, radius=0.08, seed=s),
    ]
    return [shapes[i % len(shapes)](1000 + i) for i in range(count)]


def bench_packed(specs, pes: int, slab_batch: int):
    """One Service, all requests in flight at once."""
    # steady state: compiles + plan-cache structure amortize across the
    # fleet, so warm with a small prefix fleet first
    Service(pes, slab_batch=slab_batch, check=False).serve(specs[:8])
    svc = Service(pes, slab_batch=slab_batch, check=False)

    def drive():
        t0 = time.perf_counter()
        tickets = [svc.submit(s) for s in specs]
        svc.drain()
        return time.perf_counter() - t0, tickets

    (wall, tickets), phases = traced_phases(drive)
    lat = sorted(t.latency for t in tickets)
    graphs = [t.result() for t in tickets]
    return wall, lat, graphs, svc.stats, svc.metrics(), phases


def bench_naive(specs, pes: int):
    """The baseline: plan cold + dispatch each request by itself."""
    generate(specs[0], pes, check=False)  # warm the per-family compiles
    lat = []
    graphs = []
    t0 = time.perf_counter()
    for s in specs:
        r0 = time.perf_counter()
        graphs.append(generate(s, pes, check=False))
        lat.append(time.perf_counter() - r0)
    return time.perf_counter() - t0, sorted(lat), graphs


def bench_reseed(pes: int):
    """Cold spec.plan(P) vs warm cache reseed, per family."""
    fams = {
        "gnm": lambda s: GNM(n=2048, m=4096, seed=s, chunks=8),
        "ba": lambda s: BA(n=1024, d=2, seed=s),
        "rgg": lambda s: RGG(n=512, radius=0.08, seed=s),
        "rhg": lambda s: RHG(n=512, avg_deg=6.0, gamma=2.7, seed=s),
        "rdg": lambda s: RDG(n=512, seed=s),
    }
    out = {}
    for name, make in fams.items():
        # cold cycles seeds: families with per-seed plan caches (RDG's
        # planning-structure column cache) must actually re-plan here
        cseed = [1000]

        def cold():
            cseed[0] += 1
            make(cseed[0]).plan(pes)

        cold_s = timeit(cold, warmup=1, iters=5)
        cache = PlanCache()
        cache.plan(make(1), pes, "threefry2x32")  # warm the structure
        seed = [2]

        def hit():
            seed[0] += 1
            cache.plan(make(seed[0]), pes, "threefry2x32")

        hit()  # geometric families build their replay structure lazily
        hot_s = timeit(hit, warmup=1, iters=5)
        out[name] = {
            "cold_us": round(cold_s * 1e6, 1),
            "reseed_us": round(hot_s * 1e6, 1),
            "speedup": round(cold_s / hot_s, 1),
        }
        row(f"serve_reseed_{name}_P{pes}", hot_s * 1e6,
            f"cold_us={cold_s*1e6:.0f};speedup={cold_s/hot_s:.1f}x")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--pes", type=int, default=P)
    ap.add_argument("--slab-batch", type=int, default=32)
    ap.add_argument("--verify", type=int, default=8,
                    help="spot-check this many requests for bit-identity")
    args, _ = ap.parse_known_args()

    specs = mixed_specs(args.requests)
    packed_s, packed_lat, packed_graphs, st, metrics_text, phases = \
        bench_packed(specs, args.pes, args.slab_batch)
    naive_s, naive_lat, naive_graphs = bench_naive(specs, args.pes)

    step = max(1, len(specs) // args.verify)
    for i in range(0, len(specs), step):
        np.testing.assert_array_equal(packed_graphs[i].edges,
                                      naive_graphs[i].edges)

    from repro.obs import parse_exposition
    parse_exposition(metrics_text)  # the exposition stays well-formed

    n = len(specs)
    packed_rps, naive_rps = n / packed_s, n / naive_s
    speedup = packed_rps / naive_rps
    row(f"serve_packed_{n}req_P{args.pes}", packed_s / n * 1e6,
        f"packed_rps={packed_rps:.1f};naive_rps={naive_rps:.1f};"
        f"speedup={speedup:.1f}x;slabs={st['slabs']};slots={st['slots']}")

    reseed = bench_reseed(args.pes)

    def pct(lat, q):
        return round(lat[min(len(lat) - 1, int(len(lat) * q))] * 1e3, 2)

    out = {
        "bench": "multi-tenant packed serving vs naive per-request generate",
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "P": args.pes,
        "requests": n,
        "families": ["gnm", "gnp", "ba", "rgg"],
        "packed": {
            "seconds": round(packed_s, 3),
            "req_per_s": round(packed_rps, 1),
            "latency_ms": {"p50": pct(packed_lat, 0.50),
                           "p99": pct(packed_lat, 0.99)},
            "slabs": st["slabs"], "slots": st["slots"],
            "cache": st["cache"],
            "completed": st["completed"],
        },
        "naive": {
            "seconds": round(naive_s, 3),
            "req_per_s": round(naive_rps, 1),
            "latency_ms": {"p50": pct(naive_lat, 0.50),
                           "p99": pct(naive_lat, 0.99)},
        },
        "speedup": round(speedup, 2),
        "plan_reseed": reseed,
        "phases": phases,
        "note": ("packed latency is submit-to-completion inside one shared "
                 "drain (requests finish as their last slab lands); naive "
                 "latency is a solo generate() call.  Outputs spot-checked "
                 "bit-identical."),
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
