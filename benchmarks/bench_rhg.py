"""Paper Fig. 14/15/16: RHG comparison + scaling.

Fig. 14 analog: our RHG edges/s vs the naive O(n^2) generator (the
NkGen-without-grid analog) across gamma/avg-deg regimes.
Fig. 15/16 analog: per-PE weak/strong scaling.
"""
from __future__ import annotations

import numpy as np

from repro.core import rhg
from .common import row, timeit


def bench_comparison():
    for gamma, deg in ((2.2, 16), (3.0, 16)):
        n = 4000
        params = rhg.RHGParams(n=n, avg_deg=deg, gamma=gamma, seed=1)
        t_ours = timeit(lambda: rhg.rhg_pe(params, 1, 0), warmup=0, iters=1)
        e = rhg.rhg_pe(params, 1, 0)[0]

        def naive():
            r, t = rhg.rhg_all_vertices(params, 1)
            rhg.rhg_brute_edges(r, t, params.R)

        t_naive = timeit(naive, warmup=0, iters=1)
        row(f"rhg_gamma{gamma}_deg{deg}_n4000", t_ours / max(len(e), 1) * 1e6,
            f"ours_s={t_ours:.3f};naive_s={t_naive:.3f};"
            f"edges_per_s={len(e)/t_ours:.0f};speedup={t_naive/t_ours:.2f}x")


def bench_weak_scaling():
    n_per_pe = 2000
    for P in (1, 2, 4):
        n = n_per_pe * P
        params = rhg.RHGParams(n=n, avg_deg=8, gamma=2.8, seed=2)
        per_pe = [
            timeit(lambda pe=pe: rhg.rhg_pe(params, P, pe), warmup=0, iters=1)
            for pe in range(P)
        ]
        row(f"rhg_weak_P{P}", max(per_pe) / n_per_pe * 1e6,
            f"max_pe_s={max(per_pe):.3f};imbalance={max(per_pe)/(sum(per_pe)/P):.2f}")


def bench_strong_scaling():
    n = 6000
    params = rhg.RHGParams(n=n, avg_deg=8, gamma=3.0, seed=3)
    base = None
    for P in (1, 2, 4):
        per_pe = [
            timeit(lambda pe=pe: rhg.rhg_pe(params, P, pe), warmup=0, iters=1)
            for pe in range(P)
        ]
        t = max(per_pe)
        base = base or t
        row(f"rhg_strong_P{P}", t / (n / P) * 1e6, f"speedup={base/t:.2f}x")


def main():
    bench_comparison()
    bench_weak_scaling()
    bench_strong_scaling()


if __name__ == "__main__":
    main()
