"""Per-slot dispatch vs runtime wave streaming (the PR's perf claim).

The retired streaming path dispatched one jitted slot fn per owned
chunk / candidate pair on the default device; the runtime instead
executes a ``[D, batch]`` slab of next slots for every mesh row per
dispatch (``runtime.stream_waves``), with prefetch double-buffering.
Both consume the identical per-PE streams, so the delta is pure
dispatch overhead + mesh utilization.

Runs on 8 virtual devices (the flag below must be set before jax
imports) and writes ``BENCH_stream.json`` at the repo root:
ER / RMAT (ChunkPlan) and RGG / RHG (PairPlan) at n = 2^16, P = 8.

    python -m benchmarks.bench_stream [--batch 32] [--baseline-slots 512]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import GNM, RGG, RHG
from repro.core import rmat as _rmat
from repro.distrib import engine, runtime

from .common import row

N = 1 << 16
P = 8


def _specs():
    return [
        ("ER", GNM(n=N, m=N * 16, seed=1, chunks=64).plan(P)),
        ("RMAT", engine.deal_plan(
            _rmat.rmat_plan(1, 16, N * 16, 256), P)),  # 256 virtual chunks
        ("RGG", RGG(n=N, radius=float(np.sqrt(8 / (np.pi * N))), seed=3).plan(P)),
        ("RHG", RHG(n=N, avg_deg=8, gamma=2.7, seed=5).plan(P)),
    ]


def per_slot_stream(plan, max_slots: int):
    """The retired path: one jitted dispatch per (pe, slot) on the
    default device, buffers pulled to host as a consumer would."""
    one = jax.jit(plan.slot_fn())
    arrays = plan.input_arrays()
    index = plan.stream_index()[:max_slots]
    # warm the compile outside the timed region (both paths get this)
    pe0, s0 = index[0]
    jax.block_until_ready(one(*(jnp.asarray(a[pe0, s0]) for a in arrays)))
    t0 = time.time()
    edges = 0
    for pe, slot in index:
        _, ok = one(*(jnp.asarray(a[pe, slot]) for a in arrays))
        edges += int(np.asarray(ok).sum())
    return len(index), edges, time.time() - t0


def wave_stream(plan, mesh, batch: int):
    """The runtime path: whole-mesh [D, batch] slabs, prefetch=2."""
    # warm the compile (one wave) outside the timed region
    for _ in runtime.stream_waves(plan, mesh=mesh, batch=batch):
        break
    t0 = time.time()
    edges = waves = 0
    for wave in runtime.stream_waves(plan, mesh=mesh, batch=batch, prefetch=2):
        edges += int(wave.valid.sum())
        waves += 1
    return waves, edges, time.time() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=32,
                    help="slots per mesh row per wave")
    ap.add_argument("--baseline-slots", type=int, default=512,
                    help="cap on per-slot dispatches timed (rate extrapolates)")
    args, _ = ap.parse_known_args()

    mesh = engine.default_mesh(P)
    D = runtime.mesh_size(mesh)
    results = []
    for family, plan in _specs():
        slots = len(plan.stream_index())
        k, base_edges, base_s = per_slot_stream(plan, args.baseline_slots)
        base_rate = base_edges / base_s
        waves, wave_edges, wave_s = wave_stream(plan, mesh, args.batch)
        wave_rate = wave_edges / wave_s
        speedup = wave_rate / base_rate
        row(
            f"stream_{family}_n2^16_P{P}",
            wave_s / max(1, wave_edges) * 1e6,
            f"wave_medges_per_s={wave_rate/1e6:.2f};"
            f"per_slot_medges_per_s={base_rate/1e6:.2f};"
            f"speedup={speedup:.1f}x;waves={waves};slots={slots};devices={D}",
        )
        results.append({
            "family": family, "n": N, "P": P, "devices": D, "slots": slots,
            "per_slot": {"slots_timed": k, "edges": base_edges,
                         "seconds": round(base_s, 4),
                         "edges_per_s": round(base_rate)},
            "wave": {"batch": args.batch, "waves": waves, "edges": wave_edges,
                     "seconds": round(wave_s, 4),
                     "edges_per_s": round(wave_rate)},
            "speedup": round(speedup, 2),
        })

    out = {
        "bench": "per-slot dispatch vs runtime wave streaming",
        "backend": jax.default_backend(),
        "devices": D,
        "note": ("per-slot rate measured on a prefix of the stream index "
                 "(dispatch-bound, rate is stationary); wave rate over the "
                 "full stream, prefetch=2"),
        "results": results,
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_stream.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
