"""Plan-emission phase: retired per-chunk loop/recursion emitters vs
the level-synchronous vectorized emitters, plus time-to-first-chunk
(TTFC) with and without plan/execute overlap.

Two independent claims, one file:

* **cold plan speedup** — the vectorized emitters build the identical
  plan tables (see ``tests/test_plan_vectorized.py``) without a Python
  call per chunk; ``old_plan_s / new_plan_s`` per family, structure
  caches cleared so both sides pay the full cold cost.
* **TTFC** — with a lazily segmented :class:`repro.distrib.runtime.
  PlanEmitter` the consumer sees its first chunk after roughly one
  *segment's* plan cost instead of the whole plan's; measured with a
  warm compile cache (compilation is keyed on table shapes and paid
  once per shape, not per request) and a cold plan.

Results land in the machine-readable ``BENCH_plan.json`` at the repo
root.

    PYTHONPATH=src python -m benchmarks.bench_plan [--ttfc-blocks 128]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import chunking, er, rgg, rhg, sbm
from repro.distrib import runtime

from .common import row, update_bench_json


def clear_structure_caches() -> None:
    """Drop the seed-independent structure caches so a plan build pays
    the full cold cost (the honest old-vs-new comparison: the retired
    loop emitters rebuilt structure every call too)."""
    er._gnm_cross_layout.cache_clear()
    er._gnp_cross_layout.cache_clear()
    chunking.directed_split_tree.cache_clear()
    chunking.undirected_split_tree.cache_clear()
    rgg.rgg_structure.cache_clear()


def cold_time(fn, iters: int = 3) -> float:
    """Median cold wall seconds (structure caches cleared each run)."""
    ts = []
    for _ in range(iters):
        clear_structure_caches()
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


# ------------------------------------------------- retired loop emitters

def _loop_gnm_undirected(seed: int, n: int, m: int, P: int):
    """The retired per-chunk path: pruned per-PE descent + one
    ChunkSpec per chunk (now the oracle ``undirected_chunks_for_pe``)."""
    from repro.distrib.engine import (KIND_RECT, KIND_TRI, ChunkSpec,
                                      make_chunk_plan)

    rows = [chunking.undirected_chunks_for_pe(seed, n, m, P, pe)
            for pe in range(P)]
    flat = [ch for rw in rows for ch, _ in rw]
    path = [np.array([ch.row_sec for ch in flat], np.int64),
            np.array([ch.col_sec for ch in flat], np.int64)]
    kd = er._chunk_key_data(seed, path)
    per_pe, i = [], 0
    for pe, rw in enumerate(rows):
        specs = []
        for ch, c in rw:
            kind = KIND_TRI if ch.kind == "tri" else KIND_RECT
            params = (ch.rlo, 0, 0) if ch.kind == "tri" else \
                (ch.chi - ch.clo, ch.rlo, ch.clo)
            specs.append(ChunkSpec(kind, kd[i], ch.universe, int(c), params,
                                   owned=ch.row_sec == pe))
            i += 1
        per_pe.append(specs)
    return make_chunk_plan(per_pe, n)


def _loop_sbm(seed: int, n: int, B: int, p_in: float, p_out: float, P: int):
    """The retired region-loop SBM emitter: one host-side hashed
    generator + Binomial per region."""
    from repro.distrib.engine import (KIND_RECT, KIND_TRI, ChunkSpec,
                                      make_chunk_plan)

    per_pe = [[] for _ in range(P)]
    for i in range(B):
        for j in range(i + 1):
            lo_i, hi_i = chunking.section_bounds(n, B, i)
            lo_j, hi_j = chunking.section_bounds(n, B, j)
            cnt = sbm._region_count(seed, n, B, i, j, p_in, p_out)
            kd = np.zeros(2, np.uint32)  # key emission excluded: conservative
            if i == j:
                spec = ChunkSpec(KIND_TRI, kd, chunking.tri_size(hi_i - lo_i),
                                 cnt, (lo_i, 0, 0))
            else:
                spec = ChunkSpec(KIND_RECT, kd,
                                 (hi_i - lo_i) * (hi_j - lo_j), cnt,
                                 (hi_j - lo_j, lo_i, lo_j))
            per_pe[i % P].append(spec)
            if j % P != i % P:
                per_pe[j % P].append(spec)
    return make_chunk_plan(per_pe, n)


# --------------------------------------------------------- cold speedups

def bench_cold_plans(seed: int = 3) -> dict:
    n_er, m_er, chunks = 1 << 16, 1 << 20, 256
    n_sbm, B = 1 << 15, 128
    n_rgg, dim = 1 << 14, 2
    r = 0.55 * float((np.log(n_rgg) / n_rgg) ** (1.0 / dim))
    params = rhg.RHGParams(n=1 << 13, avg_deg=8.0, gamma=2.8, seed=seed)

    cases = {
        "gnm_undirected": {
            "shape": {"n": n_er, "m": m_er, "P": chunks},
            "old": lambda: _loop_gnm_undirected(seed, n_er, m_er, chunks),
            "new": lambda: er.gnm_undirected_plan(seed, n_er, m_er, chunks),
            "old_iters": 1,
        },
        "sbm": {
            "shape": {"n": n_sbm, "blocks": B, "P": 8},
            "old": lambda: _loop_sbm(seed, n_sbm, B, 0.002, 0.0002, 8),
            "new": lambda: sbm.sbm_plan(seed, n_sbm, B, 0.002, 0.0002, 8),
            "old_iters": 1,
        },
        "rgg": {
            "shape": {"n": n_rgg, "dim": dim, "P": 8},
            "old": lambda: rgg.rgg_pair_plan_specs(seed, n_rgg, r, 8, dim),
            "new": lambda: rgg.rgg_pair_plan(seed, n_rgg, r, 8, dim),
            "old_iters": 1,
        },
        "rhg": {
            "shape": {"n": params.n, "avg_deg": params.avg_deg, "P": 8},
            "old": lambda: rhg.rhg_pair_plan_specs(params, 8),
            "new": lambda: rhg.rhg_pair_plan(params, 8),
            "old_iters": 1,
        },
    }
    out = {}
    for name, c in cases.items():
        c["new"]()  # warm jax dispatch paths once; timing below is cold-plan
        t_old = cold_time(c["old"], iters=c["old_iters"])
        t_new = cold_time(c["new"])
        out[name] = {**c["shape"], "old_plan_s": t_old, "new_plan_s": t_new,
                     "speedup": t_old / t_new}
        row(f"plan_{name}", t_new * 1e6,
            f"old_s={t_old:.3f};new_s={t_new:.3f};x{t_old / t_new:.1f}")
    return out


# ------------------------------------------------------------------ TTFC

def bench_ttfc(blocks: int = 128, P: int = 8, seed: int = 3,
               segments: int = 8) -> dict:
    """Time-to-first-chunk, cold plan / warm compile: full-plan path
    pays ``plan_s`` before the first wave; the overlapped path pays one
    segment's plan cost (SBM's native PE-range build)."""
    from repro.api import SBM, plan_emitter

    n = 1 << 15
    spec = SBM(n=n, blocks=blocks, p_in=0.002, p_out=0.0002, seed=seed)

    # warm the wave compile cache for BOTH table shapes (full + segment)
    for _ in runtime.stream_slots(spec.plan(P)):
        pass
    for _ in runtime.stream_slots(plan_emitter(spec, P, segments=segments)):
        pass

    def first(make_stream):
        t0 = time.perf_counter()
        it = iter(make_stream())  # plan build happens inside the timer
        next(it)
        dt = time.perf_counter() - t0
        for _ in it:  # drain (joins the planner thread's remaining work)
            pass
        return dt

    t_plain = min(first(lambda: runtime.stream_slots(spec.plan(P)))
                  for _ in range(3))
    t_ovl = min(first(lambda: runtime.stream_slots(
        plan_emitter(spec, P, segments=segments))) for _ in range(3))

    rec = {"family": "sbm", "n": n, "blocks": blocks, "P": P,
           "segments": segments, "ttfc_plain_s": t_plain,
           "ttfc_overlap_s": t_ovl, "ttfc_ratio": t_ovl / t_plain}
    row("ttfc_sbm", t_ovl * 1e6,
        f"plain_s={t_plain:.3f};overlap_s={t_ovl:.3f};"
        f"ratio={t_ovl / t_plain:.2f}")
    return rec


def main(ttfc_blocks: int = 128, P: int = 8) -> None:
    cold = bench_cold_plans()
    ttfc = bench_ttfc(blocks=ttfc_blocks, P=P)
    fast = [k for k, v in cold.items() if v["speedup"] >= 5.0]
    if len(fast) < 3:  # the vectorized-emitter acceptance bar
        print(f"# WARNING: only {len(fast)} families >= 5x cold plan "
              f"speedup: {fast}")
    if ttfc["ttfc_ratio"] > 0.5:
        print(f"# WARNING: overlapped TTFC ratio "
              f"{ttfc['ttfc_ratio']:.2f} > 0.5 acceptance bar")
    update_bench_json("cold_plan", cold, name="plan")
    update_bench_json("ttfc", ttfc, name="plan")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--ttfc-blocks", type=int, default=128)
    ap.add_argument("--pes", type=int, default=8)
    args = ap.parse_args()
    main(args.ttfc_blocks, args.pes)
