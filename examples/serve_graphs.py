"""Generation-as-a-service: concurrent mixed-family graph requests.

Many tenants ask for graphs at once — different families, seeds and
sizes.  The serving tier (repro.serve) resolves each request's plan
through a re-seedable cache (same shape + new seed = microsecond
reseed, not a host recursion), packs ready slots from *different*
requests into shared [devices, batch] slabs, and reassembles
per-request streams bit-identical to generate(spec, P).

    PYTHONPATH=src python examples/serve_graphs.py [--requests 64 --pes 8]
"""
import argparse
import time

import numpy as np

from repro.api import BA, GNM, GNP, RGG, RHG, generate
from repro.serve import Service


def mixed_specs(count: int):
    """count requests cycling over five families, distinct seeds."""
    shapes = [
        lambda s: GNM(n=4096, m=16384, seed=s, chunks=16),
        lambda s: GNP(n=4096, p=0.002, seed=s, chunks=16),
        lambda s: BA(n=2048, d=4, seed=s),
        lambda s: RGG(n=1024, radius=0.06, seed=s),
        lambda s: RHG(n=512, avg_deg=6.0, gamma=2.7, seed=s),
    ]
    return [shapes[i % len(shapes)](1000 + i) for i in range(count)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--pes", type=int, default=8)
    ap.add_argument("--verify", type=int, default=4,
                    help="spot-check this many requests against generate()")
    args = ap.parse_args()

    specs = mixed_specs(args.requests)
    svc = Service(args.pes, slab_batch=16)

    t0 = time.perf_counter()
    tickets = [svc.submit(s) for s in specs]
    svc.drain()
    wall = time.perf_counter() - t0

    lat = sorted(t.latency for t in tickets)
    graphs = [t.result() for t in tickets]
    edges = sum(g.m for g in graphs)
    st = svc.stats
    print(f"served {len(specs)} mixed-family requests on P={args.pes} "
          f"({len(svc.mesh.devices)} device rows): {edges:,} edges "
          f"in {wall:.2f}s = {len(specs) / wall:.1f} req/s")
    print(f"  latency p50={lat[len(lat) // 2] * 1e3:.1f}ms "
          f"p99={lat[int(len(lat) * 0.99)] * 1e3:.1f}ms "
          f"max={lat[-1] * 1e3:.1f}ms")
    print(f"  plan cache: {st['cache']['hits']} hits / "
          f"{st['cache']['misses']} misses (structure shared across seeds)")
    print(f"  slabs: {st['slabs']} packed dispatches for {st['slots']} slots")

    # spot-check bit-identity against the single-request front door
    for spec, g in list(zip(specs, graphs))[: args.verify]:
        np.testing.assert_array_equal(g.edges, generate(spec, args.pes).edges)
    print(f"  verified {args.verify} requests bit-identical to generate()")


if __name__ == "__main__":
    main()
