"""Massive-scale streaming generation — the paper's headline use case
(§8) through the GraphSpec -> plan -> stream API.

The host plan (the O(P)-ish divide-and-conquer recursion) fixes every
chunk's edge count and capacity up front, so a 2^30-edge instance can
be consumed chunk-by-chunk: peak memory is one [capacity, 2] buffer,
never the [P, C, cap, 2] materialization.  We stream a sample of
chunks on this machine and extrapolate the full run — exactly as valid
as running all PEs, because the plan really is the full graph's
(communication-free = per-chunk times ARE the parallel time).

    PYTHONPATH=src python examples/generate_massive.py [--log-n 26 --log-m 30]
"""
import argparse
import time

import numpy as np

from repro.api import GNM, generate
from repro.api import iter_edge_chunks
from repro.serve import Service


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--log-n", type=int, default=26)
    ap.add_argument("--log-m", type=int, default=30)
    ap.add_argument("--pes", type=int, default=1024)
    ap.add_argument("--sample", type=int, default=8)
    args = ap.parse_args()

    n, m, P = 1 << args.log_n, 1 << args.log_m, args.pes
    spec = GNM(n=n, m=m, directed=True, seed=0)
    print(f"planning G(n={n:,}, m={m:,}) across {P} PEs ...")
    t0 = time.time()
    plan = spec.plan(P)
    t_plan = time.time() - t0
    counts = plan.count[plan.owned]
    print(f"  full chunk plan in {t_plan:.2f}s; counts sum={counts.sum():,} "
          f"min={counts.min():,} max={counts.max():,} "
          f"(imbalance {counts.max()/counts.mean():.4f})")

    buf_bytes = plan.capacity * 2 * 8
    full_bytes = m * 2 * 8
    print(f"  streaming buffer: [{plan.capacity:,}, 2] = {buf_bytes/2**20:.1f} MiB "
          f"per chunk vs {full_bytes/2**30:.1f} GiB materialized "
          f"({full_bytes/buf_bytes:.0f}x smaller peak)")

    # warm the wave-step compile so the timed loop measures steady state
    next(iter(iter_edge_chunks(spec, P)))
    edges = 0
    t0 = time.time()
    for i, chunk in enumerate(iter_edge_chunks(spec, P)):
        if i >= args.sample:
            break
        edges += chunk.count
        np.asarray(chunk.buffer)  # consume; waves prefetch behind this
    # prefetch overlaps dispatch with consumption, so per-chunk cost is
    # the sampled prefix's wall-clock divided by the sample size
    per_chunk = (time.time() - t0) / args.sample
    print(f"  streamed {args.sample} chunks: {per_chunk:.2f}s/chunk amortized, "
          f"{edges:,} edges emitted")
    print(f"  => full graph wall-clock estimate on {P} cores: "
          f"{per_chunk:.2f}s ({m/per_chunk/P/1e6:.1f} M edges/s/core, "
          f"{m/per_chunk/1e9:.1f} B edges/s aggregate)")

    # fault tolerance drill (scaled down): kill a mesh row mid-slab on a
    # live serving run; the scheduler reissues the lost slots onto the
    # survivors (reassign_after_failure) — output is bit-identical.
    drill = GNM(n=1 << 12, m=1 << 14, directed=True, seed=0, chunks=16)
    svc = Service(4)
    ticket = svc.submit(drill)
    rows = len(svc.mesh.devices)
    if rows > 1:
        svc.inject_fault([rows - 1], at_slab=0)
    svc.drain()
    np.testing.assert_array_equal(ticket.result().edges,
                                  generate(drill, 4).edges)
    if rows > 1:
        print(f"  failure drill: 1/{rows} mesh rows died mid-slab, "
              f"{svc.scheduler.reissued} slots reissued to survivors, "
              f"output bit-identical (recomputation, no state transfer)")
    else:
        print("  failure drill: single-row mesh (nothing to kill); served "
              "output bit-identical to generate()")


if __name__ == "__main__":
    main()
