"""Massive-scale simulated multi-PE generation — the paper's headline
use case (§8): each PE generates its chunk independently; we execute a
sample of PEs on this machine and extrapolate the full run, exactly as
valid as running them on 32768 cores (communication-free = per-PE times
ARE the parallel time; the ER chunk counts for ALL PEs come from the
O(log P) recursion, so the plan below really is the 2^36-edge graph's).

    PYTHONPATH=src python examples/generate_massive.py [--log-n 30 --log-m 34]
"""
import argparse
import time

import numpy as np

from repro.core import er
from repro.core.chunking import directed_counts_all
from repro.distrib.fault import ChunkAssignment, simulate_generation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--log-n", type=int, default=26)
    ap.add_argument("--log-m", type=int, default=30)
    ap.add_argument("--pes", type=int, default=1024)
    ap.add_argument("--sample", type=int, default=8)
    args = ap.parse_args()

    n, m, P = 1 << args.log_n, 1 << args.log_m, args.pes
    print(f"planning G(n={n:,}, m={m:,}) across {P} PEs ...")
    t0 = time.time()
    counts = directed_counts_all(0, n, m, P)
    t_plan = time.time() - t0
    print(f"  full chunk plan in {t_plan:.2f}s; counts sum={counts.sum():,} "
          f"min={counts.min():,} max={counts.max():,} "
          f"(imbalance {counts.max()/counts.mean():.4f})")

    rng = np.random.default_rng(0)
    sample = rng.choice(P, size=args.sample, replace=False)
    times, edges = [], 0
    for pe in sample:
        t0 = time.time()
        e = er.gnm_directed_pe(0, n, m, P, int(pe))
        times.append(time.time() - t0)
        edges += len(e)
    per_pe = float(np.median(times))
    print(f"  sampled {args.sample} PEs: median {per_pe:.2f}s/PE, "
          f"{edges:,} edges generated locally")
    print(f"  => full graph wall-clock estimate on {P} cores: "
          f"{per_pe:.2f}s ({m/per_pe/1e6:.1f} M edges/s/core, "
          f"{m/per_pe*P/1e9:.1f} B edges/s aggregate)")

    # fault tolerance: kill two workers mid-run; survivors recompute
    k = 16
    gen = lambda c: len(er.gnm_directed_pe(0, n, m, k, c))
    assignment = ChunkAssignment(k, tuple(range(4)))
    done = simulate_generation(assignment, gen, fail_at={1: 5, 2: 9})
    print(f"  failure drill: 2/4 workers died, all {len(done)}/16 chunks "
          f"recovered by recomputation (no state transfer)")


if __name__ == "__main__":
    main()
