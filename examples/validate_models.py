"""The paper's §7 verification loop, streamed: generate each family and
gate it against its closed-form law without ever holding the edge list.

Every report is P-invariant (try changing P) because the streamed edge
multiset and the canonical vertex-ownership split both are.

    PYTHONPATH=src python examples/validate_models.py
"""
from repro.api import BA, GNM, GNP, RHG, RMAT, SBM
from repro.stats import collect, validate


def main():
    n, P = 1 << 13, 8
    specs = [
        GNP(n=n, p=16.0 / n, seed=1),
        GNM(n=n, m=8 * n, seed=2),
        SBM(n=n, blocks=8, p_in=0.02, p_out=0.001, seed=3),
        BA(n=n, d=8, seed=4),
        RMAT(log_n=13, m=8 * n, seed=5),
        RHG(n=n, avg_deg=8, gamma=2.7, seed=6),
    ]
    for spec in specs:
        print(validate(spec, P), end="\n\n")

    # sampled clustering: exact wedge/triangle counters over a hashed
    # deterministic vertex sample (so this, too, is P-invariant)
    r = collect(GNP(n=2048, p=0.01, seed=7), P,
                metrics=("degree", "clustering"), cluster_samples=128)
    print(f"GNP sampled clustering: global_cc={r.clustering.global_cc:.5f} "
          f"(ER expectation ~ p = 0.01), mean_local={r.clustering.mean_local_cc:.5f}")


if __name__ == "__main__":
    main()
