"""Serving example: batched prefill + greedy decode with KV/SSM caches.

This serves the *language model* trained on graph walks
(repro.train.serve).  For serving the graph generator itself — many
concurrent GraphSpec requests, batched into shared slabs — see
examples/serve_graphs.py and repro.serve.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3_0p6b --steps 24
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data import pipeline as D
from repro.models import transformer as T
from repro.train.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0p6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = T.model_init(jax.random.key(0), cfg)

    # prompts from the graph-walk corpus (same communication-free source)
    dc = D.DataConfig(vocab=cfg.vocab, seq_len=16, batch_per_shard=args.batch, seed=3)
    prompts = D.make_batch(dc, 0, 0)["tokens"]

    t0 = time.time()
    out = generate(params, cfg, prompts, steps=args.steps)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt_len={prompts.shape[1]} "
          f"generated={args.steps} tokens/req")
    print(f"throughput: {args.batch*args.steps/dt:.1f} tok/s (CPU, reduced config)")
    for i in range(min(3, args.batch)):
        print(f"  req{i}: prompt={prompts[i,:8].tolist()} -> {out[i,:12].tolist()}")


if __name__ == "__main__":
    main()
