"""End-to-end driver: generate a KaGen graph corpus -> train an LM on
random-walk token streams -> checkpoint -> crash -> restart -> continue.

The data pipeline is the paper's communication-free paradigm applied to
LM input: every batch is a pure function of (seed, step, shard), so the
"restart" below restores ONLY model/optimizer state — the data stream
re-synchronizes itself by recomputation.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --arch smollm_360m
(reduced config by default so it runs on CPU; pass --full for the real
 config if you have the hardware)
"""
import argparse
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import pipeline as D
from repro.models import transformer as T
from repro.train import checkpoint as CK
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/kagen_lm_ckpt")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate a failure at this step")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    dc = D.DataConfig(kind="rhg_walk", n_vertices=4096, avg_deg=16, gamma=2.6,
                      vocab=cfg.vocab, seq_len=128, batch_per_shard=8, seed=7)
    opt_cfg = O.OptConfig(lr=1e-3, warmup=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    params = T.model_init(jax.random.key(0), cfg)
    opt = O.opt_init(params)
    start = 0

    crash_at = args.crash_at or (args.steps // 2)

    def run(params, opt, start, stop, label):
        t0 = time.time()
        for s in range(start, stop):
            batch = {k: jnp.asarray(v) for k, v in D.make_batch(dc, s, 0).items()}
            params, opt, metrics = step_fn(params, opt, batch)
            if s % 25 == 0 or s == stop - 1:
                tps = dc.batch_per_shard * dc.seq_len * (s - start + 1) / (time.time() - t0)
                print(f"[{label}] step {s:4d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} tok/s {tps:.0f}")
            if s % 50 == 49:
                CK.save(args.ckpt_dir, s + 1, {"params": params, "opt": opt},
                        meta={"arch": cfg.name}, background=True)
        return params, opt

    print(f"training {cfg.name} ({sum(x.size for x in jax.tree.leaves(params)):,} params) "
          f"on RHG random-walk corpus, vocab={cfg.vocab}")
    params, opt = run(params, opt, 0, crash_at, "run-1")
    CK.save(args.ckpt_dir, crash_at, {"params": params, "opt": opt},
            meta={"arch": cfg.name})

    print(f"\n--- simulated crash at step {crash_at}; restarting from checkpoint ---\n")
    del params, opt
    params2 = T.model_init(jax.random.key(0), cfg)  # fresh process state
    restored, manifest = CK.restore(args.ckpt_dir,
                                    {"params": params2, "opt": O.opt_init(params2)})
    params2, opt2 = restored["params"], restored["opt"]
    start = manifest["step"]
    print(f"restored step={start} arch={manifest['meta']['arch']}; data pipeline "
          f"resumes deterministically from (seed, step) — no data state was saved")
    params2, opt2 = run(params2, opt2, start, args.steps, "run-2")
    print("\ndone — loss continued from the restored trajectory.")


if __name__ == "__main__":
    main()
