"""Quickstart: one GraphSpec -> plan -> run API for every network model.

Each spec is a frozen dataclass; `generate(spec, P)` plans the instance
on the host (O(P)-ish divide-and-conquer), executes it as one
zero-collective SPMD program on P virtual PEs, and returns a Graph.
The edge set is identical for any P — P only decides which PE executes
which chunk/cell/pair.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import BA, GNM, GNP, RDG, RGG, RHG, RMAT, SBM, generate
from repro.core import graph


def stats(name, g):
    deg = g.degrees()
    e = g.edges
    print(f"{name:22s} n={g.n:7d} m={g.m:8d} "
          f"avg_deg={deg.mean():6.2f} max_deg={deg.max():5.0f} "
          f"dups={graph.has_duplicates(e)} loops={graph.has_self_loops(e)}")


def main():
    seed, n, P = 42, 5000, 4

    specs = [
        ("G(n,m) directed", GNM(n=n, m=8 * n, directed=True, seed=seed)),
        ("G(n,m) undirected", GNM(n=n, m=4 * n, seed=seed)),
        ("G(n,p)", GNP(n=n, p=8.0 / n, seed=seed)),
        ("RGG 2d", RGG(n=n, radius=0.55 * float(np.sqrt(np.log(n) / n)), seed=seed)),
        ("RGG 3d", RGG(n=n, radius=0.55 * float((np.log(n) / n) ** (1 / 3)),
                       dim=3, seed=seed)),
        ("RHG (gamma=2.6)", RHG(n=1500, avg_deg=8, gamma=2.6, seed=seed)),
        ("RDG 2d (torus)", RDG(n=2000, seed=seed)),
        ("BA (d=4)", BA(n=n, d=4, seed=seed)),
        ("R-MAT", RMAT(log_n=13, m=8 * n, seed=seed)),
    ]
    for name, spec in specs:
        stats(name, generate(spec, P))
    stats("SBM (8 blocks)", generate(SBM(n=n, blocks=8, p_in=0.01, p_out=0.0005, seed=seed), P))

    print("\nEvery family above ran through the same GraphSpec -> plan -> run "
          "engine: the host emits per-PE chunk/cell/pair tables, devices "
          "execute them independently, and the lowered HLO is asserted to "
          "contain zero collective operations — no messages exchanged.")


if __name__ == "__main__":
    main()
