"""Quickstart: generate a small instance of every network model and
print its statistics.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ba, er, graph, rdg, rgg, rhg, rmat


def stats(name, edges, n):
    e = np.asarray(edges)
    deg = graph.degrees(e, n) if e.size else np.zeros(n)
    print(f"{name:22s} n={n:7d} m={len(e):8d} "
          f"avg_deg={deg.mean():6.2f} max_deg={deg.max():5.0f} "
          f"dups={graph.has_duplicates(e)} loops={graph.has_self_loops(e)}")


def main():
    seed, n = 42, 5000

    stats("G(n,m) directed", er.gnm_directed(seed, n, 8 * n, P=4), n)
    stats("G(n,m) undirected", er.gnm_undirected(seed, n, 4 * n, P=4), n)
    stats("G(n,p)", er.gnp_undirected(seed, n, 8.0 / n, P=4), n)

    r = 0.55 * np.sqrt(np.log(n) / n)
    stats("RGG 2d", rgg.rgg_union(seed, n, r, P=4, dim=2), n)
    r3 = 0.55 * (np.log(n) / n) ** (1 / 3)
    stats("RGG 3d", rgg.rgg_union(seed, n, r3, P=8, dim=3), n)

    params = rhg.RHGParams(n=n, avg_deg=8, gamma=2.6, seed=seed)
    stats("RHG (gamma=2.6)", rhg.rhg_union(params, P=4), n)

    stats("RDG 2d (torus)", rdg.rdg_union(seed, 2000, P=4, dim=2), 2000)

    stats("BA (d=4)", ba.ba_union(seed, n, 4, P=4), n)
    stats("R-MAT", rmat.rmat_union(seed, 13, 8 * n, P=4), 1 << 13)

    print("\nAll generators are communication-free: every edge above was "
          "produced by a PE holding one of its endpoints, with remote "
          "vertices recomputed from hashed seeds — no messages exchanged.")


if __name__ == "__main__":
    main()
